# Tier-1 CI entry points.
#
#   make deps                 - install dev/test dependencies (best-effort:
#                               the suite also runs without them via
#                               tests/_hypo.py)
#   make test                 - the tier-1 suite (ROADMAP.md "Tier-1 verify")
#   make bench-netsim-smoke   - tiny sweep-bench grid (seconds, no json
#                               append); also times a streaming-mode cell and
#                               ASSERTS streaming <= materialized wall-clock
#   make bench-scheme-compare-smoke
#                             - six-scheme comparison sweep on a tiny grid;
#                               asserts complete rows (streamed columns
#                               included) for every registered scheme
#   make bench-impairment-smoke
#                             - six-scheme loss/jitter grid on the 'impaired'
#                               channel model (tiny, seconds, no json append);
#                               asserts channel columns, one compile per
#                               scheme, sdr_rdma's repair-latency advantage,
#                               and ideal-channel row parity
#   make bench-topology-smoke - unequal-path (num_paths=3) grid across all
#                               schemes (tiny, seconds, no json append);
#                               asserts rdmacell's multi-link streamed
#                               columns and one compile per scheme
#   make bench-sites-smoke    - 3-site mesh grid (4 site-pair edges, per-flow
#                               endpoints) under the trace_replay channel
#                               (tiny, seconds, no json append); asserts one
#                               compile per scheme and that the replayed
#                               schedule bites at full amplitude
#   make bench-failover-smoke - link/site hard-outage grid across all schemes
#                               (tiny, seconds, no json append); asserts
#                               finite failover columns, strict conservation
#                               through the outages, and that a site outage
#                               collapses throughput harder than one link
#   make bench-grad-smoke     - grad tuner vs hillclimb on a tiny tuning
#                               cell (seconds, no json append); asserts the
#                               grad tuner matches the hillclimb objective
#                               with fewer simulator evaluations
#   make bench-obs-smoke      - observability round-trip on a tiny grid
#                               (seconds, no json append): window-mode sweep
#                               with event ring + JSONL run manifest, asserts
#                               window rows == metrics rows, a loadable
#                               Perfetto timeline containing PFC pause and
#                               matchrdma brake events, and an obs_report
#                               summarize/diff round-trip
#   make docs-check           - docs lint: intra-repo links in README/docs,
#                               scheme-table completeness, hook coverage,
#                               soft/grad knob coverage in differentiable.md,
#                               obs knob/event-kind coverage in
#                               observability.md
#   make ci                   - deps + test + smokes + docs-check
#   make bench-netsim         - batched-vs-sequential + streaming-vs-full
#                               sweep micro-bench; appends to
#                               BENCH_netsim_sweep.json
#   make bench-scheme-compare - full six-scheme Fig. 3-style sweep; appends
#                               to BENCH_netsim_sweep.json
#   make bench-impairment     - full six-scheme impairment grid; appends to
#                               BENCH_netsim_sweep.json
#   make bench-topology       - full unequal-path topology grid; appends to
#                               BENCH_netsim_sweep.json
#   make bench-sites          - full 3-site mesh grid (trace_replay channel);
#                               appends to BENCH_netsim_sweep.json
#   make bench-failover       - full link/site outage grid; appends to
#                               BENCH_netsim_sweep.json
#   make bench-grad           - full grad-tuner-vs-hillclimb comparison;
#                               appends to BENCH_netsim_sweep.json
#   make bench-obs            - window-vs-metrics wall-clock overhead on a
#                               wider grid; appends to
#                               BENCH_netsim_sweep.json

PYTHON ?= python

# The netsim string-scheme deprecation becomes an ERROR when it fires from
# inside repro.netsim itself — the shims must never regress back into the
# engine. Test modules exercising the shims still see a plain warning.
PYTEST_W = -W "error:passing a scheme name string:DeprecationWarning:repro\.netsim"

.PHONY: deps test ci bench-netsim bench-netsim-smoke \
	bench-scheme-compare bench-scheme-compare-smoke \
	bench-impairment bench-impairment-smoke \
	bench-topology bench-topology-smoke \
	bench-sites bench-sites-smoke \
	bench-failover bench-failover-smoke \
	bench-grad bench-grad-smoke bench-obs bench-obs-smoke docs-check

deps:
	$(PYTHON) -m pip install -r requirements-dev.txt || \
	  echo "pip install failed; continuing (tests degrade gracefully)"

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q $(PYTEST_W)

bench-netsim-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netsim_sweep_bench --smoke

bench-scheme-compare-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --smoke

bench-impairment-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --impairment-grid --smoke

bench-topology-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --topology-grid --smoke

bench-sites-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --sites-grid --smoke

bench-failover-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --failover-grid --smoke

bench-grad-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.grad_tune_bench --smoke

bench-obs-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.obs_bench --smoke

docs-check:
	PYTHONPATH=src $(PYTHON) tools/docs_check.py

ci: deps test bench-netsim-smoke bench-scheme-compare-smoke \
	bench-impairment-smoke bench-topology-smoke bench-sites-smoke \
	bench-failover-smoke bench-grad-smoke bench-obs-smoke docs-check

bench-netsim:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netsim_sweep_bench

bench-scheme-compare:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare

bench-impairment:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --impairment-grid

bench-topology:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --topology-grid

bench-sites:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --sites-grid

bench-failover:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scheme_compare --failover-grid

bench-grad:
	PYTHONPATH=src $(PYTHON) -m benchmarks.grad_tune_bench

bench-obs:
	PYTHONPATH=src $(PYTHON) -m benchmarks.obs_bench --full
