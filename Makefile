# Tier-1 CI entry points.
#
#   make deps               - install dev/test dependencies (best-effort: the
#                             suite also runs without them via tests/_hypo.py)
#   make test               - the tier-1 suite (ROADMAP.md "Tier-1 verify")
#   make bench-netsim-smoke - tiny sweep-bench grid (seconds, no json append)
#                             so CI exercises the benchmark path
#   make ci                 - deps + test + bench-netsim-smoke
#   make bench-netsim       - batched-vs-sequential sweep micro-bench; appends
#                             results to BENCH_netsim_sweep.json

PYTHON ?= python

.PHONY: deps test ci bench-netsim bench-netsim-smoke

deps:
	$(PYTHON) -m pip install -r requirements-dev.txt || \
	  echo "pip install failed; continuing (tests degrade gracefully)"

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

bench-netsim-smoke:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netsim_sweep_bench --smoke

ci: deps test bench-netsim-smoke

bench-netsim:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netsim_sweep_bench
