"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These intentionally use the most direct formulation (materialized scores,
step-by-step recurrences) — slow, obviously-correct references that the
kernel test sweeps assert against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  softcap: float = 0.0, window: int = 0) -> jax.Array:
    """Naive causal GQA attention. q [B,S,Hq,D]; k,v [B,S,Hk,D]."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = i >= j
    if window:
        mask = mask & (i - j < window)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, hq, d).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array) -> jax.Array:
    """Step-by-step SSD recurrence (O(S) sequential — the ground truth).

    x [b,s,h,p]; dt [b,s,h]; A [h] (<0); B,C [b,s,g,n]. Returns y [b,s,h,p].
    h_t = h_{t-1} * exp(dt_t A) + dt_t * B_t ⊗ x_t ;  y_t = C_t · h_t
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp           # [b,h,p], [b,h], [b,h,n], [b,h,n]
        decay = jnp.exp(dtt * Af[None, :])[..., None, None]
        upd = (dtt[..., None] * Bt)[..., :, None] * xt[:, :, None, :]
        state = state * decay + upd      # [b,h,n,p]
        y = jnp.einsum("bhn,bhnp->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def rglru_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Step-by-step diagonal linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: [B, S, W] (precomputed gates). Returns h [B, S, W] in f32.
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(af[:, 0]),
                         (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
