"""Pallas TPU kernels for the training substrate's compute hot-spots.

MatchRDMA itself is control-plane (no kernel warranted — see DESIGN.md §2);
these accelerate the model side: flash attention, the Mamba2 SSD scan, and
the RG-LRU recurrence. Validated in interpret mode against ref.py oracles.
"""
from repro.kernels.ops import flash_attention, rglru_recurrence, ssd_scan

__all__ = ["flash_attention", "rglru_recurrence", "ssd_scan"]
