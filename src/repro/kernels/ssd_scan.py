"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

One program instance owns one (batch·head, chunk) tile; the chunk axis is
the minor grid dimension, so the inter-chunk SSM state [N, P] lives in VMEM
scratch and flows sequentially across chunk steps (the recurrent part),
while the within-chunk quadratic term runs on the MXU:

    y_diag = (C B^T ⊙ L) · (dt x)        L = exp(segsum(dt A))   [L x L]
    y_off  = exp(cum dA) ⊙ (C · state)
    state <- exp(sum dA) state + (B ⊙ decay_to_end)^T (dt x)

VMEM working set per step: x/B/C chunks (L x P, L x N), the L x L decay
matrix, and the [N, P] state — with the default L=128, N=128, P=64 this is
~0.3 MB, comfortably inside a v5e core's VMEM, and every matmul dimension is
a multiple of the 128-lane MXU tiling.

Inputs are pre-chunked by ops.ssd_scan: xdt [BH, NC, L, P] (x·dt),
dA [BH, NC, L] (dt·A), Bm/Cm [BH, NC, L, N] (group-expanded).
Validated in interpret mode against repro.kernels.ref.ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, o_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, 0].astype(jnp.float32)           # [L, P]
    dA = dA_ref[0, 0].astype(jnp.float32)             # [L]
    Bm = b_ref[0, 0].astype(jnp.float32)              # [L, N]
    Cm = c_ref[0, 0].astype(jnp.float32)              # [L, N]

    cs = jnp.cumsum(dA)                               # [L]
    # within-chunk decay matrix: L[i,j] = exp(cs_i - cs_j), i >= j
    diff = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(li >= lj, jnp.exp(diff), 0.0)

    S = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    y_diag = jax.lax.dot_general(S * Lmat, xdt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_scr[...]                            # [N, P]
    y_off = jax.lax.dot_general(Cm, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(cs)[:, None]

    decay_to_end = jnp.exp(cs[-1] - cs)               # [L]
    state_new = (jnp.exp(cs[-1]) * state
                 + jax.lax.dot_general(Bm * decay_to_end[:, None], xdt,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = state_new
    o_ref[0, 0] = (y_diag + y_off).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan_chunked(xdt: jax.Array, dA: jax.Array, Bm: jax.Array,
                     Cm: jax.Array, *, interpret: bool = True) -> jax.Array:
    """xdt [BH, NC, L, P]; dA [BH, NC, L]; Bm/Cm [BH, NC, L, N] ->
    y [BH, NC, L, P]."""
    bh, nc, l, p = xdt.shape
    n = Bm.shape[-1]
    grid = (bh, nc)

    def ix(b, c):
        return (b, c, 0, 0)

    def ix3(b, c):
        return (b, c, 0)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, p), ix),
            pl.BlockSpec((1, 1, l), ix3),
            pl.BlockSpec((1, 1, l, n), ix),
            pl.BlockSpec((1, 1, l, n), ix),
        ],
        out_specs=pl.BlockSpec((1, 1, l, p), ix),
        out_shape=jax.ShapeDtypeStruct((bh, nc, l, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
