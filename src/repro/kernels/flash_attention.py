"""Pallas TPU flash attention (forward): blockwise causal GQA with online
softmax, explicit VMEM BlockSpecs.

Layout: q [BH, S, D] (batch*q-heads flattened), k/v [BKV, S, D]. The grid is
(bh, q_block, kv_block) with kv minor-most: on TPU the minor grid dimension
executes sequentially on a core, so the (m, l, acc) running state lives in
VMEM scratch across kv steps and the output block is written once at the
last kv step. Causal block-skipping: kv blocks strictly above the diagonal
are masked out (their contribution is exactly zero; the multiplicative
rescale trick keeps the online softmax exact).

MXU alignment: block sizes default to 512x512 tiles with D padded by the
caller to a multiple of 128 (head_dim 64/128/256 all satisfy lane tiling
after the standard (8,128) float32 / (16,128) bf16 packing).

Validated in interpret mode against repro.kernels.ref.attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, block_q: int, block_kv: int, softcap: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    # reset running state at the first kv block
    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv blocks strictly past the diagonal contribute nothing
    @pl.when(kj * block_kv <= qi * block_q + (block_q - 1))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # [Bq, D]
        k = k_ref[0].astype(jnp.float32)                   # [Bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = kj * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv",
                                             "softcap", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        block_q: int = 512, block_kv: int = 512,
                        softcap: float = 0.0,
                        interpret: bool = True) -> jax.Array:
    """q: [B, S, Hq, D]; k, v: [B, S, Hk, D]. Returns [B, S, Hq, D].

    ``interpret=True`` executes the kernel body in Python on CPU (the only
    mode available in this container); on TPU pass interpret=False.
    """
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    scale = d ** -0.5
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0

    qf = jnp.moveaxis(q, 2, 1).reshape(b * hq, s, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * hk, s, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * hk, s, d)

    nq = s // block_q
    nk = s // block_kv
    grid = (b * hq, nq, nk)

    def q_index(bh, qi, kj):
        return (bh, qi, 0)

    def kv_index(bh, qi, kj):
        return ((bh // hq) * hk + (bh % hq) // g, kj, 0)

    out = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, block_q=block_q,
                          block_kv=block_kv, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_kv, d), kv_index),
            pl.BlockSpec((1, block_kv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(b, hq, s, d), 1, 2)
