"""jit'd public wrappers around the Pallas kernels.

Each op prepares kernel-friendly layouts, dispatches to the Pallas kernel
(interpret mode on CPU — the TPU fast path is the same call with
interpret=False), and exposes a differentiable version via jax.custom_vjp
whose backward pass is the grad of the pure-jnp oracle algorithm (recompute
— a standard production pattern: optimized forward, reference backward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan import ssd_scan_chunked
from repro.models.attention import chunked_causal_attention
from repro.models.ssm import ssd_chunked

_ON_TPU = False  # flipped by deployment config; this container is CPU-only


def _interp() -> bool:
    return not _ON_TPU


# ---------------------------------------------------------------------------
# Flash attention (differentiable)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, block_q: int = 512, block_kv: int = 512):
    return flash_attention_fwd(q, k, v, block_q=block_q, block_kv=block_kv,
                               interpret=_interp())


def _fa_fwd(q, k, v, block_q, block_kv):
    out = flash_attention(q, k, v, block_q, block_kv)
    return out, (q, k, v)


def _fa_bwd(block_q, block_kv, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_causal_attention(
            q_, k_, v_, block_q=block_q, block_kv=block_kv), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, *, chunk: int = 128):
    """Pallas SSD. x [b,s,h,p]; dt [b,s,h]; A [h]; B,C [b,s,g,n] -> y."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        padded = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padded(x), padded(dt), padded(B), padded(C)
        s2 = s + pad
    else:
        s2 = s
    nc = s2 // chunk
    rep = h // g
    dtf = dt.astype(jnp.float32)
    xdt = (x.astype(jnp.float32) * dtf[..., None])
    dA = dtf * A.astype(jnp.float32)[None, None, :]
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    # [b,s,h,*] -> [b*h, nc, L, *]
    def chunked(a, feat):
        a = jnp.moveaxis(a, 2, 1)                  # [b,h,s,*]
        return a.reshape(b * h, nc, chunk, *feat)
    xdt_c = chunked(xdt, (p,))
    dA_c = chunked(dA, ())
    B_c = chunked(Bh.astype(jnp.float32), (n,))
    C_c = chunked(Ch.astype(jnp.float32), (n,))
    y = ssd_scan_chunked(xdt_c, dA_c, B_c, C_c, interpret=_interp())
    y = y.reshape(b, h, s2, p)
    y = jnp.moveaxis(y, 1, 2)[:, :s]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

def rglru_recurrence(a, b, *, block_s: int = 256, block_w: int = 512):
    """Pallas diagonal recurrence h_t = a_t h_{t-1} + b_t; [B,S,W] -> f32."""
    bb, s, w = a.shape
    bs = min(block_s, s)
    while s % bs:
        bs //= 2
    bw = min(block_w, w)
    while w % bw:
        bw //= 2
    return rglru_scan_pallas(a, b, block_s=max(bs, 1), block_w=max(bw, 1),
                             interpret=_interp())
