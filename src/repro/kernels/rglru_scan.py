"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

h_t = a_t ⊙ h_{t-1} + b_t over [B, S, W], with the width dimension tiled
across the grid (channels are independent) and the sequence dimension
blocked; the running state [BW] persists in VMEM scratch across sequence
blocks (minor grid dim). Within a block the recurrence is a sequential
fori_loop over time — each step is a [BW]-wide VPU op, so the lane
utilization is full as long as BW is a multiple of 128.

Validated in interpret mode against repro.kernels.ref.rglru_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, h):
        at = a_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)
        h = at * h + bt
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, body, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan_pallas(a: jax.Array, b: jax.Array, *, block_s: int = 256,
                      block_w: int = 512,
                      interpret: bool = True) -> jax.Array:
    """a, b: [B, S, W] (precomputed gates). Returns h [B, S, W] f32."""
    bb, s, w = a.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0
    grid = (bb, w // block_w, s // block_s)

    def ix(bi, wi, si):
        return (bi, si, wi)

    return pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), ix),
            pl.BlockSpec((1, block_s, block_w), ix),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), ix),
        out_shape=jax.ShapeDtypeStruct((bb, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
