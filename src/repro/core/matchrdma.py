"""The composed MatchRDMA controller — three coordinated segments (Fig. 2(a)).

  SOURCE-SIDE LOOP      budget-gated pseudo-ACK (pseudo_ack.py) +
                        congestion-control proxy (cc_proxy.py driven by the
                        destination's congestion summaries).
  INTER-OTN LOOP        control subchannel carrying (budget, summary)
                        DST -> SRC with one-way delay D (budget.py).
  DESTINATION-SIDE LOOP slot observations (slots.py) -> slot-weighted /
                        periodic rate estimation (estimator.py) -> budget
                        generation (budget.py).

``MatchRdmaState`` is a pytree carried through the netsim lax.scan (the
``SimState.extra`` slot); its call sites live in
``repro.netsim.schemes.matchrdma`` — the registered ``matchrdma`` scheme's
``feedback`` hook runs the cheap per-step parts (pseudo-ACK gating, proxy
CC, channel advance) every fluid step and ``maybe_slot_update`` at slot
boundaries.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig, NetParams
from repro.core.budget import (
    BudgetState, ControlChannel, channel_send_recv, control_proc_steps_traced,
    ctrl_window_slots, ctrl_window_slots_traced, init_budget, init_channel,
    update_budget,
)
from repro.core.estimator import periodic_estimate, slot_weighted_estimate
from repro.core.pseudo_ack import PseudoAckState, init_pseudo_ack
from repro.core.slots import SlotObs, SlotRing, init_ring, push_slot


class MatchRdmaState(NamedTuple):
    ring: SlotRing               # destination slot history
    budget: BudgetState          # destination budget state
    chan: ControlChannel         # DST -> SRC control subchannel
    budget_at_src: jax.Array     # scalar — budget currently known at source
    summary_at_src: jax.Array    # scalar — congestion summary at source
    pseudo: PseudoAckState       # source pseudo-ACK bookkeeping
    # per-slot accumulators (reset at slot boundary)
    acc_egress: jax.Array        # bytes forwarded this slot
    acc_cnp: jax.Array           # CNPs this slot
    acc_ack_delay: jax.Array     # summed ack-delay observations
    acc_ack_n: jax.Array         # count of ack-delay observations
    acc_queue: jax.Array         # summed local-queue occupancy samples
    acc_paused: jax.Array        # steps this slot with egress PFC-paused


def default_history_slots(cfg: NetConfig) -> int:
    """Slot-ring size covering at least two control windows of history
    (τ-aware estimation), rounded up to whole estimator windows."""
    spw = cfg.slots_per_window
    want = max(64, 2 * ctrl_window_slots(cfg))
    return ((want + spw - 1) // spw) * spw


def init_matchrdma(cfg: NetConfig, num_flows: int,
                   history_slots: int = 0, params: NetParams = None,
                   chan_delay_pad: int = 0) -> MatchRdmaState:
    """``history_slots`` / ``chan_delay_pad`` are STATIC sizes; when batching
    they must be padded to the largest scenario (the traced actual channel
    delay comes from ``params``)."""
    if history_slots <= 0:
        history_slots = default_history_slots(cfg)
    proc_steps = cfg.control_proc_steps
    if chan_delay_pad <= 0:
        chan_delay_pad = cfg.static_delay_steps + proc_steps
    if params is None:
        actual_delay = chan_delay_pad
    else:
        # traced slot length => traced processing delay (the ring SIZE
        # stays the static chan_delay_pad; this only sets the wrap index)
        actual_delay = (params.delay_steps(cfg.dt_us)
                        + control_proc_steps_traced(cfg, params))
    budget0 = init_budget(cfg, params)
    st = MatchRdmaState(
        ring=init_ring(history_slots),
        budget=budget0,
        chan=init_channel(chan_delay_pad, cfg, params=params,
                          actual_delay=actual_delay),
        budget_at_src=budget0.budget,
        summary_at_src=jnp.float32(0.0),
        pseudo=init_pseudo_ack(num_flows),
        acc_egress=jnp.float32(0.0),
        acc_cnp=jnp.float32(0.0),
        acc_ack_delay=jnp.float32(0.0),
        acc_ack_n=jnp.float32(0.0),
        acc_queue=jnp.float32(0.0),
        acc_paused=jnp.float32(0.0),
    )
    return st


def accumulate_step(state: MatchRdmaState, egress_bytes: jax.Array,
                    cnp_count: jax.Array, ack_delay_us: jax.Array,
                    ack_n: jax.Array, queue_bytes: jax.Array,
                    egress_paused: jax.Array = None) -> MatchRdmaState:
    """Cheap per-fluid-step accumulation at the destination OTN."""
    if egress_paused is None:
        egress_paused = jnp.float32(0.0)
    return state._replace(
        acc_egress=state.acc_egress + egress_bytes,
        acc_cnp=state.acc_cnp + cnp_count,
        acc_ack_delay=state.acc_ack_delay + ack_delay_us,
        acc_ack_n=state.acc_ack_n + ack_n,
        acc_queue=state.acc_queue + queue_bytes,
        acc_paused=state.acc_paused + egress_paused,
    )


def step_channel(state: MatchRdmaState, summary: jax.Array = None) -> MatchRdmaState:
    """Advance the control subchannel by one fluid step (every step).

    ``summary`` is the concise congestion summary shipped with the budget.
    It reflects the destination OTN's OWN overload (queue backlog) — leaf /
    intra-DC congestion is already folded into the budget via the capability
    estimate; feeding it to the proxy as well would double-control."""
    if summary is None:
        summary = (state.acc_cnp > 0).astype(jnp.float32)
    chan, b_src, s_src = channel_send_recv(
        state.chan, state.budget.budget, summary.astype(jnp.float32))
    return state._replace(chan=chan, budget_at_src=b_src,
                          summary_at_src=s_src)


def slot_update(state: MatchRdmaState, cfg: NetConfig,
                period_slots: int = 0,
                params: NetParams = None, soft=None) -> MatchRdmaState:
    """Run at each slot boundary: classify, estimate, regenerate budget.

    With ``params`` the slot length is the TRACED ``params.slot_us`` leaf
    (a ``slot_us`` sweep shares one compiled program); without it the
    static ``cfg.slot_us`` twin is used. ``soft`` (docs/differentiable.md)
    relaxes the busy/classifier/budget gates to tempered sigmoids.
    """
    if params is None:
        slot_s = cfg.slot_us * 1e-6
        steps_per_slot = max(int(round(cfg.slot_us / cfg.dt_us)), 1)
    else:
        slot_s = params.slot_us * 1e-6
        steps_per_slot = jnp.maximum(
            jnp.round(params.slot_us / cfg.dt_us), 1.0)
    # pause-corrected egress rate: bytes / UNPAUSED time. Egress while the
    # egress port is PFC-paused says nothing about forwarding capability.
    paused_frac = state.acc_paused / steps_per_slot
    unpaused_s = slot_s * jnp.maximum(1.0 - paused_frac, 1e-3)
    mean_queue = state.acc_queue / steps_per_slot
    obs = SlotObs(
        egress_rate=state.acc_egress / unpaused_s,
        ack_delay_us=state.acc_ack_delay / jnp.maximum(state.acc_ack_n, 1.0),
        cnp_count=state.acc_cnp,
        local_queue=mean_queue,
    )
    queue_thresh = (cfg.queue_thresh_kb if params is None
                    else params.queue_thresh_kb) * 1024.0
    # capability is only measurable when backlogged AND mostly unpaused
    if soft is None:
        busy = ((mean_queue > queue_thresh)
                & (paused_frac < 0.9)).astype(jnp.float32)
    else:
        from repro.netsim.soft import soft_gt
        busy = (soft_gt(mean_queue, queue_thresh, soft,
                        0.05 * queue_thresh + 1.0)
                * soft_gt(0.9, paused_frac, soft, 0.1))
    ring = push_slot(state.ring, obs, cfg, busy=busy,
                     queue_thresh_bytes=queue_thresh, soft=soft)
    if period_slots > 0:
        est = periodic_estimate(ring, cfg, period_slots, soft=soft)
    else:
        est = slot_weighted_estimate(ring, cfg, soft=soft)
    # fraction of the last control window flagged congested
    # (drives match vs open-up)
    from repro.core.slots import ordered_history
    if params is None:
        ctrl_slots = ctrl_window_slots(cfg)
    else:
        ctrl_slots = ctrl_window_slots_traced(params, cfg)
    _, congested_hist, _, valid = ordered_history(ring)
    r = congested_hist.shape[0]
    # shape-static "last n_recent slots" mask (n_recent may be traced)
    n_recent = jnp.clip(jnp.maximum(ctrl_slots, 4 * cfg.slots_per_window),
                        1, r)
    recent_mask = (jnp.arange(r) >= r - n_recent).astype(jnp.float32)
    recent_valid = valid * recent_mask
    cong_recent = (jnp.sum(congested_hist * recent_valid)
                   / jnp.maximum(jnp.sum(recent_valid), 1.0))
    budget = update_budget(state.budget, est, state.acc_cnp, cong_recent, cfg,
                           ctrl_slots=ctrl_slots, params=params, soft=soft)
    return state._replace(
        ring=ring, budget=budget,
        acc_egress=jnp.float32(0.0), acc_cnp=jnp.float32(0.0),
        acc_ack_delay=jnp.float32(0.0), acc_ack_n=jnp.float32(0.0),
        acc_queue=jnp.float32(0.0), acc_paused=jnp.float32(0.0),
    )


def maybe_slot_update(state: MatchRdmaState, cfg: NetConfig, step_idx: jax.Array,
                      period_slots: int = 0,
                      params: NetParams = None, soft=None) -> MatchRdmaState:
    """Branchless slot update: applied when step_idx hits a slot boundary.

    With ``params`` the boundary trigger is a TRACED-phase comparison
    (``steps_per_slot`` derives from the ``params.slot_us`` leaf), so a
    slot-length sweep shares one compiled program. The boundary select
    itself stays an exact integer comparison even in soft mode — slot
    cadence is simulator *structure*, not a knob-dependent threshold (the
    knob sensitivity flows through the traced ``steps_per_slot`` uses
    inside ``slot_update``)."""
    if params is None:
        steps_per_slot = max(int(round(cfg.slot_us / cfg.dt_us)), 1)
    else:
        steps_per_slot = jnp.maximum(
            jnp.round(params.slot_us / cfg.dt_us).astype(jnp.int32), 1)
    at_boundary = jnp.mod(step_idx + 1, steps_per_slot) == 0
    updated = slot_update(state, cfg, period_slots, params=params, soft=soft)
    return jax.tree.map(
        lambda a, b: jnp.where(at_boundary, a, b), updated, state)
