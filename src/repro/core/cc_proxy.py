"""DCQCN rate machine (vectorized over flows) + the source-OTN proxy variant.

The same pure-JAX DCQCN implementation serves three roles:
  * at the SENDER for the DCQCN-like / pseudo-ACK / THEMIS-like baselines
    (CNPs arrive after the full return path);
  * at the SOURCE OTN for MatchRDMA's congestion-control *proxying* — the
    machine reacts to the destination OTN's congestion summaries arriving on
    the control subchannel (delay D instead of 2D + intra-DC);
  * in unit tests, standalone.

State follows Zhu et al. (SIGCOMM'15): per-flow current rate Rc, target Rt,
alpha; an alpha-update timer; rate-increase timer + byte counter driving
fast-recovery / additive / hyper increase stages.

THEMIS-like fairness variant: increase scaled ∝ flow RTT, decrease attenuated
for long-RTT flows (addresses congestion-induced unfairness between feedback
loops of different lengths — ref 14).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig
# submodule import (not the package __init__), so no core<->netsim cycle
from repro.netsim.soft import lerp, reset_gate, soft_gt, soft_or

_F = 5  # fast-recovery stage count


class DcqcnState(NamedTuple):
    rc: jax.Array           # [F] current rate (bytes/s)
    rt: jax.Array           # [F] target rate
    alpha: jax.Array        # [F]
    t_alpha: jax.Array      # [F] µs since last alpha update
    t_rate: jax.Array       # [F] µs since last rate-increase event
    bytes_ctr: jax.Array    # [F] bytes since last byte-counter event
    stage_t: jax.Array      # [F] timer stages since last cut
    stage_b: jax.Array      # [F] byte stages since last cut


def init_dcqcn(num_flows: int, line_rate: float) -> DcqcnState:
    z = jnp.zeros((num_flows,), jnp.float32)
    return DcqcnState(
        rc=jnp.full((num_flows,), line_rate, jnp.float32),
        rt=jnp.full((num_flows,), line_rate, jnp.float32),
        alpha=jnp.full((num_flows,), 1.0, jnp.float32),
        t_alpha=z, t_rate=z, bytes_ctr=z,
        stage_t=z, stage_b=z,
    )


def step_dcqcn(
    state: DcqcnState,
    cnp: jax.Array,            # [F] 0/1 — CNP arrived this step
    sent_bytes: jax.Array,     # [F] bytes sent this step
    cfg: NetConfig,
    *,
    rtt_scale: jax.Array = None,   # [F] THEMIS fairness factor (None = 1)
    soft=None,                     # traced temperature (None = hard machine)
) -> DcqcnState:
    dt = cfg.dt_us
    g = cfg.dcqcn_g
    rai = cfg.dcqcn_rai_mbps * 1e6 / 8.0
    rhai = cfg.dcqcn_hai_mbps * 1e6 / 8.0
    rmin = cfg.min_rate_mbps * 1e6 / 8.0
    if rtt_scale is None:
        rtt_scale = jnp.ones_like(state.rc)

    # --- rate cut on CNP (THEMIS: attenuate for long-RTT flows) ---
    alpha_eff = state.alpha / rtt_scale
    rc_cut = jnp.maximum(state.rc * (1.0 - alpha_eff / 2.0), rmin)
    rt_cut = state.rc
    alpha_cut = (1.0 - g) * state.alpha + g

    t_alpha = state.t_alpha + dt
    t_rate = state.t_rate + dt
    bytes_ctr = state.bytes_ctr + sent_bytes

    if soft is None:
        cut = cnp > 0
        # --- alpha decay timer ---
        alpha_dec = t_alpha >= cfg.dcqcn_alpha_timer_us
        alpha_no = jnp.where(alpha_dec, (1.0 - g) * state.alpha, state.alpha)
        t_alpha_no = jnp.where(alpha_dec, 0.0, t_alpha)

        # --- rate increase events (timer and byte counter) ---
        timer_fire = t_rate >= cfg.dcqcn_rate_timer_us
        byte_fire = bytes_ctr >= cfg.dcqcn_bytes_counter_mb * 1e6
        fire = timer_fire | byte_fire
        stage_t = jnp.where(timer_fire, state.stage_t + 1, state.stage_t)
        stage_b = jnp.where(byte_fire, state.stage_b + 1, state.stage_b)
        max_stage = jnp.maximum(stage_t, stage_b)

        hyper = (stage_t > _F) & (stage_b > _F)
        additive = (max_stage > _F) & ~hyper
        inc = jnp.where(hyper, rhai, jnp.where(additive, rai, 0.0)) * rtt_scale
        rt_inc = jnp.where(fire, state.rt + inc, state.rt)
        rc_inc = jnp.where(fire, 0.5 * (state.rc + rt_inc), state.rc)

        # --- merge: cut dominates ---
        rc = jnp.where(cut, rc_cut, rc_inc)
        rt = jnp.where(cut, rt_cut, rt_inc)
        alpha = jnp.where(cut, alpha_cut, alpha_no)
        return DcqcnState(
            rc=jnp.clip(rc, rmin, None),
            rt=rt,
            alpha=jnp.clip(alpha, 0.0, 1.0),
            t_alpha=jnp.where(cut, 0.0, t_alpha_no),
            t_rate=jnp.where(cut | fire, 0.0, t_rate),
            bytes_ctr=jnp.where(cut | byte_fire, 0.0, bytes_ctr),
            stage_t=jnp.where(cut, 0.0, stage_t),
            stage_b=jnp.where(cut, 0.0, stage_b),
        )

    # --- soft machine (docs/differentiable.md): every gate a tempered
    # sigmoid, every select a lerp; converges to the hard machine above as
    # soft -> 0. CNPs are fractional in soft mode, so the cut gate sits at
    # the 0.5 midpoint.
    w_cut = soft_gt(cnp, 0.5, soft, 0.25)
    w_adec = soft_gt(t_alpha, cfg.dcqcn_alpha_timer_us, soft, dt)
    alpha_no = lerp(w_adec, (1.0 - g) * state.alpha, state.alpha)
    # timer/counter/stage resets use the DETACHED gate: the timer phase is
    # cadence structure, and the undetached reset recurrence's Jacobian
    # exceeds 1 near the firing equilibrium (soft.reset_gate docstring)
    t_alpha_no = lerp(reset_gate(w_adec), 0.0, t_alpha)

    w_tfire = soft_gt(t_rate, cfg.dcqcn_rate_timer_us, soft, dt)
    w_bfire = soft_gt(bytes_ctr, cfg.dcqcn_bytes_counter_mb * 1e6, soft,
                      0.01 * cfg.dcqcn_bytes_counter_mb * 1e6)
    w_fire = soft_or(w_tfire, w_bfire)
    stage_t = state.stage_t + w_tfire
    stage_b = state.stage_b + w_bfire
    max_stage = jnp.maximum(stage_t, stage_b)

    w_hyper = soft_gt(stage_t, float(_F), soft, 0.5) \
        * soft_gt(stage_b, float(_F), soft, 0.5)
    w_add = soft_gt(max_stage, float(_F), soft, 0.5) * (1.0 - w_hyper)
    inc = (w_hyper * rhai + w_add * rai) * rtt_scale
    rt_inc = lerp(w_fire, state.rt + inc, state.rt)
    rc_inc = lerp(w_fire, 0.5 * (state.rc + rt_inc), state.rc)

    rc = lerp(w_cut, rc_cut, rc_inc)
    rt = lerp(w_cut, rt_cut, rt_inc)
    alpha = lerp(w_cut, alpha_cut, alpha_no)
    w_cut_d = reset_gate(w_cut)
    return DcqcnState(
        rc=jnp.clip(rc, rmin, None),
        rt=rt,
        alpha=jnp.clip(alpha, 0.0, 1.0),
        t_alpha=lerp(w_cut_d, 0.0, t_alpha_no),
        t_rate=lerp(reset_gate(soft_or(w_cut, w_fire)), 0.0, t_rate),
        bytes_ctr=lerp(reset_gate(soft_or(w_cut, w_bfire)), 0.0, bytes_ctr),
        stage_t=lerp(w_cut_d, 0.0, stage_t),
        stage_b=lerp(w_cut_d, 0.0, stage_b),
    )


def themis_rtt_scale(rtt_us: jax.Array, rtt_ref_us: float = 10.0,
                     cap: float = 4.0) -> jax.Array:
    """RTT-aware fairness factor (sqrt-damped, clipped): long-haul flows
    increase faster / cut softer so they are not starved by short-loop
    flows — without inverting the unfairness."""
    return jnp.clip(jnp.sqrt(rtt_us / rtt_ref_us), 1.0, cap)
