"""The paper's contribution: MatchRDMA segmented, rate-matched control.

  reservoir.py  — Eq. (1) buffer-requirement model
  slots.py      — destination-OTN slot-level observations
  estimator.py  — communication-aware slot-weighted rate estimation
  budget.py     — rate-budget generation + inter-OTN control subchannel
  pseudo_ack.py — source-OTN budget-gated pseudo-ACK
  cc_proxy.py   — DCQCN machine (sender / proxy / THEMIS variants)
  matchrdma.py  — the composed three-segment controller
"""
from repro.core.budget import BudgetState, fair_share, init_budget, update_budget
from repro.core.cc_proxy import DcqcnState, init_dcqcn, step_dcqcn, themis_rtt_scale
from repro.core.estimator import (
    RateEstimate, periodic_estimate, slot_weighted_estimate,
)
from repro.core.matchrdma import (
    MatchRdmaState, accumulate_step, init_matchrdma, maybe_slot_update,
    slot_update, step_channel,
)
from repro.core.pseudo_ack import PseudoAckState, init_pseudo_ack, step_pseudo_ack
from repro.core.reservoir import (
    buffer_bound_e2e_vs_segmented, control_uncertainty_window_us,
    queue_trajectory, rate_mismatch_integral, required_buffer,
)
from repro.core.slots import SlotObs, SlotRing, classify_slot, init_ring, push_slot

__all__ = [
    "BudgetState", "fair_share", "init_budget", "update_budget",
    "DcqcnState", "init_dcqcn", "step_dcqcn", "themis_rtt_scale",
    "RateEstimate", "periodic_estimate", "slot_weighted_estimate",
    "MatchRdmaState", "accumulate_step", "init_matchrdma", "maybe_slot_update",
    "slot_update", "step_channel",
    "PseudoAckState", "init_pseudo_ack", "step_pseudo_ack",
    "buffer_bound_e2e_vs_segmented", "control_uncertainty_window_us",
    "queue_trajectory", "rate_mismatch_integral", "required_buffer",
    "SlotObs", "SlotRing", "classify_slot", "init_ring", "push_slot",
]
