"""Budget-gated pseudo-ACK generation at the source OTN (Fig. 2(c)/(d)).

The source OTN tracks, per RDMA connection (identified by the RoCE header
fields — QPN + PSN, Fig. 2(d)), the bytes it has accepted from the sender
(``accepted``) and the bytes it has pseudo-ACKed back (``packed``). Credits
accrue at the flow's budget share; each step the OTN releases

    new_packs = min(accepted - packed, credits)

so the sender's ACK-clocked window advances at source-local latency but
never faster than the destination-sustainable budget. The ungated variant
(credits = ∞) is the NTT pseudo-ACK baseline [ref 10].

Called from the ``pseudo_ack`` / ``matchrdma`` scheme plugins
(``repro.netsim.schemes``): their ``ack_view`` hook exposes ``packed`` to
the sender and their ``feedback`` hook steps the ledger.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PseudoAckState(NamedTuple):
    packed: jax.Array        # [F] bytes pseudo-ACKed so far
    credits: jax.Array       # [F] byte credits (token bucket)


def init_pseudo_ack(num_flows: int) -> PseudoAckState:
    return PseudoAckState(
        packed=jnp.zeros((num_flows,), jnp.float32),
        credits=jnp.zeros((num_flows,), jnp.float32),
    )


def step_pseudo_ack(state: PseudoAckState, accepted: jax.Array,
                    budget_share: jax.Array, dt_s: float,
                    gated: bool, max_burst_s: float = 2e-3):
    """One step. accepted: [F] cumulative bytes accepted at source OTN;
    budget_share: [F] bytes/s. Returns (new_state, pseudo_acked_cum [F]).

    Credits are capped at ``max_burst_s`` worth of budget so a long idle
    phase cannot bank an unbounded burst (the paper's budget is a *rate*).
    """
    backlog = jnp.maximum(accepted - state.packed, 0.0)
    if gated:
        credits = jnp.minimum(state.credits + budget_share * dt_s,
                              budget_share * max_burst_s)
        release = jnp.minimum(backlog, credits)
        credits = credits - release
    else:
        credits = state.credits
        release = backlog
    packed = state.packed + release
    return PseudoAckState(packed=packed, credits=credits), packed
