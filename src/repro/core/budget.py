"""Rate-budget generation + inter-OTN signalling (the middle segment).

The destination OTN turns the slot-weighted rate estimate into a budget
(headroom-scaled, floored, CNP-tightened) and ships it to the source OTN on
a small high-priority control subchannel modeled as a lossless delay line
(one-way propagation D + ``control_proc_slots`` slots of processing).

``fair_share`` / the channel machinery are consumed by the scheme plugins
in ``repro.netsim.schemes`` (budget×proxy release shaping, pseudo-ACK
credit rates, the per-step ``step_channel`` advance).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig, NetParams
from repro.core.estimator import RateEstimate
# submodule import (not the package __init__), so no core<->netsim cycle
from repro.netsim.soft import lerp, reset_gate, soft_gt


class BudgetState(NamedTuple):
    budget: jax.Array            # bytes/s — current budget at the DESTINATION
    tighten: jax.Array           # multiplicative reactive tightening in (0,1]
    slots_clear: jax.Array       # consecutive clear slots since last raise
    cap_ewma: jax.Array          # sticky EWMA of measured forwarding capability
    have_cap: jax.Array          # 1.0 once capability has ever been measured


def ctrl_window_slots(cfg: NetConfig) -> int:
    """The control-uncertainty window τ (Eq. 1) in slots: a budget raise is
    only observable after src<-budget (D) + effect->dst (D) + one slot."""
    import math
    return max(int(math.ceil(2.0 * cfg.one_way_delay_us / cfg.slot_us)) + 1, 4)


def ctrl_window_slots_traced(params: NetParams, cfg: NetConfig) -> jax.Array:
    """τ in slots from TRACED delay and TRACED slot length — the
    batched-engine twin of ``ctrl_window_slots`` (which must stay
    Python-int for shape sizing). Since the slot length became the traced
    ``NetParams.slot_us`` leaf, a ``slot_us`` sweep shares one compile."""
    return jnp.maximum(
        jnp.ceil(2.0 * params.one_way_delay_us / params.slot_us) + 1.0, 4.0)


def control_proc_steps_traced(cfg: NetConfig, params: NetParams) -> jax.Array:
    """Traced twin of ``NetConfig.control_proc_steps`` (int32). Uses
    ``jnp.floor`` to reproduce the static property's ``int()`` truncation
    exactly at matching slot values; rings are SIZED with the static
    padding, this traced count only sets the wrap index."""
    return jnp.floor(
        cfg.control_proc_slots * params.slot_us / cfg.dt_us
    ).astype(jnp.int32)


def init_budget(cfg: NetConfig, params: NetParams = None) -> BudgetState:
    """Proactive initial budget: a conservative fraction of the destination
    DC's drain capability (learned at flow setup), NOT the OTN line rate —
    the source must never out-run the destination on a stale assumption."""
    dst = cfg.dst_dc_gbps if params is None else params.dst_dc_gbps
    start = jnp.asarray(dst * 1e9 / 8.0 * 0.25, jnp.float32)
    return BudgetState(budget=start, tighten=jnp.float32(1.0),
                       slots_clear=jnp.float32(0.0),
                       cap_ewma=jnp.float32(0.0), have_cap=jnp.float32(0.0))


def update_budget(state: BudgetState, est: RateEstimate, cnp_in_slot: jax.Array,
                  cong_recent: jax.Array, cfg: NetConfig,
                  ctrl_slots=1, params: NetParams = None,
                  soft=None) -> BudgetState:
    """Per-slot budget update at the destination OTN.

    Two regimes (the rate-*matched* principle):
      * destination constrained (congestion within the last control window):
        budget = headroom · slot-weighted-estimate · tighten — source
        injection is matched to what the destination demonstrably forwards;
      * destination clear: multiplicative open-up (×2) paced at one raise per
        control window τ — raising faster than the loop delay means raising
        blind, and every blind raise costs a τ-window of excess in-flight
        bytes at the destination buffer (Eq. 1).
    ``tighten`` decays multiplicatively on CNP-heavy slots (reactive path)
    and recovers slowly when clear.

    ``soft`` (docs/differentiable.md): None emits the hard machine above;
    a traced temperature replaces every threshold select with a
    sigmoid-tempered blend so jax.grad flows through the controller.
    """
    if params is None:
        params = NetParams.of(cfg)
    cap = params.otn_capacity_gbps * 1e9 / 8.0
    floor = params.budget_floor_mbps * 1e6 / 8.0
    if soft is None:
        congested = cnp_in_slot > cfg.cnp_freq_thresh
        tighten = jnp.where(congested,
                            jnp.maximum(state.tighten * 0.95, 0.7),
                            jnp.minimum(state.tighten * 1.02, 1.0))
    else:
        w_cong = soft_gt(cnp_in_slot, cfg.cnp_freq_thresh, soft, 0.25)
        tighten = lerp(w_cong,
                       jnp.maximum(state.tighten * 0.95, 0.7),
                       jnp.minimum(state.tighten * 1.02, 1.0))

    # sticky EWMA capability: fold in fresh busy-slot measurements, keep the
    # last known value otherwise (ring rotation must not amnesia the budget).
    # In soft mode ``est.have_capability`` is itself a gate weight in [0,1]
    # and ``state.have_cap`` its running max — blend with them directly.
    if soft is None:
        fresh = est.have_capability > 0
        cap_ewma = jnp.where(
            fresh,
            jnp.where(state.have_cap > 0,
                      0.8 * state.cap_ewma + 0.2 * est.capability,
                      est.capability),
            state.cap_ewma)
    else:
        w_fresh = est.have_capability
        w_have = soft_gt(state.have_cap, 0.5, soft, 0.25)
        cap_ewma = lerp(
            w_fresh,
            lerp(w_have, 0.8 * state.cap_ewma + 0.2 * est.capability,
                 est.capability),
            state.cap_ewma)
    have_cap = jnp.maximum(state.have_cap, est.have_capability)

    # match to demonstrated forwarding CAPABILITY, never to self-throttled
    # egress; fall back to the plain slot-weighted estimate early on.
    if soft is None:
        cap_rate = jnp.where(have_cap > 0, cap_ewma, est.rate)
    else:
        w_havenew = soft_gt(have_cap, 0.5, soft, 0.25)
        cap_rate = lerp(w_havenew, cap_ewma, est.rate)
    matched = params.budget_headroom * cap_rate * tighten

    declared = params.dst_dc_gbps * 1e9 / 8.0
    if soft is None:
        constrained = cong_recent > 0.02
        slots_clear = jnp.where(constrained, 0.0, state.slots_clear + 1.0)
        raise_now = slots_clear >= ctrl_slots
        # a full clear control window at the current rate is itself
        # capability evidence: the destination absorbed the recent egress
        # cleanly. Ratchet the capability up to it so the probe ceiling
        # cannot deadlock below the true forwarding capability.
        cap_ewma = jnp.where(raise_now & (have_cap > 0),
                             jnp.maximum(cap_ewma, est.rate), cap_ewma)
        # gentle probe once capability is known; ×2 slow-start before — but
        # never blind-probe above 1.1× the destination's own egress-port
        # speed (known at flow setup): that bound is physical.
        ceiling = jnp.minimum(
            1.1 * jnp.where(have_cap > 0, cap_ewma, declared), cap)
        factor = jnp.where(have_cap > 0, cfg.budget_probe, 2.0)
        open_up = jnp.where(raise_now,
                            jnp.minimum(state.budget * factor, ceiling),
                            state.budget)
        slots_clear = jnp.where(raise_now, 0.0, slots_clear)
        budget = jnp.clip(jnp.where(constrained, matched, open_up),
                          floor, cap)
    else:
        w_con = soft_gt(cong_recent, 0.02, soft, 0.02)
        # slots_clear is a phase counter: its own resets take the DETACHED
        # gate (soft.reset_gate) — knob gradients still reach w_raise
        # through the traced ctrl_slots threshold
        slots_clear = lerp(reset_gate(w_con), 0.0, state.slots_clear + 1.0)
        w_raise = soft_gt(slots_clear, ctrl_slots, soft, 1.0)
        cap_ewma = lerp(w_raise * w_havenew,
                        jnp.maximum(cap_ewma, est.rate), cap_ewma)
        ceiling = jnp.minimum(
            1.1 * lerp(w_havenew, cap_ewma, declared), cap)
        factor = lerp(w_havenew, jnp.float32(cfg.budget_probe), 2.0)
        open_up = lerp(w_raise,
                       jnp.minimum(state.budget * factor, ceiling),
                       state.budget)
        slots_clear = lerp(reset_gate(w_raise), 0.0, slots_clear)
        budget = jnp.clip(lerp(w_con, matched, open_up), floor, cap)
    return BudgetState(budget=budget, tighten=tighten,
                       slots_clear=slots_clear,
                       cap_ewma=cap_ewma, have_cap=have_cap)


class ControlChannel(NamedTuple):
    """Delay line carrying (budget, congestion summary) DST -> SRC.

    The line length (``line_budget.shape[0]``) is the PADDED compile-time
    size shared by every scenario in a batch; ``delay`` is the traced actual
    delay in steps (<= padding) the ring index wraps at, so heterogeneous
    distances share one compiled program.
    """
    line_budget: jax.Array       # [Dpad]
    line_summary: jax.Array      # [Dpad]
    idx: jax.Array               # scalar int32
    delay: jax.Array             # scalar int32 — actual delay (<= Dpad)


def init_channel(delay_steps: int, cfg: NetConfig,
                 params: NetParams = None, actual_delay=None,
                 fill=None) -> ControlChannel:
    """``delay_steps`` sizes the (static) line; ``actual_delay`` (traced int,
    defaults to ``delay_steps``) is the wrap point actually used. ``fill``
    overrides the line's initial value (default: the proactive initial
    budget; cumulative credit-grant channels pass 0.0)."""
    dst = cfg.dst_dc_gbps if params is None else params.dst_dc_gbps
    start = dst * 1e9 / 8.0 * 0.25 if fill is None else fill
    d = max(delay_steps, 1)
    if actual_delay is None:
        actual_delay = d
    return ControlChannel(
        line_budget=jnp.full((d,), start, jnp.float32),
        line_summary=jnp.zeros((d,), jnp.float32),
        idx=jnp.int32(0),
        delay=jnp.clip(jnp.asarray(actual_delay, jnp.int32), 1, d),
    )


def channel_send_recv(chan: ControlChannel, budget: jax.Array,
                      summary: jax.Array):
    """Push this step's (budget, summary); pop the D-delayed values.

    Returns (new_channel, budget_at_src, summary_at_src).
    """
    out_b = chan.line_budget[chan.idx]
    out_s = chan.line_summary[chan.idx]
    new = chan._replace(
        line_budget=chan.line_budget.at[chan.idx].set(budget),
        line_summary=chan.line_summary.at[chan.idx].set(summary),
        idx=jnp.mod(chan.idx + 1, chan.delay),
    )
    return new, out_b, out_s


def fair_share(budget_total: jax.Array, active: jax.Array) -> jax.Array:
    """Split the aggregate budget among active inter-DC flows.

    active: [F] 0/1 mask. Max-min fair for equal demands = equal split.
    """
    n = jnp.maximum(active.sum(), 1.0)
    return budget_total / n * active
