"""Reservoir model of destination-OTN buffer stress — Eq. (1) of the paper.

Two coupled reservoirs linked by a long-haul pipe with one-way delay D.
Arrivals at the destination OTN are the D-delayed source output process;
the destination forwards into the receiving AI-DC at r_out(t). The minimum
runtime buffer is governed by the accumulated rate mismatch over the
control-uncertainty window τ:

    B_req >= sup_t ∫_t^{t+τ} ( r_in(u) - r_out(u) )⁺ du            (Eq. 1)

These are pure-jnp utilities used by tests (the bound must hold against the
simulated queue), by the estimator (to size headroom), and by the roofline
step-time model (to size the OTN buffer a training step needs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rate_mismatch_integral(r_in: jax.Array, r_out: jax.Array, dt: float,
                           tau_steps: int) -> jax.Array:
    """∫_t^{t+τ} (r_in - r_out)⁺ du for every t, via a cumulative-sum window.

    r_in, r_out: [T] rates (bytes/s) on a common grid of step dt (s).
    Returns [T] array; entries within τ of the end use the truncated window.
    """
    excess = jnp.maximum(r_in - r_out, 0.0) * dt              # bytes per step
    cs = jnp.concatenate([jnp.zeros(1), jnp.cumsum(excess)])  # [T+1]
    t = excess.shape[0]
    idx_hi = jnp.minimum(jnp.arange(t) + tau_steps, t)
    return cs[idx_hi] - cs[jnp.arange(t)]


def required_buffer(r_in: jax.Array, r_out: jax.Array, dt: float,
                    tau_steps: int) -> jax.Array:
    """B_req = sup_t of the windowed mismatch integral (Eq. 1)."""
    return jnp.max(rate_mismatch_integral(r_in, r_out, dt, tau_steps))


def queue_trajectory(r_in: jax.Array, r_out_cap: jax.Array, dt: float,
                     q0: float = 0.0) -> jax.Array:
    """Lindley recursion: q_{t+1} = (q_t + (r_in - r_out_cap)·dt)⁺.

    ``r_out_cap`` is the *capacity* of the drain (the realized drain is
    min(capacity, backlog/dt + arrivals)). Returns the queue series [T].
    """
    def step(q, rr):
        ri, ro = rr
        q_new = jnp.maximum(q + (ri - ro) * dt, 0.0)
        return q_new, q_new

    _, qs = jax.lax.scan(step, jnp.float32(q0), (r_in, r_out_cap))
    return qs


def control_uncertainty_window_us(one_way_delay_us: float,
                                  proc_delay_us: float = 0.0,
                                  slot_us: float = 0.0) -> float:
    """τ for the segmented scheme: budget feedback takes one OTN-to-OTN
    propagation (D) + control processing + up to one slot of estimation lag.

    For *end-to-end* control (DCQCN baseline), τ ≈ 2·D + receiver processing
    — twice as large, which is exactly why the paper's segmented control
    shrinks B_req.
    """
    return one_way_delay_us + proc_delay_us + slot_us


def buffer_bound_e2e_vs_segmented(peak_rate: float, matched_rate: float,
                                  one_way_delay_us: float, slot_us: float):
    """Analytic comparison used in EXPERIMENTS.md: worst-case B_req when the
    drain drops to ``matched_rate`` while the source still injects
    ``peak_rate`` for a full control window.

    Returns (B_e2e, B_segmented) in bytes. peak/matched in bytes/s.
    """
    tau_e2e = 2.0 * one_way_delay_us * 1e-6
    tau_seg = (one_way_delay_us + slot_us) * 1e-6
    excess = max(peak_rate - matched_rate, 0.0)
    return excess * tau_e2e, excess * tau_seg
