"""Communication-aware slot-weighted rate estimation (Fig. 2(e), right half).

From the slot ring the destination OTN:
  1. groups consecutive slots into windows of ``slots_per_window``;
  2. classifies each window as a *stable recurrent rate window* (low
     coefficient of variation, no congestion flags) or *jitter-dominated*;
  3. estimates the future sustainable inter-DC rate as a weighted mean —
     stable windows weighted ``stable_weight``, jittery ones
     ``jitter_weight`` (conservative), congested slots additionally
     tightened;
  4. optionally applies the LLM-periodicity predictor: if the most recent
     window closely matches the window one iteration-period ago, the rates
     observed *after* that historical window are used as the forecast
     (communication-aware anticipation of the next comm phase).

All pure functions over SlotRing — used inside the netsim scan and unit-
testable standalone.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig
from repro.core.slots import SlotRing, ordered_history
# submodule import (not the package __init__), so no core<->netsim cycle
from repro.netsim.soft import lerp, soft_gt, soft_pos

_EPS = 1e-9


class RateEstimate(NamedTuple):
    rate: jax.Array          # bytes/s — the slot-weighted estimate
    stable_frac: jax.Array   # fraction of windows classified stable
    recurrent: jax.Array     # 1.0 if the periodic predictor fired
    capability: jax.Array    # bytes/s — busy-slot forwarding-capability est.
    have_capability: jax.Array  # 1.0 once any busy slot has been observed


def window_stats(rates: jax.Array, congested: jax.Array, busy: jax.Array,
                 valid: jax.Array, slots_per_window: int, soft=None):
    """Reshape oldest-first history into windows; per-window mean/CV/flags.

    ``soft`` only swaps the std for an epsilon-regularized sqrt(var): at a
    constant (e.g. all-zero) window ``jnp.std`` has an infinite derivative
    and its JVP yields NaN tangents — the hard value is unchanged to ~1e-6
    and the hard path keeps the exact historical expression."""
    r = rates.shape[0]
    nw = r // slots_per_window
    cut = nw * slots_per_window
    rw = rates[:cut].reshape(nw, slots_per_window)
    cw = congested[:cut].reshape(nw, slots_per_window)
    bw = busy[:cut].reshape(nw, slots_per_window)
    vw = valid[:cut].reshape(nw, slots_per_window)
    w_valid = vw.min(axis=1)                                  # window fully valid
    mean = rw.mean(axis=1)
    if soft is None:
        std = rw.std(axis=1)
    else:
        std = jnp.sqrt(rw.var(axis=1) + 1e-12)
    cv = std / jnp.maximum(mean, _EPS)
    cong = cw.max(axis=1)
    busy_frac = bw.mean(axis=1)
    return mean, cv, cong, busy_frac, w_valid


def slot_weighted_estimate(ring: SlotRing, cfg: NetConfig,
                           soft=None) -> RateEstimate:
    rates, congested, busy, valid = ordered_history(ring)
    mean, cv, cong, busy_frac, w_valid = window_stats(
        rates, congested, busy, valid, cfg.slots_per_window, soft=soft)
    if soft is None:
        stable = ((cv < cfg.stable_cv_thresh)
                  & (cong < 0.5)).astype(jnp.float32)
        w = jnp.where(stable > 0, cfg.stable_weight,
                      cfg.jitter_weight) * w_valid
    else:
        stable = (soft_gt(cfg.stable_cv_thresh, cv, soft, 0.05)
                  * soft_gt(0.5, cong, soft, 0.25))
        w = lerp(stable, jnp.float32(cfg.stable_weight),
                 jnp.float32(cfg.jitter_weight)) * w_valid
    # recency weighting: newer windows count more (linear ramp 0.5 .. 1.0)
    nw = mean.shape[0]
    recency = 0.5 + 0.5 * (jnp.arange(nw) + 1) / nw
    w = w * recency
    est = jnp.sum(w * mean) / jnp.maximum(jnp.sum(w), _EPS)
    stable_frac = (jnp.sum(stable * w_valid)
                   / jnp.maximum(jnp.sum(w_valid), _EPS))

    # forwarding-capability estimate: rates observed while BACKLOGGED are the
    # destination's demonstrated drain capability; clear slots only lower-
    # bound it (egress == demand there). Stability weighting still applies.
    wcap = w * busy_frac
    if soft is None:
        have_cap = (jnp.sum(wcap) > _EPS).astype(jnp.float32)
    else:
        # soft_pos is exactly 0 at 0 — no busy slot ever => no capability
        have_cap = soft_pos(jnp.sum(wcap) - _EPS, soft, 0.25)
    cap = jnp.sum(wcap * mean) / jnp.maximum(jnp.sum(wcap), _EPS)
    return RateEstimate(rate=est, stable_frac=stable_frac,
                        recurrent=jnp.float32(0.0),
                        capability=cap, have_capability=have_cap)


def periodic_estimate(ring: SlotRing, cfg: NetConfig,
                      period_slots: int, soft=None) -> RateEstimate:
    """Seasonal forecast keyed to the LLM iteration period.

    If the latest ``slots_per_window`` slots match the same-phase window one
    period earlier (relative L1 distance < stable_cv_thresh), forecast the
    rates that FOLLOWED that historical window; else fall back to the
    slot-weighted estimate.
    """
    base = slot_weighted_estimate(ring, cfg, soft=soft)
    rates, congested, busy, valid = ordered_history(ring)
    r = rates.shape[0]
    spw = cfg.slots_per_window
    if r < period_slots + 2 * spw or period_slots <= spw:
        return base

    cur = jax.lax.dynamic_slice_in_dim(rates, r - spw, spw)
    hist = jax.lax.dynamic_slice_in_dim(rates, r - spw - period_slots, spw)
    nxt = jax.lax.dynamic_slice_in_dim(rates, r - period_slots, spw)
    cur_valid = jax.lax.dynamic_slice_in_dim(valid, r - spw - period_slots, spw)

    denom = jnp.maximum(jnp.abs(cur).mean(), _EPS)
    rel = jnp.abs(cur - hist).mean() / denom
    forecast = nxt.mean()
    if soft is None:
        match = (rel < cfg.stable_cv_thresh) & (cur_valid.min() > 0)
        # blend: recurrent forecast replaces the base estimate when it fires
        rate = jnp.where(match, forecast, base.rate)
        recurrent = match.astype(jnp.float32)
    else:
        # the validity mask is count-driven (no knob dependence): keep it
        # a hard multiplier; only the similarity gate is tempered
        w_valid = (cur_valid.min() > 0).astype(jnp.float32)
        recurrent = soft_gt(cfg.stable_cv_thresh, rel, soft, 0.05) * w_valid
        rate = lerp(recurrent, forecast, base.rate)
    return RateEstimate(rate=rate, stable_frac=base.stable_frac,
                        recurrent=recurrent,
                        capability=base.capability,
                        have_capability=base.have_capability)
