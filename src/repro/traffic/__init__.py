"""AICB-like LLM traffic model: analytic collective sizes + iteration timeline."""
from repro.traffic.aicb import (
    IterationProfile, iteration_profile, period_slots, training_workload,
)
from repro.traffic.patterns import StepTraffic, pp_stage_bytes, step_traffic

__all__ = [
    "IterationProfile", "iteration_profile", "period_slots",
    "training_workload", "StepTraffic", "pp_stage_bytes", "step_traffic",
]
