"""Analytic collective-size math for LLM training communication.

Given a ModelConfig + ParallelConfig + TrainConfig, derive the bytes each
parallelism dimension moves per training step — the same quantities the
AICB benchmark measures empirically. These sizes (a) parameterize the
netsim workload (message sizes / concurrency of inter-DC flows) and
(b) cross-check the dry-run's HLO collective-byte parse.

Conventions: bf16 gradients/activations (2 bytes), ring-allreduce cost
2·(n-1)/n ≈ 2 per element unless hierarchical.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.config.base import ModelConfig, ParallelConfig, TrainConfig

BYTES_GRAD = 2  # bf16


@dataclass(frozen=True)
class StepTraffic:
    """Bytes moved per training step, by class."""
    dp_grad_bytes: float          # data-parallel gradient reduction (per replica)
    inter_pod_bytes: float        # bytes that must cross the pod (inter-DC) axis
    tp_activation_bytes: float    # tensor-parallel all-reduce bytes (per device)
    ep_alltoall_bytes: float      # expert-parallel dispatch bytes (per device)
    compute_flops: float          # model FLOPs per step (6·N_active·D)
    iter_time_estimate_s: float   # compute-bound iteration estimate
    comm_frac: float              # fraction of iter spent in exposed inter-DC comm


def step_traffic(model: ModelConfig, par: ParallelConfig, train: TrainConfig,
                 chip_flops: float = 197e12, mfu: float = 0.4) -> StepTraffic:
    p = model.param_count()
    p_active = model.active_param_count()
    d = model.d_model
    tokens = train.global_batch * train.seq_len

    # --- DP gradient reduction ---
    # ring all-reduce over the data axis: 2·P bytes per replica
    dp_bytes = 2.0 * p * BYTES_GRAD

    # --- inter-pod (inter-DC) bytes ---
    if par.multi_pod:
        if par.hierarchical_allreduce:
            # reduce-scatter intra-pod first: each chip holds P/(data·model)
            # shard; the pod-axis exchange moves 2·P/(data·model) per chip,
            # i.e. 2·P per POD in aggregate across the OTN.
            inter_pod = 2.0 * p * BYTES_GRAD
        else:
            # flat all-reduce across pods: every chip's full gradient crosses
            inter_pod = 2.0 * p * BYTES_GRAD * par.data * par.model
        if par.pod_compression == "int8":
            inter_pod *= 0.5
    else:
        inter_pod = 0.0

    # --- TP activation all-reduces: 2 per block (attn out + mlp out), fwd+bwd
    per_device_tokens = tokens / max(par.data * (par.pods if par.multi_pod else 1), 1)
    tp_bytes = (4.0 * model.num_layers * per_device_tokens * d * BYTES_GRAD
                if par.model > 1 else 0.0)

    # --- EP all-to-all (kept intra-pod by design) ---
    if model.num_experts:
        n_moe = sum(1 for _, m in model.layer_blocks() if m == "moe")
        # dispatch + combine, fwd + bwd: 4 transfers of k·tokens·d
        ep_bytes = (4.0 * n_moe * per_device_tokens
                    * model.num_experts_per_tok * d * BYTES_GRAD)
    else:
        ep_bytes = 0.0

    flops = 6.0 * p_active * tokens
    chips = par.num_devices
    iter_time = flops / (chips * chip_flops * mfu)

    # exposed inter-DC time on 16x100G OTN if not overlapped
    otn_bw = 16 * 100e9 / 8.0
    inter_time = inter_pod / otn_bw
    comm_frac = inter_time / max(iter_time + inter_time, 1e-9)

    return StepTraffic(
        dp_grad_bytes=dp_bytes,
        inter_pod_bytes=inter_pod,
        tp_activation_bytes=tp_bytes,
        ep_alltoall_bytes=ep_bytes,
        compute_flops=flops,
        iter_time_estimate_s=iter_time,
        comm_frac=comm_frac,
    )


def pp_stage_bytes(model: ModelConfig, train: TrainConfig,
                   microbatches: int) -> float:
    """Pipeline-parallel activation transfer per stage boundary per step
    (fwd activation + bwd gradient per microbatch)."""
    micro_tokens = train.global_batch * train.seq_len / max(microbatches, 1)
    return 2.0 * microbatches * micro_tokens * model.d_model * BYTES_GRAD
