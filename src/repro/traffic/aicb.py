"""AICB-like iteration traffic model: turn an architecture's training step
into the netsim workload (the alternating computation-communication structure
of LLM training iterations, ref [18]).

The inter-DC traffic of one geo-distributed training step (pod axis = DC
boundary) is the hierarchical gradient exchange: ``inter_pod_bytes`` moved
during a comm phase at the end of each iteration (or overlapped with the
backward pass — ``overlap_frac`` stretches the comm phase accordingly).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.config.base import ModelConfig, NetConfig, ParallelConfig, TrainConfig
from repro.netsim.workload import FlowSpec, Workload
from repro.traffic.patterns import StepTraffic, step_traffic


@dataclass(frozen=True)
class IterationProfile:
    iter_us: float              # full iteration period
    comm_us: float              # inter-DC communication phase duration
    comm_bytes: float           # bytes crossing the OTN per iteration
    num_flows: int              # parallel QPs carrying the exchange
    msg_size: float             # bytes per message (collective chunk)
    concurrency: int            # in-flight messages per flow


def iteration_profile(model: ModelConfig, par: ParallelConfig,
                      train: TrainConfig, *, num_flows: int = 16,
                      msg_size: float = 4 << 20, concurrency: int = 16,
                      overlap_frac: float = 0.0) -> IterationProfile:
    t = step_traffic(model, par, train)
    iter_us = t.iter_time_estimate_s * 1e6
    otn_bw = 16 * 100e9 / 8.0
    comm_us = t.inter_pod_bytes / otn_bw * 1e6
    if overlap_frac > 0:
        # overlapped exchange is spread across the backward pass
        comm_us = max(comm_us, overlap_frac * iter_us)
    return IterationProfile(
        iter_us=iter_us + comm_us * (1.0 - overlap_frac),
        comm_us=comm_us,
        comm_bytes=t.inter_pod_bytes,
        num_flows=num_flows,
        msg_size=msg_size,
        concurrency=concurrency,
    )


def training_workload(model: ModelConfig, par: ParallelConfig,
                      train: TrainConfig, *, num_flows: int = 16,
                      msg_size: float = 4 << 20, concurrency: int = 16,
                      with_intra: int = 8) -> Workload:
    """netsim workload for geo-distributed training of this architecture."""
    prof = iteration_profile(model, par, train, num_flows=num_flows,
                             msg_size=msg_size, concurrency=concurrency)
    duty = min(prof.comm_us / max(prof.iter_us, 1.0), 1.0)
    flows = [FlowSpec(True, msg_size, concurrency,
                      period_us=prof.iter_us, duty=duty)
             for _ in range(num_flows)]
    flows += [FlowSpec(False, 256 << 10, 8) for _ in range(with_intra)]
    return Workload(tuple(flows))


def period_slots(prof: IterationProfile, net: NetConfig) -> int:
    """Iteration period in estimator slots (for the periodic predictor)."""
    return max(int(round(prof.iter_us / net.slot_us)), 1)
