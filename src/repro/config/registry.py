"""--arch registry: maps arch ids to (full ModelConfig, smoke ModelConfig).

Each module in ``repro.configs`` registers itself on import via
``register(full=..., smoke=..., parallel_overrides=...)``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional

from repro.config.base import ModelConfig, ParallelConfig

_FULL: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}
_PAR_OVERRIDES: Dict[str, dict] = {}

_ARCH_MODULES = {
    "musicgen-large": "repro.configs.musicgen_large",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}


def register(full: ModelConfig, smoke: ModelConfig, parallel_overrides: Optional[dict] = None) -> None:
    _FULL[full.name] = full
    _SMOKE[full.name] = smoke
    _PAR_OVERRIDES[full.name] = dict(parallel_overrides or {})


def _ensure(name: str) -> None:
    if name not in _FULL:
        mod = _ARCH_MODULES.get(name)
        if mod is None:
            raise KeyError(
                f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
        importlib.import_module(mod)


def list_archs() -> list:
    return sorted(_ARCH_MODULES)


def get_model_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure(name)
    return (_SMOKE if smoke else _FULL)[name]


def get_parallel_config(name: str, multi_pod: bool = False, **extra) -> ParallelConfig:
    """Production ParallelConfig for an arch (its registered overrides + extras)."""
    _ensure(name)
    kw = dict(_PAR_OVERRIDES[name])
    kw.update(extra)
    kw["multi_pod"] = multi_pod
    return ParallelConfig(**kw)
