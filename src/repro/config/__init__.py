from repro.config.base import (
    ATTN, LOCAL_ATTN, SSD, RGLRU,
    MLP_SWIGLU, MLP_RELU2, MLP_GELU, MLP_MOE, MLP_NONE,
    ModelConfig, NetConfig, ParallelConfig, RunConfig, ShapeSpec,
    SHAPES, shape_applicable,
)
from repro.config.registry import (
    get_model_config, get_parallel_config, list_archs, register,
)

__all__ = [
    "ATTN", "LOCAL_ATTN", "SSD", "RGLRU",
    "MLP_SWIGLU", "MLP_RELU2", "MLP_GELU", "MLP_MOE", "MLP_NONE",
    "ModelConfig", "NetConfig", "ParallelConfig", "RunConfig", "ShapeSpec",
    "SHAPES", "shape_applicable",
    "get_model_config", "get_parallel_config", "list_archs", "register",
]
