"""Configuration dataclasses for the repro framework.

Every experiment is driven by a ``RunConfig`` composed of:
  * ``ModelConfig``    — architecture definition (block types, dims, vocab).
  * ``ParallelConfig`` — mesh layout + sharding strategy knobs.
  * ``TrainConfig``    — optimizer / schedule / checkpointing / fault tolerance.
  * ``NetConfig``      — the MatchRDMA / netsim network parameters (the paper).

All configs are frozen dataclasses so they are hashable and can key jit caches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Mapping, NamedTuple, Sequence

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

# Block kinds understood by repro.models.transformer
ATTN = "attn"            # global causal GQA attention
LOCAL_ATTN = "local_attn"  # sliding-window causal attention
SSD = "ssd"              # Mamba2 state-space duality block
RGLRU = "rglru"          # RecurrentGemma RG-LRU recurrent block

MLP_SWIGLU = "swiglu"
MLP_RELU2 = "relu2"      # squared-ReLU (Nemotron-4)
MLP_GELU = "gelu"
MLP_MOE = "moe"          # top-k mixture of experts (SwiGLU experts)
MLP_NONE = "none"        # block has no separate MLP (e.g. Mamba2)


@dataclass(frozen=True)
class ModelConfig:
    """Unified decoder-only LM configuration covering all assigned families."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free)
    num_kv_heads: int                # KV heads (GQA); == num_heads for MHA
    d_ff: int
    vocab_size: int

    # Block pattern. If empty, every layer is (mixer=ATTN, mlp=default_mlp).
    # Otherwise a repeating pattern of (mixer_kind, mlp_kind) tuples.
    block_pattern: tuple = ()

    default_mlp: str = MLP_SWIGLU
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention details ---
    qkv_bias: bool = False           # Qwen1.5
    rope_theta: float = 10000.0
    local_window: int = 2048         # for LOCAL_ATTN blocks
    logit_softcap: float = 0.0       # 0 = disabled
    # --- normalization / misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # group dispatch by batch rows (GShard G-groups): expert buffers gain a
    # batch-sharded leading dim, keeping dispatch/combine local to the data
    # shard — no cross-(pod,data) collectives (see EXPERIMENTS.md §Perf)
    moe_group_by_batch: bool = False
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0               # N (state size per head)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_headdim: int = 64
    ssm_conv: int = 4                # depthwise conv width
    ssm_chunk: int = 128             # SSD chunk length
    # --- RG-LRU (RecurrentGemma) ---
    rglru_width: int = 0             # d_rnn (0 -> ssm_expand*d_model? use explicit)
    rglru_conv: int = 4
    # K cache stored time-minor [B, Hk, hd, S] (dot-ready layout: QK^T
    # contracts hd with S free — avoids a full-cache transpose per decode
    # step; EXPERIMENTS.md §Perf Cell A iteration 2)
    decode_k_time_minor: bool = False
    # --- modality frontend stub ---
    embed_inputs: bool = True        # False => inputs are precomputed embeddings
    # --- attention flavor for very long context ---
    subquadratic: bool = False       # True for ssm / hybrid (long_500k eligible)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def layer_blocks(self) -> tuple:
        """Expand block_pattern to num_layers entries of (mixer, mlp)."""
        if not self.block_pattern:
            return tuple((ATTN, self.default_mlp) for _ in range(self.num_layers))
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                       # embedding
        if not self.tie_embeddings:
            total += v * d                  # unembedding
        hd = self.resolved_head_dim
        for mixer, mlp in self.layer_blocks():
            if mixer == ATTN or mixer == LOCAL_ATTN:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * hd
            elif mixer == SSD:
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_headdim
                # in_proj: z,x,B,C,dt ; out_proj ; conv ; A,D,dt_bias, norm
                total += d * (2 * d_in + 2 * self.ssm_state + nheads)
                total += d_in * d
                total += self.ssm_conv * (d_in + 2 * self.ssm_state)
                total += 3 * nheads + d_in
            elif mixer == RGLRU:
                w = self.rglru_width or d
                # linear in (x,y branches), gates, out
                total += d * w * 2 + w * d + 3 * w + self.rglru_conv * w + 2 * w * (w // 8 if w >= 8 else w)
            # norms
            total += 2 * d
            if mlp == MLP_SWIGLU:
                total += 3 * d * self.d_ff
            elif mlp in (MLP_RELU2, MLP_GELU):
                total += 2 * d * self.d_ff
            elif mlp == MLP_MOE:
                total += d * self.num_experts  # router
                total += self.num_experts * 3 * d * self.d_ff
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        dense = self.param_count()
        n_moe_layers = sum(1 for _, m in self.layer_blocks() if m == MLP_MOE)
        per_expert = 3 * self.d_model * self.d_ff
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) * per_expert
        return dense - inactive


# ---------------------------------------------------------------------------
# Parallelism
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """Mesh layout + sharding strategy."""

    multi_pod: bool = False
    pods: int = 2
    data: int = 16
    model: int = 16

    fsdp: bool = False            # additionally shard params/opt-state over data axis
    remat: str = "block"          # none | block | full
    scan_layers: bool = True
    microbatches: int = 1         # gradient accumulation
    # pod-axis (inter-DC) optimizations — the MatchRDMA-motivated features
    hierarchical_allreduce: bool = True
    pod_compression: str = "none"  # none | int8
    # decode layout
    shard_cache_seq: bool = True   # shard KV-cache sequence dim over model axis
    flash_decode: bool = False     # explicit shard_map partial-softmax decode
    # optimizer state dtype (bf16 for the 340B config)
    opt_state_dtype: str = "float32"

    def axis_names(self) -> tuple:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    def mesh_shape(self) -> tuple:
        if self.multi_pod:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)

    def batch_axes(self) -> tuple:
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def num_devices(self) -> int:
        n = self.data * self.model
        return n * self.pods if self.multi_pod else n


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    # checkpointing / fault tolerance
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 100
    ckpt_keep: int = 3
    ckpt_async: bool = True
    # straggler mitigation (simulated policy knobs)
    step_deadline_ms: float = 0.0   # 0 = disabled
    max_restarts: int = 3


# ---------------------------------------------------------------------------
# Network (the paper)
# ---------------------------------------------------------------------------

class NetParams(NamedTuple):
    """Traced per-scenario network parameters (a jax pytree).

    ``NetConfig`` stays the static, hashable side of the split: it keys jit
    caches and fixes every compile-time *shape* (``dt_us``, slot layout,
    delay-line padding). ``NetParams`` holds the scalars a scenario sweep
    varies — distance/delay, capacities, buffer thresholds — as traced f32
    leaves, so a whole distance x capacity x buffer grid can run as ONE
    ``jax.vmap``-ed computation instead of one compile per cell.

    The three ``link_*`` leaves are the per-link topology axis: shape
    ``[L]`` with ``L = cfg.num_paths`` (STATIC — it keys the compile).
    At ``L = 1`` they are present but unused: the engine takes the
    single-pipe code path, whose jaxpr the goldens pin bit-for-bit.

    Build one with ``NetParams.of(cfg)``; stack a grid with
    ``stack_net_params([cfg0, cfg1, ...])`` (leaves gain a leading [B] axis).
    """

    one_way_delay_us: Any        # f32 — long-haul one-way propagation delay
    otn_capacity_gbps: Any       # f32 — aggregate OTN line capacity
    dst_dc_gbps: Any             # f32 — destination leaf capacity
    nic_gbps: Any                # f32 — sender NIC line rate
    pfc_xoff_kb: Any             # f32 — DC-leaf PFC pause threshold
    pfc_xon_kb: Any              # f32 — DC-leaf PFC resume threshold
    otn_buffer_bdp_frac: Any     # f32 — OTN PFC headroom as a BDP fraction
    ecn_kmin_kb: Any             # f32 — ECN marking lower threshold
    ecn_kmax_kb: Any             # f32 — ECN marking upper threshold
    queue_thresh_kb: Any         # f32 — dst-OTN backlog threshold (slots)
    budget_floor_mbps: Any       # f32 — budget floor
    budget_headroom: Any         # f32 — inject <= headroom * estimated r_out
    # related-work scheme knobs (consumed only by their schemes; traced so
    # a knob grid sweeps batch-wide in one compiled launch)
    geopipe_credit_bdp_frac: Any  # f32 — geopipe segment credit window (BDP x)
    sdr_window_bdp_frac: Any     # f32 — sdr_rdma selective-repeat window (BDP x)
    sdr_ack_coalesce_us: Any     # f32 — sdr_rdma ACK coalescing interval
    sdr_retx_budget_frac: Any    # f32 — sdr_rdma rate share reserved for repair
    # channel-impairment knobs (consumed only by non-ideal channel models —
    # repro.netsim.channel; traced so impairment grids vmap jointly with
    # scheme knobs and workloads in one compiled program per scheme)
    loss_rate: Any               # f32 — stationary long-haul byte-loss frac
    loss_burst_len: Any          # f32 — mean Gilbert–Elliott burst (steps)
    jitter_us: Any               # f32 — mean stochastic extra delay
    flap_period_us: Any          # f32 — OTN protection-switch period (0=off)
    flap_depth: Any              # f32 — capacity cut inside a flap dip [0,1]
    # rdmacell flowcell-spraying knobs (consumed only by the rdmacell
    # scheme; traced so a token/ROB grid sweeps batch-wide)
    rdmacell_token_bucket_us: Any  # f32 — per-link token-bucket depth (µs
                                   # of that link's line rate)
    rdmacell_rob_limit_mb: Any     # f32 — dst reorder-buffer budget (MB)
    # traced slot length (docs/differentiable.md): steps-per-slot and the
    # control-processing delay derive from this leaf at trace time, so a
    # slot_us sweep shares ONE compiled program (the static
    # ``NetConfig.slot_us`` twin still sizes history rings).
    slot_us: Any                 # f32 — MatchRDMA slot duration (µs)
    # soft-step relaxation temperature (docs/differentiable.md): consumed
    # only when ``NetConfig.soft_step`` is True; traced so a temperature
    # anneal batches in one compile.
    soft_temp: Any               # f32 — sigmoid temperature (→0 = hard)
    # per-link topology leaves ([L], L = cfg.num_paths — static):
    link_delay_us: Any           # f32[L] — per-link one-way delay
    link_cap_gbps: Any           # f32[L] — per-link line capacity
    link_thresh_kb: Any          # f32[L] — per-link dst-OTN PFC threshold
    # trace-replay channel schedule (repro.netsim.channel trace_replay):
    # per-edge time-indexed (loss_frac, defer_frac, cap_frac) rows. The
    # VALUES are traced; the table SHAPE [L, K, 3] is static (K =
    # cfg.schedule_len keys the compile — grids sharing one schedule
    # length share one program). [L, 0, 3] = no schedule (pass-through).
    chan_schedule: Any           # f32[L, K, 3]
    chan_sched_dt_us: Any        # f32 — schedule entry duration (µs;
                                 # <= 0 means one entry per dt_us step)
    # failure schedule (repro.netsim.failures): per-edge hard-outage
    # windows. The WINDOW TIMES are traced; the window count W is static
    # shape (W = cfg.failure_len keys the compile — grids sharing one
    # window count share one program). [L, 0, 2] = no failures.
    fail_windows: Any            # f32[L, W, 2] — (down_at_us, up_at_us)

    @classmethod
    def of(cls, cfg: "NetConfig") -> "NetParams":
        import jax.numpy as jnp
        scalars = tuple(jnp.float32(v) for v in (
            cfg.one_way_delay_us, cfg.otn_capacity_gbps, cfg.dst_dc_gbps,
            cfg.nic_gbps, cfg.pfc_xoff_kb, cfg.pfc_xon_kb,
            cfg.otn_buffer_bdp_frac, cfg.ecn_kmin_kb, cfg.ecn_kmax_kb,
            cfg.queue_thresh_kb, cfg.budget_floor_mbps,
            cfg.budget_headroom, cfg.geopipe_credit_bdp_frac,
            cfg.sdr_window_bdp_frac, cfg.sdr_ack_coalesce_us,
            cfg.sdr_retx_budget_frac, cfg.loss_rate, cfg.loss_burst_len,
            cfg.jitter_us, cfg.flap_period_us, cfg.flap_depth,
            cfg.rdmacell_token_bucket_us, cfg.rdmacell_rob_limit_mb,
            cfg.slot_us, cfg.soft_temp))
        import numpy as np
        return cls(*scalars,
                   link_delay_us=jnp.asarray(
                       np.float32(cfg.path_delays_us())),
                   link_cap_gbps=jnp.asarray(
                       np.float32(cfg.path_caps_gbps())),
                   link_thresh_kb=jnp.asarray(
                       np.float32(cfg.path_pfc_kb())),
                   chan_schedule=jnp.asarray(cfg.schedule_array()),
                   chan_sched_dt_us=jnp.float32(
                       cfg.channel_schedule_dt_us),
                   fail_windows=jnp.asarray(cfg.failure_array()))

    def delay_steps(self, dt_us: float):
        """Traced step count of the long-haul delay (>= 1)."""
        import jax.numpy as jnp
        return jnp.maximum(
            jnp.round(self.one_way_delay_us / dt_us).astype(jnp.int32), 1)


def stack_net_params(cfgs: Sequence["NetConfig"]) -> NetParams:
    """Stack per-scenario params into one [B]-leading pytree for vmap."""
    import jax
    import jax.numpy as jnp
    lens = {c.schedule_len for c in cfgs}
    if len(lens) > 1:
        raise ValueError(
            f"stack_net_params: channel_schedule lengths differ across the "
            f"batch ({sorted(lens)}) — the [L, K, 3] schedule table is a "
            f"stacked traced leaf, so every scenario must carry the same "
            f"number of entries (pad shorter schedules)")
    wlens = {c.failure_len for c in cfgs}
    if len(wlens) > 1:
        raise ValueError(
            f"stack_net_params: failure_schedule window counts differ "
            f"across the batch ({sorted(wlens)}) — the [L, W, 2] outage "
            f"table is a stacked traced leaf, so every scenario must carry "
            f"the same number of windows (pad with no-op (0, 0) windows; "
            f"repro.netsim.failures.FailureSchedule does this)")
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[NetParams.of(c) for c in cfgs])


# NetConfig fields whose values reach the batched step ONLY through the
# traced NetParams leaves — free to vary per scenario. Every OTHER field is
# compile-time structure (dt/slot layout, DCQCN constants, ECN pmax, ...)
# and must be identical across a batch; ``batch_template`` resets the traced
# ones to the class defaults so two grids of equal shape share one compiled
# program.
NET_TRACED_FIELDS = ("distance_km", "num_otn_links", "link_gbps",
                     "dst_dc_gbps", "nic_gbps", "pfc_xoff_kb", "pfc_xon_kb",
                     "otn_buffer_bdp_frac", "ecn_kmin_kb", "ecn_kmax_kb",
                     "queue_thresh_kb", "budget_floor_mbps",
                     "budget_headroom", "geopipe_credit_bdp_frac",
                     "sdr_window_bdp_frac", "sdr_ack_coalesce_us",
                     "sdr_retx_budget_frac", "loss_rate", "loss_burst_len",
                     "jitter_us", "flap_period_us", "flap_depth",
                     "rdmacell_token_bucket_us", "rdmacell_rob_limit_mb",
                     "slot_us", "soft_temp",
                     "path_delay_scale", "path_cap_frac", "path_thresh_kb",
                     "channel_schedule", "channel_schedule_dt_us",
                     "failure_schedule")


def batch_template(cfgs: Sequence["NetConfig"]) -> "NetConfig":
    """The static template keying a batch's jit cache entry: the shared
    non-traced fields, with every NetParams-covered field reset to its
    class default (after the reset all batch members yield the same
    template, so any member serves). A non-traced field varying across the
    batch is an error: it would otherwise be silently overwritten by the
    template's value for every cell."""
    for fld in dataclasses.fields(NetConfig):
        if fld.name in NET_TRACED_FIELDS:
            continue
        vals = {getattr(c, fld.name) for c in cfgs}
        if len(vals) > 1:
            raise ValueError(
                f"simulate_batch: NetConfig.{fld.name} must be identical "
                f"across the batch (got {sorted(vals)}) — it is compile-time "
                f"structure, not a traced NetParams leaf")
    defaults = {f.name: f.default for f in dataclasses.fields(NetConfig)}
    return dataclasses.replace(
        cfgs[0], **{f: defaults[f] for f in NET_TRACED_FIELDS})


@dataclass(frozen=True)
class NetConfig:
    """MatchRDMA / netsim parameters. Defaults follow the paper's Fig. 3 setup."""

    # topology
    num_otn_links: int = 16
    link_gbps: float = 100.0              # per OTN link
    intra_dc_delay_us: float = 1.0        # one-way
    distance_km: float = 100.0            # inter-DC distance
    dst_dc_gbps: float = 400.0            # destination leaf capacity (shared w/ intra traffic)
    nic_gbps: float = 400.0               # server NIC line rate
    # multi-path long haul (docs/topology.md). ``num_paths`` is STATIC —
    # it fixes the [L] link-axis shape and keys the compile; at the default
    # 1 the engine takes the single-pipe path the goldens pin bit-for-bit.
    # The per-path tuples are traced values (length 0 or num_paths; () =
    # the symmetric default): delay multipliers on one_way_delay_us,
    # capacity fractions of otn_capacity_gbps (default: equal split), and
    # per-path dst-OTN PFC thresholds (default: pfc_xoff_kb).
    num_paths: int = 1
    path_delay_scale: tuple = ()
    path_cap_frac: tuple = ()
    path_thresh_kb: tuple = ()
    # multi-SITE graph (docs/sites.md). ``num_sites`` is STATIC; each of
    # the ``num_paths`` links is a directed site-pair EDGE: ``site_edges``
    # is () (= every link connects site 0 -> 1, the legacy single pair) or
    # a length-num_paths tuple of (src_site, dst_site) pairs. A flow only
    # sprays onto edges matching its (src_site, dst_site) endpoints
    # (``FlowSpec``); at the defaults the engine emits the identical
    # program it emitted before sites existed (goldens pin this).
    num_sites: int = 2
    site_edges: tuple = ()
    # trace-replay channel schedule (docs/channel-models.md): a recorded
    # per-edge impairment timeline for the ``trace_replay`` channel model.
    # () = no schedule, or a length-num_paths tuple of per-edge entry
    # tuples, each entry a (loss_frac, defer_frac, cap_frac) triple
    # covering ``channel_schedule_dt_us`` of simulated time (<= 0 = one
    # entry per dt_us step; the schedule loops past its end). The VALUES
    # are traced NetParams leaves; the entry count K is static shape.
    channel_schedule: tuple = ()
    channel_schedule_dt_us: float = 0.0
    # hard-failure schedule (docs/failures.md): link/site outage timelines
    # for the ``repro.netsim.failures`` subsystem. () = no failures, or a
    # length-num_paths tuple of per-edge window tuples, each window a
    # (down_at_us, up_at_us) pair during which that link is DEAD (zero
    # capacity, in-flight bytes dumped into the retransmit path). All
    # edges carry the same window count W (pad with no-op (0, 0) windows —
    # ``FailureSchedule`` builds/pads these). The window TIMES are traced
    # NetParams leaves; W is static shape keying the compile.
    failure_schedule: tuple = ()

    # simulation
    dt_us: float = 5.0                    # fluid integration step
    horizon_us: float = 100_000.0         # simulated time

    # DCQCN (values follow Zhu et al. SIGCOMM'15 conventions)
    ecn_kmin_kb: float = 200.0
    ecn_kmax_kb: float = 1600.0
    ecn_pmax: float = 0.2
    dcqcn_g: float = 1.0 / 256.0
    dcqcn_rai_mbps: float = 300.0         # additive increase
    dcqcn_hai_mbps: float = 1500.0        # hyper increase
    dcqcn_alpha_timer_us: float = 55.0
    dcqcn_rate_timer_us: float = 300.0    # rate-increase timer
    dcqcn_bytes_counter_mb: float = 10.0
    cnp_interval_us: float = 50.0         # min CNP spacing per flow
    min_rate_mbps: float = 100.0

    # PFC
    pfc_xoff_kb: float = 2048.0           # pause threshold (DC leaf switches)
    pfc_xon_kb: float = 1024.0
    # OTN nodes carry long-haul BDP: their PFC headroom scales with 2D
    otn_buffer_bdp_frac: float = 0.10     # xoff_otn = max(xoff, frac*C_otn*2D)

    # MatchRDMA controller
    slot_us: float = 100.0                # slot duration (Fig. 2e)
    slots_per_window: int = 8             # consecutive slots aggregated
    ack_delay_thresh_us: float = 20.0     # slot congestion classification
    cnp_freq_thresh: float = 0.5          # CNPs/slot threshold
    queue_thresh_kb: float = 256.0        # local dst-OTN backlog threshold
    stable_cv_thresh: float = 0.15        # coefficient-of-variation gate
    stable_weight: float = 4.0            # weight of stable recurrent windows
    jitter_weight: float = 1.0            # conservative weight of jittery slots
    budget_headroom: float = 0.98         # inject at <= headroom * estimated r_out
    budget_probe: float = 1.10            # clear-regime probe factor per ctrl window
    budget_floor_mbps: float = 500.0
    control_proc_slots: int = 1           # OTN processing delay (slots)

    # Related-work scheme knobs (traced NetParams leaves — sweep batch-wide).
    # GeoPipe-style lossless pipeline shaping: the source OTN may hold at
    # most frac x (2D x C_otn) bytes outstanding toward the destination
    # segment (credits return with one-way delay D; 1.0 is exactly
    # rate-sustaining at line rate). The default provisions the window
    # WITHIN the segment buffer (< otn_buffer_bdp_frac), so pacing stays
    # PFC-free: the credit gate, not a pause frame, is the backpressure.
    geopipe_credit_bdp_frac: float = 0.08
    # SDR-RDMA-style software-defined reliability: per-flow selective-repeat
    # receive window as a BDP fraction, receiver ACK-coalescing interval,
    # and the sender rate share reserved for repair traffic under loss
    # (scaled by the observed congestion level).
    sdr_window_bdp_frac: float = 1.0
    sdr_ack_coalesce_us: float = 50.0
    sdr_retx_budget_frac: float = 0.05
    # RDMACell-style flowcell spraying (traced NetParams leaves, consumed
    # only by the `rdmacell` scheme): per-link token-bucket depth in µs of
    # that link's line rate, and the destination reorder-buffer budget the
    # sender gate keeps occupancy under (docs/topology.md).
    rdmacell_token_bucket_us: float = 50.0
    rdmacell_rob_limit_mb: float = 64.0

    # Channel-impairment knobs (traced NetParams leaves — an impairment
    # grid sweeps batch-wide in one compiled program per scheme). Only
    # non-ideal channel models (repro.netsim.channel) consume them; the
    # defaults describe a perfect pipe, so the `ideal` channel and a zeroed
    # lossy channel are bit-identical.
    loss_rate: float = 0.0        # stationary fraction of long-haul bytes lost
    loss_burst_len: float = 1.0   # mean Gilbert–Elliott Bad dwell (steps);
                                  # 1.0 degenerates to i.i.d. Bernoulli
    jitter_us: float = 0.0        # mean stochastic extra one-way delay
    flap_period_us: float = 0.0   # OTN protection-switch period (0 = off)
    flap_depth: float = 0.0       # long-haul capacity cut inside a dip [0,1]
    channel_seed: int = 0         # static PRNG seed of the impairment draws
                                  # (counter-based: folded with the scan step)

    # Differentiable engine (docs/differentiable.md). ``soft_step`` is
    # STATIC structure: True swaps every knob-dependent hard select in the
    # step function for a sigmoid-tempered blend so jax.grad flows through
    # the scan; False emits the untouched hard jaxpr the goldens pin.
    # ``soft_temp`` is the traced temperature leaf (→ 0 recovers the hard
    # gates); ``remat_steps`` > 0 checkpoints the metrics-mode scan in
    # blocks of that many steps so reverse-mode AD over long horizons
    # stays in memory (0 = off; forward values are unchanged either way).
    soft_step: bool = False
    soft_temp: float = 1.0
    remat_steps: int = 0

    # Observability (docs/observability.md). Both STATIC — they size scan
    # carries, so they key the compile and must match across a batch.
    # ``event_ring_slots`` > 0 carries a bounded per-scenario event ring
    # through the scan (``trace_mode="window"`` only): discrete events
    # (PFC edges, threshold crossings, retx onset, failure entry/exit,
    # ``Scheme.emit_events``) are timestamped in O(E) device memory; 0 (the
    # default) emits the exact pre-obs jaxpr. ``trace_window_steps`` is the
    # ring length W of the windowed trace carry — ``trace_mode="window"``
    # keeps the LAST W steps of every trace key in O(W) memory.
    event_ring_slots: int = 0
    trace_window_steps: int = 256

    @property
    def one_way_delay_us(self) -> float:
        # 5 µs per km (paper: 1 km -> 5 µs ... 1000 km -> 5 ms)
        return 5.0 * self.distance_km

    @property
    def otn_capacity_gbps(self) -> float:
        return self.num_otn_links * self.link_gbps

    # -- per-path topology (the [L] link axis; L = num_paths, static) ------
    def _path_tuple(self, vals: tuple, default: float, what: str) -> tuple:
        if len(vals) not in (0, self.num_paths):
            raise ValueError(
                f"NetConfig.{what}: expected {self.num_paths} entries "
                f"(num_paths) or an empty tuple, got {len(vals)}")
        return tuple(float(v) for v in vals) if vals \
            else (default,) * self.num_paths

    def path_delays_us(self) -> tuple:
        """Per-path one-way delays (µs), length ``num_paths``."""
        scales = self._path_tuple(self.path_delay_scale, 1.0,
                                  "path_delay_scale")
        return tuple(self.one_way_delay_us * s for s in scales)

    def path_caps_gbps(self) -> tuple:
        """Per-path line capacities (Gbps); the default splits the
        aggregate OTN capacity equally, so L equal paths carry exactly the
        single pipe's total."""
        fracs = self._path_tuple(self.path_cap_frac, 1.0 / self.num_paths,
                                 "path_cap_frac")
        return tuple(self.otn_capacity_gbps * f for f in fracs)

    def path_pfc_kb(self) -> tuple:
        """Per-path dst-OTN PFC thresholds (KB; default pfc_xoff_kb)."""
        return self._path_tuple(self.path_thresh_kb, self.pfc_xoff_kb,
                                "path_thresh_kb")

    # -- multi-site graph (edges over the link axis; docs/sites.md) --------
    def edge_pairs(self) -> tuple:
        """Resolved per-link (src_site, dst_site) pairs, length
        ``num_paths``. The default () wires every link as the legacy
        0 -> 1 site pair. Validates the graph: site indices in range,
        no self-edges."""
        if self.num_sites < 2:
            raise ValueError(
                f"NetConfig.num_sites must be >= 2, got {self.num_sites}")
        if not self.site_edges:
            return ((0, 1),) * self.num_paths
        if len(self.site_edges) != self.num_paths:
            raise ValueError(
                f"NetConfig.site_edges: expected {self.num_paths} "
                f"(num_paths) directed (src, dst) pairs or an empty tuple, "
                f"got {len(self.site_edges)}")
        pairs = []
        for e in self.site_edges:
            if len(e) != 2:
                raise ValueError(
                    f"NetConfig.site_edges: each edge is a (src_site, "
                    f"dst_site) pair, got {e!r}")
            s, d = int(e[0]), int(e[1])
            if not (0 <= s < self.num_sites and 0 <= d < self.num_sites):
                raise ValueError(
                    f"NetConfig.site_edges: edge ({s}, {d}) references a "
                    f"site outside [0, {self.num_sites})")
            if s == d:
                raise ValueError(
                    f"NetConfig.site_edges: self-edge ({s}, {d}) — a link "
                    f"must connect two distinct sites")
            pairs.append((s, d))
        return tuple(pairs)

    @property
    def is_multisite(self) -> bool:
        """True when the config declares a genuine site graph (more than
        two sites, or explicit edge wiring). At False the engine takes the
        legacy single-pair path — bit-identical to the pre-sites
        programs the goldens pin."""
        return self.num_sites > 2 or bool(self.site_edges)

    # -- trace-replay schedule (docs/channel-models.md) --------------------
    @property
    def schedule_len(self) -> int:
        """Static entry count K of the channel schedule (0 = none).
        Validates the nested tuple: one per-edge timeline per link, all of
        equal length, each entry a (loss_frac, defer_frac, cap_frac)
        triple."""
        if not self.channel_schedule:
            return 0
        if len(self.channel_schedule) != self.num_paths:
            raise ValueError(
                f"NetConfig.channel_schedule: expected {self.num_paths} "
                f"(num_paths) per-edge timelines or an empty tuple, got "
                f"{len(self.channel_schedule)}")
        lens = {len(edge) for edge in self.channel_schedule}
        if len(lens) > 1:
            raise ValueError(
                f"NetConfig.channel_schedule: per-edge timelines differ in "
                f"length ({sorted(lens)}) — pad them to a common K")
        for edge in self.channel_schedule:
            for entry in edge:
                if len(entry) != 3:
                    raise ValueError(
                        f"NetConfig.channel_schedule: each entry is a "
                        f"(loss_frac, defer_frac, cap_frac) triple, got "
                        f"{entry!r}")
        return lens.pop() if lens else 0

    def schedule_array(self):
        """The schedule as an f32 [L, K, 3] numpy table (the traced
        ``NetParams.chan_schedule`` leaf; [L, 0, 3] when unset)."""
        import numpy as np
        k = self.schedule_len
        if k == 0:
            return np.zeros((self.num_paths, 0, 3), np.float32)
        return np.asarray(self.channel_schedule, np.float32)

    # -- failure schedule (docs/failures.md) -------------------------------
    @property
    def failure_len(self) -> int:
        """Static window count W of the failure schedule (0 = none).
        Validates the nested tuple: one per-edge window list per link, all
        of equal length, each window a (down_at_us, up_at_us) pair. A
        window with up <= down is a no-op (the padding convention)."""
        if not self.failure_schedule:
            return 0
        if len(self.failure_schedule) != self.num_paths:
            raise ValueError(
                f"NetConfig.failure_schedule: expected {self.num_paths} "
                f"(num_paths) per-edge window lists or an empty tuple, got "
                f"{len(self.failure_schedule)}")
        lens = {len(edge) for edge in self.failure_schedule}
        if len(lens) > 1:
            raise ValueError(
                f"NetConfig.failure_schedule: per-edge window lists differ "
                f"in length ({sorted(lens)}) — pad with no-op (0, 0) "
                f"windows to a common W (FailureSchedule does this)")
        for li, edge in enumerate(self.failure_schedule):
            for win in edge:
                if len(win) != 2:
                    raise ValueError(
                        f"NetConfig.failure_schedule: edge {li}: each "
                        f"window is a (down_at_us, up_at_us) pair, got "
                        f"{win!r}")
                d, u = float(win[0]), float(win[1])
                if d < 0.0 or u < 0.0:
                    raise ValueError(
                        f"NetConfig.failure_schedule: edge {li}: window "
                        f"times must be >= 0, got ({d}, {u})")
        return lens.pop() if lens else 0

    def failure_array(self):
        """The outage windows as an f32 [L, W, 2] numpy table (the traced
        ``NetParams.fail_windows`` leaf; [L, 0, 2] when unset)."""
        import numpy as np
        w = self.failure_len
        if w == 0:
            return np.zeros((self.num_paths, 0, 2), np.float32)
        return np.asarray(self.failure_schedule, np.float32)

    @property
    def control_proc_steps(self) -> int:
        """Control-subchannel OTN processing delay in fluid steps — the one
        definition every control channel (budget, credit grants) sizes its
        delay line with."""
        return int(self.control_proc_slots * self.slot_us / self.dt_us)

    @property
    def static_delay_steps(self) -> int:
        """STATIC one-way-delay step count — the one definition every
        delay-ring allocation shares. Uses the same f32 arithmetic as the
        traced ``NetParams.delay_steps`` so a static ring size can never
        undercut the traced wrap index (f64 here could round 3.4999...
        down where the f32 leaf rounds up — the ring would then be written
        through a clamped out-of-range index). With ``num_paths > 1`` this
        is the MAX over the per-path delays, so one ring allocation covers
        every link's wrap index."""
        import numpy as np
        return max(max(int(np.round(np.float32(d) / np.float32(self.dt_us)))
                       for d in self.path_delays_us()), 1)

    def horizon_steps(self, horizon_us: float = None) -> int:
        """Scan length for a horizon (default: this config's) — the single
        definition both ``simulate`` and ``simulate_batch`` size their scans
        (and warm-up cutoffs) with."""
        h = self.horizon_us if horizon_us is None else horizon_us
        return int(round(h / self.dt_us))

    def params(self) -> NetParams:
        """The traced per-scenario side of the static/traced split."""
        return NetParams.of(self)


# ---------------------------------------------------------------------------
# Run = everything
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    net: NetConfig = field(default_factory=NetConfig)

    def fingerprint(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape grid)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: Mapping[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long_decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
    if shape.kind == "long_decode":
        return model.subquadratic
    return True
