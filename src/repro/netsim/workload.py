"""Flow/workload specification for the netsim fluid simulator.

A workload is a set of flows with AICB-like on/off structure (LLM training
alternates compute and communication phases). Inter-DC flows traverse
sender NIC -> source OTN -> long-haul pipe -> destination OTN -> destination
leaf; intra-DC flows contend only at the destination leaf.

``WorkloadParams`` is the traced side of the workload axis — the twin of
``NetParams`` on the config axis. Its leaves are the stacked per-flow
arrays the step function reads, padded to a common flow count with an
``active_mask`` (padded flows never send, never complete, never count), so
``simulate_batch`` can ``jax.vmap`` over heterogeneous (config × workload)
scenario grids in one device launch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Sequence, Union

import numpy as np

BIG = 1e18  # "unbounded" total bytes (throughput experiments)


def is_unbounded(total_bytes):
    """True where ``total_bytes`` carries the BIG 'unbounded' sentinel.

    The one definition both metric paths compare against (works on numpy
    and jax arrays). f32-safe: a sentinel that round-tripped through f32
    still clears the BIG/2 threshold.
    """
    return total_bytes >= BIG / 2


@dataclass(frozen=True)
class FlowSpec:
    is_inter: bool
    msg_size: float            # bytes per message
    concurrency: int           # parallel in-flight messages
    total_bytes: float = BIG   # flow size (finite => FCT experiment)
    start_us: float = 0.0
    period_us: float = 0.0     # 0 => always-on; else AICB on/off period
    duty: float = 1.0          # fraction of the period spent communicating
    # per-link routing weights over the cfg.num_paths parallel long-haul
    # links (docs/topology.md). () = symmetric default (equal weight on
    # every link); a length-L tuple steers this flow's spray proportions.
    # Intra-DC flows never reach the long haul, so their row is unused.
    route: tuple = ()
    # endpoint sites on the cfg site graph (docs/sites.md). An inter-DC
    # flow only sprays onto links whose (src_site, dst_site) edge matches
    # its endpoints; the defaults name the legacy 0 -> 1 pair, so
    # single-pair workloads need not mention sites at all. Intra-DC flows
    # contend at dst_site's leaf; their src_site is unused.
    src_site: int = 0
    dst_site: int = 1

    @property
    def window(self) -> float:
        return self.msg_size * self.concurrency


class WorkloadParams(NamedTuple):
    """Traced per-scenario workload leaves (a jax pytree).

    Per-flow [F] arrays (or [B, F] once stacked for a batch). Padded flows
    carry ``active_mask == 0`` and zeroed fields: they never become active,
    contribute zero bytes to every queue/sum, and are excluded from the
    metric extractors (``is_inter == 0`` and ``total_bytes == 0``).
    """

    is_inter: np.ndarray         # f32 — 1.0 for inter-DC flows
    window: np.ndarray           # f32 — msg_size * concurrency (bytes)
    total_bytes: np.ndarray      # f32 — flow size (BIG = unbounded)
    start_us: np.ndarray         # f32
    period_us: np.ndarray        # f32 — 0 = always-on
    duty: np.ndarray             # f32
    active_mask: np.ndarray      # f32 — 0.0 marks batch-padding flows
    route: np.ndarray            # f32[..., F, L] — per-flow x per-link spray
                                 # weights (width 1 = the symmetric default,
                                 # broadcast to cfg.num_paths by the engine)
    src_site: np.ndarray         # f32 — source site index (docs/sites.md)
    dst_site: np.ndarray         # f32 — destination site index

    @classmethod
    def of(cls, workload: "Workload", pad_to: int = 0,
           link_pad: int = 0) -> "WorkloadParams":
        """Per-flow arrays for one workload, zero-padded to ``pad_to``
        flows (and the route leaf to ``link_pad`` links)."""
        a = workload.arrays()
        f = workload.num_flows
        pad = max(pad_to, f) - f

        def _p(x, fill=0.0):
            x = np.asarray(x, np.float32)
            return np.pad(x, (0, pad), constant_values=fill) if pad else x

        routes = [x.route for x in workload.flows]
        width = max(max((len(r) for r in routes), default=1),
                    link_pad, 1)
        # default row: equal weight everywhere. An explicit route shorter
        # than the widest pads with zero weight — the flow never sprays
        # onto links it did not name.
        route = np.ones((f, width), np.float32)
        for i, r in enumerate(routes):
            if r:
                row = np.zeros((width,), np.float32)
                row[:len(r)] = np.asarray(r, np.float32)
                route[i] = row
        if pad:
            route = np.pad(route, ((0, pad), (0, 0)))

        return cls(
            is_inter=_p(a["is_inter"]),
            window=_p(a["window"]),
            total_bytes=_p(a["total_bytes"]),
            start_us=_p(a["start_us"]),
            period_us=_p(a["period_us"]),
            duty=_p(a["duty"]),
            active_mask=_p(np.ones((f,), np.float32)),
            route=route,
            src_site=_p(a["src_site"]),
            dst_site=_p(a["dst_site"]),
        )

    @property
    def num_flows(self) -> int:
        return int(self.active_mask.shape[-1])

    @property
    def route_width(self) -> int:
        return int(self.route.shape[-1])


WorkloadLike = Union["Workload", WorkloadParams]


def stack_workload_params(workloads: Sequence["Workload"],
                          pad_to: int = 0) -> WorkloadParams:
    """Pad a workload grid to its max flow count and stack to [B, F] leaves
    — the workload-axis twin of ``config.base.stack_net_params``."""
    workloads = list(workloads)
    if not workloads:
        raise ValueError("stack_workload_params: empty workload batch")
    pad = max(pad_to, max(w.num_flows for w in workloads))
    link_pad = max(max((len(f.route) for f in w.flows), default=1)
                   for w in workloads)
    cells = [WorkloadParams.of(w, pad_to=pad, link_pad=link_pad)
             for w in workloads]
    return WorkloadParams(*(np.stack(leaves)
                            for leaves in zip(*cells)))


def as_workload_batch(workload, batch_size: int) -> WorkloadParams:
    """Normalize the workload argument of a batched run to [B, F] leaves.

    Accepts one shared ``Workload`` (replicated across the batch), a
    per-scenario sequence of ``Workload``s (padded + stacked), or an
    already-stacked ``WorkloadParams``.
    """
    if isinstance(workload, WorkloadParams):
        if workload.is_inter.ndim != 2 or \
                workload.is_inter.shape[0] != batch_size:
            raise ValueError(
                f"as_workload_batch: expected [B={batch_size}, F] stacked "
                f"WorkloadParams, got shape {workload.is_inter.shape}")
        return workload
    if isinstance(workload, Workload):
        workloads = [workload] * batch_size
    else:
        workloads = list(workload)
        if len(workloads) != batch_size:
            raise ValueError(
                f"as_workload_batch: {len(workloads)} workloads for "
                f"{batch_size} scenarios — pass one per scenario (or one "
                f"shared Workload)")
    return stack_workload_params(workloads)


@dataclass(frozen=True)
class Workload:
    flows: tuple

    @property
    def num_flows(self) -> int:
        return len(self.flows)

    def arrays(self) -> dict:
        """Stack flow fields into numpy arrays for the simulator."""
        f = self.flows
        return {
            "is_inter": np.array([x.is_inter for x in f], np.float32),
            "msg_size": np.array([x.msg_size for x in f], np.float32),
            "window": np.array([x.window for x in f], np.float32),
            "total_bytes": np.array([x.total_bytes for x in f], np.float32),
            "start_us": np.array([x.start_us for x in f], np.float32),
            "period_us": np.array([x.period_us for x in f], np.float32),
            "duty": np.array([x.duty for x in f], np.float32),
            "src_site": np.array([x.src_site for x in f], np.float32),
            "dst_site": np.array([x.dst_site for x in f], np.float32),
        }

    def params(self, pad_to: int = 0) -> WorkloadParams:
        """The traced per-scenario side of the workload axis."""
        return WorkloadParams.of(self, pad_to=pad_to)


def throughput_workload(msg_size: float, concurrency: int,
                        num_flows: int = 4) -> Workload:
    """Fig. 3(b): inter-DC flows only, unbounded bytes, always-on."""
    return Workload(tuple(
        FlowSpec(True, msg_size, concurrency) for _ in range(num_flows)))


def congestion_workload(msg_size: float = 1 << 20, concurrency: int = 16,
                        num_inter: int = 8, num_intra: int = 8,
                        burst_start_us: float = 20_000.0,
                        burst_len_us: float = 40_000.0,
                        horizon_us: float = 100_000.0) -> Workload:
    """Fig. 3(c,d): inter-DC load + an intra-DC burst that congests the
    destination leaf mid-run (the 'downstream forwarding temporarily slowed'
    scenario of Fig. 1)."""
    inter = [FlowSpec(True, msg_size, concurrency) for _ in range(num_inter)]
    intra = [FlowSpec(False, 256 << 10, 8,
                      start_us=burst_start_us,
                      period_us=horizon_us,
                      duty=burst_len_us / horizon_us)
             for _ in range(num_intra)]
    return Workload(tuple(inter + intra))


def mixed_fct_workload(msg_size: float, num_inter: int = 8,
                       num_intra: int = 8, messages_per_flow: int = 4,
                       concurrency: int = 4, num_background: int = 4,
                       request_start_us: float = 30_000.0) -> Workload:
    """Fig. 3(e): mixed-traffic scenario. Continuous inter-DC LLM training
    traffic (background) + finite inter-DC transfers (the measured
    'communication requests') + steady intra-DC traffic sharing the
    destination leaf. Metric = average completion time of the finite
    inter-DC flows."""
    background = [FlowSpec(True, 1 << 20, 16) for _ in range(num_background)]
    inter = [FlowSpec(True, msg_size, concurrency,
                      total_bytes=msg_size * messages_per_flow * concurrency,
                      start_us=request_start_us + 100.0 * i)
             for i in range(num_inter)]
    intra = [FlowSpec(False, 64 << 10, 8) for _ in range(num_intra)]
    return Workload(tuple(background + inter + intra))


def aicb_workload(comm_bytes_per_iter: float, iter_us: float,
                  comm_frac: float, num_flows: int, msg_size: float,
                  concurrency: int = 16, jitter: float = 0.0,
                  seed: int = 0) -> Workload:
    """LLM-training traffic from the AICB-like analytic model
    (repro.traffic): each iteration sends ``comm_bytes_per_iter`` during a
    comm phase lasting ``comm_frac``·iter. Optional per-flow phase jitter."""
    rng = np.random.default_rng(seed)
    flows = []
    for i in range(num_flows):
        start = float(rng.uniform(0, jitter * iter_us)) if jitter else 0.0
        flows.append(FlowSpec(True, msg_size, concurrency,
                              start_us=start, period_us=iter_us,
                              duty=comm_frac))
    return Workload(tuple(flows))
