"""The shipped channel models: ideal, bernoulli_loss, jitter, otn_flap,
and their composite ``impaired``.

One implementation (``ImpairedChannel``) carries all three impairment
mechanisms behind STATIC enable flags; the registered names are instances
with different flags, so each named model compiles only the machinery it
uses while a loss x jitter grid can run the composite in ONE compiled
program (the knob VALUES are traced ``NetParams`` leaves — see
``config.base.NET_TRACED_FIELDS``).

  ``ideal``           the perfect pipe (the default — bit-identical to the
                      pre-channel engine; the base-class hooks).
  ``bernoulli_loss``  byte loss on the inter-DC segment: a per-flow
                      Gilbert–Elliott two-state chain whose Bad state drops
                      the step's arrivals. Stationary loss = ``loss_rate``;
                      mean Bad dwell = ``loss_burst_len`` steps
                      (``loss_burst_len = 1`` degenerates to i.i.d.
                      Bernoulli whole-step drops — hence the name).
  ``jitter``          stochastic delay perturbation: a random fraction of
                      each step's arrivals is held back in a per-flow
                      deferral buffer (geometric holding, mean extra delay
                      = ``jitter_us``), reordering/smearing the arrival
                      process within the padded delay ring.
  ``otn_flap``        OTN protection switching: periodic capacity dips on
                      the long-haul line — every ``flap_period_us`` the
                      line capacity drops by ``flap_depth`` for a
                      ``FLAP_DUTY`` fraction of the period, at a
                      per-scenario random phase.
  ``impaired``        all three composed (loss -> jitter on the arrival
                      side, flap on the capacity side) — the model
                      impairment grids sweep.

Determinism: every draw is counter-based — the key is
``fold_in(scenario_key(PRNGKey(channel_seed), params), t)`` — so
a run is reproducible, resume-safe inside ``lax.scan``, identical across
trace modes, and shares its noise realization across schemes (common
random numbers: scheme comparisons at equal impairments are paired).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig, NetParams
from repro.netsim.channel.base import (
    ChannelEffects, ChannelInputs, ChannelModel, register_channel_model,
)
from repro.netsim.soft import lerp, soft_gt, soft_pos

# fraction of a flap period spent in the dip (the protection-switch hit)
FLAP_DUTY = 0.1


def scenario_key(key: jax.Array, params: NetParams) -> jax.Array:
    """Fold the traced per-scenario knob bits into ``key`` one field at a
    time: scenarios with different impairment knobs (or distances) draw
    decorrelated noise inside one vmapped batch, while identical scenarios
    reproduce identical realizations. Sequential fold_in (not an XOR of
    the bits) so cells whose knob VALUES are merely permuted across
    fields — loss_burst_len=4, jitter_us=25 vs loss_burst_len=25,
    jitter_us=4 — still land on independent streams."""
    for x in (params.loss_rate, params.loss_burst_len, params.jitter_us,
              params.flap_period_us, params.flap_depth,
              params.one_way_delay_us):
        key = jax.random.fold_in(
            key, jax.lax.bitcast_convert_type(jnp.float32(x), jnp.uint32))
    return key


class ImpairState(NamedTuple):
    """Private carry of ``ImpairedChannel`` (disabled parts are ``None``)."""
    bad: Optional[jax.Array]     # [F] Gilbert–Elliott Bad-state indicator
    defer: Optional[jax.Array]   # [F] jitter-held bytes awaiting release
    phase: Optional[jax.Array]   # scalar — random flap phase in [0, 1)


@register_channel_model("ideal")
class IdealChannel(ChannelModel):
    """Today's behavior: the long haul is a perfect pipe. The engine
    structurally skips all channel machinery for ``is_ideal`` models, so
    this model pins the pre-channel bit-identical path."""
    is_ideal = True


class ImpairedChannel(ChannelModel):
    """Gilbert–Elliott loss + stochastic jitter + OTN flap dips behind
    static enable flags (see the module docstring for each mechanism)."""

    is_ideal = False

    def __init__(self, loss: bool = True, jitter: bool = True,
                 flap: bool = True):
        self.loss, self.jitter, self.flap = bool(loss), bool(jitter), bool(flap)
        super().__init__()

    def init_channel_state(self, cfg: NetConfig, params: NetParams,
                           num_flows: int, key: jax.Array, link: int = 0):
        z = jnp.zeros((num_flows,), jnp.float32)
        phase = None
        if self.flap:
            k = jax.random.fold_in(key, 0xF1A9)  # static-per-run draw
            phase = jax.random.uniform(k, (), jnp.float32)
        return ImpairState(bad=z if self.loss else None,
                           defer=z if self.jitter else None,
                           phase=phase)

    def apply_impairments(self, ctx, chan: ImpairState,
                          inp: ChannelInputs) -> ChannelEffects:
        p = ctx.params
        arrivals, cap_src = inp.pipe_out, inp.cap_src
        lost = jnp.zeros_like(arrivals)
        bad, defer = chan.bad, chan.defer

        # Every impairment joins the engine's dataflow through a where()
        # whose not-impaired branch returns the ORIGINAL tensor: with the
        # knobs at zero, the select yields the bit-exact pass-through
        # values no matter how XLA fuses the impaired branch (the
        # zero-impairment identity test pins this).
        if self.loss:
            # Gilbert–Elliott: exit Bad w.p. 1/L, enter Bad so the
            # stationary Bad fraction equals loss_rate. L=1 => i.i.d.
            r = jnp.clip(p.loss_rate, 0.0, 0.5)
            p_exit = 1.0 / jnp.maximum(p.loss_burst_len, 1.0)
            p_enter = jnp.clip(p_exit * r / jnp.maximum(1.0 - r, 0.5), 0.0, 1.0)
            u = jax.random.uniform(jax.random.fold_in(inp.key, 0),
                                   arrivals.shape, jnp.float32)
            if ctx.soft is None:
                in_bad = jnp.where(chan.bad > 0.5,
                                   u < 1.0 - p_exit, u < p_enter)
                bad = in_bad.astype(jnp.float32)
                lost = jnp.where(in_bad, arrivals, 0.0)  # Bad drops the step
                arrivals = jnp.where(in_bad, 0.0, arrivals)
            else:
                # tempered chain: the u-vs-probability comparisons become
                # sigmoids (grads flow into loss_rate / loss_burst_len)
                # blended by the previous fractional Bad weight
                w_bad = lerp(soft_gt(chan.bad, 0.5, ctx.soft, 0.25),
                             soft_gt(1.0 - p_exit, u, ctx.soft, 0.05),
                             soft_gt(p_enter, u, ctx.soft, 0.05))
                bad = w_bad
                lost = w_bad * arrivals
                arrivals = (1.0 - w_bad) * arrivals

        if self.jitter:
            # geometric holding with mean extra delay jitter_us: each step
            # a random fraction (mean p_hold) of the incoming fluid defers
            # to later steps; E[extra delay] = p/(1-p) * dt = jitter_us
            p_hold = p.jitter_us / jnp.maximum(p.jitter_us + ctx.dt_us, 1.0)
            v = jax.random.uniform(jax.random.fold_in(inp.key, 1),
                                   arrivals.shape, jnp.float32)
            income = arrivals + chan.defer
            held = jnp.where(p_hold > 0.0,
                             income * jnp.clip(2.0 * v * p_hold, 0.0, 0.95),
                             0.0)
            arrivals = jnp.where(p_hold > 0.0, income - held, arrivals)
            defer = held

        if self.flap:
            # protection-switch dips: a FLAP_DUTY-long capacity cut every
            # flap_period_us, at this scenario's random phase
            period = p.flap_period_us
            pos = jnp.mod(inp.t.astype(jnp.float32) * ctx.dt_us
                          / jnp.maximum(period, ctx.dt_us) + chan.phase, 1.0)
            dipped = cap_src * (1.0 - jnp.clip(p.flap_depth, 0.0, 1.0))
            if ctx.soft is None:
                in_dip = (pos < FLAP_DUTY) & (period > 0)
                cap_src = jnp.where(in_dip, dipped, cap_src)
            else:
                # flap_depth grads flow through the lerp; the dip PHASE
                # keeps a mod()-jump in knob space (flap_period_us is
                # finiteness-only in the FD battery — docs/differentiable.md)
                w_dip = (soft_gt(FLAP_DUTY, pos, ctx.soft, 0.05)
                         * soft_pos(period, ctx.soft, ctx.dt_us))
                cap_src = lerp(w_dip, dipped, cap_src)

        return ChannelEffects(arrivals=arrivals, lost=lost, cap_src=cap_src,
                              chan=ImpairState(bad=bad, defer=defer,
                                               phase=chan.phase))

    def held_bytes(self, chan: ImpairState) -> jax.Array:
        return chan.defer if self.jitter else jnp.float32(0.0)


register_channel_model("bernoulli_loss",
                       ImpairedChannel(loss=True, jitter=False, flap=False))
register_channel_model("jitter",
                       ImpairedChannel(loss=False, jitter=True, flap=False))
register_channel_model("otn_flap",
                       ImpairedChannel(loss=False, jitter=False, flap=True))
register_channel_model("impaired", ImpairedChannel())
