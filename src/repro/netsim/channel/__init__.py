"""Registry-backed channel-model package: stochastic long-haul impairments.

    from repro.netsim.channel import get_channel_model, register_channel_model

    ch = get_channel_model("bernoulli_loss")   # resolve a registered name

    @register_channel_model("my_channel")      # add one — no fluid.py edits
    class MyChannel(ChannelModel):
        ...

Six models ship registered: ``ideal`` (the default — the perfect pipe the
engine always modeled, structurally bit-identical), ``bernoulli_loss``
(i.i.d. + Gilbert–Elliott bursty loss), ``jitter`` (stochastic delay
perturbation), ``otn_flap`` (OTN protection-switch capacity dips),
``impaired`` (their composite, for joint impairment grids) and
``trace_replay`` (deterministic replay of a recorded per-edge impairment
schedule — ``replay.py``). ``CHANNEL_MODELS`` is the stable builtin
tuple; the registry may grow beyond it.

See ``base.py`` for the hook contract and ``docs/channel-models.md`` for
the authoritative reference.
"""
from repro.netsim.channel.base import (
    ChannelEffects, ChannelInputs, ChannelLike, ChannelModel,
    available_channel_models, get_channel_model, register_channel_model,
    unregister_channel_model,
)
from repro.netsim.channel.models import (
    FLAP_DUTY, IdealChannel, ImpairState, ImpairedChannel, scenario_key,
)
from repro.netsim.channel.replay import (
    ReplayState, TraceReplayChannel, load_schedule_json, save_schedule_json,
    schedule_from_arrays,
)

# The stable builtin tuple (tests/benchmarks/docs iterate it); the registry
# may grow beyond it.
CHANNEL_MODELS = ("ideal", "bernoulli_loss", "jitter", "otn_flap",
                  "impaired", "trace_replay")

__all__ = [
    "CHANNEL_MODELS", "ChannelEffects", "ChannelInputs", "ChannelLike",
    "ChannelModel", "FLAP_DUTY", "IdealChannel", "ImpairState",
    "ImpairedChannel", "ReplayState", "TraceReplayChannel",
    "available_channel_models", "get_channel_model", "load_schedule_json",
    "register_channel_model", "save_schedule_json", "scenario_key",
    "schedule_from_arrays", "unregister_channel_model",
]
