"""``trace_replay`` — deterministic replay of recorded OTN telemetry.

Where the stochastic models (``models.py``) *draw* impairments from traced
knobs, ``trace_replay`` *replays* a measured per-edge impairment timeline:
a ``[L, K, 3]`` schedule table (one row of ``(loss_frac, defer_frac,
cap_frac)`` per edge per schedule slot) rides in as the traced
``NetParams.chan_schedule`` leaf, and each scan step indexes its slot by
simulated time. No PRNG anywhere — the same schedule replays the same
realization bit-for-bit, across trace modes, schemes, and runs (pinned by
``tests/test_trace_replay.py``).

Schedule semantics per entry (all fractions of THIS step's quantities):

  ``loss_frac``   in [0, 1] — fraction of the bytes leaving the pipe this
                  step that drop (enter the engine's loss-repair path).
  ``defer_frac``  in [0, 0.95] — fraction of the incoming fluid (this
                  step's arrivals + previously deferred bytes) held back
                  to later steps (delay jitter as measured).
  ``cap_frac``    in [0, 1] — surviving fraction of the source-OTN line
                  capacity (OTN protection-switch dips as measured).

Each entry covers ``channel_schedule_dt_us`` of simulated time (``<= 0``
= one entry per ``dt_us`` step); the schedule loops past its end, so a
short recorded trace periodically tiles a long horizon. An entry of
``(0, 0, 1)`` is the bit-exact pass-through (every impairment joins the
dataflow through a ``where()`` whose clean branch returns the ORIGINAL
tensor — the engine-wide zero-impairment identity rule), and a config
with no schedule at all (``channel_schedule=()``) makes the whole model a
structural pass-through.

The schedule VALUES are traced — a grid over recorded traces of equal
length K compiles once per scheme; K itself is static shape
(``NetConfig.schedule_len``). I/O helpers at the bottom round-trip
schedules through a plain JSON format (see ``docs/channel-models.md``).
"""
from __future__ import annotations

import json
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams
from repro.netsim.channel.base import (
    ChannelEffects, ChannelInputs, ChannelModel, register_channel_model,
)

__all__ = [
    "ReplayState", "TraceReplayChannel", "load_schedule_json",
    "save_schedule_json", "schedule_from_arrays",
]


class ReplayState(NamedTuple):
    """Private carry of ``TraceReplayChannel``."""
    sched: jax.Array   # f32[K, 3] this link's (loss, defer, cap) timeline
    defer: jax.Array   # f32[F] deferred bytes awaiting release


@register_channel_model("trace_replay")
class TraceReplayChannel(ChannelModel):
    """Replay a recorded per-edge impairment schedule (module docstring)."""

    is_ideal = False

    def init_channel_state(self, cfg: NetConfig, params: NetParams,
                           num_flows: int, key: jax.Array, link: int = 0):
        # the per-link slice of the [L, K, 3] table travels in the carry
        # so apply_impairments needs no link index of its own (at L > 1
        # the engine vmaps this over link = 0..L-1)
        return ReplayState(sched=jnp.asarray(params.chan_schedule)[link],
                           defer=jnp.zeros((num_flows,), jnp.float32))

    def apply_impairments(self, ctx, chan: ReplayState,
                          inp: ChannelInputs) -> ChannelEffects:
        k = int(chan.sched.shape[0])          # STATIC schedule length
        if k == 0:
            # no schedule: structurally the perfect pipe (the engine's
            # repair machinery still exists but never sees a byte)
            return ChannelEffects(arrivals=inp.pipe_out,
                                  lost=jnp.zeros_like(inp.pipe_out),
                                  cap_src=inp.cap_src, chan=chan)
        arrivals, cap_src = inp.pipe_out, inp.cap_src
        # schedule slot: floor(simulated time / entry duration), looping.
        # entry duration <= 0 means one entry per dt_us step.
        sdt = jnp.asarray(ctx.params.chan_sched_dt_us, jnp.float32)
        entry_us = jnp.where(sdt > 0.0, sdt, jnp.float32(ctx.dt_us))
        t_us = inp.t.astype(jnp.float32) * ctx.dt_us
        idx = jnp.mod(jnp.floor(t_us / entry_us).astype(jnp.int32), k)
        row = chan.sched[idx]                                   # [3]
        loss_f = jnp.clip(row[0], 0.0, 1.0)
        defer_f = jnp.clip(row[1], 0.0, 0.95)
        cap_f = jnp.clip(row[2], 0.0, 1.0)

        # Every impairment joins the dataflow through a where() whose
        # clean branch returns the ORIGINAL tensor (the zero-impairment
        # bit-identity rule shared with models.py).
        lost = jnp.where(loss_f > 0.0, arrivals * loss_f, 0.0)
        arrivals = jnp.where(loss_f > 0.0, arrivals - lost, arrivals)

        # deferral buffer with release: previously held bytes re-enter the
        # income; at defer_frac == 0 everything held is released in full
        release = chan.defer
        income = arrivals + release
        held = jnp.where(defer_f > 0.0, income * defer_f, 0.0)
        arrivals = jnp.where((defer_f > 0.0) | (release > 0.0),
                             income - held, arrivals)

        cap_src = jnp.where(cap_f < 1.0, cap_src * cap_f, cap_src)
        return ChannelEffects(arrivals=arrivals, lost=lost, cap_src=cap_src,
                              chan=ReplayState(sched=chan.sched, defer=held))

    def held_bytes(self, chan: ReplayState) -> jax.Array:
        return chan.defer


# ---------------------------------------------------------------------------
# Schedule I/O — plain JSON round-trip of recorded telemetry
# ---------------------------------------------------------------------------

def schedule_from_arrays(loss, defer=None, cap=None) -> tuple:
    """Build one edge's schedule tuple from per-slot sequences.

    ``loss``/``defer``/``cap`` are equal-length sequences (``None`` =
    zeros for loss/defer, ones for cap). Returns the per-edge entry tuple
    that slots into ``NetConfig.channel_schedule``.
    """
    loss = np.asarray(loss, np.float32)
    k = loss.shape[0]
    defer = (np.zeros(k, np.float32) if defer is None
             else np.asarray(defer, np.float32))
    cap = (np.ones(k, np.float32) if cap is None
           else np.asarray(cap, np.float32))
    if defer.shape[0] != k or cap.shape[0] != k:
        raise ValueError(
            f"schedule_from_arrays: loss/defer/cap lengths differ "
            f"({k}, {defer.shape[0]}, {cap.shape[0]})")
    return tuple((float(l), float(d), float(c))
                 for l, d, c in zip(loss, defer, cap))


def load_schedule_json(path) -> tuple:
    """Load a recorded schedule file -> ``(channel_schedule, dt_us)``
    ready for ``NetConfig`` (see ``docs/channel-models.md`` for the
    format).

    Malformed timelines fail HERE, naming the offending edge — not three
    layers later as an opaque shape error when ``NetConfig.schedule_len``
    stacks the table: every edge must carry equal-length numeric
    ``loss``/``defer``/``cap`` sequences, and all edges must share one
    schedule length (the [L, K, 3] table is rectangular)."""
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    if not isinstance(doc, dict):
        raise ValueError(
            f"load_schedule_json: {path}: expected a JSON object with an "
            f"'edges' list, got {type(doc).__name__}")
    edges = []
    for i, e in enumerate(doc.get("edges", [])):
        if not isinstance(e, dict):
            raise ValueError(
                f"load_schedule_json: {path}: edge {i} must be an object "
                f"with 'loss'/'defer'/'cap' lists, got {type(e).__name__}")
        try:
            edges.append(schedule_from_arrays(
                e.get("loss", ()), e.get("defer"), e.get("cap")))
        except ValueError as err:
            # schedule_from_arrays reports the ragged lengths; name the
            # edge that carried them
            raise ValueError(
                f"load_schedule_json: {path}: edge {i} has a malformed "
                f"timeline: {err}") from err
        except TypeError as err:  # non-numeric entries
            raise ValueError(
                f"load_schedule_json: {path}: edge {i} has non-numeric "
                f"timeline entries: {err}") from err
        if i > 0 and len(edges[i]) != len(edges[0]):
            raise ValueError(
                f"load_schedule_json: {path}: edge {i} has {len(edges[i])} "
                f"schedule entries but edge 0 has {len(edges[0])} — all "
                f"edges of a schedule must share one length (pad short "
                f"edges with (0, 0, 1) pass-through entries)")
    return tuple(edges), float(doc.get("dt_us", 0.0))


def save_schedule_json(path, channel_schedule, dt_us: float = 0.0,
                       note: Optional[str] = None) -> None:
    """Write a ``NetConfig.channel_schedule`` tuple back to the JSON
    format ``load_schedule_json`` reads."""
    sched = np.asarray(channel_schedule, np.float32)
    if sched.ndim != 3 or sched.shape[-1] != 3:
        raise ValueError(
            f"save_schedule_json: expected an [L, K, 3] schedule, got "
            f"shape {sched.shape}")
    doc = {"dt_us": float(dt_us),
           "edges": [{"loss": e[:, 0].tolist(),
                      "defer": e[:, 1].tolist(),
                      "cap": e[:, 2].tolist()} for e in sched]}
    if note:
        doc["note"] = note
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")
