"""The pluggable long-haul channel-model interface + registry.

A *channel model* is the stochastic physics of the long haul — what the
inter-DC segment does to bytes in flight that no control scheme can decide
away: traced loss (i.i.d. + Gilbert–Elliott bursts), stochastic delay
jitter, and OTN protection-switch capacity dips. ``fluid.make_step_fn``
gains exactly one channel hook point (between the pipe exit and the
destination OTN, plus a capacity tap on the source-OTN line); everything
model-specific lives in a ``ChannelModel`` subclass registered under a
name, mirroring the Scheme API:

    from repro.netsim.channel import ChannelModel, register_channel_model

    @register_channel_model("my_channel")
    class MyChannel(ChannelModel):
        def apply_impairments(self, ctx, chan, inp):
            ...

Five models ship registered (``ideal`` — the default, today's perfect
pipe — plus ``bernoulli_loss``, ``jitter``, ``otn_flap`` and the composite
``impaired``; see ``models.py``). Registered names are usable from every
engine entrypoint via the ``channel=`` argument of ``simulate`` /
``simulate_batch`` / ``run_experiment[_batch]`` / ``sweep`` /
``sweep_grid``.

Division of labour with the engine (who owns what):

  * The MODEL owns the impairment draw: which bytes drop, which bytes are
    held back, how much line capacity survives a flap — updated through
    its private ``chan`` pytree. All randomness is counter-based
    (``jax.random`` keys folded from the scan step + a per-scenario salt),
    so runs are deterministic, resume-safe inside ``lax.scan``, and use
    common random numbers across schemes (paired comparisons).
  * The ENGINE owns reliability accounting: lost bytes travel back on a
    loss-notification ring (one-way delay D), enter a per-flow retransmit
    backlog at the source, and are re-injected with priority over new data
    at the rate the scheme's ``retx_rate`` hook grants — so schemes
    compete on repair latency, not on bookkeeping. The engine also emits
    the ``chan_*`` trace keys the metric hooks below reduce.

Hook contract (all jnp expressions; traced under vmap over scenarios):

  ``init_channel_state``   model-private pytree carried in ``SimState.chan``
                           (``None`` = stateless model).
  ``apply_impairments``    the per-step transform: consumes the bytes
                           leaving the pipe + this step's source-OTN
                           capacity, returns what actually arrives, what
                           was lost, the (possibly dimmed) capacity and the
                           updated private state.
  ``held_bytes``           [F] bytes the model is currently holding between
                           the pipe and the destination OTN (jitter
                           buffers) — folded into the engine's per-flow
                           conservation residual so impairments cannot
                           silently create or destroy bytes.

Streaming-metric hooks (``trace_mode="metrics"`` — mirror the Scheme
hooks; the accumulator rides in ``MetricAcc.chan``):

  ``init_metric_acc``      channel-private accumulator pytree.
  ``accumulate_metrics``   per-step in-scan reduction over the engine's
                           ``chan_*`` trace keys.
  ``finalize_metrics``     host-side (numpy) conversion into named per-cell
                           metric columns (``goodput_gbps``, ``retx_frac``,
                           ``p99_repair_latency_us``).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams
from repro.netsim.streaming import (
    HIST_BINS, hist_bin_index, hist_quantile, kahan_add,
)


class ChannelInputs(NamedTuple):
    """What the step skeleton hands ``apply_impairments`` each step."""
    t: jax.Array          # step index (i32)
    key: jax.Array        # counter-based PRNG key for THIS step (folded
                          # from channel_seed, a per-scenario salt, and t)
    pipe_out: jax.Array   # [F] bytes leaving the long-haul pipe this step
    cap_src: jax.Array    # scalar — source-OTN line capacity this step
                          # (bytes; already zeroed while long-haul PFC
                          # pauses the source)


class ChannelEffects(NamedTuple):
    """What ``apply_impairments`` returns to the skeleton."""
    arrivals: jax.Array   # [F] bytes actually entering the destination OTN
    lost: jax.Array       # [F] bytes dropped (enter the loss-repair path)
    cap_src: jax.Array    # scalar — possibly dimmed source-OTN capacity
    chan: object          # the model's updated private pytree


class ChannelModel:
    """Default hooks = the ideal channel (pass everything through).

    Subclasses that impair must set ``is_ideal = False`` — the engine
    structurally skips ALL channel machinery (no PRNG, no retransmit
    backlog, no ``chan_*`` trace keys) when the model declares itself
    ideal, which is what keeps the default path bit-identical to the
    pre-channel engine.
    """

    name: Optional[str] = None
    is_ideal: bool = True

    def __init__(self):
        if self.name is None:
            self.name = type(self).__name__

    # Value semantics mirror Scheme: channel instances are jit static args,
    # so two equivalent instances must share one compiled scan. Keep model
    # attributes plain comparable config values.
    def __eq__(self, other):
        return type(self) is type(other) and self.__dict__ == other.__dict__

    def __hash__(self):
        return hash((type(self), self.name))

    # -- construction-time hook (runs at trace time, not per step) ---------
    def init_channel_state(self, cfg: NetConfig, params: NetParams,
                           num_flows: int, key: jax.Array, link: int = 0):
        """Model-private pytree carried through the scan in
        ``SimState.chan`` (``None`` = stateless). ``key`` is the run's base
        PRNG key — draw static-per-run randomness (flap phases) here.
        ``link`` is the link-axis index this per-link state instance
        serves (always 0 at ``num_paths == 1``; models that don't care may
        ignore it — the engine only passes it to signatures that accept
        it, so pre-existing models keep working unchanged)."""
        return None

    # -- per-step hooks ----------------------------------------------------
    def apply_impairments(self, ctx, chan, inp: ChannelInputs
                          ) -> ChannelEffects:
        """The single per-step transform of the long haul. Default: the
        perfect pipe — everything arrives, nothing drops, capacity
        untouched. ``ctx`` is the run's ``SchemeCtx`` (traced impairment
        knobs live on ``ctx.params``)."""
        return ChannelEffects(arrivals=inp.pipe_out,
                              lost=jnp.zeros_like(inp.pipe_out),
                              cap_src=inp.cap_src, chan=chan)

    def held_bytes(self, chan) -> jax.Array:
        """[F] bytes the model holds between pipe and destination OTN
        (jitter buffers). Folded into the conservation residual."""
        return jnp.float32(0.0)

    # -- streaming-metric hooks (trace_mode="metrics") ---------------------
    def init_metric_acc(self, ctx, state) -> dict:
        """Channel-private streaming accumulator (a dict pytree so
        subclasses can merge ``super()``'s entries). The default reduces
        the engine-emitted ``chan_*`` keys: Kahan sums of wire / lost /
        retransmit bytes plus a log-histogram of the per-step repair-wait
        estimate — enough for every shipped impairment model."""
        z = jnp.float32(0.0)
        return {"wire_s": z, "wire_c": z, "lost_s": z, "lost_c": z,
                "retx_s": z, "retx_c": z,
                "repair_hist": jnp.zeros((HIST_BINS,), jnp.int32)}

    def accumulate_metrics(self, ctx, acc: dict, state, out: dict,
                           inc: jax.Array) -> dict:
        """Fold one step into the accumulator. ``out`` is the step's trace
        dict (the engine's ``chan_*`` keys included), ``inc`` is 1.0 past
        the warm-up cutoff. Repair-wait samples only count on steps where a
        repair is actually pending (``out["chan_repair_wait_us"] > 0``)."""
        acc = dict(acc)
        for k, key in (("wire", "chan_wire"), ("lost", "chan_lost"),
                       ("retx", "chan_retx")):
            acc[k + "_s"], acc[k + "_c"] = kahan_add(
                acc[k + "_s"], acc[k + "_c"], out[key] * inc)
        wait = out["chan_repair_wait_us"]
        acc["repair_hist"] = acc["repair_hist"].at[hist_bin_index(wait)].add(
            (inc * (wait > 0)).astype(jnp.int32))
        return acc

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int,
                         dt_s: float) -> dict:
        """Host-side: numpy-ified accumulator leaves ([B]-leading) -> the
        channel metric columns merged into every sweep row."""
        wire = np.asarray(acc["wire_s"], np.float64)
        lost = np.asarray(acc["lost_s"], np.float64)
        retx = np.asarray(acc["retx_s"], np.float64)
        per_s = 1.0 / (max(n_warm, 1) * dt_s)
        return {
            # unique bytes surviving the long haul (wire minus drops)
            "goodput_gbps": (wire - lost) * per_s * 8.0 / 1e9,
            # long-haul wire throughput incl. repair traversals
            "wire_gbps": wire * per_s * 8.0 / 1e9,
            # fraction of long-haul traffic that is repair
            "retx_frac": retx / np.maximum(wire, 1.0),
            "p99_repair_latency_us": hist_quantile(acc["repair_hist"], 0.99),
        }

    def __repr__(self):
        return f"<ChannelModel {self.name or type(self).__name__}>"


# ---------------------------------------------------------------------------
# Registry (mirrors repro.netsim.schemes.base)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ChannelModel] = {}

ChannelLike = Union[str, ChannelModel, None]


def register_channel_model(name: str, model=None, *, override: bool = False):
    """Register a ``ChannelModel`` subclass (or instance) under ``name``.

    Usable as a decorator or called directly. Registration makes the name
    resolvable by every netsim entrypoint's ``channel=`` argument.
    Re-registering a taken name raises unless ``override=True``.
    """
    def _register(obj):
        inst = obj() if isinstance(obj, type) else obj
        if not isinstance(inst, ChannelModel):
            raise TypeError(
                f"register_channel_model({name!r}): expected a ChannelModel "
                f"subclass or instance, got {type(inst).__name__}")
        if not override and name in _REGISTRY:
            raise ValueError(
                f"channel model {name!r} is already registered "
                f"({_REGISTRY[name]!r}); pass override=True to replace it")
        inst.name = name
        _REGISTRY[name] = inst
        return obj

    if model is None:
        return _register
    _register(model)
    return _REGISTRY[name]


def unregister_channel_model(name: str) -> None:
    """Remove a registered channel model (mainly for tests)."""
    _REGISTRY.pop(name, None)


def get_channel_model(channel: ChannelLike) -> ChannelModel:
    """Resolve a channel-model name (``None`` = ``"ideal"``; instances pass
    through untouched)."""
    if channel is None:
        channel = "ideal"
    if isinstance(channel, ChannelModel):
        return channel
    try:
        return _REGISTRY[channel]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown channel model {channel!r}; registered: "
            f"{', '.join(available_channel_models()) or '(none)'}") from None


def available_channel_models() -> tuple:
    """Names of every registered channel model, sorted."""
    return tuple(sorted(_REGISTRY))
