"""Experiment runner: simulate + extract the paper's Fig. 3 metrics.

The batched path is canonical: ``run_experiment_batch`` executes a whole
scenario grid — heterogeneous configs AND workloads (``Scenario``) — in ONE
vmapped device launch per scheme and extracts the Fig. 3 metric set
batch-wide in one numpy pass over the [B, T] traces. ``sweep`` /
``sweep_grid`` are built on it.

``run_experiment`` remains as the single-cell entry; ``_metrics_row`` is
its per-cell fallback extractor. Passing a scheme NAME to the single-cell
entrypoints is deprecated (resolve through ``repro.netsim.schemes
.get_scheme`` instead); names remain first-class for the grid APIs, where
``schemes=("dcqcn", "matchrdma")`` is the natural spelling.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.config.base import NetConfig
from repro.netsim.fluid import simulate, simulate_batch
from repro.netsim.schemes import get_scheme
from repro.netsim.workload import (
    BIG, Workload, WorkloadParams, as_workload_batch,
)

WARMUP_FRAC = 0.1   # discard the initial transient for steady-state metrics


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the unified scenario axis: a network config AND the
    workload that runs over it. ``sweep_grid`` accepts heterogeneous
    ``Scenario`` grids and executes them in one launch per scheme."""
    net: NetConfig
    workload: Workload


def _warn_string_scheme(fn_name: str) -> None:
    warnings.warn(
        f"passing a scheme name string to {fn_name}() is deprecated; "
        f"resolve it with repro.netsim.schemes.get_scheme(name) (or use "
        f"the batched sweep_grid API, where names remain first-class)",
        DeprecationWarning, stacklevel=3)


def _metrics_row(cfg: NetConfig, wl: WorkloadParams, scheme_name: str,
                 final_np: dict, traces_np: dict) -> Dict[str, float]:
    """Fig. 3 metric set from one cell's numpy traces/final state — the
    single-cell fallback of the batch-wide extractor below."""
    steps = traces_np["q_dst"].shape[0]
    warm = int(steps * WARMUP_FRAC)

    is_inter = np.asarray(wl.is_inter) > 0
    delivered = final_np["delivered"]
    done_at = final_np["done_at_us"]
    start = np.asarray(wl.start_us)

    # throughput: steady-state inter-DC goodput (bytes/s and Gbps)
    thr = float(traces_np["thr_inter"][warm:].mean())
    # destination-OTN runtime buffer occupancy
    q_dst = traces_np["q_dst"]
    # pause-time ratio: fraction of time the long-haul PFC pause is asserted
    pause_ratio = float(traces_np["pause_dst"][warm:].mean())
    # FCT of finite inter-DC flows
    finite = is_inter & (np.asarray(wl.total_bytes) < BIG / 2)
    if finite.any():
        fct = done_at[finite] - start[finite]
        completed = np.isfinite(fct) & (fct < 1e29)
        avg_fct = float(fct[completed].mean()) if completed.any() else float("inf")
        completion = float(completed.mean())
    else:
        avg_fct, completion = float("nan"), 1.0

    return {
        "scheme": scheme_name,
        "distance_km": cfg.distance_km,
        "throughput_gbps": thr * 8.0 / 1e9,
        "goodput_bytes": float(delivered[is_inter].sum()),
        "peak_buffer_mb": float(q_dst.max()) / 1e6,
        "mean_buffer_mb": float(q_dst[warm:].mean()) / 1e6,
        "p99_buffer_mb": float(np.percentile(q_dst[warm:], 99)) / 1e6,
        "pause_ratio": pause_ratio,
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps": float(traces_np["thr_intra"][warm:].mean()) * 8.0 / 1e9,
    }


def _metrics_batch(cfgs: Sequence[NetConfig], wl: WorkloadParams,
                   scheme_name: str, final_np: dict,
                   traces_np: dict) -> List[Dict[str, float]]:
    """Fig. 3 metric set for a whole batch in ONE vectorized pass.

    ``traces_np``: [B, T] arrays; ``final_np``: [B, F]; ``wl``: stacked
    [B, F] workload leaves (padded flows carry ``is_inter == 0`` and
    ``total_bytes == 0``, so they drop out of every mask below).
    """
    steps = traces_np["q_dst"].shape[1]
    warm = int(steps * WARMUP_FRAC)

    thr = traces_np["thr_inter"][:, warm:].mean(axis=1)            # [B]
    intra_thr = traces_np["thr_intra"][:, warm:].mean(axis=1)
    q_dst = traces_np["q_dst"]
    peak = q_dst.max(axis=1)
    mean = q_dst[:, warm:].mean(axis=1)
    p99 = np.percentile(q_dst[:, warm:], 99, axis=1)
    pause = traces_np["pause_dst"][:, warm:].mean(axis=1)

    is_inter = np.asarray(wl.is_inter) > 0                         # [B, F]
    delivered = final_np["delivered"]
    goodput = np.where(is_inter, delivered, 0.0).sum(axis=1)

    # FCT of finite inter-DC flows, batch-wide with masked reductions
    total = np.asarray(wl.total_bytes)
    start = np.asarray(wl.start_us)
    done_at = final_np["done_at_us"]
    finite = is_inter & (total < BIG / 2)                          # [B, F]
    fct = done_at - start
    completed = finite & np.isfinite(fct) & (fct < 1e29)
    n_finite = finite.sum(axis=1)
    n_completed = completed.sum(axis=1)
    sum_fct = np.where(completed, fct, 0.0).sum(axis=1)
    avg_fct = np.where(n_completed > 0,
                       sum_fct / np.maximum(n_completed, 1), np.inf)
    avg_fct = np.where(n_finite > 0, avg_fct, np.nan)
    completion = np.where(n_finite > 0,
                          n_completed / np.maximum(n_finite, 1), 1.0)

    return [
        {
            "scheme": scheme_name,
            "distance_km": cfg.distance_km,
            "throughput_gbps": float(thr[i]) * 8.0 / 1e9,
            "goodput_bytes": float(goodput[i]),
            "peak_buffer_mb": float(peak[i]) / 1e6,
            "mean_buffer_mb": float(mean[i]) / 1e6,
            "p99_buffer_mb": float(p99[i]) / 1e6,
            "pause_ratio": float(pause[i]),
            "avg_fct_us": float(avg_fct[i]),
            "completion_frac": float(completion[i]),
            "intra_thr_gbps": float(intra_thr[i]) * 8.0 / 1e9,
        }
        for i, cfg in enumerate(cfgs)
    ]


def run_experiment(cfg: NetConfig, workload: Workload, scheme,
                   horizon_us: Optional[float] = None,
                   period_slots: int = 0, delay_pad: int = 0,
                   history_slots: int = 0) -> Dict[str, float]:
    """Returns the Fig. 3 metric set for one (config, workload, scheme).

    Thin shim over the Scheme/Scenario engine; ``scheme`` as a bare name
    string is deprecated here (pass ``get_scheme(name)``).
    ``delay_pad``/``history_slots``: see ``fluid.simulate`` — pass a batch's
    padding to reproduce one of its cells exactly."""
    if isinstance(scheme, str):
        _warn_string_scheme("run_experiment")
    scheme = get_scheme(scheme)
    final, traces = simulate(cfg, workload, scheme, horizon_us, period_slots,
                             delay_pad=delay_pad, history_slots=history_slots)
    traces_np = {k: np.asarray(v) for k, v in traces.items()}
    final_np = {"delivered": np.asarray(final.delivered),
                "done_at_us": np.asarray(final.done_at_us)}
    return _metrics_row(cfg, workload.params(), scheme.name,
                        final_np, traces_np)


def run_experiment_batch(cfgs: Sequence[NetConfig], workload, scheme,
                         horizon_us: Optional[float] = None,
                         period_slots: int = 0) -> List[Dict[str, float]]:
    """Fig. 3 metrics for every scenario of a grid, from ONE device launch
    and one vectorized metric pass. ``workload``: shared ``Workload``,
    per-scenario sequence, or stacked ``WorkloadParams`` (see
    ``fluid.simulate_batch``)."""
    cfgs = list(cfgs)
    scheme = get_scheme(scheme)
    wlp = as_workload_batch(workload, len(cfgs))
    final, traces = simulate_batch(cfgs, wlp, scheme, horizon_us,
                                   period_slots)
    traces_np = {k: np.asarray(v) for k, v in traces.items()}      # [B, T]
    final_np = {"delivered": np.asarray(final.delivered),          # [B, F]
                "done_at_us": np.asarray(final.done_at_us)}
    wlp_np = WorkloadParams(*(np.asarray(v) for v in wlp))
    return _metrics_batch(cfgs, wlp_np, scheme.name, final_np, traces_np)


def sweep(cfg: NetConfig, workload: Workload, schemes, distances_km,
          horizon_us: Optional[float] = None, period_slots: int = 0):
    """Cartesian (distance x scheme) sweep; returns list of metric dicts in
    the order ``for d in distances: for s in schemes``.

    Batched execution: each scheme's whole distance grid is one vmapped
    launch (one compile per scheme). All cells share one horizon — the
    longest any distance needs for CC convergence — so short-distance cells
    simply observe a longer steady state.
    """
    cfgs = [dataclasses.replace(cfg, distance_km=float(d))
            for d in distances_km]
    h = horizon_us
    if h is None:
        # at least 20 RTTs + fixed floor so CC converges at any distance
        h = max(cfg.horizon_us,
                40.0 * max(c.one_way_delay_us for c in cfgs) + 20_000.0)
    return sweep_grid(cfgs, workload, schemes, h, period_slots)


def sweep_grid(scenarios, workload=None, schemes=(),
               horizon_us: Optional[float] = None, period_slots: int = 0):
    """Heterogeneous scenario grids × schemes — one vmapped launch per
    scheme. Returns rows in the order ``for scenario: for scheme``.

    Two spellings:
      * unified axis — ``sweep_grid([Scenario(cfg, wl), ...], schemes)``:
        each cell carries its own config AND workload (mixed OTN
        capacities, asymmetric buffers, different flow sets — one launch);
      * config axis only — ``sweep_grid(cfgs, shared_workload, schemes)``:
        the historical form, one workload across the grid.
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("sweep_grid: empty scenario grid")
    if isinstance(scenarios[0], Scenario):
        if workload is not None and not schemes \
                and not isinstance(workload, (Workload, WorkloadParams)):
            # positional sweep_grid(scenarios, schemes)
            workload, schemes = None, workload
        if workload is not None:
            raise ValueError(
                "sweep_grid: Scenario cells carry their own workloads — "
                "drop the workload argument")
        cfgs = [s.net for s in scenarios]
        wl = [s.workload for s in scenarios]
    else:
        cfgs, wl = scenarios, workload
        if wl is None:
            raise ValueError(
                "sweep_grid: pass a workload (or a grid of Scenario cells)")
    if isinstance(schemes, str):
        schemes = (schemes,)        # a lone name is a 1-scheme sweep
    if not schemes:
        raise ValueError(
            "sweep_grid: no schemes given — pass schemes=(\"dcqcn\", ...) "
            "(or positionally after the Scenario grid)")
    by_scheme = {i: run_experiment_batch(cfgs, wl, s, horizon_us,
                                         period_slots)
                 for i, s in enumerate(schemes)}
    n = len(schemes)
    return [by_scheme[j][i] for i in range(len(cfgs)) for j in range(n)]
