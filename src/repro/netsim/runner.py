"""Experiment runner: simulate + extract the paper's Fig. 3 metrics."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.netsim.fluid import simulate
from repro.netsim.workload import BIG, Workload

WARMUP_FRAC = 0.1   # discard the initial transient for steady-state metrics


def run_experiment(cfg: NetConfig, workload: Workload, scheme: str,
                   horizon_us: Optional[float] = None,
                   period_slots: int = 0) -> Dict[str, float]:
    """Returns the Fig. 3 metric set for one (config, workload, scheme)."""
    final, traces = simulate(cfg, workload, scheme, horizon_us, period_slots)
    traces = {k: np.asarray(v) for k, v in traces.items()}
    horizon = (horizon_us if horizon_us is not None else cfg.horizon_us)
    steps = traces["q_dst"].shape[0]
    warm = int(steps * WARMUP_FRAC)

    wl = workload.arrays()
    is_inter = wl["is_inter"] > 0
    delivered = np.asarray(final.delivered)
    done_at = np.asarray(final.done_at_us)
    start = wl["start_us"]

    # throughput: steady-state inter-DC goodput (bytes/s and Gbps)
    thr = float(traces["thr_inter"][warm:].mean())
    # destination-OTN runtime buffer occupancy
    q_dst = traces["q_dst"]
    # pause-time ratio: fraction of time the long-haul PFC pause is asserted
    pause_ratio = float(traces["pause_dst"][warm:].mean())
    # FCT of finite inter-DC flows
    finite = is_inter & (wl["total_bytes"] < BIG / 2)
    if finite.any():
        fct = done_at[finite] - start[finite]
        completed = np.isfinite(fct) & (fct < 1e29)
        avg_fct = float(fct[completed].mean()) if completed.any() else float("inf")
        completion = float(completed.mean())
    else:
        avg_fct, completion = float("nan"), 1.0

    return {
        "scheme": scheme,
        "distance_km": cfg.distance_km,
        "throughput_gbps": thr * 8.0 / 1e9,
        "goodput_bytes": float(delivered[is_inter].sum()),
        "peak_buffer_mb": float(q_dst.max()) / 1e6,
        "mean_buffer_mb": float(q_dst[warm:].mean()) / 1e6,
        "p99_buffer_mb": float(np.percentile(q_dst[warm:], 99)) / 1e6,
        "pause_ratio": pause_ratio,
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps": float(traces["thr_intra"][warm:].mean()) * 8.0 / 1e9,
    }


def sweep(cfg: NetConfig, workload: Workload, schemes, distances_km,
          horizon_us: Optional[float] = None, period_slots: int = 0):
    """Cartesian sweep; returns list of metric dicts."""
    rows = []
    for d in distances_km:
        c = dataclasses.replace(cfg, distance_km=float(d))
        h = horizon_us
        if h is None:
            # at least 20 RTTs + fixed floor so CC converges at any distance
            h = max(cfg.horizon_us, 40.0 * c.one_way_delay_us + 20_000.0)
        for s in schemes:
            rows.append(run_experiment(c, workload, s, h, period_slots))
    return rows
