"""Experiment runner: simulate + extract the paper's Fig. 3 metrics.

Two execution paths share one metric extractor:
  * ``run_experiment``       — one (config, workload, scheme) cell;
  * ``run_experiment_batch`` — a whole config grid in ONE vmapped device
    launch (``fluid.simulate_batch``): one compile per scheme instead of one
    per (scheme, distance), and the accelerator never idles between cells.

``sweep`` is built on the batched path: the full distance grid of a scheme
runs as a single computation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.netsim.fluid import simulate, simulate_batch
from repro.netsim.workload import BIG, Workload

WARMUP_FRAC = 0.1   # discard the initial transient for steady-state metrics


def _metrics_row(cfg: NetConfig, wl: dict, scheme: str,
                 final_np: dict, traces_np: dict) -> Dict[str, float]:
    """Fig. 3 metric set from one cell's numpy traces/final state.
    ``wl``: the stacked workload arrays (``Workload.arrays()``)."""
    steps = traces_np["q_dst"].shape[0]
    warm = int(steps * WARMUP_FRAC)

    is_inter = wl["is_inter"] > 0
    delivered = final_np["delivered"]
    done_at = final_np["done_at_us"]
    start = wl["start_us"]

    # throughput: steady-state inter-DC goodput (bytes/s and Gbps)
    thr = float(traces_np["thr_inter"][warm:].mean())
    # destination-OTN runtime buffer occupancy
    q_dst = traces_np["q_dst"]
    # pause-time ratio: fraction of time the long-haul PFC pause is asserted
    pause_ratio = float(traces_np["pause_dst"][warm:].mean())
    # FCT of finite inter-DC flows
    finite = is_inter & (wl["total_bytes"] < BIG / 2)
    if finite.any():
        fct = done_at[finite] - start[finite]
        completed = np.isfinite(fct) & (fct < 1e29)
        avg_fct = float(fct[completed].mean()) if completed.any() else float("inf")
        completion = float(completed.mean())
    else:
        avg_fct, completion = float("nan"), 1.0

    return {
        "scheme": scheme,
        "distance_km": cfg.distance_km,
        "throughput_gbps": thr * 8.0 / 1e9,
        "goodput_bytes": float(delivered[is_inter].sum()),
        "peak_buffer_mb": float(q_dst.max()) / 1e6,
        "mean_buffer_mb": float(q_dst[warm:].mean()) / 1e6,
        "p99_buffer_mb": float(np.percentile(q_dst[warm:], 99)) / 1e6,
        "pause_ratio": pause_ratio,
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps": float(traces_np["thr_intra"][warm:].mean()) * 8.0 / 1e9,
    }


def run_experiment(cfg: NetConfig, workload: Workload, scheme: str,
                   horizon_us: Optional[float] = None,
                   period_slots: int = 0, delay_pad: int = 0,
                   history_slots: int = 0) -> Dict[str, float]:
    """Returns the Fig. 3 metric set for one (config, workload, scheme).

    ``delay_pad``/``history_slots``: see ``fluid.simulate`` — pass a batch's
    padding to reproduce one of its cells exactly."""
    final, traces = simulate(cfg, workload, scheme, horizon_us, period_slots,
                             delay_pad=delay_pad, history_slots=history_slots)
    traces_np = {k: np.asarray(v) for k, v in traces.items()}
    final_np = {"delivered": np.asarray(final.delivered),
                "done_at_us": np.asarray(final.done_at_us)}
    return _metrics_row(cfg, workload.arrays(), scheme, final_np, traces_np)


def run_experiment_batch(cfgs: Sequence[NetConfig], workload: Workload,
                         scheme: str, horizon_us: Optional[float] = None,
                         period_slots: int = 0) -> List[Dict[str, float]]:
    """Fig. 3 metrics for every config of a grid, from ONE device launch."""
    cfgs = list(cfgs)
    final, traces = simulate_batch(cfgs, workload, scheme, horizon_us,
                                   period_slots)
    traces_np = {k: np.asarray(v) for k, v in traces.items()}      # [B, T]
    delivered = np.asarray(final.delivered)                        # [B, F]
    done_at = np.asarray(final.done_at_us)
    wl = workload.arrays()
    rows = []
    for i, cfg in enumerate(cfgs):
        cell_traces = {k: v[i] for k, v in traces_np.items()}
        cell_final = {"delivered": delivered[i], "done_at_us": done_at[i]}
        rows.append(_metrics_row(cfg, wl, scheme, cell_final, cell_traces))
    return rows


def sweep(cfg: NetConfig, workload: Workload, schemes, distances_km,
          horizon_us: Optional[float] = None, period_slots: int = 0):
    """Cartesian (distance x scheme) sweep; returns list of metric dicts in
    the order ``for d in distances: for s in schemes``.

    Batched execution: each scheme's whole distance grid is one vmapped
    launch (one compile per scheme). All cells share one horizon — the
    longest any distance needs for CC convergence — so short-distance cells
    simply observe a longer steady state.
    """
    cfgs = [dataclasses.replace(cfg, distance_km=float(d))
            for d in distances_km]
    h = horizon_us
    if h is None:
        # at least 20 RTTs + fixed floor so CC converges at any distance
        h = max(cfg.horizon_us,
                40.0 * max(c.one_way_delay_us for c in cfgs) + 20_000.0)
    return sweep_grid(cfgs, workload, schemes, h, period_slots)


def sweep_grid(cfgs: Sequence[NetConfig], workload: Workload, schemes,
               horizon_us: Optional[float] = None, period_slots: int = 0):
    """Arbitrary per-scenario config grids (mixed OTN capacities, asymmetric
    buffers, ...) x schemes — one vmapped launch per scheme. Returns rows in
    the order ``for cfg in cfgs: for s in schemes``."""
    cfgs = list(cfgs)
    by_scheme = {s: run_experiment_batch(cfgs, workload, s, horizon_us,
                                         period_slots)
                 for s in schemes}
    return [by_scheme[s][i] for i in range(len(cfgs)) for s in schemes]
