"""Experiment runner: simulate + extract the paper's Fig. 3 metrics.

The batched path is canonical: every grid — heterogeneous configs AND
workloads (``Scenario``) — executes through a *launch plan*: the scenario
axis is stacked once, split into equal-size chunks (auto-sized so a launch's
trace block stays in bounded memory), and each (scheme, chunk) pair becomes
one vmapped device launch. All chunks of a grid share one compiled program
(the last chunk is padded by repeating its final cell) and shard across
devices whenever the chunk divides the device count.

Execution modes (``trace_mode`` — see ``fluid.py``):
  * ``full``     [B, T] traces materialize; metrics come from one vectorized
                 numpy pass (``_metrics_batch``).
  * ``decimate`` every k-th step materializes; same extractor, approximate
                 means/percentiles.
  * ``metrics``  nothing per-step ever exists: the scan carry streams the
                 Fig. 3 reductions (``MetricAcc``) and only O(B) accumulators
                 + final states transfer to host (``_metrics_streaming``).
                 Schemes append their own columns via
                 ``Scheme.finalize_metrics``.

``run_experiment`` is a thin B=1 delegation onto the same batch-wide
extractors — there is exactly one copy of the Fig. 3 metric definitions.
Passing a scheme NAME to the single-cell entrypoints is deprecated (resolve
through ``repro.netsim.schemes.get_scheme``); names remain first-class for
the grid APIs, where ``schemes=("dcqcn", "matchrdma")`` is the natural
spelling.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.config.base import NetConfig, batch_template
from repro.netsim.channel import get_channel_model
from repro.netsim.fluid import (
    WARMUP_FRAC, MetricAcc, batch_padding, hist_quantile, is_unfinished,
    simulate_batch,
)
from repro.netsim.schemes import get_scheme
from repro.netsim.workload import (
    Workload, WorkloadParams, as_workload_batch, is_unbounded,
)

# Auto-chunk targets of the launch plan: a full-trace launch keeps its
# materialized [B_chunk, T] block under ~256 MB of f32; a streaming launch
# is O(B) anyway and only caps per-launch compile/host-row cost.
MAX_TRACE_FLOATS = 64 * 1024 * 1024
METRICS_CHUNK_CELLS = 4096
_TRACE_KEYS_EST = 12        # 8 engine trace keys + scheme extras (estimate)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the unified scenario axis: a network config AND the
    workload that runs over it. ``sweep_grid`` accepts heterogeneous
    ``Scenario`` grids and executes them in one launch plan per scheme."""
    net: NetConfig
    workload: Workload


def _warn_string_scheme(fn_name: str) -> None:
    warnings.warn(
        f"passing a scheme name string to {fn_name}() is deprecated; "
        f"resolve it with repro.netsim.schemes.get_scheme(name) (or use "
        f"the batched sweep_grid API, where names remain first-class)",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Metric extraction (batch-wide; the ONLY copies of the Fig. 3 metric set)
# ---------------------------------------------------------------------------


def _flow_metrics(wl: WorkloadParams, final_np: dict):
    """[B] goodput / avg-FCT / completion from final state + workload
    leaves — per-flow quantities that never needed per-step traces. Padded
    flows carry ``is_inter == 0`` and ``total_bytes == 0`` and drop out of
    every mask."""
    is_inter = np.asarray(wl.is_inter) > 0                         # [B, F]
    delivered = final_np["delivered"]
    goodput = np.where(is_inter, delivered, 0.0).sum(axis=1)

    total = np.asarray(wl.total_bytes)
    start = np.asarray(wl.start_us)
    done_at = final_np["done_at_us"]
    # the shared sentinel helpers — NOT re-derived magic literals, so both
    # metric paths (and the engine) can never drift apart on what counts
    # as a finite flow / a completed flow
    finite = is_inter & ~is_unbounded(total)                       # [B, F]
    fct = done_at - start
    completed = finite & ~is_unfinished(done_at)
    n_finite = finite.sum(axis=1)
    n_completed = completed.sum(axis=1)
    sum_fct = np.where(completed, fct, 0.0).sum(axis=1)
    avg_fct = np.where(n_completed > 0,
                       sum_fct / np.maximum(n_completed, 1), np.inf)
    avg_fct = np.where(n_finite > 0, avg_fct, np.nan)
    completion = np.where(n_finite > 0,
                          n_completed / np.maximum(n_finite, 1), 1.0)
    return goodput, avg_fct, completion


def _assemble_rows(cfgs: Sequence[NetConfig], scheme_name: str,
                   cols: dict, extra: Optional[dict] = None
                   ) -> List[Dict[str, float]]:
    """[B]-column dicts -> the per-cell row list of a sweep."""
    rows = []
    for i, cfg in enumerate(cfgs):
        row = {"scheme": scheme_name, "distance_km": cfg.distance_km}
        row.update({k: float(v[i]) for k, v in cols.items()})
        if extra:
            row.update({k: float(np.asarray(v)[i]) for k, v in extra.items()})
        rows.append(row)
    return rows


def _channel_cols_from_traces(traces_np: dict, warm: int, dt_s: float,
                              decimate: int = 1) -> dict:
    """The channel metric columns from materialized ``chan_*`` traces —
    the full/decimate-mode twin of ``ChannelModel.finalize_metrics`` (same
    column set, so impairment sweeps agree across trace modes).

    Rate columns normalize by SIMULATED time, not sample count: a
    decimated trace holds ``steps/decimate`` samples, each a block SUM of
    ``decimate`` steps' bytes (``fluid.DECIMATE_SUM_KEYS``), so
    ``n_samples * decimate * dt_s`` is the window the bytes accumulated
    over and the Gbps columns agree exactly with the streamed path at any
    decimation."""
    wire = traces_np["chan_wire"][:, warm:].astype(np.float64)
    lost = traces_np["chan_lost"][:, warm:].astype(np.float64)
    retx = traces_np["chan_retx"][:, warm:].astype(np.float64)
    wait = traces_np["chan_repair_wait_us"][:, warm:]
    per_s = 1.0 / (max(wire.shape[1], 1) * max(decimate, 1) * dt_s)
    # p99 over steps with a repair actually pending (matches the streamed
    # histogram, which only counts wait > 0 samples)
    p99 = np.zeros(wire.shape[0])
    for i in range(wire.shape[0]):
        pending = wait[i][wait[i] > 0]
        p99[i] = np.percentile(pending, 99) if pending.size else 0.0
    return {
        "goodput_gbps": (wire.sum(axis=1) - lost.sum(axis=1))
        * per_s * 8.0 / 1e9,
        "wire_gbps": wire.sum(axis=1) * per_s * 8.0 / 1e9,
        "retx_frac": retx.sum(axis=1) / np.maximum(wire.sum(axis=1), 1.0),
        "p99_repair_latency_us": p99,
    }


def _failover_cols_from_traces(cfgs: Sequence[NetConfig], traces_np: dict,
                               decimate: int = 1) -> dict:
    """Failover scoring columns from the ``thr_inter`` time series of a
    grid whose cells carry a failure schedule (``cfg.failure_len > 0``):

      ``failover_collapse_frac``  1 - (mean inter-DC throughput DURING the
                                  cell's outage span) / (mean before the
                                  first down edge), clipped to [0, 1] —
                                  0 = the scheme rode through the outage,
                                  1 = goodput fully collapsed.
      ``failover_recovery_us``    time from the LAST up edge until the
                                  throughput first regains 90 % of its
                                  pre-outage mean (clamped to the end of
                                  the trace when it never does).

    The outage span of a cell is [min down_at, max up_at] over its REAL
    windows (``up > down``; padding (0, 0) windows are ignored). Cells with
    no real window — the all-up control rows of a failover grid — report 0
    for both columns. Sample j of a decimated trace is the engine value at
    step ``(j+1)*decimate - 1``, so recovery times stay decimation-exact.
    Full/decimate modes only (``trace_mode="metrics"`` streams no per-step
    series to recover a timeline from)."""
    thr = np.asarray(traces_np["thr_inter"], np.float64)       # [B, S]
    n_cells, n_samples = thr.shape
    t_us = (np.arange(n_samples, dtype=np.float64) + 1.0) \
        * max(decimate, 1) * cfgs[0].dt_us
    collapse = np.zeros(n_cells)
    recovery = np.zeros(n_cells)
    for i, cfg in enumerate(cfgs[:n_cells]):
        fa = np.asarray(cfg.failure_array(), np.float64)       # [L, W, 2]
        real = fa[..., 1] > fa[..., 0]
        if not real.any():
            continue
        down = fa[..., 0][real].min()
        up = fa[..., 1][real].max()
        pre = thr[i][t_us < down]
        base = pre.mean() if pre.size else 0.0
        if base <= 0.0:
            continue
        span = thr[i][(t_us >= down) & (t_us < up)]
        during = span.mean() if span.size else 0.0
        collapse[i] = min(max(1.0 - during / base, 0.0), 1.0)
        post = t_us >= up
        rec = post & (thr[i] >= 0.9 * base)
        if rec.any():
            recovery[i] = t_us[rec].min() - up
        elif post.any():
            recovery[i] = max(t_us[-1] - up, 0.0)
    return {"failover_collapse_frac": collapse,
            "failover_recovery_us": recovery}


def _metrics_batch(cfgs: Sequence[NetConfig], wl: WorkloadParams,
                   scheme_name: str, final_np: dict, traces_np: dict,
                   decimate: int = 1) -> List[Dict[str, float]]:
    """Fig. 3 metric set from materialized [B, T] traces in ONE vectorized
    pass (``trace_mode="full"``/``"decimate"``)."""
    steps = traces_np["q_dst"].shape[1]
    warm = int(steps * WARMUP_FRAC)

    q_dst = traces_np["q_dst"]
    goodput, avg_fct, completion = _flow_metrics(wl, final_np)
    cols = {
        "throughput_gbps":
            traces_np["thr_inter"][:, warm:].mean(axis=1) * 8.0 / 1e9,
        "goodput_bytes": goodput,
        "peak_buffer_mb": q_dst.max(axis=1) / 1e6,
        "mean_buffer_mb": q_dst[:, warm:].mean(axis=1) / 1e6,
        "p99_buffer_mb": np.percentile(q_dst[:, warm:], 99, axis=1) / 1e6,
        "pause_ratio": traces_np["pause_dst"][:, warm:].mean(axis=1),
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps":
            traces_np["thr_intra"][:, warm:].mean(axis=1) * 8.0 / 1e9,
    }
    if "chan_wire" in traces_np:
        cols.update(_channel_cols_from_traces(
            traces_np, warm, cfgs[0].dt_us * 1e-6, decimate))
    if cfgs[0].failure_len > 0:
        cols.update(_failover_cols_from_traces(cfgs, traces_np, decimate))
    return _assemble_rows(cfgs, scheme_name, cols)


def _metrics_streaming(cfgs: Sequence[NetConfig], wl: WorkloadParams,
                       scheme, channel, final_np: dict, acc: MetricAcc,
                       steps: int, warm: int) -> List[Dict[str, float]]:
    """The same Fig. 3 metric set from the O(B) streamed accumulators
    (``trace_mode="metrics"`` — no [B, T] array ever existed). p99 comes
    from inverting the fixed-bin log-histogram (bounded relative error);
    everything else is exact up to summation order."""
    n_warm = max(steps - warm, 1)
    sums = {k: np.asarray(v, np.float64) for k, v in acc.sum_s.items()}
    goodput, avg_fct, completion = _flow_metrics(wl, final_np)
    cols = {
        "throughput_gbps": sums["thr_inter"] / n_warm * 8.0 / 1e9,
        "goodput_bytes": goodput,
        "peak_buffer_mb": np.asarray(acc.maxes["q_dst"]) / 1e6,
        "mean_buffer_mb": sums["q_dst"] / n_warm / 1e6,
        "p99_buffer_mb": hist_quantile(acc.hist, 0.99) / 1e6,
        "pause_ratio": sums["pause_dst"] / n_warm,
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps": sums["thr_intra"] / n_warm * 8.0 / 1e9,
    }
    extra = scheme.finalize_metrics(
        jax.tree.map(np.asarray, acc.scheme), steps, n_warm)
    # the channel accumulator also streams under the IDEAL channel when a
    # failure schedule is active (outage losses ride the chan_* keys —
    # fluid._track_chan), so finalize under the same condition
    if not channel.is_ideal or cfgs[0].failure_len > 0:
        extra = dict(extra or {})
        extra.update(channel.finalize_metrics(
            jax.tree.map(np.asarray, acc.chan), steps, n_warm,
            cfgs[0].dt_us * 1e-6))
    return _assemble_rows(cfgs, scheme.name, cols, extra)


# ---------------------------------------------------------------------------
# The launch plan: (scheme x chunk) device launches over a stacked grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Launch:
    """One device launch of a sweep's plan: ``scheme`` over grid cells
    [lo, hi), padded up to ``pad_to`` cells so every chunk of a grid shares
    one compiled program (padding rows are dropped from the output)."""
    scheme: object
    lo: int
    hi: int
    pad_to: int


def chunk_cells(steps: int, trace_mode: str = "full", decimate: int = 1,
                chunk_cells: Optional[int] = None,
                n_devices: int = 1, num_links: int = 1,
                schedule_floats: int = 0) -> int:
    """Scenario cells per device launch of a sweep's plan.

    Returns the explicit ``chunk_cells`` override when given, else the
    bounded-memory auto size: in ``full``/``decimate`` modes the chunk is
    sized so one launch's materialized trace block stays under
    ``MAX_TRACE_FLOATS`` f32 values (~256 MB) — multi-link grids
    (``num_links > 1``) add per-link [L] trace keys, so their per-step
    float estimate grows with L and the chunk shrinks accordingly; in
    ``metrics`` (and ``window`` — O(B·W) with a small fixed W) mode the
    launch is O(B) anyway and the flat ``METRICS_CHUNK_CELLS`` ceiling
    only caps per-launch compile/host-row cost. ``schedule_floats`` is the per-cell resident footprint of a
    ``trace_replay`` schedule table (``num_paths * schedule_len * 3``
    f32 values — the stacked ``chan_schedule`` leaf rides along with
    every launch), folded into the per-cell budget in every mode so a
    long recorded trace shrinks the chunk instead of blowing the launch
    past the memory target. The result is rounded up to a multiple of
    ``n_devices`` so chunked grids still shard the scenario axis evenly.
    (Not clamped to the grid size — ``_plan_launches`` caps the final
    chunk at the cell count and pads the trailing chunk so every launch
    shares one compiled program.)
    """
    if chunk_cells is None:
        if trace_mode in ("metrics", "window"):
            chunk_cells = METRICS_CHUNK_CELLS
            if schedule_floats > 0:
                chunk_cells = min(
                    chunk_cells,
                    max(MAX_TRACE_FLOATS // schedule_floats, 1))
        else:
            t = max(steps // max(decimate, 1), 1)
            # q_dst_link / link_tx / link_pause are [L] per step at L>1
            keys = _TRACE_KEYS_EST + (3 * num_links if num_links > 1 else 0)
            chunk_cells = max(
                MAX_TRACE_FLOATS // (t * keys + max(schedule_floats, 0)), 1)
    chunk_cells = max(int(chunk_cells), 1)
    if n_devices > 1:
        chunk_cells = -(-chunk_cells // n_devices) * n_devices
    return chunk_cells


# non-deprecated private alias: inside run_experiment_batch / sweep_grid the
# ``chunk_cells`` KEYWORD shadows the module-level function
_auto_chunk_cells = chunk_cells


def _sched_floats(cfg: NetConfig) -> int:
    """Per-cell f32 footprint of the cfg's resident schedule tables: the
    ``trace_replay`` channel schedule ([L, W, 3]) plus the failure-window
    table ([L, W', 2]) — both stacked leaves ride along with every launch,
    so long schedules shrink the auto chunk instead of blowing the memory
    target."""
    return (cfg.num_paths * cfg.schedule_len * 3
            + cfg.num_paths * cfg.failure_len * 2)


def __getattr__(name: str):
    if name == "_chunk_cells":
        warnings.warn(
            "repro.netsim.runner._chunk_cells is deprecated (it was a "
            "pre-PR 4 private alias) and will be removed in a future PR; "
            "use runner.chunk_cells instead",
            DeprecationWarning, stacklevel=2)
        return chunk_cells
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def _plan_launches(n_cells: int, schemes: Sequence, chunk: int,
                   n_devices: int = 1) -> List[_Launch]:
    """Flatten (scheme x chunk) into the launch list — the per-scheme
    Python loop of the old sweep path, folded into explicit plan entries.
    EVERY launch — including the single-launch case of a grid smaller than
    one chunk — pads to a device multiple, so the scenario axis always
    splits evenly across devices and ``shard_scenario_axis`` never sees an
    odd batch (padding rows are dropped)."""
    pad_to = min(chunk, n_cells)
    if n_devices > 1:
        pad_to = -(-pad_to // n_devices) * n_devices
    return [_Launch(s, lo, min(lo + chunk, n_cells), pad_to)
            for s in schemes for lo in range(0, n_cells, chunk)]


def _pad_chunk(cfgs, wlp: WorkloadParams, n: int):
    """Pad a trailing chunk to ``n`` cells by repeating its last cell (the
    duplicate rows are dropped after the launch)."""
    pad = n - len(cfgs)
    if pad <= 0:
        return cfgs, wlp
    leaves = [np.asarray(v) for v in wlp]
    wlp = WorkloadParams(*(np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                           for v in leaves))
    return list(cfgs) + [cfgs[-1]] * pad, wlp


def _grid_static(cfgs, horizon_us, delay_pad: int, history_slots: int):
    """The grid-wide static quantities every launch of a plan shares —
    resolved horizon, scan length, warm cutoff, ring paddings — computed
    ONCE over the WHOLE grid. Chunks must never re-derive them from their
    own sub-grid, or chunked launches would stop sharing one compiled
    program (and streaming normalizers would drift from the scan length)."""
    dp, hs = batch_padding(cfgs)
    horizon = (horizon_us if horizon_us is not None
               else max(c.horizon_us for c in cfgs))
    steps = batch_template(cfgs).horizon_steps(horizon)
    return (horizon, steps, int(steps * WARMUP_FRAC),
            max(delay_pad, dp), max(history_slots, hs))


# ---------------------------------------------------------------------------
# Runner hardening: conservation guard, finite guard, checkpoint/resume, OOM
# backoff (docs/failures.md)
# ---------------------------------------------------------------------------


class ConservationError(RuntimeError):
    """``strict_conservation``: a cell's byte-conservation residual
    (``cons_err`` — max over flows of |residual| / max(sent, 1)) exceeded
    the tolerance. Carries the GRID-ORDER ``cell`` index and the engine
    ``step`` of the first violation (``None`` under ``trace_mode="metrics"``,
    where only the running max streams)."""

    def __init__(self, scheme_name: str, cell: int, step: Optional[int],
                 err: float, tol: float):
        self.scheme_name, self.cell, self.step = scheme_name, cell, step
        self.err, self.tol = err, tol
        where = (f"step {step}" if step is not None
                 else "step unknown (trace_mode='metrics' streams only the "
                      "running max — rerun with trace_mode='full' to "
                      "localize)")
        super().__init__(
            f"strict_conservation: scheme {scheme_name!r} violated byte "
            f"conservation at cell {cell}, {where}: "
            f"|residual|/sent = {err:.3e} > tol {tol:.1e}")


def _check_conservation(scheme_name: str, aux, lo: int, n_real: int,
                        trace_mode: str, decimate: int, tol: float) -> None:
    """First ``cons_err > tol`` violation -> ``ConservationError`` with
    grid-order (cell, step) coordinates. Sample j of a decimated trace is
    the engine value AT step ``(j+1)*decimate - 1``, so reported steps are
    exact at any decimation; metrics mode only streams the per-cell running
    max, so its step is ``None``."""
    if trace_mode in ("metrics", "window"):
        maxes = aux.maxes if trace_mode == "metrics" else aux.acc.maxes
        m = np.asarray(maxes["cons_err"])[:n_real]
        bad = m > tol
        if bad.any():
            i = int(np.argmax(bad))
            raise ConservationError(scheme_name, lo + i, None,
                                    float(m[i]), tol)
        return
    k = decimate if trace_mode == "decimate" else 1
    cons = np.asarray(aux["cons_err"])[:n_real]
    bad = cons > tol
    if bad.any():
        i, j = np.argwhere(bad)[0]
        raise ConservationError(scheme_name, lo + int(i),
                                (int(j) + 1) * k - 1,
                                float(cons[i, j]), tol)


# ``avg_fct_us`` is exempt from the finite guard: inf (no flow finished)
# and nan (no finite flow in the cell) are its documented in-band sentinels.
_NONFINITE_EXEMPT = ("avg_fct_us",)


def _guard_nonfinite(rows: List[dict], lo: int,
                     on_nonfinite: str) -> List[dict]:
    """Per-cell finite guard. ``"keep"`` passes rows through untouched;
    ``"quarantine"`` replaces a diverged cell's row with a structured
    failure record (``failed=True`` + the offending column names + the
    grid-order cell index) so one NaN cell cannot poison a sweep's
    aggregation; ``"raise"`` aborts naming the cell and columns."""
    if on_nonfinite == "keep":
        return rows
    out = []
    for i, row in enumerate(rows):
        bad = sorted(k for k, v in row.items()
                     if k not in _NONFINITE_EXEMPT
                     and isinstance(v, float) and not np.isfinite(v))
        if not bad:
            out.append(row)
            continue
        cell = lo + i
        if on_nonfinite == "raise":
            raise RuntimeError(
                f"non-finite metrics at cell {cell} "
                f"(scheme {row.get('scheme')!r}): columns {bad} — rerun "
                f"with on_nonfinite='quarantine' to skip diverged cells")
        out.append({"scheme": row.get("scheme"),
                    "distance_km": row.get("distance_km", float("nan")),
                    "cell_index": cell, "failed": True,
                    "nonfinite_cols": bad})
    return out


def _plan_fingerprint(plan, cfgs, wlp_np, grid_static, period_slots,
                      trace_mode, decimate, channel) -> str:
    """Digest of everything that determines a plan's rows — configs,
    workload leaves, grid statics, modes, channel, scheme set. A resume
    against a checkpoint directory written under a DIFFERENT fingerprint
    refuses loudly instead of silently mixing two sweeps' rows."""
    h = hashlib.sha256()
    for c in cfgs:
        h.update(repr(c).encode())
    for leaf in wlp_np:
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    names = tuple(sorted({launch.scheme.name for launch in plan}))
    h.update(repr((tuple(grid_static), int(period_slots), trace_mode,
                   int(decimate), getattr(channel, "name", None),
                   names)).encode())
    return h.hexdigest()


def _checkpoint_path(checkpoint_dir: str, launch: _Launch) -> str:
    return os.path.join(
        checkpoint_dir,
        f"{launch.scheme.name}_{launch.lo}_{launch.hi}.json")


def _load_checkpoint(path: str, fingerprint: str) -> Optional[list]:
    """Finished-launch rows from a checkpoint file, or None to (re)run the
    launch. A torn file — the process died mid-write before the atomic
    rename — parses as garbage and is treated as absent; a VALID file from
    a different plan raises."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError):
        return None
    if data.get("fingerprint") != fingerprint:
        raise ValueError(
            f"--resume: checkpoint {path} was written by a DIFFERENT "
            f"launch plan (grid, workload, horizon, trace mode, channel "
            f"or scheme set changed); delete the checkpoint directory to "
            f"start this sweep from scratch")
    return data["rows"]


def _write_checkpoint(path: str, fingerprint: str, launch: _Launch,
                      rows: list) -> None:
    """Atomic per-launch checkpoint: rows round-trip through JSON
    bit-identically (repr-based float serialization; NaN/Infinity use the
    JSON-extension literals), and the tmp-file + rename means a kill at
    ANY point leaves either the complete file or none."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"fingerprint": fingerprint, "scheme": launch.scheme.name,
                   "lo": launch.lo, "hi": launch.hi, "rows": rows}, f)
    os.replace(tmp, path)


def _is_oom_error(e: Exception) -> bool:
    s = str(e)
    return "RESOURCE_EXHAUSTED" in s or "out of memory" in s.lower()


def _run_launch(launch: _Launch, cfgs, wlp_np, grid_static, period_slots,
                trace_mode, decimate, devices, channel, n_dev: int,
                strict_conservation: bool, conservation_tol: float,
                profile: Optional[dict] = None) -> List[dict]:
    """One launch -> its REAL cells' rows (grid order), with
    retry-with-smaller-chunk backoff: a device-OOM failure splits the
    launch into two half-size launches and recurses (each half still pads
    to a device multiple), down to single-cell launches before giving up.
    The conservation guard runs per launch so the raised coordinates are
    the first violation of the first offending chunk. ``profile``: a dict
    routed to the AOT profiling path (filled in place with the launch's
    compile/execute split and memory figures — docs/observability.md)."""
    horizon, steps, warm, delay_pad, history_slots = grid_static
    sub_cfgs = cfgs[launch.lo:launch.hi]
    sub_wlp = WorkloadParams(*(v[launch.lo:launch.hi] for v in wlp_np))
    n_real = len(sub_cfgs)
    sub_cfgs, sub_wlp = _pad_chunk(sub_cfgs, sub_wlp, launch.pad_to)
    try:
        final, aux = simulate_batch(
            sub_cfgs, sub_wlp, launch.scheme, horizon, period_slots,
            trace_mode=trace_mode, decimate=decimate,
            delay_pad=delay_pad, history_slots=history_slots,
            devices=devices, warm_steps=warm, channel=channel,
            profile=profile)
    except Exception as e:  # noqa: BLE001 — filtered to OOM right below
        if not _is_oom_error(e) or n_real <= 1:
            raise
        mid = launch.lo + (n_real + 1) // 2
        warnings.warn(
            f"launch ({launch.scheme.name}, cells [{launch.lo}, "
            f"{launch.hi})) hit device OOM; retrying as two half-size "
            f"launches", RuntimeWarning, stacklevel=2)
        if profile is not None:
            profile["oom_split"] = True
        rows = []
        for lo, hi in ((launch.lo, mid), (mid, launch.hi)):
            pad = hi - lo
            if n_dev > 1:
                pad = -(-pad // n_dev) * n_dev
            rows.extend(_run_launch(
                _Launch(launch.scheme, lo, hi, pad), cfgs, wlp_np,
                grid_static, period_slots, trace_mode, decimate, devices,
                channel, n_dev, strict_conservation, conservation_tol))
        return rows
    if strict_conservation:
        _check_conservation(launch.scheme.name, aux, launch.lo, n_real,
                            trace_mode, decimate, conservation_tol)
    final_np = {"delivered": np.asarray(final.delivered),
                "done_at_us": np.asarray(final.done_at_us)}
    wl_np = WorkloadParams(*(np.asarray(v) for v in sub_wlp))
    if trace_mode in ("metrics", "window"):
        acc = aux if trace_mode == "metrics" else aux.acc
        sub_rows = _metrics_streaming(sub_cfgs, wl_np, launch.scheme,
                                      channel, final_np, acc, steps, warm)
    else:
        traces_np = {k: np.asarray(v) for k, v in aux.items()}
        sub_rows = _metrics_batch(
            sub_cfgs, wl_np, launch.scheme.name, final_np, traces_np,
            decimate if trace_mode == "decimate" else 1)
    return sub_rows[:n_real]


def _execute_plan(plan: Sequence[_Launch], cfgs, wlp: WorkloadParams,
                  grid_static, period_slots, trace_mode, decimate,
                  devices, channel=None, *,
                  checkpoint_dir: Optional[str] = None, resume: bool = False,
                  on_nonfinite: str = "keep",
                  strict_conservation: bool = False,
                  conservation_tol: float = 1e-3,
                  abort_after_launches: Optional[int] = None,
                  manifest_path: Optional[str] = None
                  ) -> Dict[object, list]:
    """Run every launch; returns scheme -> full row list (grid order).
    ``grid_static`` is the shared ``_grid_static`` tuple, so all chunks
    (and all schemes) see identical static shapes, hence one compiled
    program per scheme.

    Hardening knobs (all opt-in; docs/failures.md):
      * ``checkpoint_dir`` — write one atomic JSON checkpoint per finished
        launch; with ``resume=True`` a rerun of the SAME plan loads
        finished launches from disk (bit-identical rows — JSON floats
        round-trip exactly) and only executes the rest. A checkpoint from
        a different plan (fingerprint mismatch) raises.
      * ``on_nonfinite`` — ``"keep"`` (default) / ``"quarantine"`` (swap
        diverged cells' rows for structured failure records) / ``"raise"``.
      * ``strict_conservation`` — raise ``ConservationError`` with (cell,
        step) coordinates on the first ``cons_err > conservation_tol``.
      * ``abort_after_launches`` — deterministic crash-injection hook:
        raise after N launches have executed (checkpoints for those N are
        already on disk); the resume test kills sweeps with it.
      * ``manifest_path`` — write a JSONL run manifest (one header record
        with git rev + plan fingerprint + backend, one record per launch
        with the compile/execute wall-clock split and XLA memory
        figures). Every launch routes through the AOT profiling path;
        ``tools/obs_report.py`` summarizes and diffs manifests
        (docs/observability.md).
    """
    channel = get_channel_model(channel)
    if on_nonfinite not in ("keep", "quarantine", "raise"):
        raise ValueError(
            f"on_nonfinite must be 'keep', 'quarantine' or 'raise', "
            f"got {on_nonfinite!r}")
    wlp_np = [np.asarray(v) for v in wlp]
    n_dev = len(devices) if devices is not None else len(jax.devices())

    fingerprint = None
    if checkpoint_dir is not None or manifest_path is not None:
        fingerprint = _plan_fingerprint(plan, cfgs, wlp_np, grid_static,
                                        period_slots, trace_mode, decimate,
                                        channel)
    if checkpoint_dir is not None:
        os.makedirs(checkpoint_dir, exist_ok=True)
    manifest = [] if manifest_path is not None else None

    rows: Dict[object, list] = {}
    executed = 0
    for launch in plan:
        ckpt = (_checkpoint_path(checkpoint_dir, launch)
                if checkpoint_dir is not None else None)
        if ckpt is not None and resume:
            cached = _load_checkpoint(ckpt, fingerprint)
            if cached is not None:
                rows.setdefault(launch.scheme, []).extend(cached)
                if manifest is not None:
                    manifest.append({"scheme": launch.scheme.name,
                                     "lo": launch.lo, "hi": launch.hi,
                                     "pad_to": launch.pad_to,
                                     "resumed": True})
                continue
        if abort_after_launches is not None \
                and executed >= abort_after_launches:
            raise RuntimeError(
                f"abort_after_launches: aborting sweep after {executed} "
                f"executed launches (crash-injection hook)")
        prof = {} if manifest is not None else None
        sub_rows = _guard_nonfinite(
            _run_launch(launch, cfgs, wlp_np, grid_static, period_slots,
                        trace_mode, decimate, devices, channel, n_dev,
                        strict_conservation, conservation_tol, prof),
            launch.lo, on_nonfinite)
        if ckpt is not None:
            _write_checkpoint(ckpt, fingerprint, launch, sub_rows)
        executed += 1
        if manifest is not None:
            prof.update(scheme=launch.scheme.name, lo=launch.lo,
                        hi=launch.hi, pad_to=launch.pad_to,
                        n_real=launch.hi - launch.lo)
            manifest.append(prof)
        rows.setdefault(launch.scheme, []).extend(sub_rows)
    if manifest_path is not None:
        from repro.netsim.obs.profile import write_manifest
        executed_recs = [m for m in manifest if not m.get("resumed")]
        header = {
            "fingerprint": fingerprint,
            "backend": jax.default_backend(),
            "n_devices": n_dev,
            "trace_mode": trace_mode,
            "decimate": int(decimate),
            "horizon_us": float(grid_static[0]),
            "steps": int(grid_static[1]),
            "warm_steps": int(grid_static[2]),
            "n_cells": len(cfgs),
            "schemes": sorted({ln.scheme.name for ln in plan}),
            "n_launches": len(plan),
            "n_resumed": len(manifest) - len(executed_recs),
            "total_compile_s": sum(m.get("compile_s", 0.0)
                                   for m in executed_recs),
            "total_execute_s": sum(m.get("execute_s", 0.0)
                                   for m in executed_recs),
        }
        write_manifest(manifest_path, header, manifest)
    return rows


# ---------------------------------------------------------------------------
# Public entrypoints
# ---------------------------------------------------------------------------


def run_experiment(cfg: NetConfig, workload: Workload, scheme,
                   horizon_us: Optional[float] = None,
                   period_slots: int = 0, delay_pad: int = 0,
                   history_slots: int = 0, *,
                   trace_mode: str = "full",
                   decimate: int = 1, channel=None) -> Dict[str, float]:
    """Returns the Fig. 3 metric set for one (config, workload, scheme) —
    a B=1 delegation onto the batch-wide extractors (one copy of the
    metric definitions, no single-cell fork).

    ``scheme`` as a bare name string is deprecated here (pass
    ``get_scheme(name)``). ``channel``: registered channel-model name or
    instance (None = ``"ideal"``). ``delay_pad``/``history_slots``: minimum
    static ring sizes — pass a batch's padding to reproduce one of its
    cells exactly."""
    if isinstance(scheme, str):
        _warn_string_scheme("run_experiment")
    scheme = get_scheme(scheme)
    return run_experiment_batch(
        [cfg], workload, scheme, horizon_us, period_slots,
        trace_mode=trace_mode, decimate=decimate, delay_pad=delay_pad,
        history_slots=history_slots, channel=channel)[0]


def run_experiment_batch(cfgs: Sequence[NetConfig], workload, scheme,
                         horizon_us: Optional[float] = None,
                         period_slots: int = 0, *,
                         trace_mode: str = "full", decimate: int = 1,
                         chunk_cells: Optional[int] = None,
                         devices: Optional[Sequence] = None,
                         delay_pad: int = 0, history_slots: int = 0,
                         channel=None,
                         checkpoint_dir: Optional[str] = None,
                         resume: bool = False, on_nonfinite: str = "keep",
                         strict_conservation: bool = False,
                         conservation_tol: float = 1e-3,
                         abort_after_launches: Optional[int] = None,
                         manifest_path: Optional[str] = None
                         ) -> List[Dict[str, float]]:
    """Fig. 3 metrics for every scenario of a grid, from a chunked launch
    plan (one compiled program per scheme) and one vectorized metric pass
    per launch. ``workload``: shared ``Workload``, per-scenario sequence,
    or stacked ``WorkloadParams`` (see ``fluid.simulate_batch``).

    ``trace_mode="metrics"`` streams all reductions in-scan: device memory
    is O(B), no [B, T] trace array is ever allocated or transferred, and
    scheme-streamed columns (``Scheme.finalize_metrics``) join the rows.
    ``chunk_cells`` caps cells per device launch (None = bounded-memory
    auto size); ``devices`` restricts sharding of the scenario axis;
    ``channel`` selects the long-haul channel model (name or instance,
    None = ``"ideal"``) — non-ideal channels add the ``goodput_gbps`` /
    ``wire_gbps`` / ``retx_frac`` / ``p99_repair_latency_us`` columns in
    every trace mode.

    Hardening knobs (opt-in; see ``_execute_plan`` / docs/failures.md):
    ``checkpoint_dir`` + ``resume`` for crash-proof per-launch
    checkpointing, ``on_nonfinite`` for the per-cell finite guard,
    ``strict_conservation`` (+ ``conservation_tol``) to raise
    ``ConservationError`` with (cell, step) coordinates,
    ``abort_after_launches`` as the deterministic crash-injection hook,
    and ``manifest_path`` to emit a JSONL run manifest with per-launch
    compile/execute timings and memory figures (docs/observability.md)."""
    cfgs = list(cfgs)
    scheme = get_scheme(scheme)
    channel = get_channel_model(channel)
    wlp = as_workload_batch(workload, len(cfgs))
    grid_static = _grid_static(cfgs, horizon_us, delay_pad, history_slots)
    n_dev = len(devices) if devices is not None else len(jax.devices())
    chunk = _auto_chunk_cells(grid_static[1], trace_mode, decimate,
                              chunk_cells, n_dev, cfgs[0].num_paths,
                              _sched_floats(cfgs[0]))
    plan = _plan_launches(len(cfgs), (scheme,), chunk, n_dev)
    return _execute_plan(plan, cfgs, wlp, grid_static, period_slots,
                         trace_mode, decimate, devices, channel=channel,
                         checkpoint_dir=checkpoint_dir, resume=resume,
                         on_nonfinite=on_nonfinite,
                         strict_conservation=strict_conservation,
                         conservation_tol=conservation_tol,
                         abort_after_launches=abort_after_launches,
                         manifest_path=manifest_path)[scheme]


def convergence_horizon_us(cfgs: Sequence[NetConfig],
                           floor_us: float = 20_000.0) -> float:
    """Horizon long enough for CC to converge at EVERY distance of a grid:
    at least 20 RTTs at the farthest scenario plus a fixed floor. The one
    definition of the convergence margin — distance sweeps
    (``sweep``, ``benchmarks/scheme_compare.py``) size their shared
    horizon with it so short-distance cells simply observe a longer
    steady state."""
    return 40.0 * max(c.one_way_delay_us for c in cfgs) + floor_us


def sweep(cfg: NetConfig, workload: Workload, schemes, distances_km,
          horizon_us: Optional[float] = None, period_slots: int = 0, **kw):
    """Cartesian (distance x scheme) sweep; returns list of metric dicts in
    the order ``for d in distances: for s in schemes``.

    Batched execution: each scheme's whole distance grid is one launch
    plan (one compile per scheme). All cells share one horizon — the
    longest any distance needs for CC convergence
    (``convergence_horizon_us``) — so short-distance cells simply observe
    a longer steady state. Keyword extras (``trace_mode``,
    ``chunk_cells``, ``devices``, ...) pass through to ``sweep_grid``.
    """
    cfgs = [dataclasses.replace(cfg, distance_km=float(d))
            for d in distances_km]
    h = horizon_us
    if h is None:
        h = max(cfg.horizon_us, convergence_horizon_us(cfgs))
    return sweep_grid(cfgs, workload, schemes, h, period_slots, **kw)


def sweep_grid(scenarios, workload=None, schemes=(),
               horizon_us: Optional[float] = None, period_slots: int = 0, *,
               trace_mode: str = "full", decimate: int = 1,
               chunk_cells: Optional[int] = None,
               devices: Optional[Sequence] = None, channel=None,
               checkpoint_dir: Optional[str] = None, resume: bool = False,
               on_nonfinite: str = "keep",
               strict_conservation: bool = False,
               conservation_tol: float = 1e-3,
               abort_after_launches: Optional[int] = None,
               manifest_path: Optional[str] = None):
    """Heterogeneous scenario grids × schemes, executed as ONE launch plan:
    the grid is stacked once, chunked once, and every (scheme, chunk) pair
    is a device launch sharing the grid-wide static shapes. Returns rows in
    the order ``for scenario: for scheme``.

    Two spellings:
      * unified axis — ``sweep_grid([Scenario(cfg, wl), ...], schemes)``:
        each cell carries its own config AND workload (mixed OTN
        capacities, asymmetric buffers, different flow sets — one launch);
      * config axis only — ``sweep_grid(cfgs, shared_workload, schemes)``:
        the historical form, one workload across the grid.

    ``trace_mode="metrics"`` makes the whole sweep O(B) in device memory
    (plus per-scheme streamed columns); with auto ``chunk_cells`` a
    10k-cell grid runs in bounded memory on a single device and shards
    across all of ``jax.devices()`` when more are visible. ``channel``
    selects the long-haul channel model for every cell (name or instance,
    None = ``"ideal"``); impairment KNOBS (loss_rate, jitter_us, ...) are
    traced ``NetParams`` leaves, so an impairment grid still runs as one
    compiled program per scheme.

    Hardening knobs (opt-in; see ``_execute_plan`` / docs/failures.md):
    ``checkpoint_dir`` + ``resume`` checkpoint each finished launch
    atomically and let a rerun of the SAME plan skip finished chunks with
    bit-identical rows; ``on_nonfinite`` quarantines or raises on diverged
    cells; ``strict_conservation`` raises ``ConservationError`` naming the
    (cell, step) of the first violation; ``abort_after_launches`` is the
    deterministic crash-injection hook the resume test kills sweeps with;
    ``manifest_path`` emits a JSONL run manifest with per-launch
    compile/execute timings and memory figures (docs/observability.md).
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("sweep_grid: empty scenario grid")
    if isinstance(scenarios[0], Scenario):
        if workload is not None and not schemes \
                and not isinstance(workload, (Workload, WorkloadParams)):
            # positional sweep_grid(scenarios, schemes)
            workload, schemes = None, workload
        if workload is not None:
            raise ValueError(
                "sweep_grid: Scenario cells carry their own workloads — "
                "drop the workload argument")
        cfgs = [s.net for s in scenarios]
        wl = [s.workload for s in scenarios]
    else:
        cfgs, wl = scenarios, workload
        if wl is None:
            raise ValueError(
                "sweep_grid: pass a workload (or a grid of Scenario cells)")
    if isinstance(schemes, str):
        schemes = (schemes,)        # a lone name is a 1-scheme sweep
    if not schemes:
        raise ValueError(
            "sweep_grid: no schemes given — pass schemes=(\"dcqcn\", ...) "
            "(or positionally after the Scenario grid)")
    scheme_objs = [get_scheme(s) for s in schemes]
    channel = get_channel_model(channel)
    wlp = as_workload_batch(wl, len(cfgs))
    grid_static = _grid_static(cfgs, horizon_us, 0, 0)
    n_dev = len(devices) if devices is not None else len(jax.devices())
    chunk = _auto_chunk_cells(grid_static[1], trace_mode, decimate,
                              chunk_cells, n_dev, cfgs[0].num_paths,
                              _sched_floats(cfgs[0]))
    plan = _plan_launches(len(cfgs), scheme_objs, chunk, n_dev)
    by_scheme = _execute_plan(plan, cfgs, wlp, grid_static, period_slots,
                              trace_mode, decimate, devices, channel=channel,
                              checkpoint_dir=checkpoint_dir, resume=resume,
                              on_nonfinite=on_nonfinite,
                              strict_conservation=strict_conservation,
                              conservation_tol=conservation_tol,
                              abort_after_launches=abort_after_launches,
                              manifest_path=manifest_path)
    return [by_scheme[s][i]
            for i in range(len(cfgs)) for s in scheme_objs]
