"""Experiment runner: simulate + extract the paper's Fig. 3 metrics.

The batched path is canonical: every grid — heterogeneous configs AND
workloads (``Scenario``) — executes through a *launch plan*: the scenario
axis is stacked once, split into equal-size chunks (auto-sized so a launch's
trace block stays in bounded memory), and each (scheme, chunk) pair becomes
one vmapped device launch. All chunks of a grid share one compiled program
(the last chunk is padded by repeating its final cell) and shard across
devices whenever the chunk divides the device count.

Execution modes (``trace_mode`` — see ``fluid.py``):
  * ``full``     [B, T] traces materialize; metrics come from one vectorized
                 numpy pass (``_metrics_batch``).
  * ``decimate`` every k-th step materializes; same extractor, approximate
                 means/percentiles.
  * ``metrics``  nothing per-step ever exists: the scan carry streams the
                 Fig. 3 reductions (``MetricAcc``) and only O(B) accumulators
                 + final states transfer to host (``_metrics_streaming``).
                 Schemes append their own columns via
                 ``Scheme.finalize_metrics``.

``run_experiment`` is a thin B=1 delegation onto the same batch-wide
extractors — there is exactly one copy of the Fig. 3 metric definitions.
Passing a scheme NAME to the single-cell entrypoints is deprecated (resolve
through ``repro.netsim.schemes.get_scheme``); names remain first-class for
the grid APIs, where ``schemes=("dcqcn", "matchrdma")`` is the natural
spelling.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.config.base import NetConfig, batch_template
from repro.netsim.channel import get_channel_model
from repro.netsim.fluid import (
    WARMUP_FRAC, MetricAcc, batch_padding, hist_quantile, is_unfinished,
    simulate_batch,
)
from repro.netsim.schemes import get_scheme
from repro.netsim.workload import (
    Workload, WorkloadParams, as_workload_batch, is_unbounded,
)

# Auto-chunk targets of the launch plan: a full-trace launch keeps its
# materialized [B_chunk, T] block under ~256 MB of f32; a streaming launch
# is O(B) anyway and only caps per-launch compile/host-row cost.
MAX_TRACE_FLOATS = 64 * 1024 * 1024
METRICS_CHUNK_CELLS = 4096
_TRACE_KEYS_EST = 12        # 8 engine trace keys + scheme extras (estimate)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the unified scenario axis: a network config AND the
    workload that runs over it. ``sweep_grid`` accepts heterogeneous
    ``Scenario`` grids and executes them in one launch plan per scheme."""
    net: NetConfig
    workload: Workload


def _warn_string_scheme(fn_name: str) -> None:
    warnings.warn(
        f"passing a scheme name string to {fn_name}() is deprecated; "
        f"resolve it with repro.netsim.schemes.get_scheme(name) (or use "
        f"the batched sweep_grid API, where names remain first-class)",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Metric extraction (batch-wide; the ONLY copies of the Fig. 3 metric set)
# ---------------------------------------------------------------------------


def _flow_metrics(wl: WorkloadParams, final_np: dict):
    """[B] goodput / avg-FCT / completion from final state + workload
    leaves — per-flow quantities that never needed per-step traces. Padded
    flows carry ``is_inter == 0`` and ``total_bytes == 0`` and drop out of
    every mask."""
    is_inter = np.asarray(wl.is_inter) > 0                         # [B, F]
    delivered = final_np["delivered"]
    goodput = np.where(is_inter, delivered, 0.0).sum(axis=1)

    total = np.asarray(wl.total_bytes)
    start = np.asarray(wl.start_us)
    done_at = final_np["done_at_us"]
    # the shared sentinel helpers — NOT re-derived magic literals, so both
    # metric paths (and the engine) can never drift apart on what counts
    # as a finite flow / a completed flow
    finite = is_inter & ~is_unbounded(total)                       # [B, F]
    fct = done_at - start
    completed = finite & ~is_unfinished(done_at)
    n_finite = finite.sum(axis=1)
    n_completed = completed.sum(axis=1)
    sum_fct = np.where(completed, fct, 0.0).sum(axis=1)
    avg_fct = np.where(n_completed > 0,
                       sum_fct / np.maximum(n_completed, 1), np.inf)
    avg_fct = np.where(n_finite > 0, avg_fct, np.nan)
    completion = np.where(n_finite > 0,
                          n_completed / np.maximum(n_finite, 1), 1.0)
    return goodput, avg_fct, completion


def _assemble_rows(cfgs: Sequence[NetConfig], scheme_name: str,
                   cols: dict, extra: Optional[dict] = None
                   ) -> List[Dict[str, float]]:
    """[B]-column dicts -> the per-cell row list of a sweep."""
    rows = []
    for i, cfg in enumerate(cfgs):
        row = {"scheme": scheme_name, "distance_km": cfg.distance_km}
        row.update({k: float(v[i]) for k, v in cols.items()})
        if extra:
            row.update({k: float(np.asarray(v)[i]) for k, v in extra.items()})
        rows.append(row)
    return rows


def _channel_cols_from_traces(traces_np: dict, warm: int, dt_s: float,
                              decimate: int = 1) -> dict:
    """The channel metric columns from materialized ``chan_*`` traces —
    the full/decimate-mode twin of ``ChannelModel.finalize_metrics`` (same
    column set, so impairment sweeps agree across trace modes).

    Rate columns normalize by SIMULATED time, not sample count: a
    decimated trace holds ``steps/decimate`` samples, each a block SUM of
    ``decimate`` steps' bytes (``fluid.DECIMATE_SUM_KEYS``), so
    ``n_samples * decimate * dt_s`` is the window the bytes accumulated
    over and the Gbps columns agree exactly with the streamed path at any
    decimation."""
    wire = traces_np["chan_wire"][:, warm:].astype(np.float64)
    lost = traces_np["chan_lost"][:, warm:].astype(np.float64)
    retx = traces_np["chan_retx"][:, warm:].astype(np.float64)
    wait = traces_np["chan_repair_wait_us"][:, warm:]
    per_s = 1.0 / (max(wire.shape[1], 1) * max(decimate, 1) * dt_s)
    # p99 over steps with a repair actually pending (matches the streamed
    # histogram, which only counts wait > 0 samples)
    p99 = np.zeros(wire.shape[0])
    for i in range(wire.shape[0]):
        pending = wait[i][wait[i] > 0]
        p99[i] = np.percentile(pending, 99) if pending.size else 0.0
    return {
        "goodput_gbps": (wire.sum(axis=1) - lost.sum(axis=1))
        * per_s * 8.0 / 1e9,
        "wire_gbps": wire.sum(axis=1) * per_s * 8.0 / 1e9,
        "retx_frac": retx.sum(axis=1) / np.maximum(wire.sum(axis=1), 1.0),
        "p99_repair_latency_us": p99,
    }


def _metrics_batch(cfgs: Sequence[NetConfig], wl: WorkloadParams,
                   scheme_name: str, final_np: dict, traces_np: dict,
                   decimate: int = 1) -> List[Dict[str, float]]:
    """Fig. 3 metric set from materialized [B, T] traces in ONE vectorized
    pass (``trace_mode="full"``/``"decimate"``)."""
    steps = traces_np["q_dst"].shape[1]
    warm = int(steps * WARMUP_FRAC)

    q_dst = traces_np["q_dst"]
    goodput, avg_fct, completion = _flow_metrics(wl, final_np)
    cols = {
        "throughput_gbps":
            traces_np["thr_inter"][:, warm:].mean(axis=1) * 8.0 / 1e9,
        "goodput_bytes": goodput,
        "peak_buffer_mb": q_dst.max(axis=1) / 1e6,
        "mean_buffer_mb": q_dst[:, warm:].mean(axis=1) / 1e6,
        "p99_buffer_mb": np.percentile(q_dst[:, warm:], 99, axis=1) / 1e6,
        "pause_ratio": traces_np["pause_dst"][:, warm:].mean(axis=1),
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps":
            traces_np["thr_intra"][:, warm:].mean(axis=1) * 8.0 / 1e9,
    }
    if "chan_wire" in traces_np:
        cols.update(_channel_cols_from_traces(
            traces_np, warm, cfgs[0].dt_us * 1e-6, decimate))
    return _assemble_rows(cfgs, scheme_name, cols)


def _metrics_streaming(cfgs: Sequence[NetConfig], wl: WorkloadParams,
                       scheme, channel, final_np: dict, acc: MetricAcc,
                       steps: int, warm: int) -> List[Dict[str, float]]:
    """The same Fig. 3 metric set from the O(B) streamed accumulators
    (``trace_mode="metrics"`` — no [B, T] array ever existed). p99 comes
    from inverting the fixed-bin log-histogram (bounded relative error);
    everything else is exact up to summation order."""
    n_warm = max(steps - warm, 1)
    sums = {k: np.asarray(v, np.float64) for k, v in acc.sum_s.items()}
    goodput, avg_fct, completion = _flow_metrics(wl, final_np)
    cols = {
        "throughput_gbps": sums["thr_inter"] / n_warm * 8.0 / 1e9,
        "goodput_bytes": goodput,
        "peak_buffer_mb": np.asarray(acc.maxes["q_dst"]) / 1e6,
        "mean_buffer_mb": sums["q_dst"] / n_warm / 1e6,
        "p99_buffer_mb": hist_quantile(acc.hist, 0.99) / 1e6,
        "pause_ratio": sums["pause_dst"] / n_warm,
        "avg_fct_us": avg_fct,
        "completion_frac": completion,
        "intra_thr_gbps": sums["thr_intra"] / n_warm * 8.0 / 1e9,
    }
    extra = scheme.finalize_metrics(
        jax.tree.map(np.asarray, acc.scheme), steps, n_warm)
    if not channel.is_ideal:
        extra = dict(extra or {})
        extra.update(channel.finalize_metrics(
            jax.tree.map(np.asarray, acc.chan), steps, n_warm,
            cfgs[0].dt_us * 1e-6))
    return _assemble_rows(cfgs, scheme.name, cols, extra)


# ---------------------------------------------------------------------------
# The launch plan: (scheme x chunk) device launches over a stacked grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Launch:
    """One device launch of a sweep's plan: ``scheme`` over grid cells
    [lo, hi), padded up to ``pad_to`` cells so every chunk of a grid shares
    one compiled program (padding rows are dropped from the output)."""
    scheme: object
    lo: int
    hi: int
    pad_to: int


def chunk_cells(steps: int, trace_mode: str = "full", decimate: int = 1,
                chunk_cells: Optional[int] = None,
                n_devices: int = 1, num_links: int = 1,
                schedule_floats: int = 0) -> int:
    """Scenario cells per device launch of a sweep's plan.

    Returns the explicit ``chunk_cells`` override when given, else the
    bounded-memory auto size: in ``full``/``decimate`` modes the chunk is
    sized so one launch's materialized trace block stays under
    ``MAX_TRACE_FLOATS`` f32 values (~256 MB) — multi-link grids
    (``num_links > 1``) add per-link [L] trace keys, so their per-step
    float estimate grows with L and the chunk shrinks accordingly; in
    ``metrics`` mode the launch is O(B) anyway and the flat
    ``METRICS_CHUNK_CELLS`` ceiling only caps per-launch compile/host-row
    cost. ``schedule_floats`` is the per-cell resident footprint of a
    ``trace_replay`` schedule table (``num_paths * schedule_len * 3``
    f32 values — the stacked ``chan_schedule`` leaf rides along with
    every launch), folded into the per-cell budget in every mode so a
    long recorded trace shrinks the chunk instead of blowing the launch
    past the memory target. The result is rounded up to a multiple of
    ``n_devices`` so chunked grids still shard the scenario axis evenly.
    (Not clamped to the grid size — ``_plan_launches`` caps the final
    chunk at the cell count and pads the trailing chunk so every launch
    shares one compiled program.)
    """
    if chunk_cells is None:
        if trace_mode == "metrics":
            chunk_cells = METRICS_CHUNK_CELLS
            if schedule_floats > 0:
                chunk_cells = min(
                    chunk_cells,
                    max(MAX_TRACE_FLOATS // schedule_floats, 1))
        else:
            t = max(steps // max(decimate, 1), 1)
            # q_dst_link / link_tx / link_pause are [L] per step at L>1
            keys = _TRACE_KEYS_EST + (3 * num_links if num_links > 1 else 0)
            chunk_cells = max(
                MAX_TRACE_FLOATS // (t * keys + max(schedule_floats, 0)), 1)
    chunk_cells = max(int(chunk_cells), 1)
    if n_devices > 1:
        chunk_cells = -(-chunk_cells // n_devices) * n_devices
    return chunk_cells


# non-deprecated private alias: inside run_experiment_batch / sweep_grid the
# ``chunk_cells`` KEYWORD shadows the module-level function
_auto_chunk_cells = chunk_cells


def _sched_floats(cfg: NetConfig) -> int:
    """Per-cell f32 footprint of the cfg's channel-schedule table."""
    return cfg.num_paths * cfg.schedule_len * 3


def __getattr__(name: str):
    if name == "_chunk_cells":
        warnings.warn(
            "repro.netsim.runner._chunk_cells is deprecated (it was a "
            "pre-PR 4 private alias) and will be removed in a future PR; "
            "use runner.chunk_cells instead",
            DeprecationWarning, stacklevel=2)
        return chunk_cells
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def _plan_launches(n_cells: int, schemes: Sequence, chunk: int,
                   n_devices: int = 1) -> List[_Launch]:
    """Flatten (scheme x chunk) into the launch list — the per-scheme
    Python loop of the old sweep path, folded into explicit plan entries.
    EVERY launch — including the single-launch case of a grid smaller than
    one chunk — pads to a device multiple, so the scenario axis always
    splits evenly across devices and ``shard_scenario_axis`` never sees an
    odd batch (padding rows are dropped)."""
    pad_to = min(chunk, n_cells)
    if n_devices > 1:
        pad_to = -(-pad_to // n_devices) * n_devices
    return [_Launch(s, lo, min(lo + chunk, n_cells), pad_to)
            for s in schemes for lo in range(0, n_cells, chunk)]


def _pad_chunk(cfgs, wlp: WorkloadParams, n: int):
    """Pad a trailing chunk to ``n`` cells by repeating its last cell (the
    duplicate rows are dropped after the launch)."""
    pad = n - len(cfgs)
    if pad <= 0:
        return cfgs, wlp
    leaves = [np.asarray(v) for v in wlp]
    wlp = WorkloadParams(*(np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                           for v in leaves))
    return list(cfgs) + [cfgs[-1]] * pad, wlp


def _grid_static(cfgs, horizon_us, delay_pad: int, history_slots: int):
    """The grid-wide static quantities every launch of a plan shares —
    resolved horizon, scan length, warm cutoff, ring paddings — computed
    ONCE over the WHOLE grid. Chunks must never re-derive them from their
    own sub-grid, or chunked launches would stop sharing one compiled
    program (and streaming normalizers would drift from the scan length)."""
    dp, hs = batch_padding(cfgs)
    horizon = (horizon_us if horizon_us is not None
               else max(c.horizon_us for c in cfgs))
    steps = batch_template(cfgs).horizon_steps(horizon)
    return (horizon, steps, int(steps * WARMUP_FRAC),
            max(delay_pad, dp), max(history_slots, hs))


def _execute_plan(plan: Sequence[_Launch], cfgs, wlp: WorkloadParams,
                  grid_static, period_slots, trace_mode, decimate,
                  devices, channel=None) -> Dict[object, list]:
    """Run every launch; returns scheme -> full row list (grid order).
    ``grid_static`` is the shared ``_grid_static`` tuple, so all chunks
    (and all schemes) see identical static shapes, hence one compiled
    program per scheme."""
    horizon, steps, warm, delay_pad, history_slots = grid_static
    channel = get_channel_model(channel)
    wlp_np = [np.asarray(v) for v in wlp]

    rows: Dict[object, list] = {}
    for launch in plan:
        sub_cfgs = cfgs[launch.lo:launch.hi]
        sub_wlp = WorkloadParams(*(v[launch.lo:launch.hi] for v in wlp_np))
        n_real = len(sub_cfgs)
        sub_cfgs, sub_wlp = _pad_chunk(sub_cfgs, sub_wlp, launch.pad_to)
        final, aux = simulate_batch(
            sub_cfgs, sub_wlp, launch.scheme, horizon, period_slots,
            trace_mode=trace_mode, decimate=decimate,
            delay_pad=delay_pad, history_slots=history_slots,
            devices=devices, warm_steps=warm, channel=channel)
        final_np = {"delivered": np.asarray(final.delivered),
                    "done_at_us": np.asarray(final.done_at_us)}
        wl_np = WorkloadParams(*(np.asarray(v) for v in sub_wlp))
        if trace_mode == "metrics":
            sub_rows = _metrics_streaming(sub_cfgs, wl_np, launch.scheme,
                                          channel, final_np, aux, steps,
                                          warm)
        else:
            traces_np = {k: np.asarray(v) for k, v in aux.items()}
            sub_rows = _metrics_batch(
                sub_cfgs, wl_np, launch.scheme.name, final_np, traces_np,
                decimate if trace_mode == "decimate" else 1)
        rows.setdefault(launch.scheme, []).extend(sub_rows[:n_real])
    return rows


# ---------------------------------------------------------------------------
# Public entrypoints
# ---------------------------------------------------------------------------


def run_experiment(cfg: NetConfig, workload: Workload, scheme,
                   horizon_us: Optional[float] = None,
                   period_slots: int = 0, delay_pad: int = 0,
                   history_slots: int = 0, *,
                   trace_mode: str = "full",
                   decimate: int = 1, channel=None) -> Dict[str, float]:
    """Returns the Fig. 3 metric set for one (config, workload, scheme) —
    a B=1 delegation onto the batch-wide extractors (one copy of the
    metric definitions, no single-cell fork).

    ``scheme`` as a bare name string is deprecated here (pass
    ``get_scheme(name)``). ``channel``: registered channel-model name or
    instance (None = ``"ideal"``). ``delay_pad``/``history_slots``: minimum
    static ring sizes — pass a batch's padding to reproduce one of its
    cells exactly."""
    if isinstance(scheme, str):
        _warn_string_scheme("run_experiment")
    scheme = get_scheme(scheme)
    return run_experiment_batch(
        [cfg], workload, scheme, horizon_us, period_slots,
        trace_mode=trace_mode, decimate=decimate, delay_pad=delay_pad,
        history_slots=history_slots, channel=channel)[0]


def run_experiment_batch(cfgs: Sequence[NetConfig], workload, scheme,
                         horizon_us: Optional[float] = None,
                         period_slots: int = 0, *,
                         trace_mode: str = "full", decimate: int = 1,
                         chunk_cells: Optional[int] = None,
                         devices: Optional[Sequence] = None,
                         delay_pad: int = 0, history_slots: int = 0,
                         channel=None) -> List[Dict[str, float]]:
    """Fig. 3 metrics for every scenario of a grid, from a chunked launch
    plan (one compiled program per scheme) and one vectorized metric pass
    per launch. ``workload``: shared ``Workload``, per-scenario sequence,
    or stacked ``WorkloadParams`` (see ``fluid.simulate_batch``).

    ``trace_mode="metrics"`` streams all reductions in-scan: device memory
    is O(B), no [B, T] trace array is ever allocated or transferred, and
    scheme-streamed columns (``Scheme.finalize_metrics``) join the rows.
    ``chunk_cells`` caps cells per device launch (None = bounded-memory
    auto size); ``devices`` restricts sharding of the scenario axis;
    ``channel`` selects the long-haul channel model (name or instance,
    None = ``"ideal"``) — non-ideal channels add the ``goodput_gbps`` /
    ``wire_gbps`` / ``retx_frac`` / ``p99_repair_latency_us`` columns in
    every trace mode."""
    cfgs = list(cfgs)
    scheme = get_scheme(scheme)
    channel = get_channel_model(channel)
    wlp = as_workload_batch(workload, len(cfgs))
    grid_static = _grid_static(cfgs, horizon_us, delay_pad, history_slots)
    n_dev = len(devices) if devices is not None else len(jax.devices())
    chunk = _auto_chunk_cells(grid_static[1], trace_mode, decimate,
                              chunk_cells, n_dev, cfgs[0].num_paths,
                              _sched_floats(cfgs[0]))
    plan = _plan_launches(len(cfgs), (scheme,), chunk, n_dev)
    return _execute_plan(plan, cfgs, wlp, grid_static, period_slots,
                         trace_mode, decimate, devices,
                         channel=channel)[scheme]


def convergence_horizon_us(cfgs: Sequence[NetConfig],
                           floor_us: float = 20_000.0) -> float:
    """Horizon long enough for CC to converge at EVERY distance of a grid:
    at least 20 RTTs at the farthest scenario plus a fixed floor. The one
    definition of the convergence margin — distance sweeps
    (``sweep``, ``benchmarks/scheme_compare.py``) size their shared
    horizon with it so short-distance cells simply observe a longer
    steady state."""
    return 40.0 * max(c.one_way_delay_us for c in cfgs) + floor_us


def sweep(cfg: NetConfig, workload: Workload, schemes, distances_km,
          horizon_us: Optional[float] = None, period_slots: int = 0, **kw):
    """Cartesian (distance x scheme) sweep; returns list of metric dicts in
    the order ``for d in distances: for s in schemes``.

    Batched execution: each scheme's whole distance grid is one launch
    plan (one compile per scheme). All cells share one horizon — the
    longest any distance needs for CC convergence
    (``convergence_horizon_us``) — so short-distance cells simply observe
    a longer steady state. Keyword extras (``trace_mode``,
    ``chunk_cells``, ``devices``, ...) pass through to ``sweep_grid``.
    """
    cfgs = [dataclasses.replace(cfg, distance_km=float(d))
            for d in distances_km]
    h = horizon_us
    if h is None:
        h = max(cfg.horizon_us, convergence_horizon_us(cfgs))
    return sweep_grid(cfgs, workload, schemes, h, period_slots, **kw)


def sweep_grid(scenarios, workload=None, schemes=(),
               horizon_us: Optional[float] = None, period_slots: int = 0, *,
               trace_mode: str = "full", decimate: int = 1,
               chunk_cells: Optional[int] = None,
               devices: Optional[Sequence] = None, channel=None):
    """Heterogeneous scenario grids × schemes, executed as ONE launch plan:
    the grid is stacked once, chunked once, and every (scheme, chunk) pair
    is a device launch sharing the grid-wide static shapes. Returns rows in
    the order ``for scenario: for scheme``.

    Two spellings:
      * unified axis — ``sweep_grid([Scenario(cfg, wl), ...], schemes)``:
        each cell carries its own config AND workload (mixed OTN
        capacities, asymmetric buffers, different flow sets — one launch);
      * config axis only — ``sweep_grid(cfgs, shared_workload, schemes)``:
        the historical form, one workload across the grid.

    ``trace_mode="metrics"`` makes the whole sweep O(B) in device memory
    (plus per-scheme streamed columns); with auto ``chunk_cells`` a
    10k-cell grid runs in bounded memory on a single device and shards
    across all of ``jax.devices()`` when more are visible. ``channel``
    selects the long-haul channel model for every cell (name or instance,
    None = ``"ideal"``); impairment KNOBS (loss_rate, jitter_us, ...) are
    traced ``NetParams`` leaves, so an impairment grid still runs as one
    compiled program per scheme.
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("sweep_grid: empty scenario grid")
    if isinstance(scenarios[0], Scenario):
        if workload is not None and not schemes \
                and not isinstance(workload, (Workload, WorkloadParams)):
            # positional sweep_grid(scenarios, schemes)
            workload, schemes = None, workload
        if workload is not None:
            raise ValueError(
                "sweep_grid: Scenario cells carry their own workloads — "
                "drop the workload argument")
        cfgs = [s.net for s in scenarios]
        wl = [s.workload for s in scenarios]
    else:
        cfgs, wl = scenarios, workload
        if wl is None:
            raise ValueError(
                "sweep_grid: pass a workload (or a grid of Scenario cells)")
    if isinstance(schemes, str):
        schemes = (schemes,)        # a lone name is a 1-scheme sweep
    if not schemes:
        raise ValueError(
            "sweep_grid: no schemes given — pass schemes=(\"dcqcn\", ...) "
            "(or positionally after the Scenario grid)")
    scheme_objs = [get_scheme(s) for s in schemes]
    channel = get_channel_model(channel)
    wlp = as_workload_batch(wl, len(cfgs))
    grid_static = _grid_static(cfgs, horizon_us, 0, 0)
    n_dev = len(devices) if devices is not None else len(jax.devices())
    chunk = _auto_chunk_cells(grid_static[1], trace_mode, decimate,
                              chunk_cells, n_dev, cfgs[0].num_paths,
                              _sched_floats(cfgs[0]))
    plan = _plan_launches(len(cfgs), scheme_objs, chunk, n_dev)
    by_scheme = _execute_plan(plan, cfgs, wlp, grid_static, period_slots,
                              trace_mode, decimate, devices,
                              channel=channel)
    return [by_scheme[s][i]
            for i in range(len(cfgs)) for s in scheme_objs]
