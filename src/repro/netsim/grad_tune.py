"""Gradient-based controller autotuning over the differentiable engine.

Replaces the zeroth-order population search in ``benchmarks.hillclimb
.netsim_tune`` with ``jax.value_and_grad`` straight through the soft-step
engine (``NetConfig.soft_step`` — docs/differentiable.md): one Adam step
costs TWO simulator evaluations per cell (forward + backward) against the
hillclimb's five-candidate population per iteration, and it follows the
actual objective slope instead of shrinking a bracket.

Pieces
------
``KNOB_BOUNDS``          the tunable controller knobs and their boxes —
                         the same boxes ``netsim_tune`` brackets over.
``ADVERSARIAL_BOUNDS``   impairment-knob boxes for the adversarial mode:
                         ``tune(..., adversarial=True)`` gradient-ASCENDS
                         the channel knobs (under the ``impaired`` model)
                         to find the worst-case impairment mix for a
                         scheme — the tuner turned attacker.
``tune``                 clamp-reparameterized Adam over the chosen knob
                         vector, shared across the distance grid; the
                         final knob is then scored on the HARD engine
                         with the true hillclimb objective (a soft-mode
                         surrogate may not be trusted as a result).

Objectives
----------
The descent objective is a *smooth surrogate* built from the streamed
sums (no p99: the histogram inversion is piecewise constant, its gradient
is zero almost everywhere):

    surrogate = thr_mean_gbps - 0.5 * mean_buffer_mb - pause_ratio

The reported ``objective`` is the true hillclimb score
``throughput_gbps - 0.5 * p99_buffer_mb`` from a hard-engine
(``soft_step=False``) evaluation at the tuned knob — comparable
number-for-number with ``netsim_tune``'s printed scores.

Accounting is honest: ``sim_evals`` counts 2 per Adam step (forward +
backward sweep of the scan) plus 1 for the final hard-engine scoring,
per cell. ``benchmarks.grad_tune_bench`` pins this against the
hillclimb's ``iters * population`` evals-to-target.

Temperature vs horizon: the backward sweep accumulates float32 tangents
over the whole scan, and cold temperatures sharpen per-step gate
Jacobians — at ``temp=0.3`` the tangents stay FD-faithful out to a
~20 ms horizon (~18k steps) but turn to noise by 40 ms, while
``temp>=0.6`` stays clean there (``temp=1.0`` matches FD at 80 ms).
``tune`` therefore defaults ``temp=None`` → ``max(0.3, 1.5e-5 ·
horizon_us)``, the measured clean frontier, and clips gradients at
±1e6 so a blown tangent can at worst waste a step, never silently
freeze Adam (an overflowing ``g²`` second moment zeroes the update);
see docs/differentiable.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.netsim.fluid import (
    WARMUP_FRAC, _run_traced_batch_impl, as_workload_batch, batch_padding,
    batch_template, stack_net_params,
)

__all__ = [
    "KNOB_BOUNDS", "ADVERSARIAL_BOUNDS", "TuneResult", "tune",
    "surrogate_from_sums", "true_objective",
]

# controller knobs the gradient tuner may move, and their boxes — the same
# brackets benchmarks.hillclimb.netsim_tune searches. Both are traced
# NetParams leaves, so every Adam step reuses one compiled program.
KNOB_BOUNDS: Dict[str, Tuple[float, float]] = {
    "budget_headroom": (0.85, 1.0),
    "slot_us": (50.0, 400.0),
}

# impairment knobs for the adversarial mode (channel model ``impaired``):
# the tuner gradient-ascends these to MINIMIZE the scheme's objective.
ADVERSARIAL_BOUNDS: Dict[str, Tuple[float, float]] = {
    "loss_rate": (0.0, 0.05),
    "jitter_us": (0.0, 200.0),
    "flap_depth": (0.0, 1.0),
}


class TuneResult(NamedTuple):
    knobs: Dict[str, float]       # tuned knob values (clamped, final)
    objective: float              # TRUE objective, hard engine, final knobs
    surrogate: float              # last soft-surrogate value seen
    sim_evals: int                # per-cell simulator evaluations spent
    history: List[Dict[str, float]]   # per-step {knob..., "surrogate"}


def surrogate_from_sums(sum_s: dict, n_warm: int) -> jax.Array:
    """Smooth scalar objective from the streamed per-cell sums ([B] each):
    mean over the batch of throughput minus buffer/pause penalties."""
    thr = sum_s["thr_inter"] / n_warm * 8.0 / 1e9          # Gbps
    qdst = sum_s["q_dst"] / n_warm / 1e6                   # mean MB
    pause = sum_s["pause_dst"] / n_warm                    # ratio
    return jnp.mean(thr - 0.5 * qdst - pause)


def true_objective(rows: Sequence[dict]) -> float:
    """The hillclimb score over a batch of hard-engine metric rows."""
    thr = sum(r["throughput_gbps"] for r in rows) / len(rows)
    buf = sum(r["p99_buffer_mb"] for r in rows) / len(rows)
    return float(thr - 0.5 * buf)


def _adam_step(theta, m, v, g, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** t)
    vhat = v / (1.0 - b2 ** t)
    return theta - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def tune(knobs: Sequence[str] = ("budget_headroom",),
         scheme="matchrdma",
         dists: Sequence[float] = (100.0, 1000.0),
         horizon_us: float = 80_000.0,
         workload=None,
         channel: Optional[str] = None,
         steps: int = 8,
         lr_frac: float = 0.08,
         temp: Optional[float] = None,
         adversarial: bool = False,
         base_cfg: Optional[NetConfig] = None,
         init: Optional[Dict[str, float]] = None,
         verbose: bool = False) -> TuneResult:
    """Adam-tune ``knobs`` (shared across the ``dists`` grid) by gradient
    descent through the soft-step engine; score the result hard.

    ``lr_frac`` is the Adam step as a fraction of each knob's box width
    (Adam's invariance to gradient scale makes this the natural unit).
    ``temp=None`` picks the horizon-scaled default (module docstring,
    "Temperature vs horizon"). ``adversarial=True`` flips the sign (the tuner MINIMIZES the scheme's
    surrogate by moving impairment knobs) and defaults the channel to
    ``"impaired"``; knobs must then come from ``ADVERSARIAL_BOUNDS``.
    """
    from repro.netsim import get_scheme, run_experiment_batch
    from repro.netsim.workload import congestion_workload

    scheme = get_scheme(scheme)
    bounds = ADVERSARIAL_BOUNDS if adversarial else KNOB_BOUNDS
    if adversarial and channel is None:
        channel = "impaired"
    for k in knobs:
        if k not in bounds:
            raise ValueError(f"grad_tune: unknown knob {k!r} "
                             f"(have {sorted(bounds)})")
    lo = jnp.asarray([bounds[k][0] for k in knobs], jnp.float32)
    hi = jnp.asarray([bounds[k][1] for k in knobs], jnp.float32)
    if init is None:
        theta = (lo + hi) / 2.0
    else:
        theta = jnp.asarray([init[k] for k in knobs], jnp.float32)
    wl = congestion_workload() if workload is None else workload
    if temp is None:
        # the measured float32-tangent clean frontier (module docstring)
        temp = max(0.3, 1.5e-5 * horizon_us)

    if base_cfg is None:
        base_cfg = NetConfig()
    soft_base = dataclasses.replace(base_cfg, soft_step=True, soft_temp=temp,
                                    horizon_us=horizon_us)
    cfgs = [dataclasses.replace(soft_base, distance_km=d) for d in dists]
    b = len(cfgs)
    tmpl = batch_template(cfgs)
    n_steps = tmpl.horizon_steps(None)
    delay_pad, hist_slots = batch_padding(cfgs)
    wlp = as_workload_batch(wl, b)
    params0 = stack_net_params(cfgs)
    warm = int(n_steps * WARMUP_FRAC)
    n_warm = max(n_steps - warm, 1)
    sign = -1.0 if adversarial else 1.0

    def loss(th):
        # clamp reparameterization: the simulator always sees an in-box
        # knob; clip's zero gradient outside the box pins saturated knobs
        # at the wall (Adam momentum walks them back in when the slope
        # reverses).
        vals = jnp.clip(th, lo, hi)
        p = params0._replace(
            **{k: jnp.full((b,), vals[i], jnp.float32)
               for i, k in enumerate(knobs)})
        _, acc = _run_traced_batch_impl(
            tmpl, p, wlp, scheme, n_steps, 0, delay_pad, hist_slots,
            mode="metrics", warm=warm, channel=channel)
        return -sign * surrogate_from_sums(acc.sum_s, n_warm)

    vg = jax.jit(jax.value_and_grad(loss))
    lr = lr_frac * (hi - lo)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    history: List[Dict[str, float]] = []
    surr = float("nan")
    for t in range(1, steps + 1):
        val, g = vg(theta)
        # a blown float32 tangent must at worst waste a step: unclipped,
        # g*g overflows Adam's second moment to inf and the update
        # silently becomes zero for the rest of the run
        g = jnp.clip(g, -1e6, 1e6)
        surr = sign * -float(val)
        rec = {k: float(jnp.clip(theta, lo, hi)[i])
               for i, k in enumerate(knobs)}
        rec["surrogate"] = surr
        history.append(rec)
        if verbose:
            print(f"  adam {t}: surrogate={surr:.3f} "
                  + " ".join(f"{k}={rec[k]:.4g}" for k in knobs))
        theta, m, v = _adam_step(theta, m, v, g, t, lr)

    final = {k: float(jnp.clip(theta, lo, hi)[i])
             for i, k in enumerate(knobs)}
    # hard-engine scoring at the tuned knob: the reported objective is the
    # same number netsim_tune prints, never the soft surrogate
    hard = [dataclasses.replace(base_cfg, distance_km=d,
                                horizon_us=horizon_us, **final)
            for d in dists]
    rows = run_experiment_batch(hard, wl, scheme, horizon_us,
                                trace_mode="metrics", channel=channel)
    obj = true_objective(rows)
    return TuneResult(knobs=final, objective=obj, surrogate=surr,
                      sim_evals=2 * steps + 1, history=history)
