"""Queue helpers for the fluid simulator: ECN marking, PFC hysteresis,
proportional-fair fluid drains.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig
from repro.netsim.soft import soft_gt, soft_hysteresis


def ecn_mark_prob(q_bytes: jax.Array, cfg: NetConfig,
                  params=None, soft=None) -> jax.Array:
    """DCQCN RED-like marking probability from queue occupancy. ``params``
    (a ``NetParams``) supplies traced per-scenario thresholds when batching;
    ``soft`` (a traced temperature, docs/differentiable.md) relaxes the
    above-kmax step term to a tempered sigmoid."""
    src = cfg if params is None else params
    kmin = src.ecn_kmin_kb * 1024.0
    kmax = src.ecn_kmax_kb * 1024.0
    frac = jnp.clip((q_bytes - kmin) / jnp.maximum(kmax - kmin, 1.0), 0.0, 1.0)
    if soft is None:
        over = (q_bytes > kmax).astype(jnp.float32)
    else:
        over = soft_gt(q_bytes, kmax, soft, 0.05 * kmax + 1.0)
    return frac * cfg.ecn_pmax + over * (1.0 - cfg.ecn_pmax)


def pfc_hysteresis(paused: jax.Array, q_bytes: jax.Array,
                   xoff_bytes: float, xon_bytes: float,
                   soft=None) -> jax.Array:
    """XOFF above ``xoff``, XON below ``xon``, hold in between. ``soft``
    (a traced temperature) swaps the hard loop for the tempered blend in
    ``repro.netsim.soft.soft_hysteresis``; the pause signal then lives in
    [0, 1] instead of {0, 1}."""
    if soft is not None:
        return soft_hysteresis(paused, q_bytes, xoff_bytes, xon_bytes, soft)
    return jnp.where(q_bytes > xoff_bytes, 1.0,
                     jnp.where(q_bytes < xon_bytes, 0.0, paused))


def drain_proportional(q: jax.Array, arrivals: jax.Array,
                       capacity_bytes: jax.Array):
    """Fluid FIFO-fair drain: remove up to ``capacity_bytes`` from the queue,
    split across flows proportionally to their backlog (+ fresh arrivals).

    q, arrivals: [F] per-flow bytes. Returns (new_q [F], drained [F]).
    """
    avail = q + arrivals
    tot = jnp.sum(avail)
    drained_tot = jnp.minimum(tot, capacity_bytes)
    share = jnp.where(tot > 0, avail / jnp.maximum(tot, 1e-12), 0.0)
    drained = share * drained_tot
    return avail - drained, drained
