"""Shared streaming-reduction helpers for ``trace_mode="metrics"``.

The O(B) execution mode accumulates its reductions inside the ``lax.scan``
carry; the pieces every accumulator reuses live here (and NOT in
``fluid.py``) so the channel subsystem and scheme packages can build their
own streamed columns without importing the engine:

  * the fixed-bin log histogram (``HIST_BINS`` / ``hist_bin_index`` /
    ``hist_quantile``) — the bounded-relative-error streaming quantile the
    engine uses for the p99 buffer and the channel subsystem reuses for the
    p99 repair latency;
  * Kahan-compensated running sums (``kahan_add``) — so a streamed mean
    matches the numpy trace mean to ~ulp over long horizons.

The histogram is generic over units (bin 0 holds everything below
``HIST_MIN``, log-spaced bins over 12 decades above it): the engine feeds
it queue *bytes*, the channel subsystem repair-wait *microseconds*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# fixed-bin log histogram for streaming quantiles: bin 0 holds everything
# below HIST_MIN, bins 1..HIST_BINS-1 are log-spaced over [HIST_MIN,
# HIST_MAX). Inverting it bounds the quantile estimate's relative error by
# the bin ratio (~5.6% at 512 bins / 12 decades), independent of the
# horizon length.
HIST_BINS = 512
HIST_MIN = 1.0
HIST_MAX = 1e12


def hist_bin_index(x: jax.Array) -> jax.Array:
    """Histogram bin of a non-negative sample (traced)."""
    span = float(np.log(HIST_MAX) - np.log(HIST_MIN))
    frac = (jnp.log(jnp.maximum(x, HIST_MIN))
            - float(np.log(HIST_MIN))) / span
    idx = 1 + jnp.floor(frac * (HIST_BINS - 1)).astype(jnp.int32)
    return jnp.where(x < HIST_MIN, 0, jnp.clip(idx, 1, HIST_BINS - 1))


def hist_bin_centers() -> np.ndarray:
    """Representative value per histogram bin: 0 for the zero bin,
    geometric bin centers for the log bins (host-side numpy)."""
    edges = np.exp(np.linspace(np.log(HIST_MIN), np.log(HIST_MAX),
                               HIST_BINS))
    return np.concatenate([[0.0], np.sqrt(edges[:-1] * edges[1:])])


def hist_quantile(hist, q: float) -> np.ndarray:
    """Invert a streamed log-histogram (leading axes preserved) into the
    q-quantile estimate, in the unit the histogram was fed."""
    hist = np.asarray(hist, np.float64)
    rank = q * hist.sum(axis=-1, keepdims=True)
    idx = (np.cumsum(hist, axis=-1) < rank).sum(axis=-1)
    return hist_bin_centers()[np.clip(idx, 0, HIST_BINS - 1)]


def kahan_add(s: jax.Array, c: jax.Array, x: jax.Array):
    """One Kahan-compensated accumulation step: returns ``(new_s, new_c)``
    for running sum ``s`` with compensation term ``c``."""
    y = x - c
    t = s + y
    return t, (t - s) - y
