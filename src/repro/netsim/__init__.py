"""Fluid-flow network simulator of the dual AI-DC leaf-spine-OTN topology.

Public surface:
  * schemes  — registry-backed pluggable control schemes (``Scheme``,
               ``register_scheme``, ``get_scheme``). Six ship registered:
               the paper's four (``SCHEMES`` = dcqcn / pseudo_ack / themis /
               matchrdma) plus the related-work pack (``RELATED_SCHEMES`` =
               geopipe / sdr_rdma); ``ALL_SCHEMES`` concatenates them and
               ``available_schemes()`` reflects the live registry. The hook
               contract is documented in ``docs/scheme-api.md`` and the
               worked tutorial in ``docs/writing-a-scheme.md``.
  * channel  — registry-backed long-haul channel models (``ChannelModel``,
               ``register_channel_model``, ``get_channel_model``). Six
               ship registered (``CHANNEL_MODELS`` = ideal /
               bernoulli_loss / jitter / otn_flap / impaired /
               trace_replay); every entrypoint takes ``channel=`` and
               non-ideal models activate the engine's loss-repair
               accounting. ``trace_replay`` replays recorded per-edge OTN
               telemetry schedules. Documented in
               ``docs/channel-models.md``.
  * topology — the multi-site graph subsystem (``SiteGraph``,
               ``SiteEdge``, ``compile_site_graph``): N sites with
               directed site-pair edges compiled onto the traced link
               axis; flows name endpoints via
               ``FlowSpec(src_site=..., dst_site=...)``. Documented in
               ``docs/sites.md``.
  * failures — hard link/site outage timelines (``FailureSchedule`` +
               JSON I/O): per-edge (down_at, up_at) windows drive a
               per-step live-mask, dead links zero their capacity and
               dump in-flight bytes into the loss-repair path, and
               schemes re-spray over survivors via
               ``SchemeCtx.link_live``. Documented in
               ``docs/failures.md``.
  * fluid    — the scheme-agnostic engine (``simulate``, ``simulate_batch``;
               execution modes ``TRACE_MODES`` = full / decimate / metrics
               / window, streaming accumulators ``MetricAcc`` +
               ``hist_quantile``, device sharding via
               ``shard_scenario_axis``).
  * obs      — the observability layer (``WindowAux``, ``EVENT_KINDS``,
               ``decode_events``, ``unroll_window``, ``export_timeline``,
               run manifests): in-scan event rings under
               ``trace_mode="window"``, Perfetto timeline export, and
               launch-plan compile/execute profiling. Documented in
               ``docs/observability.md``.
  * runner   — metric extraction + grid sweeps (``Scenario``, ``sweep``,
               ``sweep_grid``, ``run_experiment_batch``) over chunked
               (``chunk_cells``), device-sharded launch plans.
  * workload — flow sets (``Workload``) and their traced batch form
               (``WorkloadParams``, ``stack_workload_params``).
"""
from repro.netsim.channel import (
    CHANNEL_MODELS, ChannelModel, available_channel_models,
    get_channel_model, register_channel_model,
)
from repro.netsim.failures import (
    FailureSchedule, load_failure_json, save_failure_json,
)
from repro.netsim.fluid import (
    TRACE_MODES, MetricAcc, SimState, WindowAux, batch_padding,
    hist_quantile, shard_scenario_axis, simulate, simulate_batch,
)
from repro.netsim.obs import (
    EVENT_KINDS, EventRing, decode_events, event_count, export_timeline,
    read_manifest, timeline_from_traces, timeline_from_window,
    unroll_window, write_manifest,
)
from repro.netsim.runner import (
    Scenario, chunk_cells, run_experiment, run_experiment_batch, sweep,
    sweep_grid,
)
from repro.netsim.schemes import (
    ALL_SCHEMES, RELATED_SCHEMES, SCHEMES, Scheme, available_schemes,
    get_scheme, register_scheme,
)
from repro.netsim.topology import (
    SiteEdge, SiteGraph, compile_site_graph, validate_site_endpoints,
)
from repro.netsim.workload import (
    BIG, FlowSpec, Workload, WorkloadParams, aicb_workload,
    congestion_workload, mixed_fct_workload, stack_workload_params,
    throughput_workload,
)

__all__ = [
    "ALL_SCHEMES", "CHANNEL_MODELS", "ChannelModel", "EVENT_KINDS",
    "EventRing", "FailureSchedule",
    "MetricAcc",
    "RELATED_SCHEMES", "SCHEMES", "Scheme",
    "Scenario", "SimState", "SiteEdge", "SiteGraph", "TRACE_MODES",
    "WindowAux", "WorkloadParams", "compile_site_graph",
    "validate_site_endpoints", "decode_events", "event_count",
    "export_timeline", "read_manifest", "timeline_from_traces",
    "timeline_from_window", "unroll_window", "write_manifest",
    "available_channel_models", "available_schemes", "batch_padding",
    "chunk_cells", "get_channel_model", "get_scheme",
    "hist_quantile", "load_failure_json", "register_channel_model",
    "register_scheme", "save_failure_json", "shard_scenario_axis",
    "simulate", "simulate_batch", "run_experiment", "run_experiment_batch",
    "stack_workload_params", "sweep", "sweep_grid",
    "BIG", "FlowSpec", "Workload", "aicb_workload", "congestion_workload",
    "mixed_fct_workload", "throughput_workload",
]
