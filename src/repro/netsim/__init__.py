"""Fluid-flow network simulator of the dual AI-DC leaf-spine-OTN topology."""
from repro.netsim.fluid import (
    SCHEMES, SimState, batch_padding, simulate, simulate_batch,
)
from repro.netsim.runner import (
    run_experiment, run_experiment_batch, sweep, sweep_grid,
)
from repro.netsim.workload import (
    BIG, FlowSpec, Workload, aicb_workload, congestion_workload,
    mixed_fct_workload, throughput_workload,
)

__all__ = [
    "SCHEMES", "SimState", "batch_padding", "simulate", "simulate_batch",
    "run_experiment", "run_experiment_batch", "sweep", "sweep_grid",
    "BIG", "FlowSpec", "Workload", "aicb_workload", "congestion_workload",
    "mixed_fct_workload", "throughput_workload",
]
