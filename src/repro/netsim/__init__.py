"""Fluid-flow network simulator of the dual AI-DC leaf-spine-OTN topology."""
from repro.netsim.fluid import SCHEMES, SimState, simulate
from repro.netsim.runner import run_experiment, sweep
from repro.netsim.workload import (
    BIG, FlowSpec, Workload, aicb_workload, congestion_workload,
    mixed_fct_workload, throughput_workload,
)

__all__ = [
    "SCHEMES", "SimState", "simulate", "run_experiment", "sweep",
    "BIG", "FlowSpec", "Workload", "aicb_workload", "congestion_workload",
    "mixed_fct_workload", "throughput_workload",
]
