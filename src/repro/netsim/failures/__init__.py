"""Hard-failure subsystem: link/site outage timelines for the netsim engine.

Where the channel models (``repro.netsim.channel``) impair a link that is
still *up* — loss, jitter, capacity dips — this package kills links
outright: a :class:`FailureSchedule` holds per-edge ``(down_at_us,
up_at_us)`` windows during which a link is DEAD. Compiled into
``NetConfig.failure_schedule`` it rides into the vmapped scan as the
traced ``NetParams.fail_windows`` leaf ([L, W, 2]; the window count W is
static shape), so outage grids batch like every other axis.

Engine semantics (``docs/failures.md``):

  * a dead link's capacity is zeroed — nothing new launches onto it;
  * bytes already in flight are dumped into the engine-owned retransmit
    path as they reach the far end, so byte conservation holds through
    the outage and the data is eventually re-sent on surviving links;
  * schemes see a per-step ``SchemeCtx.link_live`` mask and re-spray
    their routing weights over the surviving links, stalling (never
    NaN-ing) when every link of a flow is down.

An all-up schedule (windows that never fire) is bit-identical to no
schedule at all — the engine-wide zero-impairment identity rule.
"""
from repro.netsim.failures.schedule import (
    FailureSchedule,
    load_failure_json,
    save_failure_json,
)

__all__ = [
    "FailureSchedule",
    "load_failure_json",
    "save_failure_json",
]
