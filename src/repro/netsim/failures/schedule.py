"""``FailureSchedule`` — the host-side builder for hard-outage timelines.

Mirrors the ``trace_replay`` schedule idiom: plain Python on the host, a
nested tuple on ``NetConfig`` (static window count W, traced window
times), an f32 ``[L, W, 2]`` NetParams leaf inside the scan. The builder
keeps per-edge window lists ragged while you compose outages
(:meth:`link_outage`, :meth:`site_outage`) and pads them with no-op
``(0, 0)`` windows only when compiling into a config, so every edge
carries the same static W and grids stack (``stack_net_params``).

JSON I/O helpers at the bottom round-trip schedules through the same
plain format ``repro.netsim.channel.replay`` uses for telemetry:

    {"edges": [{"windows": [[down_at_us, up_at_us], ...]}, ...]}

See ``docs/failures.md`` for the engine-side semantics.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass

__all__ = ["FailureSchedule", "load_failure_json", "save_failure_json"]

# the no-op padding window: up <= down never fires inside the scan
_NOOP = (0.0, 0.0)


def _check_window(down_at_us: float, up_at_us: float) -> tuple:
    d, u = float(down_at_us), float(up_at_us)
    if d < 0.0:
        raise ValueError(
            f"FailureSchedule: down_at_us must be >= 0, got {d}")
    if u <= d:
        raise ValueError(
            f"FailureSchedule: up_at_us must be > down_at_us for a real "
            f"outage, got ({d}, {u}) — zero-length windows are reserved "
            f"for padding")
    return (d, u)


@dataclass(frozen=True)
class FailureSchedule:
    """Per-edge hard-outage windows over the ``[L]`` link axis.

    ``windows`` is a length-``num_links`` tuple of per-edge window
    tuples, each window a ``(down_at_us, up_at_us)`` pair. Lists may be
    ragged here; :meth:`to_config_tuple` pads them to a common static
    count W with no-op ``(0, 0)`` windows. Builders are functional —
    each returns a new schedule — so outages compose::

        fs = (FailureSchedule.empty(3)
              .link_outage(0, 2_000.0, 5_000.0)
              .site_outage(1, 8_000.0, 9_000.0, cfg.edge_pairs()))
        cfg = fs.apply(cfg)
    """

    num_links: int
    windows: tuple = ()

    def __post_init__(self):
        if self.num_links < 1:
            raise ValueError(
                f"FailureSchedule: num_links must be >= 1, got "
                f"{self.num_links}")
        wins = self.windows or ((),) * self.num_links
        if len(wins) != self.num_links:
            raise ValueError(
                f"FailureSchedule: expected {self.num_links} per-edge "
                f"window lists, got {len(wins)}")
        object.__setattr__(
            self, "windows",
            tuple(tuple((float(d), float(u)) for d, u in edge)
                  for edge in wins))

    @classmethod
    def empty(cls, num_links: int) -> "FailureSchedule":
        """A schedule with no outages on ``num_links`` links."""
        return cls(num_links=num_links)

    # -- composition -------------------------------------------------------
    def link_outage(self, link: int, down_at_us: float,
                    up_at_us: float) -> "FailureSchedule":
        """A new schedule with one hard outage window added on ``link``."""
        if not (0 <= link < self.num_links):
            raise ValueError(
                f"FailureSchedule.link_outage: link {link} outside "
                f"[0, {self.num_links})")
        win = _check_window(down_at_us, up_at_us)
        wins = tuple(edge + (win,) if li == link else edge
                     for li, edge in enumerate(self.windows))
        return dataclasses.replace(self, windows=wins)

    def site_outage(self, site: int, down_at_us: float, up_at_us: float,
                    edge_pairs) -> "FailureSchedule":
        """A new schedule with the window added on EVERY edge incident to
        ``site`` — a whole-datacenter outage. ``edge_pairs`` is the
        resolved per-link (src_site, dst_site) wiring, i.e.
        ``cfg.edge_pairs()``."""
        pairs = tuple(edge_pairs)
        if len(pairs) != self.num_links:
            raise ValueError(
                f"FailureSchedule.site_outage: edge_pairs has "
                f"{len(pairs)} entries, schedule has {self.num_links} "
                f"links")
        incident = [li for li, (s, d) in enumerate(pairs)
                    if site in (int(s), int(d))]
        if not incident:
            raise ValueError(
                f"FailureSchedule.site_outage: no edge is incident to "
                f"site {site} in {pairs}")
        out = self
        for li in incident:
            out = out.link_outage(li, down_at_us, up_at_us)
        return out

    # -- compilation into NetConfig ----------------------------------------
    @property
    def num_windows(self) -> int:
        """The static window count W after padding (max over edges)."""
        return max((len(edge) for edge in self.windows), default=0)

    def to_config_tuple(self) -> tuple:
        """The padded nested tuple for ``NetConfig.failure_schedule``:
        every edge brought to the common count W with no-op ``(0, 0)``
        windows (() when the schedule holds no outages at all)."""
        w = self.num_windows
        if w == 0:
            return ()
        return tuple(edge + (_NOOP,) * (w - len(edge))
                     for edge in self.windows)

    def apply(self, cfg):
        """``cfg`` with this schedule compiled in. Validates that the
        schedule's link count matches ``cfg.num_paths``."""
        if self.num_links != cfg.num_paths:
            raise ValueError(
                f"FailureSchedule.apply: schedule covers {self.num_links} "
                f"links but cfg.num_paths is {cfg.num_paths}")
        return dataclasses.replace(
            cfg, failure_schedule=self.to_config_tuple())


# -- JSON I/O ---------------------------------------------------------------

def save_failure_json(path: str, schedule: FailureSchedule) -> None:
    """Write a schedule as ``{"edges": [{"windows": [[d, u], ...]}]}``."""
    doc = {"edges": [{"windows": [list(w) for w in edge]}
                     for edge in schedule.windows]}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2)


def load_failure_json(path: str) -> FailureSchedule:
    """Read a schedule written by :func:`save_failure_json`. Raises a
    ``ValueError`` naming the offending edge on malformed windows."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    edges = doc.get("edges", [])
    if not isinstance(edges, list) or not edges:
        raise ValueError(
            f"{path}: failure json needs a non-empty 'edges' list")
    wins = []
    for li, e in enumerate(edges):
        raw = e.get("windows", []) if isinstance(e, dict) else None
        if raw is None:
            raise ValueError(
                f"{path}: edge {li} is not an object with a 'windows' "
                f"list, got {e!r}")
        edge_wins = []
        for w in raw:
            if not isinstance(w, (list, tuple)) or len(w) != 2:
                raise ValueError(
                    f"{path}: edge {li}: each window is a [down_at_us, "
                    f"up_at_us] pair, got {w!r}")
            edge_wins.append(_check_window(w[0], w[1]))
        wins.append(tuple(edge_wins))
    return FailureSchedule(num_links=len(wins), windows=tuple(wins))
