"""Multi-site topology subsystem (``docs/sites.md``).

``SiteGraph`` + ``SiteEdge`` declare an N-site mesh; ``compile_site_graph``
lowers it onto the traced ``[L]`` link axis of ``docs/topology.md``;
``validate_site_endpoints`` is the host-side pre-flight the simulate
entry points run on multi-site configs.
"""
from repro.netsim.topology.graph import (SiteEdge, SiteGraph,
                                         compile_site_graph,
                                         validate_site_endpoints)

__all__ = [
    "SiteEdge",
    "SiteGraph",
    "compile_site_graph",
    "validate_site_endpoints",
]
