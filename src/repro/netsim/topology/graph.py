"""Site-graph topology: N sites compiled onto the traced ``[L]`` link axis.

A :class:`SiteGraph` declares the geo-distributed deployment as a small
directed multigraph — ``num_sites`` datacenters and one :class:`SiteEdge`
per long-haul OTN link, each edge carrying its own delay/capacity/PFC
threshold. ``compile_site_graph`` lowers the graph onto the per-link
machinery the engine already runs (``docs/topology.md``): each edge
becomes one entry of the ``num_paths`` link axis, its attributes become
the ``path_delay_scale`` / ``path_cap_frac`` / ``path_thresh_kb`` traced
leaves, and its (src, dst) pair lands in ``NetConfig.site_edges``.

Flows name their endpoints via ``FlowSpec(src_site=..., dst_site=...)``;
inside the scan the engine masks each flow's routing-matrix row down to
the edges matching its site pair, so one vmapped program sweeps
heterogeneous multi-site meshes without recompiling. Everything here is
plain host-side Python — no jax, no tracing; the graph exists only until
it has been compiled into ``NetConfig``.

See ``docs/sites.md`` for the full model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = [
    "SiteEdge",
    "SiteGraph",
    "compile_site_graph",
    "validate_site_endpoints",
]


@dataclass(frozen=True)
class SiteEdge:
    """One directed long-haul link between two sites.

    Attributes map 1:1 onto the per-link knobs of ``docs/topology.md``:
    ``delay_scale`` multiplies ``NetConfig.one_way_delay_us``;
    ``cap_frac`` is this link's fraction of ``otn_capacity_gbps``
    (``None`` = an equal split over all edges); ``thresh_kb`` overrides
    the per-link dst-OTN PFC threshold (``None`` = ``pfc_xoff_kb``).
    """

    src: int
    dst: int
    delay_scale: float = 1.0
    cap_frac: Optional[float] = None
    thresh_kb: Optional[float] = None


@dataclass(frozen=True)
class SiteGraph:
    """``num_sites`` datacenters + one :class:`SiteEdge` per OTN link.

    Parallel edges between the same site pair are allowed (they model a
    link bundle on that pair, exactly as PR 6's multipath did for the
    single pair). The graph validates eagerly so a bad mesh fails at
    construction, not inside jit.
    """

    num_sites: int
    edges: tuple

    def __post_init__(self):
        if self.num_sites < 2:
            raise ValueError(
                f"SiteGraph: num_sites must be >= 2, got {self.num_sites}")
        if not self.edges:
            raise ValueError("SiteGraph: at least one edge is required")
        for e in self.edges:
            if not isinstance(e, SiteEdge):
                raise TypeError(
                    f"SiteGraph: edges must be SiteEdge instances, got "
                    f"{type(e).__name__}")
            if not (0 <= e.src < self.num_sites
                    and 0 <= e.dst < self.num_sites):
                raise ValueError(
                    f"SiteGraph: edge ({e.src}, {e.dst}) references a site "
                    f"outside [0, {self.num_sites})")
            if e.src == e.dst:
                raise ValueError(
                    f"SiteGraph: self-edge at site {e.src} — a link must "
                    f"connect two distinct sites")

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def site_pairs(self) -> tuple:
        """The (src, dst) pair of every edge, in link-axis order."""
        return tuple((e.src, e.dst) for e in self.edges)

    def edges_between(self, src: int, dst: int) -> tuple:
        """Link-axis indices of the edges serving the (src, dst) pair."""
        return tuple(i for i, e in enumerate(self.edges)
                     if (e.src, e.dst) == (src, dst))

    def to_net_config(self, base_cfg):
        """Lower the graph onto ``base_cfg``'s link axis.

        Returns a new ``NetConfig`` with ``num_paths = num_edges`` and the
        per-edge attributes written into the ``path_*`` knobs; everything
        else (delay, capacity, scheme knobs, channel model, ...) is
        inherited from ``base_cfg`` unchanged.
        """
        caps = [e.cap_frac for e in self.edges]
        if any(c is not None for c in caps):
            # mixed explicit/None rows: the unnamed edges split what the
            # named ones left on the table
            named = sum(c for c in caps if c is not None)
            unnamed = sum(1 for c in caps if c is None)
            rest = max(1.0 - named, 0.0) / unnamed if unnamed else 0.0
            cap_frac = tuple(rest if c is None else float(c) for c in caps)
        else:
            cap_frac = ()
        thr = [e.thresh_kb for e in self.edges]
        if any(t is not None for t in thr):
            fill = base_cfg.pfc_xoff_kb
            thresh_kb = tuple(fill if t is None else float(t) for t in thr)
        else:
            thresh_kb = ()
        return dataclasses.replace(
            base_cfg,
            num_sites=self.num_sites,
            num_paths=self.num_edges,
            site_edges=self.site_pairs(),
            path_delay_scale=tuple(float(e.delay_scale)
                                   for e in self.edges),
            path_cap_frac=cap_frac,
            path_thresh_kb=thresh_kb,
        )


def compile_site_graph(graph: SiteGraph, base_cfg):
    """Functional alias of :meth:`SiteGraph.to_net_config`."""
    return graph.to_net_config(base_cfg)


def validate_site_endpoints(cfg, wlp) -> None:
    """Host-side pre-flight: every active inter-DC flow must have at
    least one edge serving its (src_site, dst_site) pair.

    A flow whose endpoints match no edge would see an all-zero routing
    row — its bytes spill back into the source queue forever and the run
    silently stalls. Raise before jit instead. Accepts [F] or stacked
    [B, F] ``WorkloadParams`` leaves (concrete arrays only — callers
    invoke this before entering jit).
    """
    pairs = set(cfg.edge_pairs())
    src = np.asarray(wlp.src_site).reshape(-1)
    dst = np.asarray(wlp.dst_site).reshape(-1)
    inter = np.asarray(wlp.is_inter).reshape(-1)
    active = np.asarray(wlp.active_mask).reshape(-1)
    bad = set()
    for s, d, it, ac in zip(src, dst, inter, active):
        if it > 0 and ac > 0 and (int(s), int(d)) not in pairs:
            bad.add((int(s), int(d)))
    if bad:
        shown = ", ".join(f"{s} -> {d}" for s, d in sorted(bad))
        raise ValueError(
            f"validate_site_endpoints: inter-DC flow endpoints {shown} "
            f"match no edge of the site graph "
            f"(edges: {sorted(pairs)}) — such a flow would stall forever; "
            f"add an edge for the pair or fix the FlowSpec "
            f"src_site/dst_site")
