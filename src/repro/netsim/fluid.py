"""Fluid-flow discrete-time simulator of the dual AI-DC leaf-spine-OTN path.

One ``jax.lax.scan`` step = ``dt_us`` of simulated time. Per-flow byte rates
are integrated through the congestion-relevant queues of Fig. 3(a):

    sender NIC --> [Q_src] source OTN --(pipe: delay D, cap C_otn)-->
    [Q_dst] destination OTN --> [Q_leaf] destination leaf (shared with
    intra-DC flows, ECN marking here) --> receiver

Feedback paths:
  * ACKs:  receiver -> sender, delay D (conventional) / source-OTN pseudo-ACK
           (NTT baseline, ungated) / budget-gated pseudo-ACK (MatchRDMA).
  * CNPs:  receiver -> sender, delay D (baselines) / consumed at destination
           OTN + congestion summary on the control subchannel (MatchRDMA).
  * PFC:   destination-leaf -> destination OTN (1 step);
           destination OTN -> source OTN (delay D, the long-haul pause the
           paper's pause-time-ratio measures);
           source OTN -> sender NIC (1 step).

Schemes (static compile-time switch):
  dcqcn      — conventional end-to-end RDMA (DCQCN at the sender).
  pseudo_ack — NTT GLOBECOM'24: source-OTN pseudo-ACK, ungated; CC still e2e.
  themis     — e2e with RTT-fairness-corrected DCQCN (ICNP'25-like).
  matchrdma  — the paper: segmented control + rate matching.

Static vs traced config split (the batched scenario engine):
  ``NetConfig`` stays the hashable compile-time side — it fixes ``dt_us``,
  slot layout, DCQCN constants and every array SIZE. The per-scenario
  scalars a sweep varies (distance/delay, OTN capacity, leaf capacity,
  buffer/ECN thresholds — ``NetParams``) enter the step function as traced
  leaves. Delay lines are allocated at a static padded length
  (``delay_pad`` = the largest scenario in the batch) while the ring index
  wraps at the traced actual ``delay_steps``, so heterogeneous distances
  share ONE compiled ``lax.scan`` and ``simulate_batch`` can ``jax.vmap``
  the whole scenario grid in a single device launch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams, stack_net_params
from repro.core.budget import fair_share
from repro.core.cc_proxy import (
    DcqcnState, init_dcqcn, step_dcqcn, themis_rtt_scale,
)
from repro.core.matchrdma import (
    MatchRdmaState, accumulate_step, default_history_slots, init_matchrdma,
    maybe_slot_update, step_channel,
)
from repro.core.pseudo_ack import step_pseudo_ack
from repro.netsim.queues import drain_proportional, ecn_mark_prob, pfc_hysteresis
from repro.netsim.workload import Workload

SCHEMES = ("dcqcn", "pseudo_ack", "themis", "matchrdma")
MTU = 1500.0
INF = jnp.float32(1e30)


class SimState(NamedTuple):
    sent: jax.Array          # [F] cumulative bytes leaving the sender NIC
    acked: jax.Array         # [F] cumulative bytes ACKed at the sender
    delivered: jax.Array     # [F] cumulative bytes delivered to the receiver
    done_at_us: jax.Array    # [F] completion time (INF = not done)
    cc: DcqcnState           # [F] DCQCN machine (sender or proxy)
    cnp_timer: jax.Array     # [F] µs since last CNP emission (receiver side)
    marked_acc: jax.Array    # [F] marked-byte accumulator (per-packet model)
    proxy_timer: jax.Array   # [F] µs since last proxy cut (MatchRDMA)
    proxy_mod: jax.Array     # [F] multiplicative proxy modulation in [0.25, 1]
    q_src: jax.Array         # [F] source-OTN queue bytes
    q_dst: jax.Array         # [F] destination-OTN queue bytes
    q_leaf: jax.Array        # [F] destination-leaf queue bytes
    pipe: jax.Array          # [Dp, F] in-flight long-haul bytes
    inflight: jax.Array      # [F] running sum of pipe (incremental: O(F)/step)
    ack_line: jax.Array      # [Dp, F] ACK return path
    cnp_line: jax.Array      # [Dp, F] CNP return path
    pause_line: jax.Array    # [Dp] PFC signal dst-OTN -> src-OTN
    pause_dst: jax.Array     # scalar: dst OTN asserting long-haul pause
    mr: MatchRdmaState


def _delay_steps(cfg: NetConfig) -> int:
    """STATIC delay-step count — sizes the delay-line padding."""
    return max(int(round(cfg.one_way_delay_us / cfg.dt_us)), 1)


def _proc_steps(cfg: NetConfig) -> int:
    return int(cfg.control_proc_slots * cfg.slot_us / cfg.dt_us)


def init_state(cfg: NetConfig, wl_arrays: dict, num_flows: int,
               params: NetParams = None, delay_pad: int = 0,
               history_slots: int = 0) -> SimState:
    """``delay_pad``/``history_slots`` are static ring sizes (0 = size for
    ``cfg`` itself); ``params`` carries the traced per-scenario scalars."""
    f = num_flows
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    if params is None:
        params = NetParams.of(cfg)
    z = jnp.zeros((f,), jnp.float32)
    nic = params.nic_gbps * 1e9 / 8.0
    return SimState(
        sent=z, acked=z, delivered=z,
        done_at_us=jnp.full((f,), INF),
        cc=init_dcqcn(f, nic),
        cnp_timer=jnp.full((f,), 1e9, jnp.float32),
        marked_acc=z,
        proxy_timer=jnp.full((f,), 1e9, jnp.float32),
        proxy_mod=jnp.ones((f,), jnp.float32),
        q_src=z, q_dst=z, q_leaf=z,
        pipe=jnp.zeros((delay_pad, f), jnp.float32),
        inflight=z,
        ack_line=jnp.zeros((delay_pad, f), jnp.float32),
        cnp_line=jnp.zeros((delay_pad, f), jnp.float32),
        pause_line=jnp.zeros((delay_pad,), jnp.float32),
        pause_dst=jnp.float32(0.0),
        mr=init_matchrdma(cfg, f, history_slots=history_slots, params=params,
                          chan_delay_pad=delay_pad + _proc_steps(cfg)),
    )


def make_step_fn(cfg: NetConfig, wl: dict, scheme: str, period_slots: int = 0,
                 params: NetParams = None, delay_pad: int = 0):
    """Build the per-step transition. ``wl``: stacked workload arrays.

    All per-scenario scalars are read from ``params`` (traced), so the same
    compiled step serves every cell of a vmapped scenario batch; ``cfg``
    only contributes static structure (dt, slot layout, DCQCN constants).
    """
    assert scheme in SCHEMES
    if params is None:
        params = NetParams.of(cfg)
    dt_us = cfg.dt_us
    dt_s = dt_us * 1e-6
    d_steps = params.delay_steps(dt_us)            # traced actual delay
    nic = params.nic_gbps * 1e9 / 8.0
    c_otn = params.otn_capacity_gbps * 1e9 / 8.0
    c_leaf = params.dst_dc_gbps * 1e9 / 8.0
    xoff = params.pfc_xoff_kb * 1024.0
    xon = params.pfc_xon_kb * 1024.0
    # OTN nodes are provisioned with BDP-scaled buffers (long-haul headroom)
    bdp = c_otn * 2.0 * params.one_way_delay_us * 1e-6
    xoff_otn = jnp.maximum(xoff, params.otn_buffer_bdp_frac * bdp)
    xon_otn = xoff_otn / 2.0

    is_inter = jnp.asarray(wl["is_inter"])
    is_intra = 1.0 - is_inter
    window = jnp.asarray(wl["window"])
    total_bytes = jnp.asarray(wl["total_bytes"])
    start_us = jnp.asarray(wl["start_us"])
    period_us = jnp.asarray(wl["period_us"])
    duty = jnp.asarray(wl["duty"])
    rtt_us = jnp.where(is_inter > 0, 2.0 * d_steps * dt_us + 4.0, 4.0)
    rtt_scale = themis_rtt_scale(rtt_us) if scheme == "themis" else None
    pseudo_scheme = scheme in ("pseudo_ack", "matchrdma")

    def step(state: SimState, t: jax.Array):
        t_us = t.astype(jnp.float32) * dt_us
        ridx = jnp.mod(t, d_steps)

        # ------------------------------------------------ 1. flow phase
        started = (t_us >= start_us).astype(jnp.float32)
        in_period = jnp.where(
            period_us > 0,
            (jnp.mod(jnp.maximum(t_us - start_us, 0.0), jnp.maximum(period_us, 1.0))
             < duty * period_us).astype(jnp.float32),
            1.0)
        not_done = (state.delivered < total_bytes).astype(jnp.float32)
        active = started * in_period * not_done

        # ------------------------------------------------ 2. delayed inputs
        ack_arr = state.ack_line[ridx]
        cnp_arr = state.cnp_line[ridx]
        pause_sig = state.pause_line[ridx]
        pipe_out = state.pipe[ridx]

        # ------------------------------------------------ 3. ACK accounting
        if pseudo_scheme:
            acked_inter = state.mr.pseudo.packed       # previous-step pseudo-ACKs
        else:
            acked_inter = state.acked + ack_arr
        acked = jnp.where(is_inter > 0, acked_inter,
                          state.delivered)             # intra: ~µs loop
        acked = jnp.minimum(acked, state.sent)

        # ------------------------------------------------ 4. sender rates
        win_avail = jnp.maximum(window - (state.sent - acked), 0.0)
        base_rate = jnp.minimum(win_avail / dt_s, nic)
        if scheme == "matchrdma":
            rate = jnp.where(is_inter > 0, base_rate,
                             jnp.minimum(state.cc.rc, base_rate))
        else:
            rate = jnp.minimum(state.cc.rc, base_rate)
        # src-OTN -> sender PFC (1 step, from last-step queue)
        src_nic_pause = (jnp.sum(state.q_src) > xoff_otn).astype(jnp.float32)
        rate = rate * jnp.where(is_inter > 0, 1.0 - src_nic_pause, 1.0)
        send = rate * active * dt_s                    # bytes this step
        sent = state.sent + send

        # ------------------------------------------------ 5. source OTN
        paused_src = pause_sig > 0.5                   # delayed dst PFC
        cap_src = jnp.where(paused_src, 0.0, c_otn * dt_s)
        arrivals_src = send * is_inter
        if scheme == "matchrdma":
            # proxy shaping: release <= budget share x proxy modulation. The
            # budget is authoritative; the reactive proxy is a fast bounded
            # multiplicative brake around it (not a second rate machine).
            share = fair_share(state.mr.budget_at_src, active * is_inter)
            per_flow_cap = share * state.proxy_mod * dt_s
            avail = state.q_src + arrivals_src
            want = jnp.minimum(avail, per_flow_cap * is_inter)
            scale = jnp.minimum(1.0, cap_src / jnp.maximum(jnp.sum(want), 1e-9))
            drained_src = want * scale
            q_src = avail - drained_src
        else:
            q_src, drained_src = drain_proportional(state.q_src, arrivals_src,
                                                    cap_src)
        pipe = state.pipe.at[ridx].set(drained_src)    # arrives at t + D
        inflight = state.inflight + drained_src - pipe_out

        # ------------------------------------------------ 6. destination OTN
        leaf_pfc = (jnp.sum(state.q_leaf) > xoff).astype(jnp.float32)
        cap_dst = c_leaf * dt_s * (1.0 - leaf_pfc)
        q_dst, drained_dst = drain_proportional(state.q_dst, pipe_out, cap_dst)
        egress_bytes = jnp.sum(drained_dst)
        q_dst_tot = jnp.sum(q_dst)
        pause_dst = pfc_hysteresis(state.pause_dst, q_dst_tot, xoff_otn, xon_otn)
        pause_line = state.pause_line.at[ridx].set(pause_dst)

        # ------------------------------------------------ 7. destination leaf
        arrivals_leaf = drained_dst + send * is_intra
        mark_p = ecn_mark_prob(jnp.sum(state.q_leaf), cfg, params=params)
        q_leaf, drained_leaf = drain_proportional(state.q_leaf, arrivals_leaf,
                                                  c_leaf * dt_s)
        delivered = state.delivered + drained_leaf
        marked_acc = state.marked_acc + drained_leaf * mark_p

        # ------------------------------------------------ 8. CNP generation
        cnp_timer = state.cnp_timer + dt_us
        want = marked_acc >= MTU
        emit = want & (cnp_timer >= cfg.cnp_interval_us)
        cnp_out = emit.astype(jnp.float32)
        cnp_timer = jnp.where(emit, 0.0, cnp_timer)
        marked_acc = jnp.where(emit, 0.0, marked_acc)

        # ------------------------------------------------ 9. return paths
        ack_line = state.ack_line.at[ridx].set(drained_leaf * is_inter)
        if scheme == "matchrdma":
            cnp_line = state.cnp_line.at[ridx].set(jnp.zeros_like(cnp_out))
        else:
            cnp_line = state.cnp_line.at[ridx].set(cnp_out * is_inter)
        # ------------------------------------------------ 10. pseudo-ACK
        mr = state.mr
        if pseudo_scheme:
            share = fair_share(mr.budget_at_src, active * is_inter)
            pseudo, packed = step_pseudo_ack(
                mr.pseudo, sent * is_inter, share, dt_s,
                gated=(scheme == "matchrdma"))
            mr = mr._replace(pseudo=pseudo)

        # ------------------------------------------------ 11. CC update
        if scheme == "matchrdma":
            # proxy brake from the delayed congestion summary, rate-limited:
            # cut x0.7 (floor 0.25), recover with ~1 ms time constant.
            proxy_timer = state.proxy_timer + dt_us
            fire = (mr.summary_at_src > 0.5) & (proxy_timer >= cfg.cnp_interval_us)
            proxy_mod = jnp.where(fire, jnp.maximum(state.proxy_mod * 0.7, 0.25),
                                  jnp.minimum(state.proxy_mod *
                                              (1.0 + 5e-4 * dt_us), 1.0))
            proxy_timer = jnp.where(fire, 0.0, proxy_timer)
            cnp_in = cnp_out * is_intra          # sender CC only for intra
        else:
            proxy_timer = state.proxy_timer
            proxy_mod = state.proxy_mod
            cnp_in = jnp.where(is_inter > 0, cnp_arr, cnp_out * is_intra)
        cc = step_dcqcn(state.cc, cnp_in, send, cfg, rtt_scale=rtt_scale)

        # ------------------------------------------------ 12. MatchRDMA loops
        if scheme == "matchrdma":
            leaf_delay_us = jnp.sum(q_leaf) / c_leaf * 1e6 + cfg.intra_dc_delay_us
            mr = accumulate_step(
                mr, egress_bytes,
                jnp.sum(cnp_out * is_inter),
                leaf_delay_us, jnp.float32(1.0), q_dst_tot,
                egress_paused=leaf_pfc)
            mr = maybe_slot_update(mr, cfg, t, period_slots, params=params)
            overrun = (q_dst_tot > 0.5 * xoff_otn)
            mr = step_channel(mr, overrun.astype(jnp.float32))

        # ------------------------------------------------ 13. FCT
        newly_done = (delivered >= total_bytes) & (state.done_at_us >= INF)
        done_at = jnp.where(newly_done, t_us, state.done_at_us)

        new_state = SimState(
            sent=sent, acked=acked, delivered=delivered, done_at_us=done_at,
            cc=cc, cnp_timer=cnp_timer, marked_acc=marked_acc,
            proxy_timer=proxy_timer, proxy_mod=proxy_mod,
            q_src=q_src, q_dst=q_dst, q_leaf=q_leaf,
            pipe=pipe, inflight=inflight,
            ack_line=ack_line, cnp_line=cnp_line,
            pause_line=pause_line, pause_dst=pause_dst, mr=mr,
        )
        # per-flow byte conservation residual: everything the sender emitted
        # is either delivered or sitting in exactly one queue / the pipe
        residual = sent - delivered - q_src - q_dst - q_leaf - inflight
        cons_err = jnp.max(jnp.abs(residual) / jnp.maximum(sent, 1.0))
        out = {
            "q_src": jnp.sum(q_src),
            "q_dst": q_dst_tot,
            "q_leaf": jnp.sum(q_leaf),
            "pause_dst": pause_dst,
            "src_paused": pause_sig,
            "thr_inter": jnp.sum(drained_leaf * is_inter) / dt_s,
            "thr_intra": jnp.sum(drained_leaf * is_intra) / dt_s,
            "budget": state.mr.budget.budget,
            "budget_at_src": state.mr.budget_at_src,
            "cons_err": cons_err,
        }
        return new_state, out

    return step


def simulate(cfg: NetConfig, workload: Workload, scheme: str,
             horizon_us: Optional[float] = None, period_slots: int = 0,
             delay_pad: int = 0, history_slots: int = 0):
    """Run one simulation; returns (final_state, traces dict of [T] arrays).

    ``delay_pad``/``history_slots`` override the static ring sizes (0 = size
    for ``cfg``) — pass the batch padding to reproduce a ``simulate_batch``
    cell bit-for-bit.
    """
    horizon = horizon_us if horizon_us is not None else cfg.horizon_us
    steps = int(round(horizon / cfg.dt_us))
    wl_arrays = {k: jnp.asarray(v) for k, v in workload.arrays().items()}
    return _run_traced(cfg, wl_arrays, scheme, steps, period_slots,
                       delay_pad, history_slots)


@partial(jax.jit, static_argnames=("scheme", "steps", "period_slots", "cfg",
                                   "delay_pad", "history_slots"))
def _run_traced(cfg, wl_arrays, scheme, steps, period_slots,
                delay_pad=0, history_slots=0):
    f = wl_arrays["is_inter"].shape[0]
    state0 = init_state(cfg, wl_arrays, f, delay_pad=delay_pad,
                        history_slots=history_slots)
    step = make_step_fn(cfg, wl_arrays, scheme, period_slots,
                        delay_pad=delay_pad)
    final, traces = jax.lax.scan(step, state0,
                                 jnp.arange(steps, dtype=jnp.int32))
    return final, traces


# ---------------------------------------------------------------------------
# Batched scenario engine
# ---------------------------------------------------------------------------

# NetConfig fields whose values reach the batched step ONLY through the
# traced NetParams leaves — free to vary per scenario. Every OTHER field is
# compile-time structure (dt/slot layout, DCQCN constants, ECN pmax, ...)
# and must be identical across a batch; the template resets the traced ones
# to the class defaults so two grids of equal shape share one compiled
# program.
_TRACED_FIELDS = ("distance_km", "num_otn_links", "link_gbps", "dst_dc_gbps",
                  "nic_gbps", "pfc_xoff_kb", "pfc_xon_kb",
                  "otn_buffer_bdp_frac", "ecn_kmin_kb", "ecn_kmax_kb",
                  "queue_thresh_kb", "budget_floor_mbps", "budget_headroom")


def _batch_template(cfgs: Sequence[NetConfig]) -> NetConfig:
    """The static template keying the batch's jit cache entry: the shared
    non-traced fields, with every NetParams-covered field reset to its
    class default (after the reset all batch members yield the same
    template, so any member serves). A non-traced field varying across the
    batch is an error: it would otherwise be silently overwritten by the
    template's value for every cell."""
    for field in dataclasses.fields(NetConfig):
        if field.name in _TRACED_FIELDS:
            continue
        vals = {getattr(c, field.name) for c in cfgs}
        if len(vals) > 1:
            raise ValueError(
                f"simulate_batch: NetConfig.{field.name} must be identical "
                f"across the batch (got {sorted(vals)}) — it is compile-time "
                f"structure, not a traced NetParams leaf")
    defaults = {f.name: f.default for f in dataclasses.fields(NetConfig)}
    return dataclasses.replace(
        cfgs[0], **{f: defaults[f] for f in _TRACED_FIELDS})


def batch_padding(cfgs: Sequence[NetConfig]):
    """(delay_pad, history_slots) covering every scenario in the grid —
    the static ring sizes shared by all cells of a batch."""
    far = max(cfgs, key=lambda c: c.one_way_delay_us)
    delay_pad = max(_delay_steps(c) for c in cfgs)
    return delay_pad, default_history_slots(far)


def simulate_batch(cfgs: Sequence[NetConfig], workload: Workload, scheme: str,
                   horizon_us: Optional[float] = None, period_slots: int = 0):
    """Run a whole scenario grid as ONE vmapped computation.

    ``cfgs``: the per-scenario configs (distance / capacity / buffer grids);
    every structural field (dt, slot layout) must match — the per-scenario
    scalars are extracted into a stacked ``NetParams`` pytree and traced.
    One compile per (scheme, grid-shape); every cell runs in a single
    device launch. Returns (final_states, traces) with a leading [B] axis
    on every leaf.
    """
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("simulate_batch: empty config batch")
    tmpl = _batch_template(cfgs)
    horizon = horizon_us if horizon_us is not None else max(
        c.horizon_us for c in cfgs)
    steps = int(round(horizon / tmpl.dt_us))
    delay_pad, history_slots = batch_padding(cfgs)
    params = stack_net_params(cfgs)
    wl_arrays = {k: jnp.asarray(v) for k, v in workload.arrays().items()}
    return _run_traced_batch(tmpl, params, wl_arrays, scheme, steps,
                             period_slots, delay_pad, history_slots)


@partial(jax.jit, static_argnames=("cfg", "scheme", "steps", "period_slots",
                                   "delay_pad", "history_slots"))
def _run_traced_batch(cfg, params, wl_arrays, scheme, steps, period_slots,
                      delay_pad, history_slots):
    f = wl_arrays["is_inter"].shape[0]

    def one_scenario(p):
        state0 = init_state(cfg, wl_arrays, f, params=p, delay_pad=delay_pad,
                            history_slots=history_slots)
        step = make_step_fn(cfg, wl_arrays, scheme, period_slots,
                            params=p, delay_pad=delay_pad)
        return jax.lax.scan(step, state0, jnp.arange(steps, dtype=jnp.int32))

    return jax.vmap(one_scenario)(params)
