"""Fluid-flow discrete-time simulator of the dual AI-DC leaf-spine-OTN path.

One ``jax.lax.scan`` step = ``dt_us`` of simulated time. Per-flow byte rates
are integrated through the congestion-relevant queues of Fig. 3(a):

    sender NIC --> [Q_src] source OTN --(pipe: delay D, cap C_otn)-->
    [Q_dst] destination OTN --> [Q_leaf] destination leaf (shared with
    intra-DC flows, ECN marking here) --> receiver

Feedback paths:
  * ACKs:  receiver -> sender, delay D (conventional) / source-OTN pseudo-ACK
           (NTT baseline, ungated) / budget-gated pseudo-ACK (MatchRDMA).
  * CNPs:  receiver -> sender, delay D (baselines) / consumed at destination
           OTN + congestion summary on the control subchannel (MatchRDMA).
  * PFC:   destination-leaf -> destination OTN (1 step);
           destination OTN -> source OTN (delay D, the long-haul pause the
           paper's pause-time-ratio measures);
           source OTN -> sender NIC (1 step).

Schemes (pluggable — ``repro.netsim.schemes``):
  ``make_step_fn`` is a scheme-agnostic skeleton; everything a control
  scheme decides (ACK view, sender rate law, source-OTN release, CNP
  routing, extra-state updates) enters through the ``Scheme`` hooks. Six
  schemes ship registered — the paper's four (``dcqcn``, ``pseudo_ack``,
  ``themis``, ``matchrdma``) plus the related-work pack (``geopipe``,
  ``sdr_rdma``); third-party schemes register with
  ``@register_scheme("name")`` and are usable from every entrypoint.
  Scheme arguments accept a registered name or a ``Scheme`` instance;
  the hook contract is documented in ``docs/scheme-api.md``.

Channel models (pluggable — ``repro.netsim.channel``):
  The long haul itself is a plugin: every entrypoint takes ``channel=``
  (a registered ``ChannelModel`` name or instance; default ``"ideal"`` —
  structurally bit-identical to the pre-channel engine). Non-ideal models
  (``bernoulli_loss``, ``jitter``, ``otn_flap``, ``impaired``) get ONE
  hook point between the pipe exit and the destination OTN (plus a
  capacity tap on the source line), and the engine's loss-repair path
  activates: lost bytes ride a notification ring back (delay D), queue in
  a per-flow retransmit backlog, and re-enter the source OTN at the rate
  the scheme's ``retx_rate`` hook grants. Impairment knobs are traced
  ``NetParams`` leaves (grids compile once per scheme); all randomness is
  counter-based (``fold_in(scenario_key(channel_seed, knobs), t)``) so runs are
  deterministic and resume-safe. See ``docs/channel-models.md``.

Static vs traced scenario split (the batched scenario engine):
  ``NetConfig`` stays the hashable compile-time side — it fixes ``dt_us``,
  slot layout, DCQCN constants and every array SIZE. The per-scenario
  scalars a sweep varies enter as traced ``NetParams`` leaves, and the
  per-scenario workload enters as traced ``WorkloadParams`` leaves (flow
  arrays padded to the batch-max flow count with an ``active_mask``), so
  ``simulate_batch`` vmaps over (NetParams × WorkloadParams) jointly:
  heterogeneous distances AND heterogeneous flow sets share ONE compiled
  ``lax.scan`` and run the whole scenario grid in a single device launch.
  Delay lines are allocated at a static padded length (``delay_pad``) while
  the ring index wraps at the traced actual ``delay_steps``.

Execution modes (``trace_mode``):
  ``full``      every per-step trace key materializes as a [T] (or [B, T])
                array — figures, goldens, debugging.
  ``decimate``  every ``decimate``-th step is kept: [T/k] traces, O(B·T/k)
                memory — long-horizon figures.
  ``metrics``   NO per-step arrays exist anywhere: the ``lax.scan`` carry
                accumulates the Fig. 3 reductions online (Kahan-compensated
                warm-step sums, running maxes, a fixed-bin log-histogram of
                ``q_dst`` for p99) in a ``MetricAcc``, so device memory is
                O(B) per trace key instead of O(B·T) and nothing but final
                states + accumulators ever transfers to host. Schemes
                stream their own reductions through the
                ``Scheme.init_metric_acc``/``accumulate_metrics``/
                ``finalize_metrics`` hooks (mirroring ``extra_traces``).
  ``window``    ``metrics`` plus the LAST ``cfg.trace_window_steps`` steps
                of every trace key kept in a ring carried through the scan
                (O(B·W) memory, still no [B, T] array) and — when
                ``cfg.event_ring_slots > 0`` — a bounded per-scenario ring
                of timestamped discrete events (PFC edges, threshold
                crossings, retx onset, failure entry/exit, and whatever a
                scheme's ``emit_events`` hook contributes). Returns
                ``(final, WindowAux)``; ``repro.netsim.obs`` decodes rings
                and exports Perfetto timelines (docs/observability.md).

Device sharding: ``shard_scenario_axis`` splits the stacked [B] scenario
leaves across ``jax.devices()`` (jax.sharding over the vmapped axis), and
``simulate_batch`` applies it automatically whenever the device count
evenly splits the batch — one SPMD launch sweeps the grid on every
accelerator. The runner's launch plans pad chunks to a device multiple so
the split always holds.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import (
    NetConfig, NetParams, batch_template, stack_net_params,
)
from repro.core.cc_proxy import DcqcnState, init_dcqcn, step_dcqcn
from repro.core.matchrdma import default_history_slots
from repro.netsim.channel import (
    ChannelInputs, ChannelModel, get_channel_model, scenario_key,
)
from repro.netsim.queues import drain_proportional, ecn_mark_prob, pfc_hysteresis
from repro.netsim.soft import lerp, reset_gate, soft_gt, soft_pos, ste
from repro.netsim.schemes import SCHEMES, get_scheme  # noqa: F401 (re-export)
from repro.netsim.schemes.base import Scheme, SchemeCtx, SchemeSignals
from repro.netsim.streaming import (
    HIST_BINS, hist_bin_centers, hist_bin_index, hist_quantile, kahan_add,
)
from repro.netsim.workload import WorkloadParams, as_workload_batch

MTU = 1500.0
# np (not jnp): a module-level jax array would initialize the backend at
# import time; as an f32 numpy scalar it traces identically
INF = np.float32(1e30)


def is_unfinished(done_at_us):
    """True where ``done_at_us`` still carries the INF 'not done' sentinel.

    The one definition both the engine's completion latch and the runner's
    metric extractors compare against (works on numpy and jax arrays).
    f32-safe: any sentinel at or above INF/2 counts, so a round-tripped or
    arithmetically-perturbed sentinel can never masquerade as a real
    completion time (real times are bounded by the horizon, µs-scale)."""
    return done_at_us >= INF / 2

WARMUP_FRAC = 0.1   # fraction of the horizon discarded as startup transient

TRACE_MODES = ("full", "decimate", "metrics", "window")

# engine-owned streaming reductions over the per-step trace dict: warm-step
# sums (-> means) and all-step running maxes
STREAM_SUM_KEYS = ("q_src", "q_dst", "q_leaf", "pause_dst",
                   "thr_inter", "thr_intra")
STREAM_MAX_KEYS = ("q_src", "q_dst", "q_leaf", "cons_err")

# Trace keys that are per-step byte COUNTS (not levels): under
# ``trace_mode="decimate"`` each kept row carries the SUM over its
# decimate-block rather than the last step's sample, so time-normalized
# columns (goodput/wire/retx rates) stay exact at any decimation — the
# parity fix that keeps ``runner._channel_cols_from_traces`` in agreement
# with the streamed ``ChannelModel.finalize_metrics`` path.
DECIMATE_SUM_KEYS = ("chan_wire", "chan_lost", "chan_retx")

# The fixed-bin log histogram backing the streaming p99 (q_dst bytes here;
# the channel subsystem reuses it for repair-wait µs) lives in
# repro.netsim.streaming; the historical names stay importable from here.
HIST_MIN_BYTES = 1.0
HIST_MAX_BYTES = 1e12
_hist_bin_index = hist_bin_index


class MetricAcc(NamedTuple):
    """O(1)-per-scenario scan carry of the Fig. 3 reductions
    (``trace_mode="metrics"``). Under the batched engine every leaf gains a
    leading [B] axis; nothing here scales with the step count."""
    sum_s: dict       # STREAM_SUM_KEYS -> Kahan running sum over warm steps
    sum_c: dict       # STREAM_SUM_KEYS -> Kahan compensation term
    maxes: dict       # STREAM_MAX_KEYS -> running max over ALL steps
    hist: jax.Array   # [HIST_BINS] i32 warm-step log-histogram of q_dst
                      # (integer counts: f32 would silently saturate past
                      # 2^24 increments per bin on long horizons)
    scheme: object    # scheme-private accumulator (Scheme.init_metric_acc)
    chan: object      # channel-private accumulator
                      # (ChannelModel.init_metric_acc; None when ideal)


class WindowAux(NamedTuple):
    """Aux output of ``trace_mode="window"`` (docs/observability.md).

    Everything ``metrics`` mode streams, PLUS the last
    ``cfg.trace_window_steps`` steps of every trace key and (optionally)
    the event ring — all O(W + E) per scenario, never O(T). Under the
    batched engine every leaf gains a leading [B] axis."""
    acc: MetricAcc    # the same streamed Fig. 3 reductions as "metrics"
    window: dict      # trace key -> [W, ...] ring; step t lives in row
                      # t mod W (repro.netsim.obs.unroll_window reorders)
    events: object    # obs.EventRing when cfg.event_ring_slots > 0,
                      # else None


def _failure_len(cfg, params) -> int:
    """STATIC outage-window count W of a compiled program. Prefer the
    ``fail_windows`` leaf SHAPE over ``cfg.failure_len``: inside a batched
    program ``cfg`` is the ``batch_template`` (every traced field reset to
    its default, so ``cfg.failure_len`` reads 0 there) and only the
    stacked leaf still carries W — the same shape-from-params idiom
    ``trace_replay`` uses for its schedule length."""
    fw = getattr(params, "fail_windows", None) if params is not None else None
    if fw is None:
        return cfg.failure_len
    return int(fw.shape[-2])


def _track_chan(channel, cfg, params=None) -> bool:
    """Whether the chan_* trace keys / streamed channel columns exist for
    this run: any non-ideal channel, OR a failure schedule (an outage
    activates the loss-repair path even under the ideal channel — the
    base ``ChannelModel`` streaming hooks then reduce the engine-owned
    chan_* keys)."""
    return (not channel.is_ideal) or _failure_len(cfg, params) > 0


def _init_metric_acc(scheme, channel, ctx, state0) -> MetricAcc:
    z = jnp.float32(0.0)
    return MetricAcc(
        sum_s={k: z for k in STREAM_SUM_KEYS},
        sum_c={k: z for k in STREAM_SUM_KEYS},
        maxes={k: z for k in STREAM_MAX_KEYS},
        hist=jnp.zeros((HIST_BINS,), jnp.int32),
        scheme=scheme.init_metric_acc(ctx, state0),
        chan=(channel.init_metric_acc(ctx, state0)
              if _track_chan(channel, ctx.cfg, ctx.params) else None),
    )


def _accumulate_engine(acc: MetricAcc, out: dict, inc: jax.Array) -> MetricAcc:
    sum_s, sum_c = {}, {}
    for k in STREAM_SUM_KEYS:
        # Kahan-compensated so the streaming mean matches the numpy trace
        # mean to ~ulp — "metrics" mode is a drop-in for figure numbers
        sum_s[k], sum_c[k] = kahan_add(acc.sum_s[k], acc.sum_c[k],
                                       out[k] * inc)
    maxes = {k: jnp.maximum(acc.maxes[k], out[k]) for k in STREAM_MAX_KEYS}
    hist = acc.hist.at[_hist_bin_index(out["q_dst"])].add(
        inc.astype(jnp.int32))
    return acc._replace(sum_s=sum_s, sum_c=sum_c, maxes=maxes, hist=hist)


class SimState(NamedTuple):
    sent: jax.Array          # [F] cumulative bytes leaving the sender NIC
    acked: jax.Array         # [F] cumulative bytes ACKed at the sender
    delivered: jax.Array     # [F] cumulative bytes delivered to the receiver
    done_at_us: jax.Array    # [F] completion time (INF = not done)
    cc: DcqcnState           # [F] DCQCN machine (sender or proxy)
    cnp_timer: jax.Array     # [F] µs since last CNP emission (receiver side)
    marked_acc: jax.Array    # [F] marked-byte accumulator (per-packet model)
    proxy_timer: jax.Array   # [F] µs since last proxy cut (MatchRDMA)
    proxy_mod: jax.Array     # [F] multiplicative proxy modulation in [0.25, 1]
    q_src: jax.Array         # [F] source-OTN queue bytes
    q_dst: jax.Array         # [F] destination-OTN queue bytes
                             # ([L, F] when cfg.num_paths > 1)
    q_leaf: jax.Array        # [F] destination-leaf queue bytes
    pipe: jax.Array          # [Dp, F] in-flight long-haul bytes
                             # ([Dp, L, F] when cfg.num_paths > 1)
    inflight: jax.Array      # [F] running sum of pipe (incremental: O(F)/step)
    ack_line: jax.Array      # [Dp, F] ACK return path
    cnp_line: jax.Array      # [Dp, F] CNP return path
    pause_line: jax.Array    # [Dp] PFC signal dst-OTN -> src-OTN
                             # ([Dp, L]: per-link pause at L > 1)
    pause_dst: jax.Array     # scalar: dst OTN asserting long-haul pause
                             # ([L] per-link at L > 1)
    extra: object            # scheme-private pytree (Scheme.init_extra_state)
    # channel subsystem (ALL None under the ideal channel — the engine
    # structurally skips the machinery, keeping the default path
    # bit-identical to the pre-channel engine):
    chan: object             # channel-private pytree (init_channel_state)
    retx_backlog: object     # [F] lost bytes awaiting retransmission at src
    retx_line: object        # [Dp, F] loss notifications dst -> src
    retx_inflight: object    # [F] running sum of retx_line (incremental)


def _delay_steps(cfg: NetConfig) -> int:
    """STATIC delay-step count — sizes the delay-line padding (the shared
    f32-aware definition lives on ``NetConfig.static_delay_steps``)."""
    return cfg.static_delay_steps


def _proc_steps(cfg: NetConfig) -> int:
    return cfg.control_proc_steps


def init_state(cfg: NetConfig, num_flows: int, params: NetParams = None,
               delay_pad: int = 0, history_slots: int = 0,
               scheme: Scheme = None, channel: ChannelModel = None
               ) -> SimState:
    """``delay_pad``/``history_slots`` are static ring sizes (0 = size for
    ``cfg`` itself); ``params`` carries the traced per-scenario scalars;
    ``scheme`` owns the ``extra`` slot (None = the default MatchRDMA
    block); ``channel`` owns the ``chan``/``retx_*`` slots (None = the
    ideal channel — the slots stay empty)."""
    f = num_flows
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    if params is None:
        params = NetParams.of(cfg)
    if scheme is None:
        scheme = Scheme()
    channel = get_channel_model(channel)
    multi = cfg.num_paths > 1
    L = cfg.num_paths
    z = jnp.zeros((f,), jnp.float32)
    nic = params.nic_gbps * 1e9 / 8.0
    # the loss-repair slots exist whenever anything can LOSE bytes: a
    # non-ideal channel, or a failure schedule (a dead link dumps its
    # in-flight bytes into the retransmit path — docs/failures.md)
    repair = (not channel.is_ideal) or _failure_len(cfg, params) > 0
    if repair:
        backlog, retx_inflight = z, z
        retx_line = jnp.zeros((delay_pad, f), jnp.float32)
    else:
        backlog = retx_line = retx_inflight = None
    if channel.is_ideal:
        chan = None
    else:
        if cfg.soft_step:
            # soft mode pins one noise stream per seed (no knob-bit
            # folding): differentiable + common random numbers across
            # knob perturbations — mirrors make_step_fn
            base_key = jax.random.PRNGKey(cfg.channel_seed)
        else:
            base_key = scenario_key(
                jax.random.PRNGKey(cfg.channel_seed), params)
        # models whose init accepts a ``link`` index (the base-class
        # signature since the trace_replay model landed) are told which
        # link-axis entry they serve; legacy third-party signatures
        # without it keep working unchanged
        import inspect
        try:
            takes_link = "link" in inspect.signature(
                channel.init_channel_state).parameters
        except (TypeError, ValueError):  # builtins/partials without sigs
            takes_link = False
        if multi:
            # one independent impairment process per link: fold the link
            # index into the scenario key so parallel paths draw
            # decorrelated noise
            keys = jax.vmap(lambda l: jax.random.fold_in(base_key, l))(
                jnp.arange(L))
            if takes_link:
                chan = jax.vmap(
                    lambda k, l: channel.init_channel_state(
                        cfg, params, f, key=k, link=l)
                )(keys, jnp.arange(L))
            else:
                chan = jax.vmap(
                    lambda k: channel.init_channel_state(cfg, params, f,
                                                         key=k)
                )(keys)
        else:
            chan = channel.init_channel_state(cfg, params, f, key=base_key)
    return SimState(
        sent=z, acked=z, delivered=z,
        done_at_us=jnp.full((f,), INF),
        cc=init_dcqcn(f, nic),
        cnp_timer=jnp.full((f,), 1e9, jnp.float32),
        marked_acc=z,
        proxy_timer=jnp.full((f,), 1e9, jnp.float32),
        proxy_mod=jnp.ones((f,), jnp.float32),
        q_src=z,
        q_dst=jnp.zeros((L, f), jnp.float32) if multi else z,
        q_leaf=z,
        pipe=(jnp.zeros((delay_pad, L, f), jnp.float32) if multi
              else jnp.zeros((delay_pad, f), jnp.float32)),
        inflight=z,
        ack_line=jnp.zeros((delay_pad, f), jnp.float32),
        cnp_line=jnp.zeros((delay_pad, f), jnp.float32),
        pause_line=(jnp.zeros((delay_pad, L), jnp.float32) if multi
                    else jnp.zeros((delay_pad,), jnp.float32)),
        pause_dst=(jnp.zeros((L,), jnp.float32) if multi
                   else jnp.float32(0.0)),
        extra=scheme.init_extra_state(
            cfg, params, f, history_slots=history_slots,
            chan_delay_pad=delay_pad + _proc_steps(cfg)),
        chan=chan, retx_backlog=backlog, retx_line=retx_line,
        retx_inflight=retx_inflight,
    )


def make_step_fn(cfg: NetConfig, wl: WorkloadParams, scheme,
                 period_slots: int = 0, params: NetParams = None,
                 delay_pad: int = 0, channel=None):
    """Build the per-step transition — the scheme-agnostic skeleton.

    ``wl``: the traced per-flow workload leaves. All per-scenario scalars
    are read from ``params`` (traced), so the same compiled step serves
    every cell of a vmapped scenario batch; ``cfg`` only contributes static
    structure (dt, slot layout, DCQCN constants). ``scheme`` is a
    registered name or a ``Scheme`` instance; everything scheme-specific
    happens inside its hooks. ``channel`` is a registered channel-model
    name or ``ChannelModel`` instance (None = ``"ideal"``): non-ideal
    models get the single channel hook point between the pipe exit and the
    destination OTN, and the engine's loss-repair path (notification ring,
    retransmit backlog served at ``Scheme.retx_rate``) activates.
    """
    scheme = get_scheme(scheme)
    channel = get_channel_model(channel)
    impaired = not channel.is_ideal
    # hard-failure schedule (docs/failures.md; STATIC window count keys
    # the compile). ``repair`` gates the loss-repair machinery: a dead
    # link dumps its in-flight bytes into the retransmit path, so the
    # backlog/notification-ring plumbing must exist even under the ideal
    # channel whenever failures can fire.
    if params is None:
        params = NetParams.of(cfg)
    has_fail = _failure_len(cfg, params) > 0
    repair = impaired or has_fail
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    dt_us = cfg.dt_us
    dt_s = dt_us * 1e-6
    # soft-step relaxation (docs/differentiable.md): with cfg.soft_step the
    # traced temperature replaces every knob-dependent hard select below by
    # a tempered blend; None (the default) leaves the hard jaxpr untouched.
    soft = params.soft_temp if cfg.soft_step else None
    # traced actual delay, clamped to the static ring allocation (mirrors
    # budget.init_channel) — an out-of-range wrap would silently alias
    # ring rows through JAX's index clamping instead of erroring
    d_steps = jnp.clip(params.delay_steps(dt_us), 1, delay_pad)
    nic = params.nic_gbps * 1e9 / 8.0
    c_otn = params.otn_capacity_gbps * 1e9 / 8.0
    c_leaf = params.dst_dc_gbps * 1e9 / 8.0
    xoff = params.pfc_xoff_kb * 1024.0
    xon = params.pfc_xon_kb * 1024.0
    # OTN nodes are provisioned with BDP-scaled buffers (long-haul headroom)
    bdp = c_otn * 2.0 * params.one_way_delay_us * 1e-6
    xoff_otn = jnp.maximum(xoff, params.otn_buffer_bdp_frac * bdp)
    xon_otn = xoff_otn / 2.0

    # -- multi-link topology (cfg.num_paths > 1; STATIC — keys the compile).
    # At L = 1 none of these exist and the single-pipe code path below is
    # untouched, so the L=1 jaxpr (and the goldens pinning it) stays
    # bit-identical to the pre-topology engine.
    L = cfg.num_paths
    multi = L > 1
    if cfg.is_multisite and not multi:
        raise ValueError(
            f"make_step_fn: multi-site config (num_sites={cfg.num_sites}, "
            f"site_edges={cfg.site_edges!r}) requires num_paths > 1 — a "
            f"site graph compiles onto the link axis (one edge per link; "
            f"see docs/sites.md)")
    if multi:
        link_ids = jnp.arange(L)
        link_caps = params.link_cap_gbps * 1e9 / 8.0              # [L] B/s
        link_d_steps = jnp.clip(
            jnp.round(params.link_delay_us / dt_us).astype(jnp.int32),
            1, delay_pad)                                          # [L]
        # per-link dst-OTN PFC thresholds: the explicit per-path floor or
        # the link's own BDP-scaled headroom, whichever is larger
        link_bdp = link_caps * 2.0 * params.link_delay_us * 1e-6
        xoff_link = jnp.maximum(params.link_thresh_kb * 1024.0,
                                params.otn_buffer_bdp_frac * link_bdp)
        xon_link = xoff_link / 2.0
        route = jnp.asarray(wl.route)                              # [F, W]
        if route.shape[-1] == 1:
            route = jnp.broadcast_to(route, route.shape[:-1] + (L,))
        elif route.shape[-1] != L:
            raise ValueError(
                f"WorkloadParams.route has {route.shape[-1]} link columns "
                f"but cfg.num_paths = {L} — give each flow a length-{L} "
                f"route (or () for the symmetric default)")
        if cfg.is_multisite:
            # the endpoint matrix, compiled: mask each flow's spray row
            # down to the edges serving its (src_site, dst_site) pair
            # (docs/sites.md). The edge table is static; the flow
            # endpoints are traced workload leaves, so heterogeneous
            # meshes share one program. Gated on is_multisite so legacy
            # single-pair configs keep the exact pre-sites jaxpr.
            pairs = np.asarray(cfg.edge_pairs(), np.float32)       # [L, 2]
            f_src = jnp.asarray(wl.src_site)                       # [F]
            f_dst = jnp.asarray(wl.dst_site)                       # [F]
            pair_mask = ((f_src[:, None] == pairs[None, :, 0]) &
                         (f_dst[:, None] == pairs[None, :, 1]))
            route = route * pair_mask.astype(jnp.float32)          # [F, L]

    is_inter = jnp.asarray(wl.is_inter)
    is_intra = 1.0 - is_inter
    window = jnp.asarray(wl.window)
    total_bytes = jnp.asarray(wl.total_bytes)
    start_us = jnp.asarray(wl.start_us)
    period_us = jnp.asarray(wl.period_us)
    duty = jnp.asarray(wl.duty)
    active_mask = jnp.asarray(wl.active_mask)
    rtt_us = jnp.where(is_inter > 0, 2.0 * d_steps * dt_us + 4.0, 4.0)

    ctx = SchemeCtx(
        cfg=cfg, params=params, period_slots=period_slots,
        dt_us=dt_us, dt_s=dt_s, nic=nic, c_otn=c_otn, c_leaf=c_leaf,
        xoff=xoff, xon=xon, xoff_otn=xoff_otn, xon_otn=xon_otn,
        is_inter=is_inter, is_intra=is_intra, rtt_us=rtt_us,
        d_steps=d_steps,
        num_links=L,
        link_caps=link_caps if multi else None,
        link_d_steps=link_d_steps if multi else None,
        num_sites=cfg.num_sites,
        edge_sites=(jnp.asarray(cfg.edge_pairs(), jnp.int32)
                    if cfg.is_multisite else None),
        flow_src_site=(jnp.asarray(wl.src_site)
                       if cfg.is_multisite else None),
        flow_dst_site=(jnp.asarray(wl.dst_site)
                       if cfg.is_multisite else None),
        soft=soft,
    )
    rtt_scale = scheme.rtt_scale(ctx)
    if impaired:
        # counter-based randomness: the per-step key is a pure function of
        # (static seed, per-scenario salt, step index) — deterministic,
        # resume-safe inside lax.scan, shared across schemes (common
        # random numbers for paired comparisons)
        if cfg.soft_step:
            # knob-bit folding is a non-differentiable bitcast AND would
            # redraw the noise at every knob perturbation — soft mode pins
            # one stream per seed (common random numbers across gradient
            # steps, the CRN contract grad_tune relies on)
            chan_key0 = jax.random.PRNGKey(cfg.channel_seed)
        else:
            chan_key0 = scenario_key(
                jax.random.PRNGKey(cfg.channel_seed), params)
    zero_f = jnp.zeros((is_inter.shape[0],), jnp.float32)
    if has_fail:
        fw = jnp.asarray(params.fail_windows)          # [L, W, 2]
        fail_lo, fail_hi = fw[..., 0], fw[..., 1]      # [L, W]

    def step(state: SimState, t: jax.Array):
        t_us = t.astype(jnp.float32) * dt_us
        ridx = jnp.mod(t, d_steps)

        # -------------------------------------------- 0. failure live-mask
        # A link is DOWN inside any of its (down_at, up_at) windows
        # (strict upper bound, so padding (0, 0) windows never fire).
        # Schemes see the mask through ``SchemeCtx.link_live`` and
        # re-spray their routing weights over the survivors; at an
        # all-up step every where() below selects the ORIGINAL tensor,
        # keeping the program bit-identical to a schedule-free run.
        if has_fail:
            link_down = jnp.any((t_us >= fail_lo) & (t_us < fail_hi),
                                axis=-1)                           # [L]
            link_live = 1.0 - link_down.astype(jnp.float32)        # [L]
            hctx = ctx._replace(link_live=link_live)
        else:
            hctx = ctx

        # ------------------------------------------------ 1. flow phase
        if soft is None:
            started = (t_us >= start_us).astype(jnp.float32)
            in_period = jnp.where(
                period_us > 0,
                (jnp.mod(jnp.maximum(t_us - start_us, 0.0),
                         jnp.maximum(period_us, 1.0))
                 < duty * period_us).astype(jnp.float32),
                1.0)
            not_done = (state.delivered < total_bytes).astype(jnp.float32)
        else:
            started = soft_gt(t_us, start_us, soft, dt_us)
            phase = jnp.mod(jnp.maximum(t_us - start_us, 0.0),
                            jnp.maximum(period_us, 1.0))
            gate = soft_gt(duty * period_us, phase, soft, dt_us)
            in_period = lerp(soft_pos(period_us, soft, dt_us), gate, 1.0)
            # STE on the activity gate: the forward pass keeps the exact
            # live-mask (consistent with the hard completion latch below);
            # the backward pass sees the tempered gate
            not_done = ste(
                (state.delivered < total_bytes).astype(jnp.float32),
                soft_gt(total_bytes, state.delivered, soft,
                        jnp.maximum(1e-3 * total_bytes, MTU)))
        active = started * in_period * not_done * active_mask

        # ------------------------------------------------ 2. delayed inputs
        ack_arr = state.ack_line[ridx]
        cnp_arr = state.cnp_line[ridx]
        if multi:
            # each link's ring row wraps at ITS OWN traced delay: row l of
            # the padded ring holds what link l launched d_l steps ago
            lidx = jnp.mod(t, link_d_steps)            # [L]
            pause_sig = state.pause_line[lidx, link_ids]        # [L]
            pipe_out = state.pipe[lidx, link_ids]               # [L, F]
        else:
            pause_sig = state.pause_line[ridx]
            pipe_out = state.pipe[ridx]

        # ------------------------------------------------ 2b. channel hook
        # The single hook point of the channel subsystem: what leaves the
        # pipe is impaired BEFORE the destination OTN sees it, and the
        # source-OTN line capacity may be dimmed (OTN flap). Lost bytes
        # ride the loss-notification ring back to the source (delay D).
        # At L > 1 the model is vmapped over the link axis — each parallel
        # path carries its own impairment process (independent keys, own
        # flap phase / loss chain / jitter buffer).
        if soft is None:
            paused_src = pause_sig > 0.5               # delayed dst PFC
            if multi:
                cap_link = jnp.where(paused_src, 0.0,
                                     link_caps * dt_s)           # [L]
                if has_fail:
                    cap_link = jnp.where(link_down, 0.0, cap_link)
                cap_src = jnp.sum(cap_link)
            else:
                cap_src = jnp.where(paused_src, 0.0, c_otn * dt_s)
                if has_fail:
                    cap_src = jnp.where(link_down[0], 0.0, cap_src)
        else:
            # the delayed pause signal already lives in [0, 1] in soft
            # mode (soft_hysteresis); re-temper it around the midpoint and
            # scale the capacity instead of zeroing it. The failure mask
            # is schedule-structure (knob-independent), so the hard 0/1
            # multiplier stays.
            w_pause = soft_gt(pause_sig, 0.5, soft, 0.25)
            if multi:
                cap_link = (1.0 - w_pause) * link_caps * dt_s    # [L]
                if has_fail:
                    cap_link = cap_link * link_live
                cap_src = jnp.sum(cap_link)
            else:
                cap_src = (1.0 - w_pause) * c_otn * dt_s
                if has_fail:
                    cap_src = cap_src * link_live[0]
        retx_arr = state.retx_line[ridx] if repair else zero_f
        if impaired:
            if multi:
                step_key = jax.random.fold_in(chan_key0, t)
                keys = jax.vmap(
                    lambda l: jax.random.fold_in(step_key, l))(link_ids)
                eff = jax.vmap(
                    lambda c, k, po, cs: channel.apply_impairments(
                        ctx, c, ChannelInputs(t=t, key=k, pipe_out=po,
                                              cap_src=cs)))(
                    state.chan, keys, pipe_out, cap_link)
                pipe_arrivals, chan_new = eff.arrivals, eff.chan  # [L, F]
                lost = jnp.sum(eff.lost, axis=0)                  # [F]
                cap_link = eff.cap_src                            # [L]
                cap_src = jnp.sum(cap_link)
            else:
                eff = channel.apply_impairments(ctx, state.chan, ChannelInputs(
                    t=t, key=jax.random.fold_in(chan_key0, t),
                    pipe_out=pipe_out, cap_src=cap_src))
                pipe_arrivals, lost = eff.arrivals, eff.lost
                cap_src, chan_new = eff.cap_src, eff.chan
        else:
            pipe_arrivals, lost, chan_new = pipe_out, zero_f, None
        # -------------------------------------------- 2c. outage dump
        # Bytes reaching the far end of a DEAD link are lost there and
        # ride the loss-notification ring back: conservation holds
        # through the outage and the data re-enters the source queue to
        # be re-sprayed over the surviving links. (Bytes in flight when
        # a link dies keep transiting the ring; they are dumped at exit
        # time while the link stays down, delivered if it came back.)
        if has_fail:
            if multi:
                deadc = link_down[:, None]                       # [L, 1]
                fail_lost = jnp.sum(
                    jnp.where(deadc, pipe_arrivals, 0.0), axis=0)  # [F]
                pipe_arrivals = jnp.where(deadc, 0.0, pipe_arrivals)
            else:
                fail_lost = jnp.where(link_down[0], pipe_arrivals, zero_f)
                pipe_arrivals = jnp.where(link_down[0], zero_f,
                                          pipe_arrivals)
            lost = jnp.where(fail_lost > 0.0, lost + fail_lost, lost)

        # ------------------------------------------------ 3. ACK accounting
        acked_inter = scheme.ack_view(hctx, state, ack_arr)
        acked = jnp.where(is_inter > 0, acked_inter,
                          state.delivered)             # intra: ~µs loop
        acked = jnp.minimum(acked, state.sent)

        # ------------------------------------------------ 4. sender rates
        win_avail = jnp.maximum(window - (state.sent - acked), 0.0)
        base_rate = jnp.minimum(win_avail / dt_s, nic)
        rate = scheme.sender_rate(hctx, state, base_rate)
        # src-OTN -> sender PFC (1 step, from last-step queue)
        if soft is None:
            src_nic_pause = (jnp.sum(state.q_src)
                             > xoff_otn).astype(jnp.float32)
        else:
            src_nic_pause = soft_gt(jnp.sum(state.q_src), xoff_otn, soft,
                                    0.05 * xoff_otn + 1.0)
        rate = rate * jnp.where(is_inter > 0, 1.0 - src_nic_pause, 1.0)
        # -------------------------------------------- 4b. loss repair
        # Lost bytes whose notification has arrived are retransmitted with
        # priority: the scheme grants a repair rate (retx_rate) and the
        # skeleton deducts what repair uses from the new-data rate, so
        # total per-flow emission never exceeds max(rate, granted) * dt.
        # The where() keeps the no-repair branch the UNTOUCHED rate tensor
        # AND leaves the send/sent expressions below structurally
        # identical to the ideal path — at zero impairments the compiled
        # arithmetic (XLA fusion/FMA contraction included) is the
        # pre-channel program's, which the zero-impairment identity test
        # pins bit-for-bit against the goldens.
        if repair:
            backlog_avail = state.retx_backlog + retx_arr
            retx_bps = jnp.maximum(scheme.retx_rate(hctx, state, rate), 0.0)
            retx_send = (jnp.minimum(jnp.minimum(backlog_avail,
                                                 retx_bps * dt_s),
                                     nic * dt_s)
                         * is_inter * (1.0 - src_nic_pause))
            rate = jnp.where(retx_send > 0.0,
                             jnp.maximum(rate - retx_send / dt_s, 0.0),
                             rate)
            retx_backlog = backlog_avail - retx_send
        else:
            retx_send, retx_backlog = zero_f, zero_f
        send = rate * active * dt_s                    # bytes this step
        sent = state.sent + send

        # ------------------------------------------------ 5. source OTN
        arrivals_src = send * is_inter
        if repair:
            # where(): at retx_send == 0 the select returns the original
            # arrivals tensor (see the send select above)
            arrivals_src = jnp.where(retx_send > 0.0,
                                     arrivals_src + retx_send, arrivals_src)
        q_src, drained_src = scheme.src_otn_release(hctx, state, arrivals_src,
                                                    cap_src, active)
        if multi:
            # spray the scheme's aggregate release across the parallel
            # links: per-flow weights (workload routing matrix, reweighted
            # by the scheme's route_weights hook), masked by links with
            # capacity this step, then clipped per link. Bytes a saturated
            # link cannot take spill back into the source-OTN queue — an
            # equal-weight spray over unequal paths therefore bottlenecks
            # on its slowest link, which is exactly the imbalance
            # token-gated spraying (rdmacell) adapts away.
            w = jnp.maximum(scheme.route_weights(hctx, state, route), 0.0)
            if soft is None:
                w = w * (cap_link > 0.0)[None, :]                 # [F, L]
            else:
                # soft zero-cap mask: exactly 0 at cap 0 (soft_pos), so a
                # fully paused/flapped link still attracts no spray
                w = w * soft_pos(cap_link, soft, MTU)[None, :]
            row = jnp.sum(w, axis=1, keepdims=True)
            share = w / jnp.maximum(row, 1e-9)                    # [F, L]
            want = drained_src[:, None] * share                   # [F, L]
            link_want = jnp.sum(want, axis=0)                     # [L]
            scale = jnp.minimum(
                1.0, cap_link / jnp.maximum(link_want, 1e-9))
            sent_link = (want * scale[None, :]).T                 # [L, F]
            spilled = drained_src - jnp.sum(sent_link, axis=0)
            q_src = q_src + spilled
            pipe = state.pipe.at[lidx, link_ids].set(sent_link)
            inflight = (state.inflight + jnp.sum(sent_link, axis=0)
                        - jnp.sum(pipe_out, axis=0))
            link_tx = jnp.sum(sent_link, axis=1)                  # [L]
        else:
            pipe = state.pipe.at[ridx].set(drained_src)  # arrives at t + D
            inflight = state.inflight + drained_src - pipe_out

        # ------------------------------------------------ 6. destination OTN
        if soft is None:
            leaf_pfc = (jnp.sum(state.q_leaf) > xoff).astype(jnp.float32)
        else:
            leaf_pfc = soft_gt(jnp.sum(state.q_leaf), xoff, soft,
                               0.05 * xoff + 1.0)
        cap_dst = c_leaf * dt_s * (1.0 - leaf_pfc)
        q_dst, drained_dst = drain_proportional(state.q_dst, pipe_arrivals,
                                                cap_dst)
        egress_bytes = jnp.sum(drained_dst)
        q_dst_tot = jnp.sum(q_dst)
        if multi:
            # per-link backlog -> per-link PFC toward that link's source
            # line; each pause rides back at the LINK's own delay
            q_dst_link = jnp.sum(q_dst, axis=1)                   # [L]
            pause_dst = pfc_hysteresis(state.pause_dst, q_dst_link,
                                       xoff_link, xon_link,
                                       soft=soft)                 # [L]
            pause_line = state.pause_line.at[lidx, link_ids].set(pause_dst)
            drained_dst_f = jnp.sum(drained_dst, axis=0)          # [F]
        else:
            pause_dst = pfc_hysteresis(state.pause_dst, q_dst_tot, xoff_otn,
                                       xon_otn, soft=soft)
            pause_line = state.pause_line.at[ridx].set(pause_dst)
            drained_dst_f = drained_dst

        # ------------------------------------------------ 7. destination leaf
        arrivals_leaf = drained_dst_f + send * is_intra
        mark_p = ecn_mark_prob(jnp.sum(state.q_leaf), cfg, params=params,
                               soft=soft)
        q_leaf, drained_leaf = drain_proportional(state.q_leaf, arrivals_leaf,
                                                  c_leaf * dt_s)
        delivered = state.delivered + drained_leaf
        marked_acc = state.marked_acc + drained_leaf * mark_p

        # ------------------------------------------------ 8. CNP generation
        cnp_timer = state.cnp_timer + dt_us
        if soft is None:
            want = marked_acc >= MTU
            emit = want & (cnp_timer >= cfg.cnp_interval_us)
            cnp_out = emit.astype(jnp.float32)
            cnp_timer = jnp.where(emit, 0.0, cnp_timer)
            marked_acc = jnp.where(emit, 0.0, marked_acc)
        else:
            # fractional CNPs: downstream consumers (slot classifier, CC
            # cut gate) already read them through tempered gates at the
            # 0.5 midpoint
            cnp_out = (soft_gt(marked_acc, MTU, soft, 0.1 * MTU)
                       * soft_gt(cnp_timer, cfg.cnp_interval_us, soft,
                                 dt_us))
            # self-referential resets take the DETACHED gate (soft.reset_gate
            # docstring); cnp_out itself keeps full gradients downstream
            cnp_timer = lerp(reset_gate(cnp_out), 0.0, cnp_timer)
            marked_acc = lerp(reset_gate(cnp_out), 0.0, marked_acc)

        # ------------------------------------------------ 9. scheme feedback
        # (CNP routing, pseudo-ACK ledger, proxy brake, slot/budget/channel)
        fb = scheme.feedback(hctx, state, SchemeSignals(
            t=t, active=active, sent=sent, cnp_out=cnp_out, cnp_arr=cnp_arr,
            egress_bytes=egress_bytes, q_dst_tot=q_dst_tot, q_leaf=q_leaf,
            leaf_pfc=leaf_pfc, retx_arr=retx_arr, retx_backlog=retx_backlog,
            link_sent=sent_link if multi else None,
            link_arrivals=pipe_arrivals if multi else None,
            link_want=link_want if multi else None,
            link_cap=cap_link if multi else None))

        # ------------------------------------------------ 10. return paths
        ack_line = state.ack_line.at[ridx].set(drained_leaf * is_inter)
        cnp_line = state.cnp_line.at[ridx].set(fb.cnp_wire)

        # ------------------------------------------------ 11. CC update
        cc = step_dcqcn(state.cc, fb.cnp_in, send, cfg, rtt_scale=rtt_scale,
                        soft=soft)

        # ------------------------------------------------ 12. FCT
        # the completion latch stays HARD even in soft mode: the INF
        # sentinel makes any blend meaningless (forward exactness is the
        # contract; FCT gradients flow through the byte counters instead)
        newly_done = (delivered >= total_bytes) & is_unfinished(
            state.done_at_us)
        done_at = jnp.where(newly_done, t_us, state.done_at_us)

        if repair:
            retx_line = state.retx_line.at[ridx].set(lost)
            retx_inflight = state.retx_inflight + lost - retx_arr
        else:
            retx_line, retx_inflight = None, None

        new_state = SimState(
            sent=sent, acked=acked, delivered=delivered, done_at_us=done_at,
            cc=cc, cnp_timer=cnp_timer, marked_acc=marked_acc,
            proxy_timer=fb.proxy_timer, proxy_mod=fb.proxy_mod,
            q_src=q_src, q_dst=q_dst, q_leaf=q_leaf,
            pipe=pipe, inflight=inflight,
            ack_line=ack_line, cnp_line=cnp_line,
            pause_line=pause_line, pause_dst=pause_dst, extra=fb.extra,
            chan=chan_new, retx_backlog=(retx_backlog if repair else None),
            retx_line=retx_line, retx_inflight=retx_inflight,
        )
        # per-flow byte conservation residual: everything the sender emitted
        # is either delivered or sitting in exactly one queue / the pipe —
        # with a channel, also the loss-notification transit, the
        # retransmit backlog, or a jitter deferral buffer
        q_dst_f = jnp.sum(q_dst, axis=0) if multi else q_dst
        residual = sent - delivered - q_src - q_dst_f - q_leaf - inflight
        if repair:
            residual = residual - retx_inflight - retx_backlog
        if impaired:
            held = (jnp.sum(jax.vmap(channel.held_bytes)(chan_new), axis=0)
                    if multi else channel.held_bytes(chan_new))
            residual = residual - held
        cons_err = jnp.max(jnp.abs(residual) / jnp.maximum(sent, 1.0))
        if multi:
            # capacity-weighted pause means keep the scalar trace keys (and
            # the Fig. 3 pause-ratio column) shape-stable across L
            cap_w = link_caps / jnp.maximum(jnp.sum(link_caps), 1e-9)
            pause_trace = jnp.sum(pause_dst * cap_w)
            src_paused_trace = jnp.sum(pause_sig * cap_w)
        else:
            pause_trace, src_paused_trace = pause_dst, pause_sig
        out = {
            "q_src": jnp.sum(q_src),
            "q_dst": q_dst_tot,
            "q_leaf": jnp.sum(q_leaf),
            "pause_dst": pause_trace,
            "src_paused": src_paused_trace,
            "thr_inter": jnp.sum(drained_leaf * is_inter) / dt_s,
            "thr_intra": jnp.sum(drained_leaf * is_intra) / dt_s,
            "cons_err": cons_err,
        }
        if multi:
            out.update({
                "q_dst_link": q_dst_link,     # [L] per-link dst backlog
                "link_tx": link_tx,           # [L] bytes launched per link
                "link_pause": pause_dst,      # [L] per-link PFC state
            })
        if repair:
            # engine-owned channel trace keys (goodput = wire - lost: with
            # selective repair nothing delivered is ever a duplicate)
            backlog_tot = jnp.sum(retx_backlog)
            # granted repair capacity, floored at 1 MB/s: a transport
            # whose window is momentarily exhausted still times out and
            # retransmits eventually — without the floor a zero-rate step
            # inflates the wait estimate to the histogram clamp
            serv_cap = jnp.maximum(
                jnp.sum(jnp.minimum(retx_bps, nic) * is_inter), 1e6)
            d_us = d_steps.astype(jnp.float32) * dt_us
            # fluid repair-latency estimate for the currently pending
            # backlog: notification transit D + virtual drain time at the
            # granted repair rate + retransmit transit D
            wait_us = jnp.where(
                backlog_tot > 0,
                2.0 * d_us + backlog_tot / serv_cap * 1e6,
                0.0)
            out.update({
                "chan_wire": jnp.sum(pipe_out),
                "chan_lost": jnp.sum(lost),
                "chan_retx": jnp.sum(retx_send),
                "chan_backlog": backlog_tot,
                "chan_repair_wait_us": wait_us,
            })
        if has_fail:
            # the live mask as a trace key ([L] at multi; scalar at L=1)
            out["fail_live"] = link_live if multi else link_live[0]
        out.update(scheme.extra_traces(hctx, state))
        return new_state, out

    step.ctx = ctx      # shared per-run quantities for the metric machinery
    return step


def _scan_with_mode(step, scheme, channel, state0, steps: int, mode: str,
                    decimate: int, warm: int):
    """Drive the per-step transition under one of the execution modes.

    Returns ``(final_state, aux)`` where ``aux`` is the [T]-stacked trace
    dict (``full``), the [T//decimate]-stacked trace dict of every
    ``decimate``-th step (``decimate``), a ``MetricAcc`` (``metrics`` —
    no per-step array is ever allocated), or a ``WindowAux`` (``window``
    — the metrics accumulator plus the last-W-steps trace ring and the
    optional event ring, still no [T]-sized array).
    """
    ts = jnp.arange(steps, dtype=jnp.int32)
    if mode == "window":
        # Observability path (docs/observability.md): the event/window
        # machinery wraps AROUND ``step`` — the transition itself is the
        # byte-identical function every other mode runs, so ring-off
        # modes never see any of this code in their jaxpr.
        from repro.netsim.obs.events import (
            engine_event_candidates, init_event_ring, push_events,
        )
        ctx = step.ctx
        w = max(int(ctx.cfg.trace_window_steps), 1)
        slots = int(ctx.cfg.event_ring_slots)
        acc0 = _init_metric_acc(scheme, channel, ctx, state0)
        track_chan = _track_chan(channel, ctx.cfg, ctx.params)
        out_spec = jax.eval_shape(lambda s, t: step(s, t)[1], state0,
                                  jnp.int32(0))
        ring0 = {k: jnp.zeros((w,) + tuple(v.shape), v.dtype)
                 for k, v in out_spec.items()}
        ering0 = init_event_ring(slots) if slots > 0 else None

        def wstep(carry, t):
            state, acc, ring, ev = carry
            new_state, out = step(state, t)
            inc = (t >= warm).astype(jnp.float32)
            acc = _accumulate_engine(acc, out, inc)
            acc = acc._replace(scheme=scheme.accumulate_metrics(
                ctx, acc.scheme, new_state, out, inc))
            if track_chan:
                acc = acc._replace(chan=channel.accumulate_metrics(
                    ctx, acc.chan, new_state, out, inc))
            ring = {k: ring[k].at[jnp.mod(t, w)].set(out[k]) for k in ring}
            if ev is not None:
                cands = list(engine_event_candidates(ctx, state, new_state,
                                                     t))
                cands += list(scheme.emit_events(ctx, state, new_state,
                                                 out))
                if len(cands) > slots:
                    raise ValueError(
                        f"event_ring_slots={slots} is smaller than the "
                        f"{len(cands)} per-step event candidates of this "
                        f"run — raise NetConfig.event_ring_slots so one "
                        f"step can never overflow the ring "
                        f"(docs/observability.md)")
                t_us = t.astype(jnp.float32) * ctx.dt_us
                ev = push_events(ev, slots, t_us, cands)
            return (new_state, acc, ring, ev), None

        (final, acc, ring, ering), _ = jax.lax.scan(
            wstep, (state0, acc0, ring0, ering0), ts)
        return final, WindowAux(acc=acc, window=ring, events=ering)
    if mode == "metrics":
        acc0 = _init_metric_acc(scheme, channel, step.ctx, state0)
        track_chan = _track_chan(channel, step.ctx.cfg, step.ctx.params)

        def mstep(carry, t):
            state, acc = carry
            state, out = step(state, t)
            inc = (t >= warm).astype(jnp.float32)
            acc = _accumulate_engine(acc, out, inc)
            acc = acc._replace(scheme=scheme.accumulate_metrics(
                step.ctx, acc.scheme, state, out, inc))
            if track_chan:
                acc = acc._replace(chan=channel.accumulate_metrics(
                    step.ctx, acc.chan, state, out, inc))
            return (state, acc), None

        k = step.ctx.cfg.remat_steps
        if k > 1 and steps > k:
            # gradient checkpointing (cfg.remat_steps): rematerialize each
            # k-step block in the backward pass, so jax.grad through the
            # whole scan holds O(T/k + k) residuals instead of O(T) —
            # the memory knob behind long-horizon grad_tune runs. k = 0
            # (the default) keeps the single flat scan below untouched.
            nblocks = steps // k

            @jax.checkpoint
            def block(carry, b):
                carry, _ = jax.lax.scan(
                    mstep, carry, b * k + jnp.arange(k, dtype=jnp.int32))
                return carry, None

            carry, _ = jax.lax.scan(block, (state0, acc0),
                                    jnp.arange(nblocks, dtype=jnp.int32))
            rem = steps - nblocks * k
            if rem:
                carry, _ = jax.lax.scan(
                    mstep, carry,
                    nblocks * k + jnp.arange(rem, dtype=jnp.int32))
            return carry
        (final, acc), _ = jax.lax.scan(mstep, (state0, acc0), ts)
        return final, acc
    if mode == "decimate" and decimate > 1:
        k = decimate
        nblocks = steps // k

        def block(state, b):
            # the inner [k]-stacked traces are transient per outer step:
            # live memory is O(T/k + k), never O(T). Level-like keys keep
            # the block's LAST sample; per-step byte counts
            # (DECIMATE_SUM_KEYS) keep the block SUM so time-normalized
            # rate columns stay exact at any decimation.
            state, outs = jax.lax.scan(step, state,
                                       b * k + jnp.arange(k, dtype=jnp.int32))
            return state, {key: (jnp.sum(v, axis=0)
                                 if key in DECIMATE_SUM_KEYS else v[-1])
                           for key, v in outs.items()}

        final, traces = jax.lax.scan(block, state0,
                                     jnp.arange(nblocks, dtype=jnp.int32))
        rem = steps - nblocks * k
        if rem:
            final, _ = jax.lax.scan(
                step, final, nblocks * k + jnp.arange(rem, dtype=jnp.int32))
        return final, traces
    return jax.lax.scan(step, state0, ts)


def _check_trace_mode(trace_mode: str, decimate: int) -> None:
    if trace_mode not in TRACE_MODES:
        raise ValueError(f"unknown trace_mode {trace_mode!r}; "
                         f"expected one of {TRACE_MODES}")
    if decimate < 1:
        raise ValueError(f"decimate must be >= 1, got {decimate}")


def simulate(cfg: NetConfig, workload, scheme,
             horizon_us: Optional[float] = None, period_slots: int = 0,
             delay_pad: int = 0, history_slots: int = 0,
             trace_mode: str = "full", decimate: int = 1, channel=None):
    """Run one simulation; returns (final_state, traces dict of [T] arrays)
    — or ``(final_state, MetricAcc)`` under ``trace_mode="metrics"``.

    ``workload``: a ``Workload`` (or prebuilt ``WorkloadParams``);
    ``scheme``: a registered name or ``Scheme`` instance; ``channel``: a
    registered channel-model name or ``ChannelModel`` instance (None =
    ``"ideal"`` — names stay first-class here, mirroring the grid APIs).
    ``delay_pad``/``history_slots`` override the static ring sizes (0 = size
    for ``cfg``) — pass the batch padding to reproduce a ``simulate_batch``
    cell bit-for-bit. ``trace_mode``/``decimate``: see the module docstring.
    """
    if isinstance(scheme, str):
        import warnings
        warnings.warn(
            "passing a scheme name string to simulate() is deprecated; "
            "resolve it with repro.netsim.schemes.get_scheme(name) (names "
            "remain first-class in the batched sweep APIs)",
            DeprecationWarning, stacklevel=2)
    scheme = get_scheme(scheme)
    channel = get_channel_model(channel)
    _check_trace_mode(trace_mode, decimate)
    steps = cfg.horizon_steps(horizon_us)
    wlp = workload if isinstance(workload, WorkloadParams) \
        else workload.params()
    wlp = WorkloadParams(*(jnp.asarray(v) for v in wlp))
    if cfg.is_multisite:
        from repro.netsim.topology import validate_site_endpoints
        validate_site_endpoints(cfg, wlp)   # host-side: stalls fail early
    return _run_traced(cfg, wlp, scheme, steps, period_slots,
                       delay_pad, history_slots, trace_mode, decimate,
                       int(steps * WARMUP_FRAC), channel)


@partial(jax.jit, static_argnames=("scheme", "steps", "period_slots", "cfg",
                                   "delay_pad", "history_slots", "mode",
                                   "decimate", "warm", "channel"))
def _run_traced(cfg, wlp, scheme, steps, period_slots,
                delay_pad=0, history_slots=0, mode="full", decimate=1,
                warm=0, channel=None):
    channel = get_channel_model(channel)
    f = wlp.is_inter.shape[0]
    state0 = init_state(cfg, f, delay_pad=delay_pad,
                        history_slots=history_slots, scheme=scheme,
                        channel=channel)
    step = make_step_fn(cfg, wlp, scheme, period_slots,
                        delay_pad=delay_pad, channel=channel)
    return _scan_with_mode(step, scheme, channel, state0, steps, mode,
                           decimate, warm)


# ---------------------------------------------------------------------------
# Batched scenario engine
# ---------------------------------------------------------------------------


def batch_padding(cfgs: Sequence[NetConfig]):
    """(delay_pad, history_slots) covering every scenario in the grid —
    the static ring sizes shared by all cells of a batch.

    Control-channel rings are sized ``delay_pad + proc_steps(template)``
    inside the batched program, and a cell's OWN processing delay derives
    from its (traced) ``slot_us`` — so the pad absorbs any excess of a
    cell's proc steps over the template's, and the history covers the
    longest control window any cell needs. At a uniform default
    ``slot_us`` both reduce to the historical sizes."""
    tmpl = batch_template(cfgs)
    delay_pad = (max(_delay_steps(c) for c in cfgs)
                 + max(0, max(_proc_steps(c) for c in cfgs)
                       - _proc_steps(tmpl)))
    return delay_pad, max(default_history_slots(c) for c in cfgs)


def shard_scenario_axis(params: NetParams, wlp: WorkloadParams,
                        devices: Optional[Sequence] = None):
    """Place stacked [B]-leading scenario leaves so the batch axis is split
    across ``devices`` (default: all of ``jax.devices()``). The computation
    is embarrassingly parallel along [B], so a jit over the sharded inputs
    partitions the whole vmapped scan with zero cross-device traffic.
    Requires the device count to divide B (even split); no-op on a single
    device."""
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) <= 1:
        return params, wlp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    b = int(np.shape(params.one_way_delay_us)[0])
    if b % len(devices):
        raise ValueError(
            f"shard_scenario_axis: {len(devices)} devices do not evenly "
            f"split a batch of {b} scenarios — pad the batch to a device "
            f"multiple (runner launch plans do this automatically)")
    mesh = Mesh(np.array(devices), ("scenario",))

    def put(x):
        x = jnp.asarray(x)
        spec = PartitionSpec("scenario", *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, params), jax.tree.map(put, wlp)


def simulate_batch(cfgs: Sequence[NetConfig], workload, scheme,
                   horizon_us: Optional[float] = None, period_slots: int = 0,
                   trace_mode: str = "full", decimate: int = 1,
                   delay_pad: int = 0, history_slots: int = 0,
                   devices: Optional[Sequence] = None,
                   warm_steps: Optional[int] = None, channel=None,
                   profile: Optional[dict] = None):
    """Run a whole scenario grid as ONE vmapped computation.

    ``cfgs``: the per-scenario configs (distance / capacity / buffer grids);
    every structural field (dt, slot layout) must match — the per-scenario
    scalars are extracted into a stacked ``NetParams`` pytree and traced.
    ``workload``: one shared ``Workload``, a per-scenario sequence of
    ``Workload``s (padded to the batch-max flow count, see
    ``WorkloadParams``), or a prebuilt [B, F] ``WorkloadParams`` — the
    workload axis is vmapped jointly with the config axis.
    One compile per (scheme, grid-shape); every cell runs in a single
    device launch (sharded across devices whenever the device count
    evenly splits B). Returns (final_states, traces) with a leading [B]
    axis on every leaf — or ``(final_states, MetricAcc)`` under
    ``trace_mode="metrics"`` (O(B) device memory, no [B, T] arrays).
    ``delay_pad``/``history_slots`` set MINIMUM static ring sizes (so
    chunked launches of one big grid share a compiled program);
    ``warm_steps`` overrides the warm-up cutoff of the streaming
    reductions (default ``WARMUP_FRAC`` of the horizon); ``channel`` is a
    registered channel-model name or instance (None = ``"ideal"``) —
    impairment KNOBS are traced ``NetParams`` leaves, so a loss x jitter
    grid still compiles once per scheme. ``profile``: pass a dict to route
    the launch through the AOT profiling path
    (``repro.netsim.obs.profiled_traced_batch``) — it is filled in place
    with the compile/execute wall-clock split and XLA memory figures
    (docs/observability.md).
    """
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("simulate_batch: empty config batch")
    scheme = get_scheme(scheme)
    channel = get_channel_model(channel)
    _check_trace_mode(trace_mode, decimate)
    tmpl = batch_template(cfgs)
    steps = tmpl.horizon_steps(
        horizon_us if horizon_us is not None
        else max(c.horizon_us for c in cfgs))
    warm = int(steps * WARMUP_FRAC) if warm_steps is None else int(warm_steps)
    dp, hs = batch_padding(cfgs)
    delay_pad, history_slots = max(delay_pad, dp), max(history_slots, hs)
    params = stack_net_params(cfgs)
    wlp = as_workload_batch(workload, len(cfgs))
    if tmpl.is_multisite:
        from repro.netsim.topology import validate_site_endpoints
        validate_site_endpoints(tmpl, wlp)  # host-side: stalls fail early
    # fresh host-backed buffers: the jitted runner donates its batch inputs
    # (harmless on CPU where donation is skipped), so caller-held device
    # arrays must never be passed through as-is
    params = NetParams(*(jnp.asarray(np.asarray(v)) for v in params))
    wlp = WorkloadParams(*(jnp.asarray(np.asarray(v)) for v in wlp))
    devs = list(devices) if devices is not None else jax.devices()
    b = len(cfgs)
    pad = (-b) % len(devs) if len(devs) > 1 else 0
    if pad:
        # pad-and-shard: replicate the last scenario until the device
        # count divides the batch, run sharded, then strip the padded
        # rows from every output leaf — a ragged batch no longer falls
        # back silently to a single-device launch
        def rep(x):
            return jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)],
                                   axis=0)
        params = jax.tree.map(rep, params)
        wlp = jax.tree.map(rep, wlp)
    if len(devs) > 1:
        params, wlp = shard_scenario_axis(params, wlp, devs)
    if profile is not None:
        from repro.netsim.obs.profile import profiled_traced_batch
        profile.update(n_cells=b, pad=pad, n_devices=len(devs),
                       steps=steps, trace_mode=trace_mode)
        out = profiled_traced_batch(tmpl, params, wlp, scheme, steps,
                                    period_slots, delay_pad, history_slots,
                                    trace_mode, decimate, warm, channel,
                                    profile)
    else:
        out = _run_traced_batch(tmpl, params, wlp, scheme, steps,
                                period_slots, delay_pad, history_slots,
                                trace_mode, decimate, warm, channel)
    if pad:
        out = jax.tree.map(lambda x: x[:b], out)
    return out


def _run_traced_batch_impl(cfg, params, wlp, scheme, steps, period_slots,
                           delay_pad, history_slots, mode="full",
                           decimate=1, warm=0, channel=None):
    channel = get_channel_model(channel)
    f = wlp.is_inter.shape[-1]

    def one_scenario(p, w):
        state0 = init_state(cfg, f, params=p, delay_pad=delay_pad,
                            history_slots=history_slots, scheme=scheme,
                            channel=channel)
        step = make_step_fn(cfg, w, scheme, period_slots,
                            params=p, delay_pad=delay_pad, channel=channel)
        return _scan_with_mode(step, scheme, channel, state0, steps, mode,
                               decimate, warm)

    return jax.vmap(one_scenario)(params, wlp)


@lru_cache(maxsize=1)
def _jitted_traced_batch():
    """Build the jitted batch runner on FIRST use, not at import: the
    donation decision needs ``jax.default_backend()``, which initializes
    the backend — importing ``repro.netsim`` must never do that. The
    stacked batch inputs are donated so giant-grid chunk launches reuse
    their buffers in place (XLA ignores donation on CPU and would warn
    about it, hence none there)."""
    donate = () if jax.default_backend() == "cpu" else (1, 2)
    return partial(jax.jit,
                   static_argnames=("cfg", "scheme", "steps", "period_slots",
                                    "delay_pad", "history_slots", "mode",
                                    "decimate", "warm", "channel"),
                   donate_argnums=donate)(_run_traced_batch_impl)


def _run_traced_batch(*args, **kwargs):
    return _jitted_traced_batch()(*args, **kwargs)


_run_traced_batch._cache_size = lambda: _jitted_traced_batch()._cache_size()
