"""Fluid-flow discrete-time simulator of the dual AI-DC leaf-spine-OTN path.

One ``jax.lax.scan`` step = ``dt_us`` of simulated time. Per-flow byte rates
are integrated through the congestion-relevant queues of Fig. 3(a):

    sender NIC --> [Q_src] source OTN --(pipe: delay D, cap C_otn)-->
    [Q_dst] destination OTN --> [Q_leaf] destination leaf (shared with
    intra-DC flows, ECN marking here) --> receiver

Feedback paths:
  * ACKs:  receiver -> sender, delay D (conventional) / source-OTN pseudo-ACK
           (NTT baseline, ungated) / budget-gated pseudo-ACK (MatchRDMA).
  * CNPs:  receiver -> sender, delay D (baselines) / consumed at destination
           OTN + congestion summary on the control subchannel (MatchRDMA).
  * PFC:   destination-leaf -> destination OTN (1 step);
           destination OTN -> source OTN (delay D, the long-haul pause the
           paper's pause-time-ratio measures);
           source OTN -> sender NIC (1 step).

Schemes (pluggable — ``repro.netsim.schemes``):
  ``make_step_fn`` is a scheme-agnostic skeleton; everything a control
  scheme decides (ACK view, sender rate law, source-OTN release, CNP
  routing, extra-state updates) enters through the ``Scheme`` hooks. The
  paper's four schemes ship registered (``dcqcn``, ``pseudo_ack``,
  ``themis``, ``matchrdma``); third-party schemes register with
  ``@register_scheme("name")`` and are usable from every entrypoint.
  Scheme arguments accept a registered name or a ``Scheme`` instance.

Static vs traced scenario split (the batched scenario engine):
  ``NetConfig`` stays the hashable compile-time side — it fixes ``dt_us``,
  slot layout, DCQCN constants and every array SIZE. The per-scenario
  scalars a sweep varies enter as traced ``NetParams`` leaves, and the
  per-scenario workload enters as traced ``WorkloadParams`` leaves (flow
  arrays padded to the batch-max flow count with an ``active_mask``), so
  ``simulate_batch`` vmaps over (NetParams × WorkloadParams) jointly:
  heterogeneous distances AND heterogeneous flow sets share ONE compiled
  ``lax.scan`` and run the whole scenario grid in a single device launch.
  Delay lines are allocated at a static padded length (``delay_pad``) while
  the ring index wraps at the traced actual ``delay_steps``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import (
    NetConfig, NetParams, batch_template, stack_net_params,
)
from repro.core.cc_proxy import DcqcnState, init_dcqcn, step_dcqcn
from repro.core.matchrdma import default_history_slots
from repro.netsim.queues import drain_proportional, ecn_mark_prob, pfc_hysteresis
from repro.netsim.schemes import SCHEMES, get_scheme  # noqa: F401 (re-export)
from repro.netsim.schemes.base import Scheme, SchemeCtx, SchemeSignals
from repro.netsim.workload import WorkloadParams, as_workload_batch

MTU = 1500.0
INF = jnp.float32(1e30)


class SimState(NamedTuple):
    sent: jax.Array          # [F] cumulative bytes leaving the sender NIC
    acked: jax.Array         # [F] cumulative bytes ACKed at the sender
    delivered: jax.Array     # [F] cumulative bytes delivered to the receiver
    done_at_us: jax.Array    # [F] completion time (INF = not done)
    cc: DcqcnState           # [F] DCQCN machine (sender or proxy)
    cnp_timer: jax.Array     # [F] µs since last CNP emission (receiver side)
    marked_acc: jax.Array    # [F] marked-byte accumulator (per-packet model)
    proxy_timer: jax.Array   # [F] µs since last proxy cut (MatchRDMA)
    proxy_mod: jax.Array     # [F] multiplicative proxy modulation in [0.25, 1]
    q_src: jax.Array         # [F] source-OTN queue bytes
    q_dst: jax.Array         # [F] destination-OTN queue bytes
    q_leaf: jax.Array        # [F] destination-leaf queue bytes
    pipe: jax.Array          # [Dp, F] in-flight long-haul bytes
    inflight: jax.Array      # [F] running sum of pipe (incremental: O(F)/step)
    ack_line: jax.Array      # [Dp, F] ACK return path
    cnp_line: jax.Array      # [Dp, F] CNP return path
    pause_line: jax.Array    # [Dp] PFC signal dst-OTN -> src-OTN
    pause_dst: jax.Array     # scalar: dst OTN asserting long-haul pause
    extra: object            # scheme-private pytree (Scheme.init_extra_state)


def _delay_steps(cfg: NetConfig) -> int:
    """STATIC delay-step count — sizes the delay-line padding.

    Uses the same f32 arithmetic as the traced ``NetParams.delay_steps``
    so the static ring size can never undercut the traced wrap index
    (f64 here could round 3.4999... down where the f32 leaf rounds up —
    the rings would then be written through a clamped out-of-range index).
    """
    return max(int(np.round(np.float32(cfg.one_way_delay_us)
                            / np.float32(cfg.dt_us))), 1)


def _proc_steps(cfg: NetConfig) -> int:
    return int(cfg.control_proc_slots * cfg.slot_us / cfg.dt_us)


def init_state(cfg: NetConfig, num_flows: int, params: NetParams = None,
               delay_pad: int = 0, history_slots: int = 0,
               scheme: Scheme = None) -> SimState:
    """``delay_pad``/``history_slots`` are static ring sizes (0 = size for
    ``cfg`` itself); ``params`` carries the traced per-scenario scalars;
    ``scheme`` owns the ``extra`` slot (None = the default MatchRDMA
    block)."""
    f = num_flows
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    if params is None:
        params = NetParams.of(cfg)
    if scheme is None:
        scheme = Scheme()
    z = jnp.zeros((f,), jnp.float32)
    nic = params.nic_gbps * 1e9 / 8.0
    return SimState(
        sent=z, acked=z, delivered=z,
        done_at_us=jnp.full((f,), INF),
        cc=init_dcqcn(f, nic),
        cnp_timer=jnp.full((f,), 1e9, jnp.float32),
        marked_acc=z,
        proxy_timer=jnp.full((f,), 1e9, jnp.float32),
        proxy_mod=jnp.ones((f,), jnp.float32),
        q_src=z, q_dst=z, q_leaf=z,
        pipe=jnp.zeros((delay_pad, f), jnp.float32),
        inflight=z,
        ack_line=jnp.zeros((delay_pad, f), jnp.float32),
        cnp_line=jnp.zeros((delay_pad, f), jnp.float32),
        pause_line=jnp.zeros((delay_pad,), jnp.float32),
        pause_dst=jnp.float32(0.0),
        extra=scheme.init_extra_state(
            cfg, params, f, history_slots=history_slots,
            chan_delay_pad=delay_pad + _proc_steps(cfg)),
    )


def make_step_fn(cfg: NetConfig, wl: WorkloadParams, scheme,
                 period_slots: int = 0, params: NetParams = None,
                 delay_pad: int = 0):
    """Build the per-step transition — the scheme-agnostic skeleton.

    ``wl``: the traced per-flow workload leaves. All per-scenario scalars
    are read from ``params`` (traced), so the same compiled step serves
    every cell of a vmapped scenario batch; ``cfg`` only contributes static
    structure (dt, slot layout, DCQCN constants). ``scheme`` is a
    registered name or a ``Scheme`` instance; everything scheme-specific
    happens inside its hooks.
    """
    scheme = get_scheme(scheme)
    if params is None:
        params = NetParams.of(cfg)
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    dt_us = cfg.dt_us
    dt_s = dt_us * 1e-6
    # traced actual delay, clamped to the static ring allocation (mirrors
    # budget.init_channel) — an out-of-range wrap would silently alias
    # ring rows through JAX's index clamping instead of erroring
    d_steps = jnp.clip(params.delay_steps(dt_us), 1, delay_pad)
    nic = params.nic_gbps * 1e9 / 8.0
    c_otn = params.otn_capacity_gbps * 1e9 / 8.0
    c_leaf = params.dst_dc_gbps * 1e9 / 8.0
    xoff = params.pfc_xoff_kb * 1024.0
    xon = params.pfc_xon_kb * 1024.0
    # OTN nodes are provisioned with BDP-scaled buffers (long-haul headroom)
    bdp = c_otn * 2.0 * params.one_way_delay_us * 1e-6
    xoff_otn = jnp.maximum(xoff, params.otn_buffer_bdp_frac * bdp)
    xon_otn = xoff_otn / 2.0

    is_inter = jnp.asarray(wl.is_inter)
    is_intra = 1.0 - is_inter
    window = jnp.asarray(wl.window)
    total_bytes = jnp.asarray(wl.total_bytes)
    start_us = jnp.asarray(wl.start_us)
    period_us = jnp.asarray(wl.period_us)
    duty = jnp.asarray(wl.duty)
    active_mask = jnp.asarray(wl.active_mask)
    rtt_us = jnp.where(is_inter > 0, 2.0 * d_steps * dt_us + 4.0, 4.0)

    ctx = SchemeCtx(
        cfg=cfg, params=params, period_slots=period_slots,
        dt_us=dt_us, dt_s=dt_s, nic=nic, c_otn=c_otn, c_leaf=c_leaf,
        xoff=xoff, xon=xon, xoff_otn=xoff_otn, xon_otn=xon_otn,
        is_inter=is_inter, is_intra=is_intra, rtt_us=rtt_us,
        d_steps=d_steps,
    )
    rtt_scale = scheme.rtt_scale(ctx)

    def step(state: SimState, t: jax.Array):
        t_us = t.astype(jnp.float32) * dt_us
        ridx = jnp.mod(t, d_steps)

        # ------------------------------------------------ 1. flow phase
        started = (t_us >= start_us).astype(jnp.float32)
        in_period = jnp.where(
            period_us > 0,
            (jnp.mod(jnp.maximum(t_us - start_us, 0.0), jnp.maximum(period_us, 1.0))
             < duty * period_us).astype(jnp.float32),
            1.0)
        not_done = (state.delivered < total_bytes).astype(jnp.float32)
        active = started * in_period * not_done * active_mask

        # ------------------------------------------------ 2. delayed inputs
        ack_arr = state.ack_line[ridx]
        cnp_arr = state.cnp_line[ridx]
        pause_sig = state.pause_line[ridx]
        pipe_out = state.pipe[ridx]

        # ------------------------------------------------ 3. ACK accounting
        acked_inter = scheme.ack_view(ctx, state, ack_arr)
        acked = jnp.where(is_inter > 0, acked_inter,
                          state.delivered)             # intra: ~µs loop
        acked = jnp.minimum(acked, state.sent)

        # ------------------------------------------------ 4. sender rates
        win_avail = jnp.maximum(window - (state.sent - acked), 0.0)
        base_rate = jnp.minimum(win_avail / dt_s, nic)
        rate = scheme.sender_rate(ctx, state, base_rate)
        # src-OTN -> sender PFC (1 step, from last-step queue)
        src_nic_pause = (jnp.sum(state.q_src) > xoff_otn).astype(jnp.float32)
        rate = rate * jnp.where(is_inter > 0, 1.0 - src_nic_pause, 1.0)
        send = rate * active * dt_s                    # bytes this step
        sent = state.sent + send

        # ------------------------------------------------ 5. source OTN
        paused_src = pause_sig > 0.5                   # delayed dst PFC
        cap_src = jnp.where(paused_src, 0.0, c_otn * dt_s)
        arrivals_src = send * is_inter
        q_src, drained_src = scheme.src_otn_release(ctx, state, arrivals_src,
                                                    cap_src, active)
        pipe = state.pipe.at[ridx].set(drained_src)    # arrives at t + D
        inflight = state.inflight + drained_src - pipe_out

        # ------------------------------------------------ 6. destination OTN
        leaf_pfc = (jnp.sum(state.q_leaf) > xoff).astype(jnp.float32)
        cap_dst = c_leaf * dt_s * (1.0 - leaf_pfc)
        q_dst, drained_dst = drain_proportional(state.q_dst, pipe_out, cap_dst)
        egress_bytes = jnp.sum(drained_dst)
        q_dst_tot = jnp.sum(q_dst)
        pause_dst = pfc_hysteresis(state.pause_dst, q_dst_tot, xoff_otn, xon_otn)
        pause_line = state.pause_line.at[ridx].set(pause_dst)

        # ------------------------------------------------ 7. destination leaf
        arrivals_leaf = drained_dst + send * is_intra
        mark_p = ecn_mark_prob(jnp.sum(state.q_leaf), cfg, params=params)
        q_leaf, drained_leaf = drain_proportional(state.q_leaf, arrivals_leaf,
                                                  c_leaf * dt_s)
        delivered = state.delivered + drained_leaf
        marked_acc = state.marked_acc + drained_leaf * mark_p

        # ------------------------------------------------ 8. CNP generation
        cnp_timer = state.cnp_timer + dt_us
        want = marked_acc >= MTU
        emit = want & (cnp_timer >= cfg.cnp_interval_us)
        cnp_out = emit.astype(jnp.float32)
        cnp_timer = jnp.where(emit, 0.0, cnp_timer)
        marked_acc = jnp.where(emit, 0.0, marked_acc)

        # ------------------------------------------------ 9. scheme feedback
        # (CNP routing, pseudo-ACK ledger, proxy brake, slot/budget/channel)
        fb = scheme.feedback(ctx, state, SchemeSignals(
            t=t, active=active, sent=sent, cnp_out=cnp_out, cnp_arr=cnp_arr,
            egress_bytes=egress_bytes, q_dst_tot=q_dst_tot, q_leaf=q_leaf,
            leaf_pfc=leaf_pfc))

        # ------------------------------------------------ 10. return paths
        ack_line = state.ack_line.at[ridx].set(drained_leaf * is_inter)
        cnp_line = state.cnp_line.at[ridx].set(fb.cnp_wire)

        # ------------------------------------------------ 11. CC update
        cc = step_dcqcn(state.cc, fb.cnp_in, send, cfg, rtt_scale=rtt_scale)

        # ------------------------------------------------ 12. FCT
        newly_done = (delivered >= total_bytes) & (state.done_at_us >= INF)
        done_at = jnp.where(newly_done, t_us, state.done_at_us)

        new_state = SimState(
            sent=sent, acked=acked, delivered=delivered, done_at_us=done_at,
            cc=cc, cnp_timer=cnp_timer, marked_acc=marked_acc,
            proxy_timer=fb.proxy_timer, proxy_mod=fb.proxy_mod,
            q_src=q_src, q_dst=q_dst, q_leaf=q_leaf,
            pipe=pipe, inflight=inflight,
            ack_line=ack_line, cnp_line=cnp_line,
            pause_line=pause_line, pause_dst=pause_dst, extra=fb.extra,
        )
        # per-flow byte conservation residual: everything the sender emitted
        # is either delivered or sitting in exactly one queue / the pipe
        residual = sent - delivered - q_src - q_dst - q_leaf - inflight
        cons_err = jnp.max(jnp.abs(residual) / jnp.maximum(sent, 1.0))
        out = {
            "q_src": jnp.sum(q_src),
            "q_dst": q_dst_tot,
            "q_leaf": jnp.sum(q_leaf),
            "pause_dst": pause_dst,
            "src_paused": pause_sig,
            "thr_inter": jnp.sum(drained_leaf * is_inter) / dt_s,
            "thr_intra": jnp.sum(drained_leaf * is_intra) / dt_s,
            "cons_err": cons_err,
        }
        out.update(scheme.extra_traces(ctx, state))
        return new_state, out

    return step


def simulate(cfg: NetConfig, workload, scheme,
             horizon_us: Optional[float] = None, period_slots: int = 0,
             delay_pad: int = 0, history_slots: int = 0):
    """Run one simulation; returns (final_state, traces dict of [T] arrays).

    ``workload``: a ``Workload`` (or prebuilt ``WorkloadParams``);
    ``scheme``: a registered name or ``Scheme`` instance.
    ``delay_pad``/``history_slots`` override the static ring sizes (0 = size
    for ``cfg``) — pass the batch padding to reproduce a ``simulate_batch``
    cell bit-for-bit.
    """
    if isinstance(scheme, str):
        import warnings
        warnings.warn(
            "passing a scheme name string to simulate() is deprecated; "
            "resolve it with repro.netsim.schemes.get_scheme(name) (names "
            "remain first-class in the batched sweep APIs)",
            DeprecationWarning, stacklevel=2)
    scheme = get_scheme(scheme)
    horizon = horizon_us if horizon_us is not None else cfg.horizon_us
    steps = int(round(horizon / cfg.dt_us))
    wlp = workload if isinstance(workload, WorkloadParams) \
        else workload.params()
    wlp = WorkloadParams(*(jnp.asarray(v) for v in wlp))
    return _run_traced(cfg, wlp, scheme, steps, period_slots,
                       delay_pad, history_slots)


@partial(jax.jit, static_argnames=("scheme", "steps", "period_slots", "cfg",
                                   "delay_pad", "history_slots"))
def _run_traced(cfg, wlp, scheme, steps, period_slots,
                delay_pad=0, history_slots=0):
    f = wlp.is_inter.shape[0]
    state0 = init_state(cfg, f, delay_pad=delay_pad,
                        history_slots=history_slots, scheme=scheme)
    step = make_step_fn(cfg, wlp, scheme, period_slots,
                        delay_pad=delay_pad)
    final, traces = jax.lax.scan(step, state0,
                                 jnp.arange(steps, dtype=jnp.int32))
    return final, traces


# ---------------------------------------------------------------------------
# Batched scenario engine
# ---------------------------------------------------------------------------


def batch_padding(cfgs: Sequence[NetConfig]):
    """(delay_pad, history_slots) covering every scenario in the grid —
    the static ring sizes shared by all cells of a batch."""
    far = max(cfgs, key=lambda c: c.one_way_delay_us)
    delay_pad = max(_delay_steps(c) for c in cfgs)
    return delay_pad, default_history_slots(far)


def simulate_batch(cfgs: Sequence[NetConfig], workload, scheme,
                   horizon_us: Optional[float] = None, period_slots: int = 0):
    """Run a whole scenario grid as ONE vmapped computation.

    ``cfgs``: the per-scenario configs (distance / capacity / buffer grids);
    every structural field (dt, slot layout) must match — the per-scenario
    scalars are extracted into a stacked ``NetParams`` pytree and traced.
    ``workload``: one shared ``Workload``, a per-scenario sequence of
    ``Workload``s (padded to the batch-max flow count, see
    ``WorkloadParams``), or a prebuilt [B, F] ``WorkloadParams`` — the
    workload axis is vmapped jointly with the config axis.
    One compile per (scheme, grid-shape); every cell runs in a single
    device launch. Returns (final_states, traces) with a leading [B] axis
    on every leaf.
    """
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("simulate_batch: empty config batch")
    scheme = get_scheme(scheme)
    tmpl = batch_template(cfgs)
    horizon = horizon_us if horizon_us is not None else max(
        c.horizon_us for c in cfgs)
    steps = int(round(horizon / tmpl.dt_us))
    delay_pad, history_slots = batch_padding(cfgs)
    params = stack_net_params(cfgs)
    wlp = as_workload_batch(workload, len(cfgs))
    wlp = WorkloadParams(*(jnp.asarray(v) for v in wlp))
    return _run_traced_batch(tmpl, params, wlp, scheme, steps,
                             period_slots, delay_pad, history_slots)


@partial(jax.jit, static_argnames=("cfg", "scheme", "steps", "period_slots",
                                   "delay_pad", "history_slots"))
def _run_traced_batch(cfg, params, wlp, scheme, steps, period_slots,
                      delay_pad, history_slots):
    f = wlp.is_inter.shape[-1]

    def one_scenario(p, w):
        state0 = init_state(cfg, f, params=p, delay_pad=delay_pad,
                            history_slots=history_slots, scheme=scheme)
        step = make_step_fn(cfg, w, scheme, period_slots,
                            params=p, delay_pad=delay_pad)
        return jax.lax.scan(step, state0, jnp.arange(steps, dtype=jnp.int32))

    return jax.vmap(one_scenario)(params, wlp)
