"""Fluid-flow discrete-time simulator of the dual AI-DC leaf-spine-OTN path.

One ``jax.lax.scan`` step = ``dt_us`` of simulated time. Per-flow byte rates
are integrated through the congestion-relevant queues of Fig. 3(a):

    sender NIC --> [Q_src] source OTN --(pipe: delay D, cap C_otn)-->
    [Q_dst] destination OTN --> [Q_leaf] destination leaf (shared with
    intra-DC flows, ECN marking here) --> receiver

Feedback paths:
  * ACKs:  receiver -> sender, delay D (conventional) / source-OTN pseudo-ACK
           (NTT baseline, ungated) / budget-gated pseudo-ACK (MatchRDMA).
  * CNPs:  receiver -> sender, delay D (baselines) / consumed at destination
           OTN + congestion summary on the control subchannel (MatchRDMA).
  * PFC:   destination-leaf -> destination OTN (1 step);
           destination OTN -> source OTN (delay D, the long-haul pause the
           paper's pause-time-ratio measures);
           source OTN -> sender NIC (1 step).

Schemes (pluggable — ``repro.netsim.schemes``):
  ``make_step_fn`` is a scheme-agnostic skeleton; everything a control
  scheme decides (ACK view, sender rate law, source-OTN release, CNP
  routing, extra-state updates) enters through the ``Scheme`` hooks. Six
  schemes ship registered — the paper's four (``dcqcn``, ``pseudo_ack``,
  ``themis``, ``matchrdma``) plus the related-work pack (``geopipe``,
  ``sdr_rdma``); third-party schemes register with
  ``@register_scheme("name")`` and are usable from every entrypoint.
  Scheme arguments accept a registered name or a ``Scheme`` instance;
  the hook contract is documented in ``docs/scheme-api.md``.

Static vs traced scenario split (the batched scenario engine):
  ``NetConfig`` stays the hashable compile-time side — it fixes ``dt_us``,
  slot layout, DCQCN constants and every array SIZE. The per-scenario
  scalars a sweep varies enter as traced ``NetParams`` leaves, and the
  per-scenario workload enters as traced ``WorkloadParams`` leaves (flow
  arrays padded to the batch-max flow count with an ``active_mask``), so
  ``simulate_batch`` vmaps over (NetParams × WorkloadParams) jointly:
  heterogeneous distances AND heterogeneous flow sets share ONE compiled
  ``lax.scan`` and run the whole scenario grid in a single device launch.
  Delay lines are allocated at a static padded length (``delay_pad``) while
  the ring index wraps at the traced actual ``delay_steps``.

Execution modes (``trace_mode``):
  ``full``      every per-step trace key materializes as a [T] (or [B, T])
                array — figures, goldens, debugging.
  ``decimate``  every ``decimate``-th step is kept: [T/k] traces, O(B·T/k)
                memory — long-horizon figures.
  ``metrics``   NO per-step arrays exist anywhere: the ``lax.scan`` carry
                accumulates the Fig. 3 reductions online (Kahan-compensated
                warm-step sums, running maxes, a fixed-bin log-histogram of
                ``q_dst`` for p99) in a ``MetricAcc``, so device memory is
                O(B) per trace key instead of O(B·T) and nothing but final
                states + accumulators ever transfers to host. Schemes
                stream their own reductions through the
                ``Scheme.init_metric_acc``/``accumulate_metrics``/
                ``finalize_metrics`` hooks (mirroring ``extra_traces``).

Device sharding: ``shard_scenario_axis`` splits the stacked [B] scenario
leaves across ``jax.devices()`` (jax.sharding over the vmapped axis), and
``simulate_batch`` applies it automatically whenever the device count
evenly splits the batch — one SPMD launch sweeps the grid on every
accelerator. The runner's launch plans pad chunks to a device multiple so
the split always holds.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import (
    NetConfig, NetParams, batch_template, stack_net_params,
)
from repro.core.cc_proxy import DcqcnState, init_dcqcn, step_dcqcn
from repro.core.matchrdma import default_history_slots
from repro.netsim.queues import drain_proportional, ecn_mark_prob, pfc_hysteresis
from repro.netsim.schemes import SCHEMES, get_scheme  # noqa: F401 (re-export)
from repro.netsim.schemes.base import Scheme, SchemeCtx, SchemeSignals
from repro.netsim.workload import WorkloadParams, as_workload_batch

MTU = 1500.0
# np (not jnp): a module-level jax array would initialize the backend at
# import time; as an f32 numpy scalar it traces identically
INF = np.float32(1e30)

WARMUP_FRAC = 0.1   # fraction of the horizon discarded as startup transient

TRACE_MODES = ("full", "decimate", "metrics")

# engine-owned streaming reductions over the per-step trace dict: warm-step
# sums (-> means) and all-step running maxes
STREAM_SUM_KEYS = ("q_src", "q_dst", "q_leaf", "pause_dst",
                   "thr_inter", "thr_intra")
STREAM_MAX_KEYS = ("q_src", "q_dst", "q_leaf", "cons_err")

# fixed-bin log histogram of q_dst for the streaming p99: bin 0 holds
# everything below HIST_MIN_BYTES, bins 1..HIST_BINS-1 are log-spaced over
# [HIST_MIN_BYTES, HIST_MAX_BYTES). Inverting it bounds the quantile
# estimate's relative error by the bin ratio (~5.6% at 512 bins / 12
# decades), independent of the horizon length.
HIST_BINS = 512
HIST_MIN_BYTES = 1.0
HIST_MAX_BYTES = 1e12


class MetricAcc(NamedTuple):
    """O(1)-per-scenario scan carry of the Fig. 3 reductions
    (``trace_mode="metrics"``). Under the batched engine every leaf gains a
    leading [B] axis; nothing here scales with the step count."""
    sum_s: dict       # STREAM_SUM_KEYS -> Kahan running sum over warm steps
    sum_c: dict       # STREAM_SUM_KEYS -> Kahan compensation term
    maxes: dict       # STREAM_MAX_KEYS -> running max over ALL steps
    hist: jax.Array   # [HIST_BINS] i32 warm-step log-histogram of q_dst
                      # (integer counts: f32 would silently saturate past
                      # 2^24 increments per bin on long horizons)
    scheme: object    # scheme-private accumulator (Scheme.init_metric_acc)


def _hist_bin_index(q: jax.Array) -> jax.Array:
    span = float(np.log(HIST_MAX_BYTES) - np.log(HIST_MIN_BYTES))
    frac = (jnp.log(jnp.maximum(q, HIST_MIN_BYTES))
            - float(np.log(HIST_MIN_BYTES))) / span
    idx = 1 + jnp.floor(frac * (HIST_BINS - 1)).astype(jnp.int32)
    return jnp.where(q < HIST_MIN_BYTES, 0, jnp.clip(idx, 1, HIST_BINS - 1))


def hist_bin_centers() -> np.ndarray:
    """Representative value per histogram bin: 0 for the zero bin,
    geometric bin centers for the log bins (host-side numpy)."""
    edges = np.exp(np.linspace(np.log(HIST_MIN_BYTES),
                               np.log(HIST_MAX_BYTES), HIST_BINS))
    return np.concatenate([[0.0], np.sqrt(edges[:-1] * edges[1:])])


def hist_quantile(hist, q: float) -> np.ndarray:
    """Invert a streamed ``MetricAcc.hist`` (leading axes preserved) into
    the q-quantile estimate in bytes."""
    hist = np.asarray(hist, np.float64)
    rank = q * hist.sum(axis=-1, keepdims=True)
    idx = (np.cumsum(hist, axis=-1) < rank).sum(axis=-1)
    return hist_bin_centers()[np.clip(idx, 0, HIST_BINS - 1)]


def _init_metric_acc(scheme, ctx, state0) -> MetricAcc:
    z = jnp.float32(0.0)
    return MetricAcc(
        sum_s={k: z for k in STREAM_SUM_KEYS},
        sum_c={k: z for k in STREAM_SUM_KEYS},
        maxes={k: z for k in STREAM_MAX_KEYS},
        hist=jnp.zeros((HIST_BINS,), jnp.int32),
        scheme=scheme.init_metric_acc(ctx, state0),
    )


def _accumulate_engine(acc: MetricAcc, out: dict, inc: jax.Array) -> MetricAcc:
    sum_s, sum_c = {}, {}
    for k in STREAM_SUM_KEYS:
        # Kahan-compensated so the streaming mean matches the numpy trace
        # mean to ~ulp — "metrics" mode is a drop-in for figure numbers
        y = out[k] * inc - acc.sum_c[k]
        t = acc.sum_s[k] + y
        sum_c[k] = (t - acc.sum_s[k]) - y
        sum_s[k] = t
    maxes = {k: jnp.maximum(acc.maxes[k], out[k]) for k in STREAM_MAX_KEYS}
    hist = acc.hist.at[_hist_bin_index(out["q_dst"])].add(
        inc.astype(jnp.int32))
    return acc._replace(sum_s=sum_s, sum_c=sum_c, maxes=maxes, hist=hist)


class SimState(NamedTuple):
    sent: jax.Array          # [F] cumulative bytes leaving the sender NIC
    acked: jax.Array         # [F] cumulative bytes ACKed at the sender
    delivered: jax.Array     # [F] cumulative bytes delivered to the receiver
    done_at_us: jax.Array    # [F] completion time (INF = not done)
    cc: DcqcnState           # [F] DCQCN machine (sender or proxy)
    cnp_timer: jax.Array     # [F] µs since last CNP emission (receiver side)
    marked_acc: jax.Array    # [F] marked-byte accumulator (per-packet model)
    proxy_timer: jax.Array   # [F] µs since last proxy cut (MatchRDMA)
    proxy_mod: jax.Array     # [F] multiplicative proxy modulation in [0.25, 1]
    q_src: jax.Array         # [F] source-OTN queue bytes
    q_dst: jax.Array         # [F] destination-OTN queue bytes
    q_leaf: jax.Array        # [F] destination-leaf queue bytes
    pipe: jax.Array          # [Dp, F] in-flight long-haul bytes
    inflight: jax.Array      # [F] running sum of pipe (incremental: O(F)/step)
    ack_line: jax.Array      # [Dp, F] ACK return path
    cnp_line: jax.Array      # [Dp, F] CNP return path
    pause_line: jax.Array    # [Dp] PFC signal dst-OTN -> src-OTN
    pause_dst: jax.Array     # scalar: dst OTN asserting long-haul pause
    extra: object            # scheme-private pytree (Scheme.init_extra_state)


def _delay_steps(cfg: NetConfig) -> int:
    """STATIC delay-step count — sizes the delay-line padding (the shared
    f32-aware definition lives on ``NetConfig.static_delay_steps``)."""
    return cfg.static_delay_steps


def _proc_steps(cfg: NetConfig) -> int:
    return cfg.control_proc_steps


def init_state(cfg: NetConfig, num_flows: int, params: NetParams = None,
               delay_pad: int = 0, history_slots: int = 0,
               scheme: Scheme = None) -> SimState:
    """``delay_pad``/``history_slots`` are static ring sizes (0 = size for
    ``cfg`` itself); ``params`` carries the traced per-scenario scalars;
    ``scheme`` owns the ``extra`` slot (None = the default MatchRDMA
    block)."""
    f = num_flows
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    if params is None:
        params = NetParams.of(cfg)
    if scheme is None:
        scheme = Scheme()
    z = jnp.zeros((f,), jnp.float32)
    nic = params.nic_gbps * 1e9 / 8.0
    return SimState(
        sent=z, acked=z, delivered=z,
        done_at_us=jnp.full((f,), INF),
        cc=init_dcqcn(f, nic),
        cnp_timer=jnp.full((f,), 1e9, jnp.float32),
        marked_acc=z,
        proxy_timer=jnp.full((f,), 1e9, jnp.float32),
        proxy_mod=jnp.ones((f,), jnp.float32),
        q_src=z, q_dst=z, q_leaf=z,
        pipe=jnp.zeros((delay_pad, f), jnp.float32),
        inflight=z,
        ack_line=jnp.zeros((delay_pad, f), jnp.float32),
        cnp_line=jnp.zeros((delay_pad, f), jnp.float32),
        pause_line=jnp.zeros((delay_pad,), jnp.float32),
        pause_dst=jnp.float32(0.0),
        extra=scheme.init_extra_state(
            cfg, params, f, history_slots=history_slots,
            chan_delay_pad=delay_pad + _proc_steps(cfg)),
    )


def make_step_fn(cfg: NetConfig, wl: WorkloadParams, scheme,
                 period_slots: int = 0, params: NetParams = None,
                 delay_pad: int = 0):
    """Build the per-step transition — the scheme-agnostic skeleton.

    ``wl``: the traced per-flow workload leaves. All per-scenario scalars
    are read from ``params`` (traced), so the same compiled step serves
    every cell of a vmapped scenario batch; ``cfg`` only contributes static
    structure (dt, slot layout, DCQCN constants). ``scheme`` is a
    registered name or a ``Scheme`` instance; everything scheme-specific
    happens inside its hooks.
    """
    scheme = get_scheme(scheme)
    if params is None:
        params = NetParams.of(cfg)
    if delay_pad <= 0:
        delay_pad = _delay_steps(cfg)
    dt_us = cfg.dt_us
    dt_s = dt_us * 1e-6
    # traced actual delay, clamped to the static ring allocation (mirrors
    # budget.init_channel) — an out-of-range wrap would silently alias
    # ring rows through JAX's index clamping instead of erroring
    d_steps = jnp.clip(params.delay_steps(dt_us), 1, delay_pad)
    nic = params.nic_gbps * 1e9 / 8.0
    c_otn = params.otn_capacity_gbps * 1e9 / 8.0
    c_leaf = params.dst_dc_gbps * 1e9 / 8.0
    xoff = params.pfc_xoff_kb * 1024.0
    xon = params.pfc_xon_kb * 1024.0
    # OTN nodes are provisioned with BDP-scaled buffers (long-haul headroom)
    bdp = c_otn * 2.0 * params.one_way_delay_us * 1e-6
    xoff_otn = jnp.maximum(xoff, params.otn_buffer_bdp_frac * bdp)
    xon_otn = xoff_otn / 2.0

    is_inter = jnp.asarray(wl.is_inter)
    is_intra = 1.0 - is_inter
    window = jnp.asarray(wl.window)
    total_bytes = jnp.asarray(wl.total_bytes)
    start_us = jnp.asarray(wl.start_us)
    period_us = jnp.asarray(wl.period_us)
    duty = jnp.asarray(wl.duty)
    active_mask = jnp.asarray(wl.active_mask)
    rtt_us = jnp.where(is_inter > 0, 2.0 * d_steps * dt_us + 4.0, 4.0)

    ctx = SchemeCtx(
        cfg=cfg, params=params, period_slots=period_slots,
        dt_us=dt_us, dt_s=dt_s, nic=nic, c_otn=c_otn, c_leaf=c_leaf,
        xoff=xoff, xon=xon, xoff_otn=xoff_otn, xon_otn=xon_otn,
        is_inter=is_inter, is_intra=is_intra, rtt_us=rtt_us,
        d_steps=d_steps,
    )
    rtt_scale = scheme.rtt_scale(ctx)

    def step(state: SimState, t: jax.Array):
        t_us = t.astype(jnp.float32) * dt_us
        ridx = jnp.mod(t, d_steps)

        # ------------------------------------------------ 1. flow phase
        started = (t_us >= start_us).astype(jnp.float32)
        in_period = jnp.where(
            period_us > 0,
            (jnp.mod(jnp.maximum(t_us - start_us, 0.0), jnp.maximum(period_us, 1.0))
             < duty * period_us).astype(jnp.float32),
            1.0)
        not_done = (state.delivered < total_bytes).astype(jnp.float32)
        active = started * in_period * not_done * active_mask

        # ------------------------------------------------ 2. delayed inputs
        ack_arr = state.ack_line[ridx]
        cnp_arr = state.cnp_line[ridx]
        pause_sig = state.pause_line[ridx]
        pipe_out = state.pipe[ridx]

        # ------------------------------------------------ 3. ACK accounting
        acked_inter = scheme.ack_view(ctx, state, ack_arr)
        acked = jnp.where(is_inter > 0, acked_inter,
                          state.delivered)             # intra: ~µs loop
        acked = jnp.minimum(acked, state.sent)

        # ------------------------------------------------ 4. sender rates
        win_avail = jnp.maximum(window - (state.sent - acked), 0.0)
        base_rate = jnp.minimum(win_avail / dt_s, nic)
        rate = scheme.sender_rate(ctx, state, base_rate)
        # src-OTN -> sender PFC (1 step, from last-step queue)
        src_nic_pause = (jnp.sum(state.q_src) > xoff_otn).astype(jnp.float32)
        rate = rate * jnp.where(is_inter > 0, 1.0 - src_nic_pause, 1.0)
        send = rate * active * dt_s                    # bytes this step
        sent = state.sent + send

        # ------------------------------------------------ 5. source OTN
        paused_src = pause_sig > 0.5                   # delayed dst PFC
        cap_src = jnp.where(paused_src, 0.0, c_otn * dt_s)
        arrivals_src = send * is_inter
        q_src, drained_src = scheme.src_otn_release(ctx, state, arrivals_src,
                                                    cap_src, active)
        pipe = state.pipe.at[ridx].set(drained_src)    # arrives at t + D
        inflight = state.inflight + drained_src - pipe_out

        # ------------------------------------------------ 6. destination OTN
        leaf_pfc = (jnp.sum(state.q_leaf) > xoff).astype(jnp.float32)
        cap_dst = c_leaf * dt_s * (1.0 - leaf_pfc)
        q_dst, drained_dst = drain_proportional(state.q_dst, pipe_out, cap_dst)
        egress_bytes = jnp.sum(drained_dst)
        q_dst_tot = jnp.sum(q_dst)
        pause_dst = pfc_hysteresis(state.pause_dst, q_dst_tot, xoff_otn, xon_otn)
        pause_line = state.pause_line.at[ridx].set(pause_dst)

        # ------------------------------------------------ 7. destination leaf
        arrivals_leaf = drained_dst + send * is_intra
        mark_p = ecn_mark_prob(jnp.sum(state.q_leaf), cfg, params=params)
        q_leaf, drained_leaf = drain_proportional(state.q_leaf, arrivals_leaf,
                                                  c_leaf * dt_s)
        delivered = state.delivered + drained_leaf
        marked_acc = state.marked_acc + drained_leaf * mark_p

        # ------------------------------------------------ 8. CNP generation
        cnp_timer = state.cnp_timer + dt_us
        want = marked_acc >= MTU
        emit = want & (cnp_timer >= cfg.cnp_interval_us)
        cnp_out = emit.astype(jnp.float32)
        cnp_timer = jnp.where(emit, 0.0, cnp_timer)
        marked_acc = jnp.where(emit, 0.0, marked_acc)

        # ------------------------------------------------ 9. scheme feedback
        # (CNP routing, pseudo-ACK ledger, proxy brake, slot/budget/channel)
        fb = scheme.feedback(ctx, state, SchemeSignals(
            t=t, active=active, sent=sent, cnp_out=cnp_out, cnp_arr=cnp_arr,
            egress_bytes=egress_bytes, q_dst_tot=q_dst_tot, q_leaf=q_leaf,
            leaf_pfc=leaf_pfc))

        # ------------------------------------------------ 10. return paths
        ack_line = state.ack_line.at[ridx].set(drained_leaf * is_inter)
        cnp_line = state.cnp_line.at[ridx].set(fb.cnp_wire)

        # ------------------------------------------------ 11. CC update
        cc = step_dcqcn(state.cc, fb.cnp_in, send, cfg, rtt_scale=rtt_scale)

        # ------------------------------------------------ 12. FCT
        newly_done = (delivered >= total_bytes) & (state.done_at_us >= INF)
        done_at = jnp.where(newly_done, t_us, state.done_at_us)

        new_state = SimState(
            sent=sent, acked=acked, delivered=delivered, done_at_us=done_at,
            cc=cc, cnp_timer=cnp_timer, marked_acc=marked_acc,
            proxy_timer=fb.proxy_timer, proxy_mod=fb.proxy_mod,
            q_src=q_src, q_dst=q_dst, q_leaf=q_leaf,
            pipe=pipe, inflight=inflight,
            ack_line=ack_line, cnp_line=cnp_line,
            pause_line=pause_line, pause_dst=pause_dst, extra=fb.extra,
        )
        # per-flow byte conservation residual: everything the sender emitted
        # is either delivered or sitting in exactly one queue / the pipe
        residual = sent - delivered - q_src - q_dst - q_leaf - inflight
        cons_err = jnp.max(jnp.abs(residual) / jnp.maximum(sent, 1.0))
        out = {
            "q_src": jnp.sum(q_src),
            "q_dst": q_dst_tot,
            "q_leaf": jnp.sum(q_leaf),
            "pause_dst": pause_dst,
            "src_paused": pause_sig,
            "thr_inter": jnp.sum(drained_leaf * is_inter) / dt_s,
            "thr_intra": jnp.sum(drained_leaf * is_intra) / dt_s,
            "cons_err": cons_err,
        }
        out.update(scheme.extra_traces(ctx, state))
        return new_state, out

    step.ctx = ctx      # shared per-run quantities for the metric machinery
    return step


def _scan_with_mode(step, scheme, state0, steps: int, mode: str,
                    decimate: int, warm: int):
    """Drive the per-step transition under one of the execution modes.

    Returns ``(final_state, aux)`` where ``aux`` is the [T]-stacked trace
    dict (``full``), the [T//decimate]-stacked trace dict of every
    ``decimate``-th step (``decimate``), or a ``MetricAcc`` (``metrics`` —
    no per-step array is ever allocated).
    """
    ts = jnp.arange(steps, dtype=jnp.int32)
    if mode == "metrics":
        acc0 = _init_metric_acc(scheme, step.ctx, state0)

        def mstep(carry, t):
            state, acc = carry
            state, out = step(state, t)
            inc = (t >= warm).astype(jnp.float32)
            acc = _accumulate_engine(acc, out, inc)
            acc = acc._replace(scheme=scheme.accumulate_metrics(
                step.ctx, acc.scheme, state, out, inc))
            return (state, acc), None

        (final, acc), _ = jax.lax.scan(mstep, (state0, acc0), ts)
        return final, acc
    if mode == "decimate" and decimate > 1:
        k = decimate
        nblocks = steps // k

        def block(state, b):
            # the inner [k]-stacked traces are transient per outer step:
            # live memory is O(T/k + k), never O(T)
            state, outs = jax.lax.scan(step, state,
                                       b * k + jnp.arange(k, dtype=jnp.int32))
            return state, jax.tree.map(lambda x: x[-1], outs)

        final, traces = jax.lax.scan(block, state0,
                                     jnp.arange(nblocks, dtype=jnp.int32))
        rem = steps - nblocks * k
        if rem:
            final, _ = jax.lax.scan(
                step, final, nblocks * k + jnp.arange(rem, dtype=jnp.int32))
        return final, traces
    return jax.lax.scan(step, state0, ts)


def _check_trace_mode(trace_mode: str, decimate: int) -> None:
    if trace_mode not in TRACE_MODES:
        raise ValueError(f"unknown trace_mode {trace_mode!r}; "
                         f"expected one of {TRACE_MODES}")
    if decimate < 1:
        raise ValueError(f"decimate must be >= 1, got {decimate}")


def simulate(cfg: NetConfig, workload, scheme,
             horizon_us: Optional[float] = None, period_slots: int = 0,
             delay_pad: int = 0, history_slots: int = 0,
             trace_mode: str = "full", decimate: int = 1):
    """Run one simulation; returns (final_state, traces dict of [T] arrays)
    — or ``(final_state, MetricAcc)`` under ``trace_mode="metrics"``.

    ``workload``: a ``Workload`` (or prebuilt ``WorkloadParams``);
    ``scheme``: a registered name or ``Scheme`` instance.
    ``delay_pad``/``history_slots`` override the static ring sizes (0 = size
    for ``cfg``) — pass the batch padding to reproduce a ``simulate_batch``
    cell bit-for-bit. ``trace_mode``/``decimate``: see the module docstring.
    """
    if isinstance(scheme, str):
        import warnings
        warnings.warn(
            "passing a scheme name string to simulate() is deprecated; "
            "resolve it with repro.netsim.schemes.get_scheme(name) (names "
            "remain first-class in the batched sweep APIs)",
            DeprecationWarning, stacklevel=2)
    scheme = get_scheme(scheme)
    _check_trace_mode(trace_mode, decimate)
    steps = cfg.horizon_steps(horizon_us)
    wlp = workload if isinstance(workload, WorkloadParams) \
        else workload.params()
    wlp = WorkloadParams(*(jnp.asarray(v) for v in wlp))
    return _run_traced(cfg, wlp, scheme, steps, period_slots,
                       delay_pad, history_slots, trace_mode, decimate,
                       int(steps * WARMUP_FRAC))


@partial(jax.jit, static_argnames=("scheme", "steps", "period_slots", "cfg",
                                   "delay_pad", "history_slots", "mode",
                                   "decimate", "warm"))
def _run_traced(cfg, wlp, scheme, steps, period_slots,
                delay_pad=0, history_slots=0, mode="full", decimate=1,
                warm=0):
    f = wlp.is_inter.shape[0]
    state0 = init_state(cfg, f, delay_pad=delay_pad,
                        history_slots=history_slots, scheme=scheme)
    step = make_step_fn(cfg, wlp, scheme, period_slots,
                        delay_pad=delay_pad)
    return _scan_with_mode(step, scheme, state0, steps, mode, decimate, warm)


# ---------------------------------------------------------------------------
# Batched scenario engine
# ---------------------------------------------------------------------------


def batch_padding(cfgs: Sequence[NetConfig]):
    """(delay_pad, history_slots) covering every scenario in the grid —
    the static ring sizes shared by all cells of a batch."""
    far = max(cfgs, key=lambda c: c.one_way_delay_us)
    delay_pad = max(_delay_steps(c) for c in cfgs)
    return delay_pad, default_history_slots(far)


def shard_scenario_axis(params: NetParams, wlp: WorkloadParams,
                        devices: Optional[Sequence] = None):
    """Place stacked [B]-leading scenario leaves so the batch axis is split
    across ``devices`` (default: all of ``jax.devices()``). The computation
    is embarrassingly parallel along [B], so a jit over the sharded inputs
    partitions the whole vmapped scan with zero cross-device traffic.
    Requires the device count to divide B (even split); no-op on a single
    device."""
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) <= 1:
        return params, wlp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    b = int(np.shape(params.one_way_delay_us)[0])
    if b % len(devices):
        raise ValueError(
            f"shard_scenario_axis: {len(devices)} devices do not evenly "
            f"split a batch of {b} scenarios — pad the batch to a device "
            f"multiple (runner launch plans do this automatically)")
    mesh = Mesh(np.array(devices), ("scenario",))

    def put(x):
        x = jnp.asarray(x)
        spec = PartitionSpec("scenario", *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, params), jax.tree.map(put, wlp)


def simulate_batch(cfgs: Sequence[NetConfig], workload, scheme,
                   horizon_us: Optional[float] = None, period_slots: int = 0,
                   trace_mode: str = "full", decimate: int = 1,
                   delay_pad: int = 0, history_slots: int = 0,
                   devices: Optional[Sequence] = None,
                   warm_steps: Optional[int] = None):
    """Run a whole scenario grid as ONE vmapped computation.

    ``cfgs``: the per-scenario configs (distance / capacity / buffer grids);
    every structural field (dt, slot layout) must match — the per-scenario
    scalars are extracted into a stacked ``NetParams`` pytree and traced.
    ``workload``: one shared ``Workload``, a per-scenario sequence of
    ``Workload``s (padded to the batch-max flow count, see
    ``WorkloadParams``), or a prebuilt [B, F] ``WorkloadParams`` — the
    workload axis is vmapped jointly with the config axis.
    One compile per (scheme, grid-shape); every cell runs in a single
    device launch (sharded across devices whenever the device count
    evenly splits B). Returns (final_states, traces) with a leading [B]
    axis on every leaf — or ``(final_states, MetricAcc)`` under
    ``trace_mode="metrics"`` (O(B) device memory, no [B, T] arrays).
    ``delay_pad``/``history_slots`` set MINIMUM static ring sizes (so
    chunked launches of one big grid share a compiled program);
    ``warm_steps`` overrides the warm-up cutoff of the streaming
    reductions (default ``WARMUP_FRAC`` of the horizon).
    """
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("simulate_batch: empty config batch")
    scheme = get_scheme(scheme)
    _check_trace_mode(trace_mode, decimate)
    tmpl = batch_template(cfgs)
    steps = tmpl.horizon_steps(
        horizon_us if horizon_us is not None
        else max(c.horizon_us for c in cfgs))
    warm = int(steps * WARMUP_FRAC) if warm_steps is None else int(warm_steps)
    dp, hs = batch_padding(cfgs)
    delay_pad, history_slots = max(delay_pad, dp), max(history_slots, hs)
    params = stack_net_params(cfgs)
    wlp = as_workload_batch(workload, len(cfgs))
    # fresh host-backed buffers: the jitted runner donates its batch inputs
    # (harmless on CPU where donation is skipped), so caller-held device
    # arrays must never be passed through as-is
    params = NetParams(*(jnp.asarray(np.asarray(v)) for v in params))
    wlp = WorkloadParams(*(jnp.asarray(np.asarray(v)) for v in wlp))
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) > 1 and len(cfgs) % len(devs) == 0:
        params, wlp = shard_scenario_axis(params, wlp, devs)
    return _run_traced_batch(tmpl, params, wlp, scheme, steps,
                             period_slots, delay_pad, history_slots,
                             trace_mode, decimate, warm)


def _run_traced_batch_impl(cfg, params, wlp, scheme, steps, period_slots,
                           delay_pad, history_slots, mode="full",
                           decimate=1, warm=0):
    f = wlp.is_inter.shape[-1]

    def one_scenario(p, w):
        state0 = init_state(cfg, f, params=p, delay_pad=delay_pad,
                            history_slots=history_slots, scheme=scheme)
        step = make_step_fn(cfg, w, scheme, period_slots,
                            params=p, delay_pad=delay_pad)
        return _scan_with_mode(step, scheme, state0, steps, mode, decimate,
                               warm)

    return jax.vmap(one_scenario)(params, wlp)


@lru_cache(maxsize=1)
def _jitted_traced_batch():
    """Build the jitted batch runner on FIRST use, not at import: the
    donation decision needs ``jax.default_backend()``, which initializes
    the backend — importing ``repro.netsim`` must never do that. The
    stacked batch inputs are donated so giant-grid chunk launches reuse
    their buffers in place (XLA ignores donation on CPU and would warn
    about it, hence none there)."""
    donate = () if jax.default_backend() == "cpu" else (1, 2)
    return partial(jax.jit,
                   static_argnames=("cfg", "scheme", "steps", "period_slots",
                                    "delay_pad", "history_slots", "mode",
                                    "decimate", "warm"),
                   donate_argnums=donate)(_run_traced_batch_impl)


def _run_traced_batch(*args, **kwargs):
    return _jitted_traced_batch()(*args, **kwargs)


_run_traced_batch._cache_size = lambda: _jitted_traced_batch()._cache_size()
