"""Soft-step relaxation helpers for the differentiable engine.

When ``NetConfig.soft_step`` is True the fluid engine (and the control
stack underneath it — DCQCN proxy, budget controller, estimators, slot
accounting, PFC hysteresis, channel impairments) replaces every hard
``where()``-select whose predicate depends on a traced knob with a
sigmoid-tempered blend.  The temperature is the traced
``NetParams.soft_temp`` leaf: as ``soft_temp -> 0`` every gate converges
pointwise to the hard step it relaxes, so soft-mode streamed metrics
converge to the hard-mode metrics (tests/test_soft_convergence.py pins
this).  With ``soft_step=False`` none of these helpers are traced at all
— the jaxpr is bit-identical to the hard engine (golden tests).

Conventions
-----------
* Every gate returns a weight in ``[0, 1]``; callers blend with
  :func:`lerp` (``lerp(g, on, off)``) instead of ``jnp.where``.
* ``scale`` is the natural unit of the compared quantity (µs for
  timers, bytes for queues, …); the sigmoid half-width is
  ``temp * scale`` so ``soft_temp`` is dimensionless.
* :func:`soft_pos` is *exactly* 0 at ``x <= 0`` — use it for
  "any traffic?" / token-bucket dry gates where an exactly-zero input
  must keep the gate exactly closed (bit-identical quiescent start).
* :func:`ste` is the straight-through estimator: forward-exact hard
  value, gradient of the smooth surrogate.  Used only where forward
  exactness matters (flow live-masks, failure live-masks); completion
  sentinels (``done_at_us`` INF latches) stay fully hard.
* :func:`reset_gate` detaches a gate used in a *self-referential*
  timer/counter reset (``t = lerp(w(t), 0, t)``): near the firing
  equilibrium that recurrence's Jacobian exceeds 1 and tangents grow
  exponentially through the scan (inf within ~200 steps).  Phase
  variables are simulator cadence, not knob response — their resets are
  structure (zero local sensitivity), while the gate's value and its
  gradient at every data-path use are untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "ste", "soft_gt", "soft_pos", "soft_or", "lerp", "soft_hysteresis",
    "reset_gate",
]


def ste(hard: jax.Array, soft: jax.Array) -> jax.Array:
    """Straight-through estimator: ``hard`` forward, ``d soft`` backward."""
    return soft + jax.lax.stop_gradient(hard - soft)


def soft_gt(x, thresh, temp, scale):
    """Relaxed ``(x > thresh).astype(f32)``: sigmoid of width ``temp*scale``.

    The argument is clipped at ±30 (forward value unchanged to f32
    precision) so deeply saturated gates — timers parked at 1e9 µs, queues
    orders of magnitude past threshold — have an *exactly zero* derivative
    instead of a denormal-times-huge product that pollutes tangents.
    """
    return jax.nn.sigmoid(jnp.clip((x - thresh) / (temp * scale),
                                   -30.0, 30.0))


def soft_pos(x, temp, scale):
    """Relaxed ``(x > 0).astype(f32)`` that is *exactly* 0 at ``x <= 0``.

    ``1 - exp(-relu(x) / (temp*scale))`` — smooth for x > 0, hard zero
    below, so quiescent state (no tokens, no retransmit backlog) stays
    bit-quiet instead of leaking a ``sigmoid(0) = 0.5`` ghost signal.
    """
    return -jnp.expm1(-jnp.maximum(x, 0.0) / (temp * scale))


def soft_or(a, b):
    """Probabilistic OR of two gate weights: ``a + b - a*b``."""
    return a + b - a * b


def lerp(gate, on, off):
    """Blend: ``gate*on + (1-gate)*off`` (== ``where(g, on, off)`` at g∈{0,1})."""
    return off + gate * (on - off)


def reset_gate(w):
    """Detach a gate weight for use in its own state's reset recurrence.

    ``t = lerp(w(t), 0, t + dt)`` has Jacobian ``(1-w) - (t+dt)·w'``; at
    the firing equilibrium ``(t+dt)·w' ≈ θ·s(1-s)/(temp·scale)`` exceeds 1
    for any threshold much larger than the sigmoid width, so tangents
    compound exponentially inside ``lax.scan``.  Detaching the gate makes
    the reset a contraction (``|∂t⁺/∂t| = 1-w ≤ 1``) while the *same*
    (undetached) gate keeps full gradients wherever it blends data-path
    quantities (rates, budgets, CNP volume).  See docs/differentiable.md.
    """
    return jax.lax.stop_gradient(w)


def soft_hysteresis(paused, q, xoff, xon, temp):
    """Relaxed PFC xoff/xon hysteresis.

    Hard semantics (``queues.pfc_hysteresis``): q > xoff → 1,
    q < xon → 0, else hold ``paused``.  Soft: blend with sigmoids whose
    width is 5% of each threshold, recovering the hard loop as
    ``temp -> 0``.
    """
    up = soft_gt(q, xoff, temp, 0.05 * xoff + 1.0)
    dn = soft_gt(q, xon, temp, 0.05 * xon + 1.0)
    # above xoff: 1; between: hold; below xon: 0
    return up + (1.0 - up) * dn * paused
