"""Observability layer for the netsim engine (docs/observability.md).

Three pillars, all opt-in and bit-identical to the pre-obs engine when
off:

  * **event rings** (`events`): a bounded per-scenario ring of discrete,
    timestamped events carried through the scan under
    ``trace_mode="window"`` + ``NetConfig.event_ring_slots > 0``
  * **timeline export** (`timeline`): window/event/trace data -> Chrome
    trace-event JSON for Perfetto UI / ``chrome://tracing``
  * **launch profiling + manifests** (`profile`): AOT compile/execute
    wall-clock split, XLA memory/cost figures, and JSONL run manifests
    summarized by ``tools/obs_report.py``
"""
from .events import (EVENT_KINDS, EventRing, decode_events,
                     engine_event_candidates, event_count, init_event_ring,
                     kind_name, push_events, unroll_window)
from .profile import (MANIFEST_VERSION, git_rev, memory_figures,
                      profiled_traced_batch, read_manifest, write_manifest)
from .timeline import (export_timeline, timeline_cell,
                       timeline_from_traces, timeline_from_window)

__all__ = [
    "EVENT_KINDS", "EventRing", "decode_events", "engine_event_candidates",
    "event_count", "init_event_ring", "kind_name", "push_events",
    "unroll_window",
    "MANIFEST_VERSION", "git_rev", "memory_figures",
    "profiled_traced_batch", "read_manifest", "write_manifest",
    "export_timeline", "timeline_cell", "timeline_from_traces",
    "timeline_from_window",
]
