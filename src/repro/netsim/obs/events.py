"""In-scan event telemetry: a bounded per-scenario event ring in the scan
carry (``trace_mode="window"`` + ``NetConfig.event_ring_slots > 0``).

The engine cannot afford a [B, T] trace buffer on long horizons, yet the
paper's claims hinge on *when* things happen — PFC pause onsets, brake
firings, retransmit bursts, failover dips. The event ring keeps the LAST
``event_ring_slots`` discrete events per scenario in O(E) device memory:
each scan step evaluates a STATIC list of candidate events (one slot per
possible event source), computes a traced ``fired`` predicate for each via
where()-selects of quantities the step already produced, and scatters the
fired candidates into a circular buffer. The ``count`` field is the
MONOTONE total of events ever fired, so overflow is observable (oldest
events are evicted, never silently miscounted).

Taxonomy (``EVENT_KINDS``):

  * ``pfc_xoff`` / ``pfc_xon``       — destination-OTN PFC pause asserted /
                                       released (per link at L > 1; ``obj``
                                       is the link index)
  * ``otn_xoff_cross``               — total destination-OTN backlog crossed
                                       the xoff threshold upward
  * ``retx_onset``                   — retransmit backlog became non-empty
                                       (loss-repair path active runs only)
  * ``fail_enter`` / ``fail_exit``   — a failure-schedule outage window
                                       opened / closed on link ``obj``
  * ``scheme_brake``                 — the scheme's proxy brake fired
                                       (matchrdma: budget-summary / loss cut)
  * ``scheme_budget_on`` / ``_off``  — the scheme's repair-budget reservation
                                       engaged / released (sdr_rdma: the
                                       congestion EWMA crossed 0.5)

Schemes add their own candidates through ``Scheme.emit_events`` (see
``docs/observability.md`` + ``docs/scheme-api.md``). Ring-off runs
(``event_ring_slots == 0`` — the default) never build any of this, so the
default jaxpr and the goldens stay bit-identical.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# name -> i32 kind code stored in the ring. Third-party schemes may register
# additional kinds (pick codes >= 100 to stay clear of future engine kinds).
EVENT_KINDS = {
    "pfc_xoff": 0,
    "pfc_xon": 1,
    "otn_xoff_cross": 2,
    "retx_onset": 3,
    "fail_enter": 4,
    "fail_exit": 5,
    "scheme_brake": 6,
    "scheme_budget_on": 7,
    "scheme_budget_off": 8,
}


def kind_name(code: int) -> str:
    """Kind-code -> taxonomy name (``kind_12`` for unknown codes)."""
    for name, c in EVENT_KINDS.items():
        if c == int(code):
            return name
    return f"kind_{int(code)}"


class EventRing(NamedTuple):
    """Circular event buffer carried through the scan. Arrays are sized
    ``[E + 1]``: slot ``E`` is the DISCARD slot every non-fired candidate
    scatters into, so the per-step write is a fixed-shape scatter with no
    data-dependent control flow. Under the batched engine every leaf gains
    a leading [B] axis."""
    t_us: jax.Array    # [E+1] f32 event timestamps (simulated µs); -1 empty
    kind: jax.Array    # [E+1] i32 EVENT_KINDS code; -1 empty
    obj: jax.Array     # [E+1] i32 object index (link id, 0 when N/A)
    value: jax.Array   # [E+1] f32 payload (backlog bytes, brake level, ...)
    count: jax.Array   # scalar i32 — MONOTONE total of events ever fired


def init_event_ring(slots: int) -> EventRing:
    e = int(slots) + 1
    return EventRing(
        t_us=jnp.full((e,), -1.0, jnp.float32),
        kind=jnp.full((e,), -1, jnp.int32),
        obj=jnp.zeros((e,), jnp.int32),
        value=jnp.zeros((e,), jnp.float32),
        count=jnp.int32(0),
    )


def push_events(ring: EventRing, slots: int, t_us, candidates) -> EventRing:
    """Scatter this step's fired candidates into the ring.

    ``candidates``: sequence of ``(kind_name, obj, value, fired)`` with
    STATIC ``kind_name``/``obj`` and traced scalar ``value``/``fired``.
    Fired candidates take consecutive ring positions ``(count + rank) mod
    slots`` (rank = exclusive prefix sum of the fired mask, so positions
    within one step never collide as long as ``slots >= len(candidates)``
    — checked at trace time by the engine); non-fired candidates write to
    the discard slot. Oldest events are evicted on wraparound; ``count``
    only ever grows."""
    names = [c[0] for c in candidates]
    unknown = [n for n in names if n not in EVENT_KINDS]
    if unknown:
        raise ValueError(
            f"push_events: unknown event kind(s) {unknown!r} — register "
            f"them in repro.netsim.obs.EVENT_KINDS (docs/observability.md)")
    kinds = jnp.asarray([EVENT_KINDS[n] for n in names], jnp.int32)
    objs = jnp.asarray([int(c[1]) for c in candidates], jnp.int32)
    vals = jnp.stack([jnp.asarray(c[2], jnp.float32).reshape(())
                      for c in candidates])
    fired = jnp.stack([jnp.asarray(c[3]).reshape(()).astype(jnp.bool_)
                       for c in candidates])
    fired_i = fired.astype(jnp.int32)
    rank = jnp.cumsum(fired_i) - fired_i           # exclusive prefix sum
    pos = jnp.where(fired, jnp.mod(ring.count + rank, slots), slots)
    ts = jnp.broadcast_to(jnp.asarray(t_us, jnp.float32), pos.shape)
    return EventRing(
        t_us=ring.t_us.at[pos].set(ts),
        kind=ring.kind.at[pos].set(kinds),
        obj=ring.obj.at[pos].set(objs),
        value=ring.value.at[pos].set(vals),
        count=ring.count + jnp.sum(fired_i),
    )


def engine_event_candidates(ctx, prev_state, state, t):
    """The engine-owned candidate list of one step — a pure function of the
    (pre, post) state pair and the traced step index, evaluated AROUND the
    step transition (never inside it, so ring-off runs keep the exact
    step jaxpr). Candidate COUNT is static: it depends only on compile-time
    structure (link count, repair path, failure schedule)."""
    multi = ctx.num_links > 1
    L = ctx.num_links
    t_f = jnp.asarray(t, jnp.float32)
    cands = []

    # PFC pause edges on the destination-OTN pause state (per link at L>1)
    pd0, pd1 = prev_state.pause_dst, state.pause_dst
    if multi:
        q_link = jnp.sum(state.q_dst, axis=1)                     # [L]
        for li in range(L):
            cands.append(("pfc_xoff", li, q_link[li],
                          (pd0[li] < 0.5) & (pd1[li] >= 0.5)))
            cands.append(("pfc_xon", li, q_link[li],
                          (pd0[li] >= 0.5) & (pd1[li] < 0.5)))
    else:
        q_tot = jnp.sum(state.q_dst)
        cands.append(("pfc_xoff", 0, q_tot, (pd0 < 0.5) & (pd1 >= 0.5)))
        cands.append(("pfc_xon", 0, q_tot, (pd0 >= 0.5) & (pd1 < 0.5)))

    # total dst-OTN backlog crossing the (single-pipe) xoff threshold upward
    prev_tot = jnp.sum(prev_state.q_dst)
    new_tot = jnp.sum(state.q_dst)
    th = jnp.asarray(ctx.xoff_otn, jnp.float32).reshape(())
    cands.append(("otn_xoff_cross", 0, new_tot,
                  (prev_tot < th) & (new_tot >= th)))

    # retransmit-backlog onset (loss-repair path active runs only — the
    # slot is absent otherwise, keeping the candidate count static per
    # compiled program)
    if state.retx_backlog is not None:
        pb = jnp.sum(prev_state.retx_backlog)
        nb = jnp.sum(state.retx_backlog)
        cands.append(("retx_onset", 0, nb, (pb <= 0.0) & (nb > 0.0)))

    # failure-window entry/exit, recomputed from the traced window table
    # (a pure function of t — no extra carry)
    fw = getattr(ctx.params, "fail_windows", None)
    if fw is not None and int(np.shape(fw)[-2]) > 0:
        fw = jnp.asarray(fw)                                      # [L, W, 2]
        t_us_now = t_f * ctx.dt_us
        t_us_prev = (t_f - 1.0) * ctx.dt_us
        down_now = jnp.any((t_us_now >= fw[..., 0])
                           & (t_us_now < fw[..., 1]), axis=-1)     # [L]
        down_prev = jnp.any((t_us_prev >= fw[..., 0])
                            & (t_us_prev < fw[..., 1]), axis=-1) & (t > 0)
        for li in range(fw.shape[0]):
            cands.append(("fail_enter", li, jnp.float32(0.0),
                          down_now[li] & ~down_prev[li]))
            cands.append(("fail_exit", li, jnp.float32(1.0),
                          down_prev[li] & ~down_now[li]))
    return cands


def decode_events(ring: EventRing, slots: int,
                  cell: Optional[int] = None) -> list:
    """Host-side: ring -> chronologically ordered event dicts
    (``{"t_us", "kind", "obj", "value"}``). For a batched ring (leading
    [B] axis) pass the ``cell`` index. Returns the last ``min(count,
    slots)`` events, oldest first."""
    t = np.asarray(ring.t_us)
    k = np.asarray(ring.kind)
    o = np.asarray(ring.obj)
    v = np.asarray(ring.value)
    c = np.asarray(ring.count)
    if t.ndim == 2:
        if cell is None:
            raise ValueError(
                "decode_events: batched ring — pass the cell index")
        t, k, o, v, c = t[cell], k[cell], o[cell], v[cell], c[cell]
    count = int(c)
    n = min(count, slots)
    if count <= slots:
        idx = np.arange(n)
    else:
        start = count % slots
        idx = (start + np.arange(slots)) % slots
    return [{"t_us": float(t[i]), "kind": kind_name(k[i]),
             "obj": int(o[i]), "value": float(v[i])} for i in idx]


def event_count(ring: EventRing) -> np.ndarray:
    """Host-side monotone event totals (scalar, or [B] for a batch)."""
    return np.asarray(ring.count)


def unroll_window(window: dict, steps: int, window_steps: int,
                  cell: Optional[int] = None):
    """Host-side: the [W, ...]-ring trace dict of ``trace_mode="window"``
    -> ``(step_idx, traces)`` in chronological order. ``step_idx`` is the
    [min(steps, W)] array of engine step indices each row corresponds to;
    ``traces`` maps each key to its time-ordered samples. For a batched
    window (leading [B] axis on every leaf) pass ``cell``."""
    w = int(window_steps)
    n = min(int(steps), w)
    if int(steps) <= w:
        idx = np.arange(n)
        step_idx = np.arange(n)
    else:
        start = int(steps) % w
        idx = (start + np.arange(w)) % w
        step_idx = np.arange(int(steps) - w, int(steps))
    out = {}
    for key, arr in window.items():
        a = np.asarray(arr)
        if cell is not None:
            a = a[cell]
        out[key] = a[idx]
    return step_idx, out
