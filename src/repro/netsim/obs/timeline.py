"""Timeline export: window-mode trace rings + event rings -> Chrome
trace-event JSON loadable in Perfetto UI / ``chrome://tracing``.

The Chrome trace-event format (``{"traceEvents": [...]}``) is the lowest
common denominator both viewers accept. We map the netsim structure onto
it as:

  * one **process** (``pid``) per sweep cell, named after its label
  * **counter tracks** (``"ph": "C"``) for every windowed trace key — one
    counter per scalar key, one per link/flow lane of a vector key — so
    queue depths, pause states and throughputs render as stacked area
    charts over simulated time
  * **instant events** (``"ph": "i"``) for every decoded ring event, on a
    per-kind track, carrying ``obj``/``value`` in ``args``

Timestamps are the engine's simulated microseconds verbatim (the trace
format's native unit), so the viewer's ruler reads sim time directly.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np

from .events import EventRing, decode_events, unroll_window


def _counter_events(pid: int, key: str, step_idx, values, dt_us: float):
    """One windowed trace key -> counter events (one lane per trailing
    index for vector keys)."""
    vals = np.asarray(values, np.float64)
    lanes = [("", vals)] if vals.ndim == 1 else [
        (f"[{i}]", vals[..., i]) for i in range(vals.shape[-1])]
    # collapse >2-D keys ([W, L, F] etc.) to per-leading-lane sums: the
    # viewer wants a handful of lanes, not a lane per flow
    if vals.ndim > 2:
        vals2 = vals.reshape(vals.shape[0], vals.shape[1], -1).sum(axis=-1)
        lanes = [(f"[{i}]", vals2[..., i]) for i in range(vals2.shape[-1])]
    out = []
    for suffix, series in lanes:
        name = key + suffix
        for t, v in zip(step_idx, series):
            out.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                        "ts": float(t) * float(dt_us),
                        "args": {name: float(v)}})
    return out


def timeline_cell(pid: int, *, label: str, dt_us: float, steps: int,
                  window_steps: int, window: Optional[dict] = None,
                  events: Optional[list] = None) -> list:
    """Trace events of ONE cell: a process-name metadata record, counter
    tracks for ``window`` (already cell-indexed, leaves [W, ...]), and
    instant events for ``events`` (decoded dicts from
    ``decode_events``)."""
    recs = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": label}},
            {"name": "thread_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": "traces"}}]
    if window:
        step_idx, ordered = unroll_window(window, steps, window_steps)
        for key in sorted(ordered):
            recs.extend(_counter_events(pid, key, step_idx, ordered[key],
                                        dt_us))
    if events:
        recs.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": 1, "args": {"name": "events"}})
        for ev in events:
            recs.append({"name": ev["kind"], "ph": "i", "s": "t",
                         "pid": pid, "tid": 1, "ts": float(ev["t_us"]),
                         "args": {"obj": ev["obj"], "value": ev["value"]}})
    return recs


def timeline_from_window(aux, *, dt_us: float, steps: int,
                         window_steps: int, event_ring_slots: int = 0,
                         labels: Optional[list] = None) -> dict:
    """A ``WindowAux`` (from ``simulate``/``simulate_batch`` under
    ``trace_mode="window"``) -> Chrome trace-event document. Handles both
    the unbatched aux (leaves [W, ...]) and the batched one (leaves
    [B, W, ...]); ``labels`` names the per-cell processes."""
    # unbatched window leaves are [W, ...]; batched are [B, W, ...]. The
    # engine always emits the scalar ``cons_err`` trace, which makes the
    # distinction unambiguous ([W] vs [B, W]).
    probe_key = "cons_err" if "cons_err" in aux.window else min(
        aux.window, key=lambda k: np.asarray(aux.window[k]).ndim)
    probe = np.asarray(aux.window[probe_key])
    batched = probe.ndim == 2
    n_cells = int(probe.shape[0]) if batched else 1
    recs = []
    for b in range(n_cells):
        label = labels[b] if labels else f"cell {b}"
        win = {k: np.asarray(v)[b] if batched else np.asarray(v)
               for k, v in aux.window.items()}
        evs = None
        if aux.events is not None and event_ring_slots > 0:
            evs = decode_events(aux.events, event_ring_slots,
                                cell=b if batched else None)
        recs.extend(timeline_cell(b, label=label, dt_us=dt_us, steps=steps,
                                  window_steps=window_steps, window=win,
                                  events=evs))
    return {"traceEvents": recs, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.netsim.obs",
                          "steps": int(steps), "dt_us": float(dt_us)}}


def timeline_from_traces(traces: dict, *, dt_us: float, decimate: int = 1,
                         labels: Optional[list] = None,
                         cell: Optional[int] = None) -> dict:
    """Full/decimate-mode trace dict -> Chrome trace-event document.
    Leaves are [T, ...] (sequential run) or [B, T, ...] (batch); pass
    ``cell`` to export a single batch cell. Decimated traces are spaced
    ``decimate`` steps apart on the time axis."""
    first = np.asarray(next(iter(traces.values())))
    batched = first.ndim >= 2 and cell is None and _looks_batched(traces)
    cells = range(first.shape[0]) if batched else [cell or 0]
    recs = []
    for pid, b in enumerate(cells):
        label = labels[pid] if labels else f"cell {b}"
        recs.append({"name": "process_name", "ph": "M", "pid": pid,
                     "tid": 0, "args": {"name": label}})
        for key in sorted(traces):
            arr = np.asarray(traces[key])
            series = arr[b] if (batched or cell is not None) else arr
            t_idx = np.arange(series.shape[0]) * decimate
            recs.extend(_counter_events(pid, key, t_idx, series, dt_us))
    return {"traceEvents": recs, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.netsim.obs",
                          "decimate": int(decimate), "dt_us": float(dt_us)}}


def _looks_batched(traces: dict) -> bool:
    # batched trace dicts have every leaf sharing the same 2 leading dims
    shapes = {np.asarray(v).shape[:2] for v in traces.values()}
    return len(shapes) == 1 and all(np.asarray(v).ndim >= 2
                                    for v in traces.values())


def export_timeline(path: str, doc_or_aux, **kwargs) -> str:
    """Write a timeline document (or build one from a ``WindowAux`` via
    ``timeline_from_window(**kwargs)``) as Chrome trace-event JSON.
    Returns ``path``."""
    if isinstance(doc_or_aux, dict) and "traceEvents" in doc_or_aux:
        doc = doc_or_aux
    else:
        doc = timeline_from_window(doc_or_aux, **kwargs)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return path
