"""Launch-plan profiling and run manifests.

The runner's launch plans (chunk × scheme × device padding) decide how a
sweep actually hits the hardware, but until now the only way to see the
compile-vs-execute split or the XLA memory footprint was ad-hoc prints.
This module provides:

  * ``profiled_traced_batch`` — an ahead-of-time (lower → compile →
    execute) drive of the SAME jitted batch program ``simulate_batch``
    uses, with ``jax.block_until_ready`` fencing so compile seconds and
    execute seconds are separately attributable, plus guarded
    ``memory_analysis()`` / ``cost_analysis()`` capture. Compiled
    executables are cached per static signature, so repeat launches of a
    chunked plan report ``compile_cached: true`` with ``compile_s ≈ 0``.
  * ``git_rev`` / ``memory_figures`` — the canonical helpers the benches
    re-export through ``benchmarks/record.py`` (src never imports
    benchmarks).
  * ``write_manifest`` / ``read_manifest`` — JSONL run manifests: one
    header record (git rev, plan sha256 fingerprint, backend, grid
    summary) followed by one record per launch (scheme, cell range,
    compile/execute seconds, memory figures). ``tools/obs_report.py``
    summarizes and diffs them.

Schema: every line is a JSON object with a ``record`` field — ``header``
for the first line, ``launch`` for the rest (see docs/observability.md).
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Optional

MANIFEST_VERSION = 1

# static-signature -> compiled executable. Module-level on purpose: the jit
# cache and this AOT cache are separate, so every profiled launch must come
# through here to amortize its own compile.
_AOT_CACHE: dict = {}


def git_rev(cwd: Optional[str] = None) -> str:
    """``git describe --always --dirty`` of the repo containing this file
    (or ``cwd``); ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except Exception:
        return "unknown"


def memory_figures(compiled) -> dict:
    """Guarded ``memory_analysis()``/``cost_analysis()`` capture from a
    compiled executable. Both APIs vary across JAX/XLA versions and
    backends — absent figures are simply omitted, never raised."""
    figs = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                figs[attr] = int(v)
    except Exception:
        pass
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        if isinstance(ca, dict):
            for key in ("flops", "bytes accessed"):
                if key in ca:
                    figs[key.replace(" ", "_")] = float(ca[key])
    except Exception:
        pass
    return figs


def _leaf_sig(tree) -> tuple:
    import jax
    return tuple((tuple(l.shape), str(l.dtype), str(getattr(l, "sharding",
                                                            "")))
                 for l in jax.tree_util.tree_leaves(tree))


def profiled_traced_batch(cfg, params, wlp, scheme, steps, period_slots,
                          delay_pad, history_slots, mode, decimate, warm,
                          channel, profile: dict):
    """Run the batched engine through an explicit lower → compile →
    execute pipeline, filling ``profile`` in place with:

    ``compile_s`` / ``compile_cached`` / ``execute_s`` / ``backend`` and
    the ``memory_figures`` of the executable. Returns the engine output
    (same pytree as ``fluid._run_traced_batch``)."""
    import jax
    from repro.netsim import fluid

    jitted = fluid._jitted_traced_batch()
    key = (cfg, scheme, steps, period_slots, delay_pad, history_slots,
           mode, decimate, warm, channel, jax.default_backend(),
           _leaf_sig(params), _leaf_sig(wlp))
    compiled = _AOT_CACHE.get(key)
    cached = compiled is not None
    t0 = time.perf_counter()
    if not cached:
        lowered = jitted.lower(cfg, params, wlp, scheme, steps,
                               period_slots, delay_pad, history_slots,
                               mode, decimate, warm, channel)
        compiled = lowered.compile()
        _AOT_CACHE[key] = compiled
    profile["compile_s"] = time.perf_counter() - t0 if not cached else 0.0
    profile["compile_cached"] = cached
    profile["backend"] = jax.default_backend()
    profile.update(memory_figures(compiled))
    t0 = time.perf_counter()
    out = compiled(params, wlp)
    out = jax.block_until_ready(out)
    profile["execute_s"] = time.perf_counter() - t0
    return out


def _json_safe(obj):
    """Round-trippable JSON: non-finite floats become strings, numpy
    scalars collapse to Python numbers."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, bool) or obj is None or isinstance(obj, (int, str)):
        return obj
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else str(obj)
    if hasattr(obj, "item"):
        return _json_safe(obj.item())
    return str(obj)


def write_manifest(path: str, header: dict, launches: list) -> str:
    """Write a JSONL run manifest: one ``record: "header"`` line, then one
    ``record: "launch"`` line per launch. Returns ``path``."""
    head = dict(header)
    head.setdefault("record", "header")
    head.setdefault("manifest_version", MANIFEST_VERSION)
    head.setdefault("git_rev", git_rev())
    head.setdefault("timestamp", time.strftime("%Y-%m-%dT%H:%M:%S"))
    lines = [head] + [dict(l, record="launch") for l in launches]
    with open(path, "w") as f:
        for rec in lines:
            f.write(json.dumps(_json_safe(rec), sort_keys=True) + "\n")
    return path


def read_manifest(path: str):
    """Read a JSONL manifest -> ``(header, launches)``. Tolerates a
    missing header (returns ``{}``) so partial files still summarize."""
    header, launches = {}, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("record") == "header":
                header = rec
            else:
                launches.append(rec)
    return header, launches
