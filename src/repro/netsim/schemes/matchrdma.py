"""The paper's scheme: segmented control + rate matching (Fig. 2).

Three coordinated segments, expressed as hook overrides:

  * ``ack_view``        — budget-gated pseudo-ACK: the sender's window spins
    at source-local latency but never faster than the destination budget.
  * ``sender_rate``     — inter-DC flows are NOT rate-limited by sender
    DCQCN (the source OTN shapes them); intra-DC flows keep the local loop.
  * ``src_otn_release`` — release ≤ budget share × proxy modulation: the
    budget is authoritative, the reactive proxy a fast bounded
    multiplicative brake around it (not a second rate machine).
  * ``feedback``        — CNPs are consumed at the destination OTN (nothing
    on the long return wire); the destination-side loop accumulates slot
    observations, runs the slot/budget update at slot boundaries, and ships
    (budget, congestion summary) on the control subchannel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.budget import fair_share
from repro.core.matchrdma import (
    accumulate_step, maybe_slot_update, step_channel,
)
from repro.core.pseudo_ack import step_pseudo_ack
from repro.netsim.soft import lerp, reset_gate, soft_gt, soft_or, soft_pos
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeSignals, apply_link_live,
)

# soft-gate byte scale for loss-notification presence (docs/differentiable.md)
_MTU = 1500.0


class MatchRdmaScheme(Scheme):
    """Segmented, rate-matched long-haul RDMA (the paper)."""

    # -- streaming metrics: on top of the inherited destination-budget
    # mean, stream the D-delayed budget as the SOURCE sees it — the rate
    # the release shaping actually enforced.
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        return dict(super().init_metric_acc(ctx, state),
                    budget_at_src_sum=jnp.float32(0.0))

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        acc = super().accumulate_metrics(ctx, acc, state, out, inc)
        return dict(acc, budget_at_src_sum=acc["budget_at_src_sum"]
                    + state.extra.budget_at_src * inc)

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        cols = super().finalize_metrics(acc, n_steps, n_warm)
        cols["mean_budget_at_src_gbps"] = (
            np.asarray(acc["budget_at_src_sum"]) / max(n_warm, 1)
            * 8.0 / 1e9)
        return cols

    def emit_events(self, ctx: SchemeCtx, prev_state, state, out) -> tuple:
        # the proxy brake fired iff some flow's brake timer was reset this
        # step (both the hard where() and the soft reset_gate path only
        # ever move the timer DOWN on a firing — it otherwise grows by
        # dt_us); value = the deepest post-brake modulation level
        fired = jnp.any(state.proxy_timer < prev_state.proxy_timer)
        return (("scheme_brake", 0, jnp.min(state.proxy_mod), fired),)

    def ack_view(self, ctx: SchemeCtx, state, ack_arr):
        return state.extra.pseudo.packed

    def route_weights(self, ctx: SchemeCtx, state, base_route):
        # rate matching shapes the AGGREGATE release; the spray itself
        # follows the workload routing, rerouted off links the failure
        # schedule killed this step (docs/failures.md)
        return apply_link_live(ctx, base_route)

    def sender_rate(self, ctx: SchemeCtx, state, base_rate):
        # inter-DC: window-limited only (the source OTN shapes the rate);
        # intra-DC: conventional sender DCQCN.
        return jnp.where(ctx.is_inter > 0, base_rate,
                         jnp.minimum(state.cc.rc, base_rate))

    def src_otn_release(self, ctx: SchemeCtx, state, arrivals, cap, active):
        # proxy shaping: release <= budget share x proxy modulation. The
        # budget is authoritative; the reactive proxy is a fast bounded
        # multiplicative brake around it (not a second rate machine).
        share = fair_share(state.extra.budget_at_src, active * ctx.is_inter)
        per_flow_cap = share * state.proxy_mod * ctx.dt_s
        avail = state.q_src + arrivals
        want = jnp.minimum(avail, per_flow_cap * ctx.is_inter)
        scale = jnp.minimum(1.0, cap / jnp.maximum(jnp.sum(want), 1e-9))
        drained = want * scale
        return avail - drained, drained

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        cfg = ctx.cfg
        # ---- source-side: budget-gated pseudo-ACK release
        mr = state.extra
        share = fair_share(mr.budget_at_src, sig.active * ctx.is_inter)
        pseudo, _ = step_pseudo_ack(mr.pseudo, sig.sent * ctx.is_inter,
                                    share, ctx.dt_s, gated=True)
        mr = mr._replace(pseudo=pseudo)

        # ---- proxy brake from the delayed congestion summary, rate-limited:
        # cut x0.7 (floor 0.25), recover with ~1 ms time constant. Loss
        # notifications from the channel subsystem (zeros under the ideal
        # channel — the golden pin stays bit-identical) brake the same way:
        # a dropping long haul is over-injection the budget estimator only
        # sees a control-window later.
        proxy_timer = state.proxy_timer + ctx.dt_us
        cut = jnp.maximum(state.proxy_mod * 0.7, 0.25)
        recover = jnp.minimum(state.proxy_mod * (1.0 + 5e-4 * ctx.dt_us),
                              1.0)
        if ctx.soft is None:
            fire = (((mr.summary_at_src > 0.5) | (sig.retx_arr > 0))
                    & (proxy_timer >= cfg.cnp_interval_us))
            proxy_mod = jnp.where(fire, cut, recover)
            proxy_timer = jnp.where(fire, 0.0, proxy_timer)
        else:
            # tempered brake trigger: the delayed summary is itself a soft
            # weight (gate at the 0.5 midpoint); loss notifications gate
            # through soft_pos (exactly 0 with no loss)
            w_fire = (soft_or(soft_gt(mr.summary_at_src, 0.5, ctx.soft,
                                      0.25),
                              soft_pos(sig.retx_arr, ctx.soft, _MTU))
                      * soft_gt(proxy_timer, cfg.cnp_interval_us, ctx.soft,
                                ctx.dt_us))
            proxy_mod = lerp(w_fire, cut, recover)
            # detached gate in the timer's own reset (soft.reset_gate)
            proxy_timer = lerp(reset_gate(w_fire), 0.0, proxy_timer)

        # ---- destination-side loop: slot accumulation, boundary update,
        # control subchannel
        leaf_delay_us = (jnp.sum(sig.q_leaf) / ctx.c_leaf * 1e6
                         + cfg.intra_dc_delay_us)
        mr = accumulate_step(
            mr, sig.egress_bytes,
            jnp.sum(sig.cnp_out * ctx.is_inter),
            leaf_delay_us, jnp.float32(1.0), sig.q_dst_tot,
            egress_paused=sig.leaf_pfc)
        mr = maybe_slot_update(mr, cfg, sig.t, ctx.period_slots,
                               params=ctx.params, soft=ctx.soft)
        if ctx.soft is None:
            overrun = (sig.q_dst_tot
                       > 0.5 * ctx.xoff_otn).astype(jnp.float32)
        else:
            overrun = soft_gt(sig.q_dst_tot, 0.5 * ctx.xoff_otn, ctx.soft,
                              0.05 * ctx.xoff_otn + 1.0)
        mr = step_channel(mr, overrun)

        return Feedback(
            # CNPs are consumed at the destination OTN: the long return
            # wire carries nothing, and the sender CC only hears intra-DC.
            cnp_wire=jnp.zeros_like(sig.cnp_out),
            cnp_in=sig.cnp_out * ctx.is_intra,
            proxy_timer=proxy_timer,
            proxy_mod=proxy_mod,
            extra=mr,
        )
