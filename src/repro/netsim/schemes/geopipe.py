"""GeoPipe-style lossless source-OTN pipeline shaping (arXiv:2510.12064).

GeoPipe trains pipeline-parallel LLMs across DCs over a *lossless*
RDMA-enabled OTN: instead of letting long-haul PFC storms form, the source
OTN paces its release into the long haul so the destination segment can
never be overrun, and schedules the release of pipeline-stage traffic so
stage bursts do not collide on the line. Expressed as hook overrides:

  * ``src_otn_release`` — PFC-free pacing gated on a per-segment credit
    window: the source may hold at most ``geopipe_credit_bdp_frac × 2D·C``
    bytes outstanding toward the destination segment (outstanding = released
    minus the credit grants returned from the destination, which arrive with
    one-way delay D). Grants advertise drained bytes PLUS the destination
    buffer's remaining headroom, so a downstream stall dries the source's
    credit one grant-return delay later — no pause frame involved. At 1.0
    the window is exactly rate-sustaining (C·D in the pipe plus C·D of
    grant-return lag); the default (0.08, inside the OTN segment buffer's
    0.10·BDP provisioning) keeps the destination backlog below the PFC
    threshold so the long-haul pause ratio stays at zero. Release is
    *pipeline-stage aware*: flows are partitioned round-robin into
    ``num_stages`` pipeline stages and the stage whose communication slice
    is current drains with a ``stage_boost`` weight, so stage bursts are
    serialized instead of colliding.
  * ``sender_rate`` — inter-DC flows are window-limited only (the credit
    gate at the source OTN is the rate control; backpressure reaches the
    NIC through q_src PFC); intra-DC flows keep conventional DCQCN.
  * ``feedback`` — inter-DC CNPs are consumed at the destination OTN (the
    credit window already bounds the destination backlog, so the long
    return wire carries nothing); the destination ships cumulative-egress
    credit grants on the control subchannel.

The credit window knob is a traced ``NetParams`` leaf
(``NetConfig.geopipe_credit_bdp_frac``), so a credit-window grid sweeps
batch-wide in one compiled launch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams
from repro.core.budget import (
    ControlChannel, channel_send_recv, control_proc_steps_traced,
    init_channel,
)
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeSignals, apply_link_live,
    long_haul_bdp,
)

from typing import NamedTuple


class GeoPipeState(NamedTuple):
    """Scheme-private pytree carried in ``SimState.extra``."""
    chan: ControlChannel         # DST -> SRC credit-grant channel (cum. egress)
    granted_at_src: jax.Array    # scalar — delayed cumulative dst-OTN egress
    egress_cum: jax.Array        # scalar — cumulative dst-OTN egress (dst side)
    stage_phase: jax.Array       # scalar int32 — pipeline stage whose slice is current


class GeoPipeScheme(Scheme):
    """Lossless-OTN pipeline shaping: credit-window pacing + stage scheduling.

    ``num_stages`` partitions flows round-robin into pipeline stages
    (flow i belongs to stage ``i % num_stages``); ``stage_slice_us`` is the
    rotation period of the release schedule and ``stage_boost`` the drain
    weight of the scheduled stage. All three are static (hashable scheme
    attributes); the credit window itself is the traced
    ``geopipe_credit_bdp_frac`` leaf.
    """

    def __init__(self, num_stages: int = 4, stage_slice_us: float = 200.0,
                 stage_boost: float = 4.0):
        self.num_stages = int(num_stages)
        self.stage_slice_us = float(stage_slice_us)
        self.stage_boost = float(stage_boost)
        super().__init__()

    # -- construction-time ------------------------------------------------
    def init_extra_state(self, cfg: NetConfig, params: NetParams,
                         num_flows: int, *, history_slots: int = 0,
                         chan_delay_pad: int = 0):
        if params is None:
            params = NetParams.of(cfg)
        if chan_delay_pad <= 0:
            chan_delay_pad = cfg.static_delay_steps + cfg.control_proc_steps
        # the grant line starts at zero (cumulative egress), unlike the
        # budget channel which starts at the proactive initial budget.
        # The ring SIZE is the static pad; the wrap index uses the traced
        # slot_us-derived processing delay so a slot_us sweep shares one
        # compiled program (mirrors core.matchrdma.init_matchrdma).
        chan = init_channel(
            chan_delay_pad, cfg, params=params,
            actual_delay=(params.delay_steps(cfg.dt_us)
                          + control_proc_steps_traced(cfg, params)),
            fill=0.0)
        return GeoPipeState(chan=chan,
                            granted_at_src=jnp.float32(0.0),
                            egress_cum=jnp.float32(0.0),
                            stage_phase=jnp.int32(0))

    # -- credit bookkeeping -----------------------------------------------
    def _credit(self, ctx: SchemeCtx, state):
        """(available credit bytes, window bytes) from the source's view.

        ``released`` is recovered from the conserved quantities the source
        OTN already tracks — cumulative inter-DC bytes accepted minus the
        bytes still queued — so no extra per-step ledger is carried.
        """
        window = ctx.params.geopipe_credit_bdp_frac * long_haul_bdp(ctx)
        released = (jnp.sum(state.sent * ctx.is_inter)
                    - jnp.sum(state.q_src))
        credit = jnp.maximum(window - (released - state.extra.granted_at_src),
                             0.0)
        return credit, window

    # -- per-step hooks ----------------------------------------------------
    def route_weights(self, ctx: SchemeCtx, state, base_route):
        # credit pacing gates the release volume, not its placement: the
        # spray follows the workload routing, rerouted off dead links so
        # the credit-metered bytes land on survivors (docs/failures.md)
        return apply_link_live(ctx, base_route)

    def sender_rate(self, ctx: SchemeCtx, state, base_rate):
        # inter-DC: window-limited only — the credit gate at the source OTN
        # is the rate control; intra-DC: conventional sender DCQCN.
        return jnp.where(ctx.is_inter > 0, base_rate,
                         jnp.minimum(state.cc.rc, base_rate))

    def src_otn_release(self, ctx: SchemeCtx, state, arrivals, cap, active):
        credit, window = self._credit(ctx, state)
        if ctx.soft is None:
            cap = jnp.minimum(cap, credit)   # PFC-free pacing: credit gate
        else:
            # the credit gate BINDS nearly every steady-state step (release
            # is credit-paced), so the hard min() sits exactly on its kink
            # in knob space and FD-vs-AD checks diverge there; a tempered
            # softmin (width ~1% of the window) keeps the binding region
            # smooth and converges to min() as the temperature drops
            w = ctx.soft * (0.01 * window + 1.0)
            cap = jnp.maximum(
                -w * jnp.logaddexp(-cap / w, -credit / w), 0.0)
        avail = state.q_src + arrivals
        f = avail.shape[0]
        stage = jnp.mod(jnp.arange(f), self.num_stages)
        boost = jnp.where(stage == state.extra.stage_phase,
                          self.stage_boost, 1.0)
        w = avail * boost                    # stage-aware weighted drain
        tot_w = jnp.sum(w)
        drained_tot = jnp.minimum(jnp.sum(avail), cap)
        share = jnp.where(tot_w > 0, w / jnp.maximum(tot_w, 1e-12), 0.0)
        drained = jnp.minimum(share * drained_tot, avail)
        # work-conserving second pass: capacity the boosted stage could not
        # absorb (its weighted share exceeded its backlog) goes to the
        # remaining backlog proportionally instead of idling the line.
        # leftover <= sum(rem) always, so the redistribution never overdrains.
        leftover = drained_tot - jnp.sum(drained)
        rem = avail - drained
        rem_tot = jnp.sum(rem)
        drained = drained + jnp.where(
            rem_tot > 0, rem / jnp.maximum(rem_tot, 1e-12), 0.0) * leftover
        return avail - drained, drained

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        gp = state.extra
        # destination side: grants advertise drained bytes PLUS remaining
        # buffer headroom (credit-based flow control) — when downstream
        # forwarding stalls, headroom collapses and the source's credit
        # dries up one grant-return delay later, without any PFC frame
        egress_cum = gp.egress_cum + sig.egress_bytes
        headroom = jnp.maximum(ctx.xoff_otn - sig.q_dst_tot, 0.0)
        chan, granted, _ = channel_send_recv(gp.chan, egress_cum + headroom,
                                             jnp.float32(0.0))
        # stage rotation for the NEXT step's release schedule
        t_us = (sig.t.astype(jnp.float32) + 1.0) * ctx.dt_us
        phase = jnp.mod(
            jnp.floor(t_us / self.stage_slice_us).astype(jnp.int32),
            self.num_stages)
        return Feedback(
            # lossless segment: inter-DC CNPs are absorbed at the
            # destination OTN — the credit window is the backpressure
            cnp_wire=jnp.zeros_like(sig.cnp_out),
            cnp_in=sig.cnp_out * ctx.is_intra,
            proxy_timer=state.proxy_timer,
            proxy_mod=state.proxy_mod,
            extra=gp._replace(chan=chan, granted_at_src=granted,
                              egress_cum=egress_cum, stage_phase=phase),
        )

    def extra_traces(self, ctx: SchemeCtx, state) -> dict:
        credit, _ = self._credit(ctx, state)
        stall = ((credit <= 1.0)
                 & (jnp.sum(state.q_src) > 1.0)).astype(jnp.float32)
        return {"credit_bytes": credit, "credit_stall": stall}

    # -- streaming metrics -------------------------------------------------
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        return {"credit_sum": jnp.float32(0.0),
                "credit_stall_sum": jnp.float32(0.0)}

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        return dict(acc,
                    credit_sum=acc["credit_sum"] + out["credit_bytes"] * inc,
                    credit_stall_sum=acc["credit_stall_sum"]
                    + out["credit_stall"] * inc)

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        return {
            "mean_credit_mb":
                np.asarray(acc["credit_sum"]) / max(n_warm, 1) / 1e6,
            "credit_stall_frac":
                np.asarray(acc["credit_stall_sum"]) / max(n_warm, 1),
        }
