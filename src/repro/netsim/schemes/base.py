"""The pluggable Scheme interface + registry for the netsim fluid engine.

A *scheme* is the paper's unit of contribution — how a long-haul RDMA
control plane sees ACKs, shapes the source-OTN release, and routes
congestion feedback. ``fluid.make_step_fn`` is a scheme-agnostic skeleton
(flow phase → queues → ECN/PFC → CC → FCT) that composes the hooks below;
everything scheme-specific lives in a ``Scheme`` subclass registered under
a name:

    from repro.netsim.schemes import Scheme, register_scheme

    @register_scheme("my_scheme")
    class MyScheme(Scheme):
        def sender_rate(self, ctx, state, base_rate):
            ...

Registered names are immediately usable from every entrypoint that takes a
scheme — ``simulate`` / ``simulate_batch`` / ``run_experiment_batch`` /
``sweep`` / ``sweep_grid`` / the figure benchmarks — without touching
``fluid.py``.

Hook contract (all jnp expressions; traced under vmap over scenarios):

  ``init_extra_state``   scheme-private pytree carried in ``SimState.extra``
                         (default: the shared MatchRDMA block — slot ring,
                         budget, control subchannel, pseudo-ACK ledger — so
                         schemes that only tweak rate laws inherit working
                         budget traces for free).
  ``ack_view``           how the sender sees inter-DC ACKs: cumulative
                         acked bytes per flow (e2e delayed ACKs by default;
                         pseudo-ACK schemes return the source-OTN ledger).
  ``sender_rate``        sender rate law before NIC-PFC gating.
  ``src_otn_release``    how the source OTN drains toward the long haul:
                         FIFO-fair by default, budget×proxy shaping for
                         rate-matched schemes.
  ``feedback``           CNP routing (what goes on the return wire, what
                         reaches the sender CC) + every per-step update of
                         the scheme's extra state (pseudo-ACK ledger, proxy
                         brake, slot/budget/channel machinery).
  ``rtt_scale``          optional per-flow DCQCN fairness factor (THEMIS).
  ``extra_traces``       scheme-owned additions to the per-step trace dict.

Streaming-metric hooks (``trace_mode="metrics"`` — the O(B) execution mode
that never materializes [B, T] traces):

  ``init_metric_acc``    scheme-private accumulator pytree carried in
                         ``MetricAcc.scheme`` through the scan.
  ``accumulate_metrics`` per-step in-scan reduction update (runs under vmap
                         over scenarios, like every other hook).
  ``finalize_metrics``   host-side (numpy) conversion of the accumulated
                         leaves into named per-cell metric columns, merged
                         into the sweep rows.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig, NetParams
from repro.core.matchrdma import MatchRdmaState, init_matchrdma
from repro.netsim.queues import drain_proportional


def long_haul_bdp(ctx: "SchemeCtx") -> jax.Array:
    """Long-haul bandwidth-delay product in bytes (2D x C_otn, traced) —
    the reference quantity BDP-fraction knobs (geopipe credit window,
    sdr_rdma receive window) scale against. Matches the engine's OTN
    buffer provisioning base in ``fluid.make_step_fn``."""
    return ctx.c_otn * 2.0 * ctx.params.one_way_delay_us * 1e-6


def apply_link_live(ctx: "SchemeCtx", weights: jax.Array) -> jax.Array:
    """Mask [F, L] spray weights down to the links alive this step — the
    reroute contract every ``route_weights`` implementation honors
    (docs/failures.md). With no failure schedule (``ctx.link_live is
    None``) the weights pass through UNTOUCHED, and at an all-up step the
    ``where()`` selects the ORIGINAL tensor — both keep the program
    bit-identical to a schedule-free run. When every link of a flow is
    down its row goes all-zero; the skeleton's renormalization then
    stalls that flow (zero share, bytes spill back to the source queue)
    instead of dividing by zero."""
    if ctx.link_live is None:
        return weights
    live = ctx.link_live[None, :]
    return jnp.where(live < 1.0, weights * live, weights)


class SchemeCtx(NamedTuple):
    """Per-run quantities shared by every hook, built once per trace by
    ``make_step_fn``. Traced leaves (capacities, delays) come from
    ``NetParams`` so one compiled step serves a whole scenario batch."""
    cfg: NetConfig               # static structure (dt, slot layout, DCQCN)
    params: NetParams            # traced per-scenario scalars
    period_slots: int            # static estimator periodicity hint
    dt_us: float                 # static step length
    dt_s: float
    nic: jax.Array               # sender NIC rate, bytes/s
    c_otn: jax.Array             # OTN line capacity, bytes/s
    c_leaf: jax.Array            # destination leaf capacity, bytes/s
    xoff: jax.Array              # DC-leaf PFC pause threshold, bytes
    xon: jax.Array
    xoff_otn: jax.Array          # OTN PFC threshold (BDP-scaled), bytes
    xon_otn: jax.Array
    is_inter: jax.Array          # [F] 1.0 for inter-DC flows
    is_intra: jax.Array          # [F]
    rtt_us: jax.Array            # [F] e2e RTT estimate per flow
    d_steps: jax.Array           # traced one-way delay in steps
    # multi-link topology (cfg.num_paths > 1 only; None on the single-pipe
    # path so the L=1 jaxpr — and the goldens pinning it — is untouched):
    num_links: int = 1           # static L
    link_caps: Optional[jax.Array] = None      # f32[L] per-link bytes/s
    link_d_steps: Optional[jax.Array] = None   # i32[L] per-link delay steps
    # multi-site graph views (cfg.is_multisite only; None on legacy
    # single-pair configs — docs/sites.md):
    num_sites: int = 2           # static site count N
    edge_sites: Optional[jax.Array] = None     # i32[L, 2] per-link
                                               # (src_site, dst_site) pair
    flow_src_site: Optional[jax.Array] = None  # f32[F] flow source site
    flow_dst_site: Optional[jax.Array] = None  # f32[F] flow dest site
    # hard-failure live mask (docs/failures.md): set PER STEP by the
    # skeleton whenever a failure schedule is active — f32[L], 1.0 = the
    # link is up this step, 0.0 = hard outage. None when no schedule
    # exists (the bit-identity contract: hooks must not perturb the
    # schedule-free program). ``route_weights`` implementations fold it
    # in via ``apply_link_live`` so sprays avoid dead links.
    link_live: Optional[jax.Array] = None      # f32[L] per-step live mask
    # soft-step temperature (docs/differentiable.md): the traced
    # ``params.soft_temp`` leaf when ``cfg.soft_step`` is on, else None.
    # Hooks thread it into their knob-dependent gates (tempered sigmoids
    # replacing hard selects); None keeps every hook's hard program.
    soft: Optional[jax.Array] = None


class SchemeSignals(NamedTuple):
    """Everything the datapath computed this step that feedback may need."""
    t: jax.Array                 # step index
    active: jax.Array            # [F] flow-phase activity mask
    sent: jax.Array              # [F] NEW cumulative bytes sent
    cnp_out: jax.Array           # [F] CNPs generated at the receiver
    cnp_arr: jax.Array           # [F] CNPs arriving after the return delay
    egress_bytes: jax.Array      # scalar — bytes the dst OTN forwarded
    q_dst_tot: jax.Array         # scalar — new dst-OTN backlog
    q_leaf: jax.Array            # [F] new dst-leaf queue
    leaf_pfc: jax.Array          # scalar — leaf asserting PFC toward dst OTN
    # channel-subsystem loss signals (zeros under the ideal channel):
    retx_arr: jax.Array          # [F] loss-notification bytes arriving at
                                 # the source after the one-way delay D
    retx_backlog: jax.Array      # [F] post-service retransmit backlog
    # multi-link signals (None on the L=1 single-pipe path):
    link_sent: Optional[jax.Array] = None      # [L, F] bytes sprayed onto
                                               # each link this step
    link_arrivals: Optional[jax.Array] = None  # [L, F] bytes landing at the
                                               # dst OTN per link this step
    link_want: Optional[jax.Array] = None      # [L] pre-clip spray demand
                                               # per link this step
    link_cap: Optional[jax.Array] = None       # [L] effective per-link
                                               # capacity this step (bytes;
                                               # 0 while paused / flapped)


class Feedback(NamedTuple):
    """What ``feedback`` hands back to the skeleton."""
    cnp_wire: jax.Array          # [F] value written on the CNP return line
    cnp_in: jax.Array            # [F] CNPs fed to the sender CC this step
    proxy_timer: jax.Array       # [F]
    proxy_mod: jax.Array         # [F]
    extra: object                # the scheme's updated extra-state pytree


class Scheme:
    """Default hooks = conventional end-to-end RDMA (DCQCN at the sender)."""

    name: Optional[str] = None

    def __init__(self):
        # fall back to the class name so an unregistered instance still
        # yields labeled metric rows; register_scheme overwrites this.
        if self.name is None:
            self.name = type(self).__name__

    # Value semantics: scheme instances are jit static args, so two
    # equivalent instances must share one compiled scan. Equality compares
    # the full instance state so parameterized schemes (constructor args
    # stored as attributes) with different settings never collide in the
    # cache; keep scheme attributes plain comparable config values.
    def __eq__(self, other):
        return type(self) is type(other) and self.__dict__ == other.__dict__

    def __hash__(self):
        return hash((type(self), self.name))

    # -- construction-time hooks (run at trace time, not per step) ---------
    def init_extra_state(self, cfg: NetConfig, params: NetParams,
                         num_flows: int, *, history_slots: int = 0,
                         chan_delay_pad: int = 0):
        """Scheme-private state carried through the scan in
        ``SimState.extra``. The default is the full MatchRDMA block so the
        ``budget``/``budget_at_src`` traces exist for every scheme; override
        together with ``extra_traces`` to carry something else."""
        return init_matchrdma(cfg, num_flows, history_slots=history_slots,
                              params=params, chan_delay_pad=chan_delay_pad)

    def rtt_scale(self, ctx: SchemeCtx):
        """Optional [F] DCQCN increase/cut fairness factor (None = 1)."""
        return None

    # -- per-step hooks ----------------------------------------------------
    def ack_view(self, ctx: SchemeCtx, state, ack_arr: jax.Array) -> jax.Array:
        """Cumulative acked bytes as the sender sees them (inter-DC flows).
        Default: conventional ACKs returning over the full path."""
        return state.acked + ack_arr

    def sender_rate(self, ctx: SchemeCtx, state,
                    base_rate: jax.Array) -> jax.Array:
        """Sender rate law (before source-OTN PFC gating). Default: window
        limit ∧ the sender's DCQCN rate."""
        return jnp.minimum(state.cc.rc, base_rate)

    def src_otn_release(self, ctx: SchemeCtx, state, arrivals: jax.Array,
                        cap: jax.Array, active: jax.Array):
        """Drain law of the source OTN toward the long haul. Returns
        ``(new_q_src [F], drained [F])``. Default: FIFO-fair fluid drain."""
        return drain_proportional(state.q_src, arrivals, cap)

    def route_weights(self, ctx: SchemeCtx, state,
                      base_route: jax.Array) -> jax.Array:
        """[F, L] spray weights steering each flow's drained bytes across
        the parallel long-haul links (``cfg.num_paths > 1`` only — the
        single-pipe skeleton never calls this). ``base_route`` is the
        workload's per-flow routing matrix (``WorkloadParams.route``
        broadcast to L columns); the default routes exactly as the
        workload asked. Schemes that load-balance dynamically (rdmacell's
        token-gated flowcell spraying) reweight it from their extra state.
        Weights are relative per flow — the skeleton normalizes rows and
        masks links with zero capacity this step. Implementations must
        honor the reroute contract: fold ``ctx.link_live`` in via
        ``apply_link_live`` so an outage re-sprays onto survivors
        (docs/failures.md)."""
        return apply_link_live(ctx, base_route)

    def retx_rate(self, ctx: SchemeCtx, state, rate: jax.Array) -> jax.Array:
        """[F] bytes/s the sender may devote to retransmitting lost bytes
        this step (non-ideal channels only — the engine's loss-repair
        path). Repair is served with priority: the skeleton deducts what it
        grants from the new-data emission, so the default — repair shares
        the scheme's own sender rate ``rate`` — models a transport whose
        recovery competes with (and is squeezed by) its congestion-
        controlled rate. Schemes with an explicit reliability budget
        (sdr_rdma) return more than ``rate`` to repair faster than their
        congested goodput rate."""
        return rate

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        """CNP routing + extra-state updates. Default: CNPs ride the full
        return path; intra-DC CNPs loop locally; extra state untouched."""
        return Feedback(
            cnp_wire=sig.cnp_out * ctx.is_inter,
            cnp_in=jnp.where(ctx.is_inter > 0, sig.cnp_arr,
                             sig.cnp_out * ctx.is_intra),
            proxy_timer=state.proxy_timer,
            proxy_mod=state.proxy_mod,
            extra=state.extra,
        )

    def extra_traces(self, ctx: SchemeCtx, state) -> dict:
        """Scheme-owned per-step trace entries (from the PRE-step state,
        matching the historical trace convention). The default only knows
        how to trace the default MatchRDMA extra block — a scheme that
        overrides ``init_extra_state`` with its own pytree gets no extra
        traces unless it overrides this hook too."""
        if isinstance(state.extra, MatchRdmaState):
            return {
                "budget": state.extra.budget.budget,
                "budget_at_src": state.extra.budget_at_src,
            }
        return {}

    def emit_events(self, ctx: SchemeCtx, prev_state, state,
                    out: dict) -> tuple:
        """Scheme-owned event-ring candidates (docs/observability.md).

        Called once per step AROUND the transition — ``prev_state`` /
        ``state`` are the pre/post ``SimState`` — but ONLY under
        ``trace_mode="window"`` with ``NetConfig.event_ring_slots > 0``,
        so the default jaxpr never contains this code. Returns a tuple of
        ``(kind_name, obj, value, fired)`` candidates: ``kind_name`` a
        STATIC key of ``repro.netsim.obs.EVENT_KINDS``, ``obj`` a static
        object index, ``value`` a traced scalar payload and ``fired`` a
        traced scalar predicate. The candidate COUNT must be static (it
        sizes the per-step scatter). Default: no scheme events."""
        return ()

    # -- streaming-metric hooks (trace_mode="metrics") ---------------------
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        """Scheme-private streaming accumulator (a dict pytree so subclass
        overrides can merge ``super()``'s entries). Mirrors ``extra_traces``:
        the default streams the destination budget's warm-step sum whenever
        the extra block is the shared MatchRDMA state, so every scheme that
        inherits the default extra state gets a ``mean_budget_gbps`` column
        for free."""
        if isinstance(state.extra, MatchRdmaState):
            return {"budget_sum": jnp.float32(0.0)}
        return {}

    def accumulate_metrics(self, ctx: SchemeCtx, acc: dict, state,
                           out: dict, inc: jax.Array) -> dict:
        """Fold one step into the accumulator. ``state`` is the post-step
        ``SimState``, ``out`` the step's trace dict, ``inc`` is 1.0 on
        steps past the warm-up cutoff (multiply sums by it)."""
        if "budget_sum" in acc:
            acc = dict(acc,
                       budget_sum=acc["budget_sum"]
                       + state.extra.budget.budget * inc)
        return acc

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        """Host-side: numpy-ified accumulator leaves ([B]-leading) -> dict
        of per-cell metric columns to merge into the sweep rows."""
        if "budget_sum" in acc:
            import numpy as np
            return {"mean_budget_gbps":
                    np.asarray(acc["budget_sum"]) / max(n_warm, 1)
                    * 8.0 / 1e9}
        return {}

    def __repr__(self):
        return f"<Scheme {self.name or type(self).__name__}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scheme] = {}

SchemeLike = Union[str, Scheme]


def register_scheme(name: str, scheme=None, *, override: bool = False):
    """Register a ``Scheme`` subclass (or instance) under ``name``.

    Usable as a decorator — ``@register_scheme("my_scheme")`` above a class
    definition — or called directly with a class/instance. Registration
    makes the name resolvable by every netsim entrypoint. Re-registering a
    taken name raises unless ``override=True``.
    """
    def _register(obj):
        inst = obj() if isinstance(obj, type) else obj
        if not isinstance(inst, Scheme):
            raise TypeError(
                f"register_scheme({name!r}): expected a Scheme subclass or "
                f"instance, got {type(inst).__name__}")
        if not override and name in _REGISTRY:
            raise ValueError(
                f"scheme {name!r} is already registered "
                f"({_REGISTRY[name]!r}); pass override=True to replace it")
        inst.name = name
        _REGISTRY[name] = inst
        return obj

    if scheme is None:
        return _register
    _register(scheme)
    return _REGISTRY[name]


def unregister_scheme(name: str) -> None:
    """Remove a registered scheme (mainly for tests)."""
    _REGISTRY.pop(name, None)


def get_scheme(scheme: SchemeLike) -> Scheme:
    """Resolve a scheme name (or pass a ``Scheme`` instance through)."""
    if isinstance(scheme, Scheme):
        return scheme
    try:
        return _REGISTRY[scheme]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown scheme {scheme!r}; registered: "
            f"{', '.join(available_schemes()) or '(none)'}") from None


def available_schemes() -> tuple:
    """Names of every registered scheme, sorted."""
    return tuple(sorted(_REGISTRY))
