"""Registry-backed scheme package: the paper's four schemes, the related-work
pack, and the plug-in API.

    from repro.netsim.schemes import get_scheme, register_scheme, Scheme

    sch = get_scheme("matchrdma")            # resolve a registered name

    @register_scheme("my_scheme")            # add one — no fluid.py edits
    class MyScheme(Scheme):
        ...

Seven schemes ship registered: the paper's four (``SCHEMES`` — the stable
builtin tuple pinned against pre-refactor goldens) plus the related-work
pack (``RELATED_SCHEMES``): GeoPipe-style lossless pipeline shaping,
SDR-RDMA-style software-defined reliability, and RDMACell-style token-gated
flowcell spraying over the multi-link topology (``docs/topology.md``).
``ALL_SCHEMES`` is their concatenation; the registry may grow beyond it.

See ``base.py`` for the hook contract, ``docs/scheme-api.md`` for the
authoritative reference, and ``docs/writing-a-scheme.md`` for a worked
tutorial.
"""
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeLike, SchemeSignals,
    available_schemes, get_scheme, register_scheme, unregister_scheme,
)
from repro.netsim.schemes.dcqcn import DcqcnScheme, ThemisScheme
from repro.netsim.schemes.geopipe import GeoPipeScheme, GeoPipeState
from repro.netsim.schemes.matchrdma import MatchRdmaScheme
from repro.netsim.schemes.pseudo_ack import PseudoAckScheme
from repro.netsim.schemes.rdmacell import RdmaCellScheme, RdmaCellState
from repro.netsim.schemes.sdr_rdma import SdrRdmaScheme, SdrRdmaState

# The paper's four schemes (Fig. 3). ``SCHEMES`` stays the stable builtin
# tuple (tests/benchmarks iterate it); the registry may grow beyond it.
register_scheme("dcqcn", DcqcnScheme)
register_scheme("pseudo_ack", PseudoAckScheme)
register_scheme("themis", ThemisScheme)
register_scheme("matchrdma", MatchRdmaScheme)

SCHEMES = ("dcqcn", "pseudo_ack", "themis", "matchrdma")

# The related-work pack (PR 4): pinned against their own goldens and swept
# alongside the paper schemes by ``benchmarks/scheme_compare.py``.
register_scheme("geopipe", GeoPipeScheme)
register_scheme("sdr_rdma", SdrRdmaScheme)
register_scheme("rdmacell", RdmaCellScheme)

RELATED_SCHEMES = ("geopipe", "sdr_rdma", "rdmacell")
ALL_SCHEMES = SCHEMES + RELATED_SCHEMES

__all__ = [
    "ALL_SCHEMES", "Feedback", "RELATED_SCHEMES", "SCHEMES", "Scheme",
    "SchemeCtx", "SchemeLike", "SchemeSignals",
    "DcqcnScheme", "GeoPipeScheme", "GeoPipeState", "MatchRdmaScheme",
    "PseudoAckScheme", "RdmaCellScheme", "RdmaCellState", "SdrRdmaScheme",
    "SdrRdmaState", "ThemisScheme",
    "available_schemes", "get_scheme", "register_scheme",
    "unregister_scheme",
]
