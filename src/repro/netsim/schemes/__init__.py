"""Registry-backed scheme package: the four paper schemes + the plug-in API.

    from repro.netsim.schemes import get_scheme, register_scheme, Scheme

    sch = get_scheme("matchrdma")            # resolve a registered name

    @register_scheme("my_scheme")            # add one — no fluid.py edits
    class MyScheme(Scheme):
        ...

See ``base.py`` for the hook contract and README "Scheme API" for a worked
example.
"""
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeLike, SchemeSignals,
    available_schemes, get_scheme, register_scheme, unregister_scheme,
)
from repro.netsim.schemes.dcqcn import DcqcnScheme, ThemisScheme
from repro.netsim.schemes.matchrdma import MatchRdmaScheme
from repro.netsim.schemes.pseudo_ack import PseudoAckScheme

# The paper's four schemes (Fig. 3). ``SCHEMES`` stays the stable builtin
# tuple (tests/benchmarks iterate it); the registry may grow beyond it.
register_scheme("dcqcn", DcqcnScheme)
register_scheme("pseudo_ack", PseudoAckScheme)
register_scheme("themis", ThemisScheme)
register_scheme("matchrdma", MatchRdmaScheme)

SCHEMES = ("dcqcn", "pseudo_ack", "themis", "matchrdma")

__all__ = [
    "Feedback", "Scheme", "SchemeCtx", "SchemeLike", "SchemeSignals",
    "SCHEMES", "DcqcnScheme", "ThemisScheme", "MatchRdmaScheme",
    "PseudoAckScheme", "available_schemes", "get_scheme", "register_scheme",
    "unregister_scheme",
]
