"""Ungated source-OTN pseudo-ACK (NTT GLOBECOM'24 baseline).

The source OTN acknowledges every byte it accepts immediately
(``credits = ∞``), so the sender's ACK-clocked window spins at source-local
latency — distance-insensitive throughput, but nothing matches the release
rate to what the destination can absorb, hence the buffer/pause blowups of
Fig. 3(c,d). Congestion control stays end-to-end.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.budget import fair_share
from repro.core.pseudo_ack import step_pseudo_ack
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeSignals, apply_link_live,
)


class PseudoAckScheme(Scheme):
    """Source-OTN pseudo-ACK, ungated; CC still e2e."""

    gated = False

    def route_weights(self, ctx: SchemeCtx, state, base_route):
        # pseudo-ACK only changes the feedback plane: the spray follows
        # the workload routing, rerouted off dead links (docs/failures.md)
        return apply_link_live(ctx, base_route)

    # -- streaming metrics: the pseudo-ACK "lead" — bytes acknowledged to
    # the sender that have not actually been delivered yet. The ungated
    # variant's lead is exactly the optimism that floods the destination
    # OTN (Fig. 3c); the budget-gated scheme keeps it near one BDP.
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        return dict(super().init_metric_acc(ctx, state),
                    pseudo_lead_sum=jnp.float32(0.0))

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        acc = super().accumulate_metrics(ctx, acc, state, out, inc)
        lead = jnp.sum(jnp.maximum(
            state.extra.pseudo.packed - state.delivered, 0.0) * ctx.is_inter)
        return dict(acc, pseudo_lead_sum=acc["pseudo_lead_sum"] + lead * inc)

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        cols = super().finalize_metrics(acc, n_steps, n_warm)
        cols["mean_pseudo_lead_mb"] = (np.asarray(acc["pseudo_lead_sum"])
                                       / max(n_warm, 1) / 1e6)
        return cols

    def ack_view(self, ctx: SchemeCtx, state, ack_arr):
        # the sender sees the source OTN's pseudo-ACK ledger, one step old
        return state.extra.pseudo.packed

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        mr = state.extra
        share = fair_share(mr.budget_at_src, sig.active * ctx.is_inter)
        pseudo, _ = step_pseudo_ack(mr.pseudo, sig.sent * ctx.is_inter,
                                    share, ctx.dt_s, gated=self.gated)
        base = super().feedback(ctx, state, sig)
        return base._replace(extra=mr._replace(pseudo=pseudo))
