"""Ungated source-OTN pseudo-ACK (NTT GLOBECOM'24 baseline).

The source OTN acknowledges every byte it accepts immediately
(``credits = ∞``), so the sender's ACK-clocked window spins at source-local
latency — distance-insensitive throughput, but nothing matches the release
rate to what the destination can absorb, hence the buffer/pause blowups of
Fig. 3(c,d). Congestion control stays end-to-end.
"""
from __future__ import annotations

from repro.core.budget import fair_share
from repro.core.pseudo_ack import step_pseudo_ack
from repro.netsim.schemes.base import Feedback, Scheme, SchemeCtx, SchemeSignals


class PseudoAckScheme(Scheme):
    """Source-OTN pseudo-ACK, ungated; CC still e2e."""

    gated = False

    def ack_view(self, ctx: SchemeCtx, state, ack_arr):
        # the sender sees the source OTN's pseudo-ACK ledger, one step old
        return state.extra.pseudo.packed

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        mr = state.extra
        share = fair_share(mr.budget_at_src, sig.active * ctx.is_inter)
        pseudo, _ = step_pseudo_ack(mr.pseudo, sig.sent * ctx.is_inter,
                                    share, ctx.dt_s, gated=self.gated)
        base = super().feedback(ctx, state, sig)
        return base._replace(extra=mr._replace(pseudo=pseudo))
