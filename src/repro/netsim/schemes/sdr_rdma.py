"""SDR-RDMA-style software-defined reliability (arXiv:2505.05366).

SDR-RDMA replaces the NIC's hard-wired go-back-N with a *software-defined*
selective-repeat reliability layer for planetary-scale RDMA: the receiver
keeps a SACK-style receive window, coalesces acknowledgements to bound the
reverse-channel load, and the sender provisions an explicit budget for
repair (retransmission) traffic. In the fluid model those become three
tunable knobs, each a traced ``NetParams`` leaf so a knob grid sweeps
batch-wide in one compiled launch:

  ``sdr_window_bdp_frac``   per-flow selective-repeat receive window as a
                            fraction of the long-haul BDP (2D·C). The sender
                            may hold at most this many un-acked bytes in
                            flight — the distance-scaling window the
                            go-back-N NIC cannot afford.
  ``sdr_ack_coalesce_us``   receiver ACK-coalescing interval: the sender's
                            window view only advances at coalescing
                            boundaries (between them acks accumulate in the
                            scheme's own cumulative ledger).
  ``sdr_retx_budget_frac``  NIC rate share reserved for repair traffic,
                            engaged in proportion to the observed degradation
                            level (an EWMA of arriving CNPs AND — under a
                            lossy channel model — of loss notifications):
                            goodput gives way to retransmissions exactly when
                            the path degrades.

Hook mapping: ``ack_view`` exposes the coalesced snapshot, ``sender_rate``
applies the selective-repeat window cap and the repair-budget reservation,
``feedback`` advances the ack ledger / coalescing timer / congestion EWMA,
and ``retx_rate`` grants the engine's loss-repair path the RESERVED budget
on top of the congestion-controlled rate — the software-defined
reliability slice that keeps repairing while DCQCN's rate is collapsed
(strictly lower repair latency than e2e dcqcn at equal loss; pinned by
test and by ``benchmarks/scheme_compare.py --impairment-grid``).
Congestion control itself stays conventional end-to-end DCQCN — SDR-RDMA is
a reliability architecture, not a CC scheme.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams
from repro.netsim.soft import lerp, reset_gate, soft_gt, soft_or, soft_pos
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeSignals, apply_link_live,
    long_haul_bdp,
)

from typing import NamedTuple

# the repair-budget reservation can never starve new data entirely
MAX_RETX_FRAC = 0.9
# soft-gate byte scale for loss-notification presence (docs/differentiable.md)
_MTU = 1500.0


class SdrRdmaState(NamedTuple):
    """Scheme-private pytree carried in ``SimState.extra``."""
    ack_cum: jax.Array           # [F] true cumulative acked bytes (per step)
    ack_held: jax.Array          # [F] coalesced snapshot the sender sees
    coalesce_timer: jax.Array    # scalar µs since the last ACK release
    cong_ewma: jax.Array         # scalar in [0,1] — CNP-arrival loss proxy


class SdrRdmaScheme(Scheme):
    """Software-defined selective-repeat reliability over e2e DCQCN."""

    # -- construction-time ------------------------------------------------
    def init_extra_state(self, cfg: NetConfig, params: NetParams,
                         num_flows: int, *, history_slots: int = 0,
                         chan_delay_pad: int = 0):
        z = jnp.zeros((num_flows,), jnp.float32)
        return SdrRdmaState(ack_cum=z, ack_held=z,
                            coalesce_timer=jnp.float32(1e9),
                            cong_ewma=jnp.float32(0.0))

    def _retx_frac(self, ctx: SchemeCtx, state):
        """Repair-budget rate share currently engaged (traced)."""
        return (jnp.clip(ctx.params.sdr_retx_budget_frac, 0.0, MAX_RETX_FRAC)
                * state.extra.cong_ewma)

    # -- per-step hooks ----------------------------------------------------
    def route_weights(self, ctx: SchemeCtx, state, base_route):
        # software-defined reliability repairs losses; it does not place
        # bytes on dead links in the first place — reroute onto survivors
        # (docs/failures.md), retransmissions included (they re-enter the
        # source queue and spray with these same weights)
        return apply_link_live(ctx, base_route)

    def ack_view(self, ctx: SchemeCtx, state, ack_arr):
        # the sender's window only sees the coalesced snapshot
        return state.extra.ack_held

    def sender_rate(self, ctx: SchemeCtx, state, base_rate):
        p = ctx.params
        swnd = p.sdr_window_bdp_frac * long_haul_bdp(ctx)
        unacked = state.sent - jnp.minimum(state.extra.ack_held, state.sent)
        sr_avail = jnp.maximum(swnd - unacked, 0.0)
        rate = jnp.minimum(state.cc.rc, base_rate)      # e2e DCQCN kept
        eff = (jnp.minimum(rate, sr_avail / ctx.dt_s)
               * (1.0 - self._retx_frac(ctx, state)))
        return jnp.where(ctx.is_inter > 0, eff, rate)

    def retx_rate(self, ctx: SchemeCtx, state, rate):
        """The software-defined reliability budget: repair gets the engaged
        reservation (a NIC-rate slice, NOT squeezed by DCQCN) on top of
        the default shared-rate service — so retransmissions keep flowing
        at full budget while congestion collapses the goodput rate."""
        return (super().retx_rate(ctx, state, rate)
                + self._retx_frac(ctx, state) * ctx.nic)

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        sd = state.extra
        # Same delayed ACK-line reading the skeleton consumed this step:
        # ``feedback`` receives the PRE-step state and the skeleton only
        # overwrites ``ack_line[t mod d_steps]`` after this hook runs, so
        # this reads each ACK batch exactly once. The golden traces pin
        # that ordering — a skeleton reorder shows up as a bit-level diff.
        ack_arr = state.ack_line[jnp.mod(sig.t, ctx.d_steps)]
        ack_cum = sd.ack_cum + ack_arr * ctx.is_inter
        timer = sd.coalesce_timer + ctx.dt_us
        if ctx.soft is None:
            fire = timer >= ctx.params.sdr_ack_coalesce_us
            held = jnp.where(fire, ack_cum, sd.ack_held)
            timer = jnp.where(fire, 0.0, timer)
        else:
            w_fire = soft_gt(timer, ctx.params.sdr_ack_coalesce_us,
                             ctx.soft, ctx.dt_us)
            held = lerp(w_fire, ack_cum, sd.ack_held)
            # detached gate in the timer's own reset (soft.reset_gate)
            timer = lerp(reset_gate(w_fire), 0.0, timer)
        # degradation EWMA (~1 ms time constant) engaging the repair
        # budget: CNP arrivals (the congestion proxy) OR actual loss
        # notifications from the channel subsystem (zeros when ideal — the
        # pre-channel pin stays bit-identical)
        if ctx.soft is None:
            hit = ((jnp.sum(sig.cnp_arr * ctx.is_inter) > 0)
                   | (jnp.sum(sig.retx_arr * ctx.is_inter) > 0)
                   ).astype(jnp.float32)
        else:
            # soft_pos is exactly 0 at 0: no CNPs and no losses keep the
            # EWMA parked at zero even in soft mode
            hit = soft_or(
                soft_pos(jnp.sum(sig.cnp_arr * ctx.is_inter), ctx.soft,
                         0.25),
                soft_pos(jnp.sum(sig.retx_arr * ctx.is_inter), ctx.soft,
                         _MTU))
        g = min(ctx.dt_us / 1000.0, 1.0)
        cong = (1.0 - g) * sd.cong_ewma + g * hit
        base = super().feedback(ctx, state, sig)   # e2e CNP routing
        return base._replace(extra=SdrRdmaState(
            ack_cum=ack_cum, ack_held=held,
            coalesce_timer=timer, cong_ewma=cong))

    def extra_traces(self, ctx: SchemeCtx, state) -> dict:
        sd = state.extra
        lag = jnp.sum(jnp.maximum(sd.ack_cum - sd.ack_held, 0.0)
                      * ctx.is_inter)
        return {"sr_ack_lag": lag,
                "sr_retx_frac": self._retx_frac(ctx, state)}

    def emit_events(self, ctx: SchemeCtx, prev_state, state, out) -> tuple:
        # the repair-budget reservation engages/releases when the
        # degradation EWMA crosses its midpoint; value = the engaged
        # NIC-rate fraction after the crossing
        e0 = prev_state.extra.cong_ewma
        e1 = state.extra.cong_ewma
        frac = (jnp.clip(ctx.params.sdr_retx_budget_frac, 0.0,
                         MAX_RETX_FRAC) * e1)
        return (("scheme_budget_on", 0, frac, (e0 < 0.5) & (e1 >= 0.5)),
                ("scheme_budget_off", 0, frac, (e0 >= 0.5) & (e1 < 0.5)))

    # -- streaming metrics -------------------------------------------------
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        return {"ack_lag_sum": jnp.float32(0.0),
                "retx_frac_sum": jnp.float32(0.0)}

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        return dict(acc,
                    ack_lag_sum=acc["ack_lag_sum"] + out["sr_ack_lag"] * inc,
                    retx_frac_sum=acc["retx_frac_sum"]
                    + out["sr_retx_frac"] * inc)

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        return {
            "mean_ack_lag_mb":
                np.asarray(acc["ack_lag_sum"]) / max(n_warm, 1) / 1e6,
            "mean_retx_reserve_frac":
                np.asarray(acc["retx_frac_sum"]) / max(n_warm, 1),
        }
