"""RDMACell-style token-gated flowcell spraying (arxiv 2606.20581).

RDMACell load-balances a long haul made of parallel unequal paths by
spraying *flowcells* (sub-flow byte bursts) across links in proportion to
per-link token buckets, and pacing senders against the destination
reorder-buffer (ROB) the spraying inevitably creates. In the fluid engine
that becomes three hook overrides on top of the conventional e2e baseline:

  * ``route_weights``  — each flow's spray weights are its workload routing
    row reweighted by the per-link token level. Tokens refill with the
    link's effective capacity and drain with the bytes offered to it, so a
    slow / paused / flapped link runs dry and traffic shifts away within a
    bucket's worth of bytes — flowcell spraying without per-packet state.
  * ``sender_rate``    — inter-DC senders are collectively throttled when
    the estimated destination ROB occupancy exceeds
    ``rdmacell_rob_limit_mb`` (the paper's ROB back-pressure).
  * ``feedback``       — advances the token buckets and the cumulative
    per-link tx/arrival ledgers the ROB estimate is computed from.

The ROB estimate is a fluid proxy for packet reordering: a flow's bytes
are deliverable in order only up to the slowest link's arrival *frontier*
(arrivals scaled by that link's share of the flow's transmissions);
everything received beyond the frontier waits in the ROB. Single-link runs
(``cfg.num_paths == 1``) carry the default extra state and inherit the
baseline hooks untouched, so ``rdmacell`` at L=1 is bit-identical to
``dcqcn`` — spraying machinery only exists where there is something to
spray across.

Knobs (``NetConfig``): ``rdmacell_token_bucket_us`` (bucket depth in µs of
link capacity) and ``rdmacell_rob_limit_mb`` (ROB back-pressure threshold).
Streamed columns: ``mean_reorder_buf_mb``, ``spray_entropy``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig, NetParams
from repro.core.matchrdma import MatchRdmaState
from repro.netsim.soft import lerp, soft_pos
from repro.netsim.schemes.base import (
    Feedback, Scheme, SchemeCtx, SchemeSignals, apply_link_live,
)

# soft dry-gate byte scale (docs/differentiable.md)
_MTU = 1500.0


class RdmaCellState(NamedTuple):
    """Spraying state carried in ``SimState.extra`` (multi-link runs only)."""
    mr: MatchRdmaState     # the shared budget block (budget traces for free)
    tokens: jax.Array      # f32[L] — per-link spray tokens, bytes
    tx_cum: jax.Array      # f32[L, F] — cumulative bytes sprayed per link
    arr_cum: jax.Array     # f32[L, F] — cumulative bytes arrived per link


def _rob_bytes(ex: RdmaCellState) -> jax.Array:
    """[F] estimated destination reorder-buffer occupancy per flow.

    A link's arrival *frontier* for a flow is its cumulative arrivals
    scaled up by the inverse of that link's share of the flow's
    transmissions — the total flow prefix that link's deliveries can
    cover. In-order delivery is bounded by the slowest frontier; arrived
    bytes beyond it sit in the ROB.
    """
    tx_tot = jnp.sum(ex.tx_cum, axis=0)                      # [F]
    arr_tot = jnp.sum(ex.arr_cum, axis=0)                    # [F]
    share = ex.tx_cum / jnp.maximum(tx_tot[None, :], 1.0)    # [L, F]
    est = jnp.where(share > 1e-6,
                    ex.arr_cum / jnp.maximum(share, 1e-6),
                    jnp.inf)
    frontier = jnp.min(est, axis=0)                          # [F]
    frontier = jnp.where(jnp.isfinite(frontier), frontier, arr_tot)
    return jnp.maximum(arr_tot - jnp.minimum(frontier, arr_tot), 0.0)


class RdmaCellScheme(Scheme):
    """Token-gated flowcell spraying with ROB back-pressure."""

    # -- construction ------------------------------------------------------
    def init_extra_state(self, cfg: NetConfig, params: NetParams,
                         num_flows: int, *, history_slots: int = 0,
                         chan_delay_pad: int = 0):
        mr = super().init_extra_state(cfg, params, num_flows,
                                      history_slots=history_slots,
                                      chan_delay_pad=chan_delay_pad)
        if cfg.num_paths <= 1:
            return mr  # single pipe: be the baseline, bit-for-bit
        link_caps = params.link_cap_gbps * 1e9 / 8.0         # [L] bytes/s
        tokens = params.rdmacell_token_bucket_us * 1e-6 * link_caps
        return RdmaCellState(
            mr=mr,
            tokens=tokens.astype(jnp.float32),
            tx_cum=jnp.zeros((cfg.num_paths, num_flows), jnp.float32),
            arr_cum=jnp.zeros((cfg.num_paths, num_flows), jnp.float32),
        )

    # -- datapath ----------------------------------------------------------
    def route_weights(self, ctx: SchemeCtx, state, base_route):
        ex = state.extra
        if not isinstance(ex, RdmaCellState):
            return apply_link_live(ctx, base_route)
        tok = jnp.maximum(ex.tokens, 0.0)
        # all buckets dry (transient): fall back to the workload's own
        # weights rather than parking traffic in the source OTN. During
        # an outage only the SURVIVING links' tokens count toward the dry
        # condition — a dead link's full bucket must neither attract
        # traffic nor mask an otherwise-dry spray (docs/failures.md).
        live_tok = (jnp.sum(tok * ctx.link_live) if ctx.link_live is not None
                    else jnp.sum(tok))
        if ctx.soft is None:
            dry = live_tok <= 0.0
            tok = jnp.where(dry, jnp.ones_like(tok), tok)
        else:
            # tempered dry gate: soft_pos is exactly 0 at 0, so a fully
            # dry spray still blends all the way to the uniform fallback
            w_dry = 1.0 - soft_pos(live_tok, ctx.soft, _MTU)
            tok = lerp(w_dry, jnp.ones_like(tok), tok)
        return apply_link_live(ctx, base_route * tok[None, :])

    def sender_rate(self, ctx: SchemeCtx, state, base_rate):
        rate = super().sender_rate(ctx, state, base_rate)
        ex = state.extra
        if not isinstance(ex, RdmaCellState):
            return rate
        rob_tot = jnp.sum(_rob_bytes(ex) * ctx.is_inter)
        limit = ctx.params.rdmacell_rob_limit_mb * 1e6
        gate = jnp.where(rob_tot > limit,
                         limit / jnp.maximum(rob_tot, 1.0), 1.0)
        return jnp.where(ctx.is_inter > 0, rate * gate, rate)

    def feedback(self, ctx: SchemeCtx, state, sig: SchemeSignals) -> Feedback:
        fb = super().feedback(ctx, state, sig)
        ex = state.extra
        if not isinstance(ex, RdmaCellState):
            return fb
        bucket = ctx.params.rdmacell_token_bucket_us * 1e-6 * ctx.link_caps
        # refill with what the link could carry, drain with what was
        # offered to it — persistent over-offering runs the bucket dry.
        tokens = jnp.clip(ex.tokens + sig.link_cap - sig.link_want,
                          0.0, bucket)
        return fb._replace(extra=ex._replace(
            tokens=tokens,
            tx_cum=ex.tx_cum + sig.link_sent,
            arr_cum=ex.arr_cum + sig.link_arrivals,
        ))

    # -- traces ------------------------------------------------------------
    def extra_traces(self, ctx: SchemeCtx, state) -> dict:
        ex = state.extra
        if not isinstance(ex, RdmaCellState):
            return super().extra_traces(ctx, state)
        return {
            "budget": ex.mr.budget.budget,
            "budget_at_src": ex.mr.budget_at_src,
            "rdmacell_rob_mb": jnp.sum(_rob_bytes(ex) * ctx.is_inter) / 1e6,
            "rdmacell_tokens_mb": jnp.sum(ex.tokens) / 1e6,
        }

    # -- streaming metrics -------------------------------------------------
    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        ex = state.extra
        if not isinstance(ex, RdmaCellState):
            return super().init_metric_acc(ctx, state)
        return {
            "budget_sum": jnp.float32(0.0),
            "rob_sum": jnp.float32(0.0),
            "tx_by_link": jnp.zeros_like(ex.tokens),
        }

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        if "rob_sum" not in acc:
            return super().accumulate_metrics(ctx, acc, state, out, inc)
        ex = state.extra
        rob = jnp.sum(_rob_bytes(ex) * ctx.is_inter)
        return dict(acc,
                    budget_sum=acc["budget_sum"]
                    + ex.mr.budget.budget * inc,
                    rob_sum=acc["rob_sum"] + rob * inc,
                    tx_by_link=jnp.sum(ex.tx_cum, axis=1))

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        if "rob_sum" not in acc:
            return super().finalize_metrics(acc, n_steps, n_warm)
        cols = {
            "mean_budget_gbps": np.asarray(acc["budget_sum"])
            / max(n_warm, 1) * 8.0 / 1e9,
            "mean_reorder_buf_mb": np.asarray(acc["rob_sum"])
            / max(n_warm, 1) / 1e6,
        }
        tx = np.asarray(acc["tx_by_link"])
        batched = tx.ndim == 2
        tx = np.atleast_2d(tx)                                # [B, L]
        tot = np.maximum(tx.sum(axis=1, keepdims=True), 1.0)
        p = tx / tot
        with np.errstate(divide="ignore", invalid="ignore"):
            h = -np.where(p > 0.0, p * np.log(np.maximum(p, 1e-30)),
                          0.0).sum(axis=1)
        L = tx.shape[1]
        # normalized to [0, 1]: 1 = perfectly even spray, 0 = one link
        # (or no traffic at all).
        ent = h / np.log(L) if L > 1 else np.zeros(tx.shape[0])
        ent = np.where(tx.sum(axis=1) > 0.0, ent, 0.0)
        cols["spray_entropy"] = ent if batched else float(ent[0])
        return cols
