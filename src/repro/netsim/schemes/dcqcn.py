"""Conventional end-to-end baselines: DCQCN and the THEMIS-like variant.

``dcqcn`` is exactly the ``Scheme`` default hook set — CNPs and ACKs ride
the full sender↔receiver path, the sender runs stock DCQCN, the source OTN
is a FIFO. ``themis`` differs only in the RTT-fairness-corrected DCQCN
gains (ICNP'25-like): long-haul flows increase faster / cut softer so the
short intra-DC feedback loop cannot starve them.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cc_proxy import themis_rtt_scale
from repro.netsim.schemes.base import Scheme, SchemeCtx, apply_link_live


class DcqcnScheme(Scheme):
    """Conventional e2e RDMA — the paper's primary baseline.

    Streams the mean inter-DC DCQCN sender rate (the quantity the
    long-feedback-loop bottleneck suppresses) as ``mean_cc_rate_gbps``.
    """

    def route_weights(self, ctx: SchemeCtx, state, base_route):
        # e2e baselines spray exactly as the workload asked, minus links
        # the failure schedule killed this step (docs/failures.md);
        # themis inherits this unchanged
        return apply_link_live(ctx, base_route)

    def init_metric_acc(self, ctx: SchemeCtx, state) -> dict:
        return dict(super().init_metric_acc(ctx, state),
                    cc_rate_sum=jnp.float32(0.0))

    def accumulate_metrics(self, ctx: SchemeCtx, acc, state, out, inc):
        acc = super().accumulate_metrics(ctx, acc, state, out, inc)
        n_inter = jnp.maximum(jnp.sum(ctx.is_inter), 1.0)
        rc = jnp.sum(state.cc.rc * ctx.is_inter) / n_inter
        return dict(acc, cc_rate_sum=acc["cc_rate_sum"] + rc * inc)

    def finalize_metrics(self, acc: dict, n_steps: int, n_warm: int) -> dict:
        cols = super().finalize_metrics(acc, n_steps, n_warm)
        cols["mean_cc_rate_gbps"] = (np.asarray(acc["cc_rate_sum"])
                                     / max(n_warm, 1) * 8.0 / 1e9)
        return cols


class ThemisScheme(DcqcnScheme):
    """e2e RDMA with RTT-fairness-corrected DCQCN gains."""

    def rtt_scale(self, ctx: SchemeCtx):
        return themis_rtt_scale(ctx.rtt_us)
