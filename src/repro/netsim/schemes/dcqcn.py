"""Conventional end-to-end baselines: DCQCN and the THEMIS-like variant.

``dcqcn`` is exactly the ``Scheme`` default hook set — CNPs and ACKs ride
the full sender↔receiver path, the sender runs stock DCQCN, the source OTN
is a FIFO. ``themis`` differs only in the RTT-fairness-corrected DCQCN
gains (ICNP'25-like): long-haul flows increase faster / cut softer so the
short intra-DC feedback loop cannot starve them.
"""
from __future__ import annotations

from repro.core.cc_proxy import themis_rtt_scale
from repro.netsim.schemes.base import Scheme, SchemeCtx


class DcqcnScheme(Scheme):
    """Conventional e2e RDMA — the paper's primary baseline."""


class ThemisScheme(Scheme):
    """e2e RDMA with RTT-fairness-corrected DCQCN gains."""

    def rtt_scale(self, ctx: SchemeCtx):
        return themis_rtt_scale(ctx.rtt_us)
