"""Gradient compression for the inter-pod (inter-DC) hop.

int8 per-chunk-scaled quantization with error feedback: the quantization
residual is carried in optimizer-adjacent state and added back before the
next step's quantization, making the compressed reduction unbiased over
time (Seide et al. / Karimireddy et al. error-feedback results).

Only the POD-axis exchange is compressed — intra-pod reductions ride the
full-bandwidth ICI and stay exact. bf16 -> int8 halves the bytes crossing
the OTN; the MatchRDMA step-time model prices exactly that.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8 quantization. Returns (q int8, scales f32)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Quantize (g + err); return (q, scale, new_err). new_err is the
    residual g_corrected - dequant(q)."""
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape, jnp.float32)
    new_err = corrected - deq
    return q, scale, new_err.astype(err.dtype)


def compressed_psum(x: jax.Array, axis_name: str, err: jax.Array):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Each participant quantizes (x+err) to int8, all-gathers the int8 payload
    + scales (1/8th + epsilon of the bf16 bytes per peer), and locally
    dequant-sums. Returns (sum, new_err)."""
    q, scale, new_err = compress_with_feedback(x, err)
    q_all = jax.lax.all_gather(q, axis_name)          # [npods, chunks, CHUNK]
    s_all = jax.lax.all_gather(scale, axis_name)      # [npods, chunks]
    deq = (q_all.astype(jnp.float32) * s_all[..., None]).sum(axis=0)
    flat = deq.reshape(-1)
    n = 1
    for s in x.shape:
        n *= s
    return flat[:n].reshape(x.shape).astype(x.dtype), new_err
