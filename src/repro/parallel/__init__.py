from repro.parallel.collectives import (
    hierarchical_grad_reduce, inter_pod_bytes_per_step,
    make_hierarchical_allreduce,
)
from repro.parallel.compat import (
    axis_size, get_ambient_mesh, make_mesh, set_mesh, shard_map,
)
from repro.parallel.compression import (
    compress_with_feedback, compressed_psum, dequantize_int8, quantize_int8,
)
from repro.parallel.sharding import ShardingRules, named

__all__ = [
    "hierarchical_grad_reduce", "inter_pod_bytes_per_step",
    "make_hierarchical_allreduce", "compress_with_feedback", "compressed_psum",
    "dequantize_int8", "quantize_int8", "ShardingRules", "named",
    "axis_size", "get_ambient_mesh", "make_mesh", "set_mesh", "shard_map",
]
