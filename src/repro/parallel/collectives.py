"""Pod-aware collectives: hierarchical gradient reduction for geo-distributed
training (the framework-level MatchRDMA integration).

The pattern that minimizes inter-DC bytes (DESIGN.md §6):

    reduce-scatter intra-pod  (ICI, full bandwidth)
    all-reduce inter-pod      (OTN — only 1/(data*model) of the gradient per
                               chip crosses the long-haul link; optionally
                               int8-compressed with error feedback)
    all-gather intra-pod      (ICI)

Implemented with ``shard_map`` over the production mesh (through the
version-compat shim in ``repro.parallel.compat`` — ``jax.shard_map`` on
new JAX, ``jax.experimental.shard_map`` on 0.4.x). Used by the geo train
step and unit-tested on a host-device mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import axis_size, shard_map
from repro.parallel.compression import compressed_psum


def hierarchical_grad_reduce(g: jax.Array, *, pod_axis: str = "pod",
                             intra_axis: str = "data",
                             compress: bool = False,
                             err: Optional[jax.Array] = None):
    """Inside shard_map: mean-reduce ``g`` over (pod_axis, intra_axis).

    Equivalent to psum(g)/(n_pod*n_intra) but structured so only the
    scattered shard crosses the pod axis. Returns (g_mean, new_err).
    """
    n_intra = axis_size(intra_axis)
    n_pod = axis_size(pod_axis)

    # 1) reduce-scatter intra-pod along a padded leading dim
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n_intra
    flat = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(flat.reshape(n_intra, -1), intra_axis,
                                 scatter_dimension=0, tiled=False)
    # shard: this chip's 1/n_intra piece, summed over the pod's data axis

    # 2) inter-pod exchange on the shard only
    if compress:
        if err is None:
            err = jnp.zeros(g.shape, jnp.float32)
        # error-feedback residual lives at shard granularity; keep the
        # caller-facing state full-size (replicated) for simplicity
        idx = jax.lax.axis_index(intra_axis)
        err_pad = jnp.pad(err.reshape(-1).astype(jnp.float32), (0, pad))
        err_shard = err_pad.reshape(n_intra, -1)[idx]
        shard, new_err_shard = compressed_psum(shard, pod_axis, err_shard)
        new_err = (jax.lax.all_gather(new_err_shard, intra_axis)
                   .reshape(-1)[: err.size].reshape(err.shape)
                   .astype(err.dtype))
    else:
        shard = jax.lax.psum(shard, pod_axis)
        new_err = err

    # 3) all-gather intra-pod
    full = jax.lax.all_gather(shard, intra_axis)      # [n_intra, piece]
    out = full.reshape(-1)[: g.size].reshape(g.shape)
    return out / (n_intra * n_pod), new_err


def make_hierarchical_allreduce(mesh: Mesh, *, compress: bool = False):
    """jit-able tree all-reduce-mean over ("pod","data") for grads that are
    replicated over those axes inside a shard_map region."""

    pspec = P()  # grads replicated over pod/data in this demonstration path

    @partial(shard_map, mesh=mesh, in_specs=(pspec, pspec),
             out_specs=(pspec, pspec), check_vma=False)
    def _reduce_one(g, err):
        out, new_err = hierarchical_grad_reduce(
            g, compress=compress, err=err)
        return out, (new_err if new_err is not None else err)

    def reduce_tree(grads, errs):
        flat_g, tree = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errs)
        outs, new_errs = [], []
        for g, e in zip(flat_g, flat_e):
            o, ne = _reduce_one(g, e)
            outs.append(o)
            new_errs.append(ne)
        return tree.unflatten(outs), tree.unflatten(new_errs)

    return reduce_tree


def inter_pod_bytes_per_step(num_params: int, *, bytes_per_el: int = 2,
                             compress: bool = False, pods: int = 2) -> float:
    """Analytic bytes crossing the OTN per training step under the
    hierarchical exchange (cross-check for the HLO parse + netsim feed)."""
    per_el = bytes_per_el * (0.5 if compress else 1.0)
    # all-gather-based exchange: each pod ships its full scattered gradient
    # once per peer direction: (pods-1)/pods * P elements out per pod
    return num_params * per_el * (pods - 1) / pods * 2.0
