"""Sharding rules: map every param / activation / cache leaf to a PartitionSpec.

Layout (Megatron 2D + optional FSDP/ZeRO-3):
  * "model" axis shards heads (attention), d_ff (MLP), experts (MoE),
    d_inner (SSD), rnn width (RG-LRU) and the vocab dim of the embeddings.
  * "data" axis (optionally) shards the OTHER weight dim when fsdp=True —
    ZeRO-3: params + optimizer state fully sharded over data*model.
  * "pod" axis replicates params (a pod = an AI-DC; inter-pod traffic is the
    gradient exchange only — the MatchRDMA-motivated design decision).
  * batch is sharded over ("pod","data"); heads-dims shard over "model" only
    when divisible (e.g. recurrentgemma's 10 heads stay replicated).

Rules are keyed by leaf *path name* — stable because param trees are built by
repro.models with fixed key names.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, ParallelConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingRules:
    """Resolves PartitionSpecs for one (model, parallel) configuration."""

    def __init__(self, model: ModelConfig, par: ParallelConfig):
        self.model = model
        self.par = par
        self.fsdp = "data" if par.fsdp else None
        self.n_model = par.model
        # shard the HEADS dim itself (cache layout [B,S,H,hd] and the
        # per-head compute both need head-count divisibility)
        self.q_shardable = _div(model.num_heads, self.n_model)
        self.kv_shardable = _div(model.num_kv_heads, self.n_model)
        self.ff_shardable = _div(model.d_ff, self.n_model)
        self.vocab_shardable = _div(model.vocab_size, self.n_model)
        # grouped (per-batch-row) MoE dispatch keeps routing local to the
        # data shard; expert weights are REPLICATED over "model" (EP -> DP)
        # so no token ever crosses a mesh axis for routing. The FSDP axis
        # still shards them when enabled.
        self.experts_shardable = (_div(model.num_experts, self.n_model)
                                  and not model.moe_group_by_batch)
        d_in = model.ssm_expand * model.d_model
        self.ssd_shardable = _div(d_in, self.n_model) and _div(
            d_in // max(model.ssm_headdim, 1), self.n_model)
        w = model.rglru_width or model.d_model
        self.rglru_shardable = _div(w, self.n_model)

    # -- param rules -------------------------------------------------------
    def param_spec(self, path: str, ndim: int) -> P:
        """path: '/'-joined key names, e.g. 'backbone/groups/0/attn/wq'."""
        name = path.split("/")[-1]
        stacked = "/groups/" in path  # leading layer-stack dim
        lead = (None,) if stacked else ()
        mdl = "model"
        f = self.fsdp

        def spec(*dims):
            return P(*lead, *dims)

        # embeddings
        if name == "tok":
            return P(mdl if self.vocab_shardable else None, f)
        if name == "unembed":
            return P(f, mdl if self.vocab_shardable else None)
        # attention
        if name in ("wq",):
            return spec(f, mdl if self.q_shardable else None)
        if name in ("wk", "wv"):
            return spec(f, mdl if self.kv_shardable else None)
        if name == "wo":
            return spec(mdl if self.q_shardable else None, f)
        if name in ("bq",):
            return spec(mdl if self.q_shardable else None)
        if name in ("bk", "bv"):
            return spec(mdl if self.kv_shardable else None)
        # dense MLP
        if name in ("w_gate", "w_up") and ndim - len(lead) == 2:
            return spec(f, mdl if self.ff_shardable else None)
        if name == "w_down" and ndim - len(lead) == 2:
            return spec(mdl if self.ff_shardable else None, f)
        # MoE experts [E, d, f] / [E, f, d]; router [d, E]
        if name in ("w_gate", "w_up") and ndim - len(lead) == 3:
            return spec(mdl if self.experts_shardable else None, f, None)
        if name == "w_down" and ndim - len(lead) == 3:
            return spec(mdl if self.experts_shardable else None, None, f)
        if name == "router":
            return spec(f, None)
        # SSD (Mamba2)
        if name in ("w_z", "w_x"):
            return spec(f, mdl if self.ssd_shardable else None)
        if name in ("w_bc", "w_dt"):
            return spec(f, None)
        if name in ("conv_x_w",):
            return spec(None, mdl if self.ssd_shardable else None)
        if name in ("conv_x_b", "norm_scale"):
            return spec(mdl if self.ssd_shardable else None)
        if name in ("conv_bc_w", "conv_bc_b"):
            return spec(*([None] * (ndim - len(lead))))
        if name in ("A_log", "D", "dt_bias"):
            return spec(mdl if self.ssd_shardable else None)
        if name == "w_out" and "ssd" in path:
            return spec(mdl if self.ssd_shardable else None, f)
        # RG-LRU
        if "rglru" in path:
            r = mdl if self.rglru_shardable else None
            if name in ("w_x", "w_gate"):
                return spec(f, r)
            if name in ("w_a", "w_i"):
                return spec(None, r)
            if name in ("conv_w",):
                return spec(None, r)
            if name in ("conv_b", "b_a", "b_i", "lam"):
                return spec(r)
            if name == "w_out":
                return spec(r, f)
        # norms / scalars / anything else: replicated (layer-stacked keeps lead)
        return spec(*([None] * (ndim - len(lead))))

    def params_tree_specs(self, params) -> object:
        """PartitionSpec tree matching a param pytree."""
        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + "/" + k) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                out = [walk(v, prefix + f"/{i}") for i, v in enumerate(tree)]
                return type(tree)(out)
            return self.param_spec(prefix, np.ndim(tree))
        return walk(params, "")

    # -- activation / batch rules ------------------------------------------
    def batch_axes(self):
        return self.par.batch_axes()

    def data_spec(self, ndim: int) -> P:
        """Input batches: batch dim sharded over (pod, data)."""
        return P(self.batch_axes(), *([None] * (ndim - 1)))

    def hidden_spec(self) -> P:
        return P(self.batch_axes(), None, None)

    # -- KV cache rules ------------------------------------------------------
    def cache_spec(self, path: str, ndim: int) -> P:
        """Decode caches. Attention k/v: [G?, B, S, Hk, hd] — batch over data,
        then kv-heads over model if divisible, else SEQUENCE over model
        (flash-decode layout). SSM/RG-LRU states: batch over data only."""
        stacked = "/groups/" in path
        lead = (None,) if stacked else ()
        name = path.split("/")[-1]
        b = self.batch_axes()
        if name == "k" and self.model.decode_k_time_minor:
            # time-minor K: [B, Hk, hd, S]
            if self.kv_shardable:
                return P(*lead, b, "model", None, None)
            if self.par.shard_cache_seq:
                return P(*lead, b, None, None, "model")
            return P(*lead, b, None, None, None)
        if name in ("k", "v"):
            if self.kv_shardable:
                return P(*lead, b, None, "model", None)
            if self.par.shard_cache_seq:
                return P(*lead, b, "model", None, None)
            return P(*lead, b, None, None, None)
        # ssm / conv / rglru states: [B, ...]
        rest = ndim - len(lead) - 1
        return P(*lead, b, *([None] * rest))

    def cache_tree_specs(self, caches) -> object:
        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, prefix + "/" + k) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                out = [walk(v, prefix + f"/{i}") for i, v in enumerate(tree)]
                return type(tree)(out)
            return self.cache_spec(prefix, np.ndim(tree))
        return walk(caches, "")


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
