"""Version-compatible shard_map / mesh-context shims.

The multi-device code targets two JAX generations:

  * JAX >= 0.5/0.6: ``jax.shard_map`` (kwarg ``check_vma``),
    ``jax.set_mesh`` as the ambient-mesh context, and
    ``jax.sharding.get_abstract_mesh()`` to read it back.
  * JAX 0.4.x (the pinned CI install): ``jax.experimental.shard_map``
    (kwarg ``check_rep``), the ``Mesh`` object itself as the context
    manager, and the thread-resources physical mesh as the ambient mesh.

Everything multi-device in this repo goes through the three shims below so
``repro.parallel`` collectives, the grouped-MoE shard_map path and the
subprocess tests run (not skip) on either generation. Import stays cheap:
feature detection is attribute probing only — no device/backend
initialization at import time.
"""
from __future__ import annotations

from functools import partial

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on
    0.4.x (where the replication-check kwarg is ``check_rep``). Usable as
    a decorator factory like ``functools.partial(jax.shard_map, ...)``.
    ``check_vma`` defaults to True like upstream — the shim is a drop-in,
    it never silently weakens the replication check."""
    if f is None:
        return partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=check_vma)
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis_name):
    """STATIC size of a named mesh axis inside a shard_map region.
    ``jax.lax.axis_size`` only exists on newer JAX; on 0.4.x
    ``lax.psum(1, axis)`` constant-folds to the same Python int."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def set_mesh(mesh):
    """The ambient-mesh context manager: ``jax.set_mesh`` on new JAX; on
    0.4.x entering the ``Mesh`` itself sets the thread-resources physical
    mesh (which ``get_ambient_mesh`` reads back)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_ambient_mesh():
    """The mesh set by ``set_mesh``, or None when outside any context.
    New JAX: ``jax.sharding.get_abstract_mesh()``; 0.4.x: the
    thread-resources physical mesh."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        return None if (mesh is None or mesh.empty) else mesh
    try:  # pragma: no cover - 0.4.x path, exercised by the subprocess tests
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except ImportError:
        return None
    return None if (mesh is None or mesh.empty) else mesh


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    JAX supports them (>= 0.5; on 0.4.x every axis is implicitly Auto)."""
    from repro.launch.mesh import _axis_type_kwargs
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         **_axis_type_kwargs(len(tuple(axis_names))))
