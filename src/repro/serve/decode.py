"""Serving: prefill + batched greedy decode with donated caches.

``make_serve_step`` builds the jitted single-token step used by the
decode_32k / long_500k dry-run cells: one new token against a cache of
``max_len``, cache donated (in-place update — no double allocation in the
memory analysis).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config.base import ModelConfig, ParallelConfig
from repro.models.model import Model
from repro.parallel.sharding import ShardingRules, named
from repro.serve.kvcache import cache_shardings


def make_serve_step(model: Model, par: ParallelConfig, mesh: Mesh,
                    batch: int, max_len: int):
    """Returns jitted step(params, caches, inp, pos) -> (caches, token)."""
    rules = ShardingRules(model.cfg, par)

    def step(params, caches, inp, pos):
        caches, logits = model.decode_step(params, caches, inp, pos)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return caches, token

    cache_sh, _ = cache_shardings(model.cfg, par, mesh, batch, max_len)
    params_specs = rules.params_tree_specs  # resolved at jit time by caller
    return step, cache_sh, rules


def greedy_generate(model: Model, params, prompt: jax.Array, *,
                    max_new: int = 32, max_len: int = 0):
    """Single-host convenience loop (examples/tests): prefill then decode."""
    b, s = prompt.shape[0], prompt.shape[1]
    max_len = max_len or (s + max_new)
    caches, logits = model.prefill(params, prompt, max_len=max_len)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [token]
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    for t in range(max_new - 1):
        caches, logits = decode(params, caches, token, jnp.int32(s + t))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(token)
    return jnp.stack(out, axis=1)
