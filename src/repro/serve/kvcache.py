"""KV-cache utilities for serving: allocation, sharding specs, shape specs.

Cache layout mirrors the backbone's grouped/scanned structure
(repro.models.transformer.init_caches): attention caches [G, B, Smax, Hk, hd],
SSM/RG-LRU O(1) states. Sharding: batch over ("pod","data"); kv-heads over
"model" when divisible, else the SEQUENCE dim (flash-decode layout) — see
ShardingRules.cache_spec.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ParallelConfig
from repro.models.transformer import init_caches
from repro.parallel.sharding import ShardingRules, named


def cache_shape_specs(model: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree of the cache (no allocation) via eval_shape."""
    return jax.eval_shape(lambda: init_caches(model, batch, max_len, dtype))


def cache_shardings(model: ModelConfig, par: ParallelConfig, mesh,
                    batch: int, max_len: int, dtype=jnp.bfloat16):
    rules = ShardingRules(model, par)
    specs = cache_shape_specs(model, batch, max_len, dtype)
    spec_tree = rules.cache_tree_specs(specs)
    return named(mesh, spec_tree), spec_tree


def cache_bytes(model: ModelConfig, batch: int, max_len: int) -> int:
    specs = cache_shape_specs(model, batch, max_len)
    return sum(int(s.size) * s.dtype.itemsize for s in jax.tree.leaves(specs))
