from repro.serve.decode import greedy_generate, make_serve_step
from repro.serve.kvcache import cache_bytes, cache_shape_specs, cache_shardings

__all__ = ["greedy_generate", "make_serve_step", "cache_bytes",
           "cache_shape_specs", "cache_shardings"]
