"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation. The dry-run lowers directly
from these.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config.base import ModelConfig, ParallelConfig, ShapeSpec
from repro.models.model import Model
from repro.models.transformer import init_caches
from repro.parallel.sharding import ShardingRules
from repro.train.optimizer import init_adam

SDS = jax.ShapeDtypeStruct


def _batch_axes_or_none(par: ParallelConfig, batch: int):
    """Batch sharding axes only when the batch divides them (long_500k has
    global_batch=1 -> replicate)."""
    axes = par.batch_axes()
    import numpy as np
    n = {"pod": par.pods, "data": par.data, "model": par.model}
    total = 1
    for a in axes:
        total *= n[a]
    return axes if batch % total == 0 else None


def train_input_specs(model: ModelConfig, par: ParallelConfig,
                      shape: ShapeSpec) -> Tuple[Dict[str, Any], Dict[str, P]]:
    b, s = shape.global_batch, shape.seq_len
    axes = _batch_axes_or_none(par, b)
    bspec = P(axes) if axes else P()
    specs, pspecs = {}, {}
    if model.embed_inputs:
        specs["tokens"] = SDS((b, s), jnp.int32)
        pspecs["tokens"] = P(axes, None) if axes else P(None, None)
    else:
        specs["embeds"] = SDS((b, s, model.d_model), jnp.dtype(model.act_dtype))
        pspecs["embeds"] = P(axes, None, None) if axes else P(None, None, None)
    specs["labels"] = SDS((b, s), jnp.int32)
    pspecs["labels"] = P(axes, None) if axes else P(None, None)
    return specs, pspecs


def prefill_input_specs(model: ModelConfig, par: ParallelConfig,
                        shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    axes = _batch_axes_or_none(par, b)
    if model.embed_inputs:
        spec = SDS((b, s), jnp.int32)
        pspec = P(axes, None) if axes else P(None, None)
    else:
        spec = SDS((b, s, model.d_model), jnp.dtype(model.act_dtype))
        pspec = P(axes, None, None) if axes else P(None, None, None)
    return spec, pspec


def decode_input_specs(model: ModelConfig, par: ParallelConfig,
                       shape: ShapeSpec):
    """(cache_specs, cache_pspecs, inp_spec, inp_pspec, pos_spec)."""
    b, s = shape.global_batch, shape.seq_len
    axes = _batch_axes_or_none(par, b)
    rules = ShardingRules(model, par)
    cache = jax.eval_shape(
        lambda: init_caches(model, b, s, jnp.dtype(model.act_dtype)))
    cache_pspecs = rules.cache_tree_specs(cache)
    if axes is None:
        # replicate batch dim everywhere
        cache_pspecs = jax.tree.map(
            lambda sp: P(*[None if (isinstance(ax, tuple) or ax in ("pod", "data")) else ax
                           for ax in sp]),
            cache_pspecs, is_leaf=lambda x: isinstance(x, P))
    if model.embed_inputs:
        inp = SDS((b,), jnp.int32)
        inp_p = P(axes) if axes else P()
    else:
        inp = SDS((b, 1, model.d_model), jnp.dtype(model.act_dtype))
        inp_p = P(axes, None, None) if axes else P(None, None, None)
    pos = SDS((), jnp.int32)
    return cache, cache_pspecs, inp, inp_p, pos


def params_and_opt_specs(modelobj: Model, par: ParallelConfig,
                         with_opt: bool = True):
    """ShapeDtypeStruct trees + PartitionSpec trees for params (+ AdamState)."""
    params = jax.eval_shape(lambda: modelobj.init(jax.random.PRNGKey(0)))
    rules = ShardingRules(modelobj.cfg, par)
    pspecs = rules.params_tree_specs(params)
    if not with_opt:
        return params, pspecs, None, None
    opt = jax.eval_shape(lambda p: init_adam(p, par.opt_state_dtype), params)
    from repro.train.optimizer import AdamState
    opt_pspecs = AdamState(step=P(), m=pspecs, v=pspecs)
    return params, pspecs, opt, opt_pspecs
