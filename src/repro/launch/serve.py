"""Serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_model_config
from repro.models import build_model
from repro.serve.decode import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if cfg.embed_inputs:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
    else:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    if cfg.embed_inputs:
        out = greedy_generate(model, params, prompt, max_new=args.max_new)
        dt = time.time() - t0
        print(f"generated {out.shape} tokens in {dt:.2f}s "
              f"({args.batch * args.max_new / dt:.1f} tok/s)")
        print("sample:", out[0, :16].tolist())
    else:
        caches, logits = model.prefill(params, prompt,
                                       max_len=args.prompt_len + args.max_new)
        toks = [jnp.argmax(logits, -1)]
        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        emb = prompt[:, -1:]
        for t in range(args.max_new - 1):
            caches, logits = decode(params, caches, emb,
                                    jnp.int32(args.prompt_len + t))
            toks.append(jnp.argmax(logits, -1))
        dt = time.time() - t0
        print(f"decoded {args.max_new} steps in {dt:.2f}s")
        print("sample:", jnp.stack(toks, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
