"""End-to-end training driver.

Runs a real training loop on the available devices (CPU here; the same code
path drives a TPU pod slice): config -> mesh -> sharded init -> jitted
train_step -> checkpointed, fault-tolerant loop with straggler monitoring.

Examples
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_model_config
from repro.config.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_mesh_for
from repro.parallel.compat import set_mesh
from repro.models import build_model
from repro.parallel.sharding import ShardingRules, named
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.elastic import FailureRecovery, StragglerMonitor
from repro.train.optimizer import adam_update, clip_by_global_norm, init_adam
from repro.train.train_step import batch_specs


def build(arch: str, smoke: bool, par: ParallelConfig, train_cfg: TrainConfig):
    model_cfg = get_model_config(arch, smoke=smoke)
    model = build_model(model_cfg, remat=par.remat)
    mesh = make_mesh_for(par, devices=np.array(jax.devices()[:par.num_devices]))
    rules = ShardingRules(model_cfg, par)

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        params, opt_state, om = adam_update(params, grads, opt_state, train_cfg)
        out = {"loss": metrics["loss"], "ce": metrics["ce"],
               "grad_norm": gnorm, **om}
        return params, opt_state, out

    return model, model_cfg, mesh, rules, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    par = ParallelConfig(multi_pod=False, data=args.data, model=args.model)
    train_cfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                            lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 10, 1),
                            ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every)
    model, model_cfg, mesh, rules, step_fn = build(
        args.arch, args.smoke, par, train_cfg)

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(train_cfg.seed))
        opt = init_adam(params, par.opt_state_dtype)
        pspecs = rules.params_tree_specs(params)
        from repro.train.optimizer import AdamState
        from jax.sharding import PartitionSpec as P
        opt_specs = AdamState(step=P(), m=pspecs, v=pspecs)
        params = jax.device_put(params, named(mesh, pspecs))
        opt = jax.device_put(opt, named(mesh, opt_specs))
        bspec = named(mesh, batch_specs(model_cfg, rules))
        data = SyntheticDataset(model_cfg, train_cfg, sharding=bspec)
        ckpt = CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.ckpt_keep)
        monitor = StragglerMonitor()

        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        state = {"params": params, "opt": opt}

        def run(start: int) -> int:
            step = start
            while step < train_cfg.total_steps:
                t0 = time.time()
                batch = data.batch_at(step)
                state["params"], state["opt"], metrics = jstep(
                    state["params"], state["opt"], batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                verdict = monitor.observe(dt)
                step += 1
                if step % args.log_every == 0 or step == 1:
                    print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                          f"ce {float(metrics['ce']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                          f"{' [' + verdict + ']' if verdict != 'ok' else ''}",
                          flush=True)
                if step % train_cfg.ckpt_every == 0:
                    ckpt.save(step, state)
            return step

        recovery = FailureRecovery(ckpt, max_restarts=train_cfg.max_restarts)
        start = 0
        latest = ckpt.latest_step()
        if latest is not None:
            start, state = ckpt.restore(latest, state)
            print(f"resumed from checkpoint step {start}")
        final = recovery.run(run, start, train_cfg.total_steps)
        ckpt.save(final, state)
        ckpt.wait()
        print(f"done at step {final}")
        return final


if __name__ == "__main__":
    main()
