"""Post-optimization HLO analysis: collective bytes per device, classified
inter-pod vs intra-pod.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
traffic — we parse the optimized HLO module text:

  * find every all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute (+ async -start variants);
  * read the participating group size from ``replica_groups`` (both the
    explicit ``{{0,1},...}`` and the iota ``[groups,size]<=[...]`` forms);
  * convert to ring-model bytes-on-wire per device;
  * classify the mesh axes involved BY GROUP SIZE — exact for our meshes:
    {2, 32, 512} necessarily span the pod (inter-DC) axis, {16, 256} are
    intra-pod (data/model axes);
  * multiply collectives inside while bodies by the loop trip count
    (layer-scan / microbatch scans), recovered from the canonical
    ``compare(iter, constant(N))`` condition.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_COMP_START_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\])")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every tensor literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Collective:
    kind: str
    group_size: int
    result_bytes: int
    count: int = 1          # after while-loop multipliers

    def wire_bytes_per_device(self) -> float:
        g = max(self.group_size, 1)
        r = self.result_bytes
        if self.kind == "all-reduce":
            return 2.0 * r * (g - 1) / g
        if self.kind == "all-gather":
            return r * (g - 1) / g
        if self.kind == "reduce-scatter":
            return r * (g - 1)          # result is the scattered shard
        if self.kind == "all-to-all":
            return r * (g - 1) / g
        return float(r)                 # collective-permute


@dataclass
class Computation:
    name: str
    collectives: List[Collective] = field(default_factory=list)
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # (body, cond)
    calls: List[str] = field(default_factory=list)
    fusion_calls: List[str] = field(default_factory=list)
    fusion_sites: List[Tuple[str, int]] = field(default_factory=list)
    max_const: int = 1      # max s32 constant seen (trip-count recovery)
    dot_flops: float = 0.0  # FLOPs of dot ops defined directly in this comp
    hbm_bytes: float = 0.0  # operand+result bytes of top-level ops
    root_dus_update_bytes: int = -1  # >=0 when ROOT is dynamic-update-slice
    root_op: str = ""       # op kind of the ROOT instruction


_OP_NAME_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9\-]+)\(")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "conditional", "call", "iota", "partition-id",
    "replica-id",
}
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _first_operand_dims(line: str) -> Optional[List[int]]:
    """Dims of the first operand inside the op's parens."""
    try:
        inner = line.split("(", 1)[1]
    except IndexError:
        return None
    m = _SHAPE_RE.search(inner)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


_RHS_RE = re.compile(r"^(\([^=]*?\)|\S+)\s+([a-z0-9\-]+)\(")


def _dims_of(shape_str: str) -> Optional[List[int]]:
    ms = _SHAPE_RE.findall(shape_str)
    if len(ms) != 1:
        return None
    dims = ms[0][1]
    return [int(d) for d in dims.split(",") if d] if dims else []


def parse_hlo_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    symbols: Dict[str, Tuple[int, Optional[List[int]]]] = {}
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_START_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = Computation(name=m.group(1))
            comps[cur.name] = cur
            symbols = {}
            # header params: name: shape / name: (tuple)
            header = stripped[: stripped.rfind("->")]
            for pname, pshape in _PARAM_RE.findall(header):
                symbols[pname] = (_shape_bytes(pshape), _dims_of(pshape))
            if stripped.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue

        dm = _DEF_RE.match(line)
        opname, result_str, operand_seg = "", "", ""
        if dm:
            vname, rhs = dm.group(1), dm.group(2)
            rm = _RHS_RE.match(rhs)
            if rm:
                result_str, opname = rm.group(1), rm.group(2)
                rest = rhs[rm.end():]
                operand_seg = rest.split(")", 1)[0]
                symbols[vname] = (_shape_bytes(result_str),
                                  _dims_of(result_str))

        operand_names = re.findall(r"%([\w.\-]+)", operand_seg)
        operand_bytes = sum(symbols.get(n, (0, None))[0]
                            for n in operand_names)

        # --- dot FLOPs: 2 x result_elems x prod(contracting dims of lhs)
        if opname == "dot":
            res_elems = 0
            for dt, dims in _SHAPE_RE.findall(result_str):
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                res_elems += n
            lhs_dims = (symbols.get(operand_names[0], (0, None))[1]
                        if operand_names else None) or []
            mc = _DOT_CONTRACT_RE.search(line)
            contract = 1
            if mc and lhs_dims:
                for idx in mc.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            cur.dot_flops += 2.0 * res_elems * contract
        # --- HBM bytes. Writes-based model: every produced byte is written
        # once and its inputs read ~once (2x result). Ops that genuinely
        # stream large operands (dot, copy, concatenate, collectives) count
        # operands + result. Slicing ops touch only the slice (in-place DUS
        # on TPU) — and crucially, while-loop CARRIES passed through fusion
        # operand lists are NOT re-counted every iteration (they alias in
        # HBM), which a naive operand+result model inflates by the trip
        # count. Fusions whose body ROOT is a dynamic-update-slice (scan-ys
        # writes) are resolved at walk time to the UPDATE bytes, not the
        # full-buffer result.
        if dm and stripped.startswith("ROOT"):
            cur.root_op = opname
            if opname == "dynamic-update-slice":
                cur.root_dus_update_bytes = (
                    symbols.get(operand_names[1], (0, None))[0]
                    if len(operand_names) > 1 else 0)
        if opname == "fusion":
            target = None
            mfc = _CALLED_RE.search(line)
            if mfc:
                target = mfc.group(1)
            cur.fusion_sites.append((target or "",
                                     _shape_bytes(result_str)))
        elif opname == "dynamic-update-slice":
            upd = (symbols.get(operand_names[1], (0, None))[0]
                   if len(operand_names) > 1 else 0)
            cur.hbm_bytes += 2 * upd
        elif opname == "dynamic-slice":
            cur.hbm_bytes += 2 * _shape_bytes(result_str)
        elif opname in ("dot", "convolution", "copy", "concatenate",
                        "all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute", "gather",
                        "scatter", "pad", "transpose", "reverse"):
            cur.hbm_bytes += _shape_bytes(result_str) + operand_bytes
        elif opname == "convert":
            # pure dtype casts are a CPU-backend bf16-emulation artifact —
            # fused/free on the TPU target (DESIGN.md hardware adaptation)
            pass
        elif opname and opname not in _SKIP_BYTES_OPS:
            cur.hbm_bytes += 2 * _shape_bytes(result_str)
        cm = _COLL_RE.search(line)
        if cm and "-done" not in line.split("=")[0]:
            kind = cm.group(1)
            rbytes = _shape_bytes(result_str) or _shape_bytes(
                line.split(f" {kind}", 1)[0])
            gsz = 0
            me = _GROUPS_EXPL_RE.search(line)
            if me:
                gsz = len(me.group(1).split(","))
            else:
                mi = _GROUPS_IOTA_RE.search(line)
                if mi:
                    gsz = int(mi.group(2))
            cur.collectives.append(Collective(kind, gsz, rbytes))
        if " while(" in line:
            body = cond = None
            for key, val in re.findall(r"(body|condition)=%?([\w.\-]+)", line):
                if key == "body":
                    body = val
                else:
                    cond = val
            if body:
                cur.whiles.append((body, cond or ""))
        elif opname in ("fusion", "reduce", "sort", "scatter", "map",
                        "reduce-window", "select-and-scatter"):
            # bodies execute in-register: count their dot FLOPs, not bytes
            for name in _CALLED_RE.findall(line):
                cur.fusion_calls.append(name)
        else:
            for name in _CALLED_RE.findall(line):
                cur.calls.append(name)
            mb = _BRANCHES_RE.search(line)
            if mb:
                for b in mb.group(1).split(","):
                    cur.calls.append(b.strip().lstrip("%"))
        for c in _CONST_RE.findall(line):
            cur.max_const = max(cur.max_const, int(c))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def aggregate(comps: Dict[str, Computation]):
    """Walk from entry with while trip-count multipliers.

    Returns (collectives, dot_flops, hbm_bytes) — all per-device totals for
    one execution of the entry computation."""
    entry = comps.get("__entry__")
    if entry is None:
        return [], 0.0, 0.0
    out: List[Collective] = []
    totals = {"flops": 0.0, "bytes": 0.0}
    seen_stack = set()

    def walk(comp: Computation, mult: int, count_bytes: bool):
        if comp.name in seen_stack:    # recursion guard
            return
        seen_stack.add(comp.name)
        totals["flops"] += comp.dot_flops * mult
        if count_bytes:
            totals["bytes"] += comp.hbm_bytes * mult
            # fusion call sites: DUS-rooted bodies (scan-ys writes) touch
            # only the update slice; convert-rooted bodies are free dtype
            # casts (CPU bf16 emulation); others 2x their result
            for target, rbytes in comp.fusion_sites:
                body = comps.get(target)
                if body is not None and body.root_dus_update_bytes >= 0:
                    totals["bytes"] += 2 * body.root_dus_update_bytes * mult
                elif body is not None and body.root_op == "convert":
                    pass
                else:
                    totals["bytes"] += 2 * rbytes * mult
        for c in comp.collectives:
            out.append(Collective(c.kind, c.group_size, c.result_bytes,
                                  count=mult))
        for body, cond in comp.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            if body in comps:
                walk(comps[body], mult * max(trip, 1), count_bytes)
        for name in comp.calls:
            if name in comps:
                walk(comps[name], mult, count_bytes)
        for name in comp.fusion_calls:
            if name in comps:
                walk(comps[name], mult, False)
        seen_stack.discard(comp.name)

    walk(entry, 1, True)
    return out, totals["flops"], totals["bytes"]


def aggregate_collectives(comps: Dict[str, Computation]) -> List[Collective]:
    return aggregate(comps)[0]


# group sizes that necessarily span the pod axis for our (2,16,16) mesh
_POD_SIZES = {2, 32, 512}


def op_breakdown(text: str, top: int = 20) -> list:
    """Per-op-kind HBM-byte attribution with while multipliers — the
    profiling view the §Perf hypothesis loop reads ('where do the bytes
    go'). Returns [(opname, bytes)] sorted descending."""
    comps = parse_hlo_module(text)
    # re-attribute bytes per op kind by re-walking with a tracking shim
    totals: Dict[str, float] = {}

    # parse_hlo_module aggregates per computation; we need per-op detail, so
    # do a light second pass collecting (comp -> {op: bytes}).
    comps_parsed = parse_hlo_module(text)
    entry = comps_parsed.get("__entry__")
    if entry is None:
        return []
    seen = set()

    def walk(comp, mult, count):
        if comp.name in seen:
            return
        seen.add(comp.name)
        if count:
            totals["non-fusion"] = (totals.get("non-fusion", 0.0)
                                    + comp.hbm_bytes * mult)
            for target, rbytes in comp.fusion_sites:
                body = comps_parsed.get(target)
                if body is not None and body.root_dus_update_bytes >= 0:
                    totals["fusion(dus-root)"] = (
                        totals.get("fusion(dus-root)", 0.0)
                        + 2 * body.root_dus_update_bytes * mult)
                elif body is not None and body.root_op == "convert":
                    totals["fusion(convert:free)"] = (
                        totals.get("fusion(convert:free)", 0.0))
                else:
                    totals["fusion"] = (totals.get("fusion", 0.0)
                                        + 2 * rbytes * mult)
        for body, cond in comp.whiles:
            trip = comps_parsed[cond].max_const if cond in comps_parsed else 1
            if body in comps_parsed:
                walk(comps_parsed[body], mult * max(trip, 1), count)
        for n in comp.calls:
            if n in comps_parsed:
                walk(comps_parsed[n], mult, count)
        for n in comp.fusion_calls:
            if n in comps_parsed:
                walk(comps_parsed[n], mult, False)
        seen.discard(comp.name)

    walk(entry, 1, True)
    return sorted(totals.items(), key=lambda kv: -kv[1])[:top]


def collective_summary(text: str, multi_pod: bool) -> dict:
    colls, dot_flops, hbm_bytes = aggregate(parse_hlo_module(text))
    inter = intra = 0.0
    by_kind: Dict[str, float] = {}
    for c in colls:
        b = c.wire_bytes_per_device() * c.count
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + b
        if multi_pod and c.group_size in _POD_SIZES:
            inter += b
        else:
            intra += b
    return {
        "collective_bytes_per_device": inter + intra,
        "inter_pod_bytes_per_device": inter,
        "intra_pod_bytes_per_device": intra,
        "by_kind": by_kind,
        "num_collectives": len(colls),
        # trip-count-aware per-device totals (cost_analysis counts while
        # bodies once; these multiply by loop trip counts)
        "hlo_dot_flops_per_device": dot_flops,
        "hlo_hbm_bytes_per_device": hbm_bytes,
    }
