import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on 512 placeholder devices; record memory/cost/collective
analysis for the roofline.

MUST be run as a script / -m module (the XLA_FLAGS line above has to execute
before any jax import anywhere in the process).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (
    SHAPES, get_model_config, get_parallel_config, list_archs,
    shape_applicable,
)
from repro.config.base import TrainConfig
from repro.parallel.compat import set_mesh
from repro.launch.hlo_analysis import collective_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_input_specs, params_and_opt_specs, prefill_input_specs,
    train_input_specs,
)
from repro.models import build_model
from repro.parallel.sharding import named
from repro.train.optimizer import adam_update, clip_by_global_norm

# v5e-like hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (intra-pod)
OTN_BW = 16 * 100e9 / 8.0    # inter-DC aggregate per pod pair (16x100G)


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        return out
    except Exception:
        return {}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "transcendentals",
                          "optimal_seconds")
                    or k.startswith("bytes accessed"))}
    except Exception:
        return {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             include_hlo_text: bool = False) -> dict:
    t0 = time.time()
    shape = SHAPES[shape_name]
    model_cfg = get_model_config(arch)
    par = get_parallel_config(arch, multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = par.num_devices

    model = build_model(model_cfg, remat=par.remat)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "params": model_cfg.param_count(),
        "active_params": model_cfg.active_param_count(),
    }

    if not shape_applicable(model_cfg, shape):
        result["status"] = "SKIP(full-attention)"
        return result

    params_s, params_p, opt_s, opt_p = params_and_opt_specs(model, par)

    if shape.kind == "train":
        train_cfg = TrainConfig(global_batch=shape.global_batch,
                                seq_len=shape.seq_len)
        batch_s, batch_p = train_input_specs(model_cfg, par, shape)

        micro = max(par.microbatches, 1)

        def train_step(params, opt_state, batch):
            if micro > 1:
                mb = {k: v.reshape(micro, v.shape[0] // micro, *v.shape[1:])
                      for k, v in batch.items()}

                def acc(carry, one):
                    gsum, lsum = carry
                    (l, _), g = jax.value_and_grad(
                        model.loss_fn, has_aux=True)(params, one)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
                (grads, lsum), _ = jax.lax.scan(
                    acc, (g0, jnp.float32(0.0)), mb)
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = lsum / micro
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
            params, opt_state, om = adam_update(grads=grads, params=params,
                                                state=opt_state, cfg=train_cfg)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

        in_sh = (named(mesh, params_p), named(mesh, opt_p), named(mesh, batch_p))
        out_sh = (named(mesh, params_p), named(mesh, opt_p), None)
        fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        with set_mesh(mesh):
            lowered = fn.lower(params_s, opt_s, batch_s)
        tokens = shape.global_batch * shape.seq_len
        result["model_flops"] = 6.0 * model_cfg.active_param_count() * tokens

    elif shape.kind == "prefill":
        inp_s, inp_p = prefill_input_specs(model_cfg, par, shape)

        def prefill_step(params, inputs):
            caches, logits = model.prefill(params, inputs,
                                           max_len=shape.seq_len)
            return caches, logits

        fn = jax.jit(prefill_step,
                     in_shardings=(named(mesh, params_p), named(mesh, inp_p)))
        with set_mesh(mesh):
            lowered = fn.lower(params_s, inp_s)
        tokens = shape.global_batch * shape.seq_len
        result["model_flops"] = 2.0 * model_cfg.active_param_count() * tokens

    else:  # decode / long_decode
        cache_s, cache_p, inp_s, inp_p, pos_s = decode_input_specs(
            model_cfg, par, shape)

        def serve_step(params, caches, inp, pos):
            caches, logits = model.decode_step(params, caches, inp, pos)
            return caches, jnp.argmax(logits, -1).astype(jnp.int32)

        fn = jax.jit(serve_step,
                     in_shardings=(named(mesh, params_p), named(mesh, cache_p),
                                   named(mesh, inp_p), None),
                     donate_argnums=(1,))
        with set_mesh(mesh):
            lowered = fn.lower(params_s, cache_s, inp_s, pos_s)
        result["model_flops"] = 2.0 * model_cfg.active_param_count() * shape.global_batch

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    result.update(_mem_analysis(compiled))
    cost = _cost_analysis(compiled)
    result["cost_analysis"] = cost

    hlo = compiled.as_text()
    result.update(collective_summary(hlo, multi_pod))
    if include_hlo_text:
        result["hlo_len"] = len(hlo)

    # ---- roofline terms (per device, seconds) ----
    # trip-count-aware parsed values (cost_analysis counts while bodies once)
    flops_dev = max(result.get("hlo_dot_flops_per_device", 0.0),
                    cost.get("flops", 0.0))
    bytes_dev = max(result.get("hlo_hbm_bytes_per_device", 0.0),
                    cost.get("bytes accessed", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_intra = result.get("intra_pod_bytes_per_device", 0.0) / ICI_BW
    # inter-pod: per-device bytes x 256 chips share the 16x100G OTN pipe
    inter_dev = result.get("inter_pod_bytes_per_device", 0.0)
    t_inter = inter_dev * 256 / OTN_BW if multi_pod else 0.0
    t_coll = t_intra + t_inter
    result["roofline"] = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_coll_intra_s": t_intra,
        "t_coll_inter_s": t_inter,
        "dominant": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "useful_flops_ratio": (result["model_flops"] / (chips * flops_dev)
                               if flops_dev else 0.0),
    }
    result["lower_s"] = round(t_lower - t0, 2)
    result["compile_s"] = round(t_compile - t_lower, 2)
    result["status"] = "OK"
    return result


def cell_name(arch, shape, multi_pod):
    m = "multi" if multi_pod else "single"
    return f"{arch}__{shape}__{m}".replace("/", "_")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = cell_name(arch, shape, mp)
                path = os.path.join(args.out, name + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {name}")
                    continue
                print(f"[run] {name}", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"FAIL: {type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                st = res.get("status")
                rf = res.get("roofline", {})
                print(f"  -> {st} compile={res.get('compile_s', '-')}s "
                      f"dominant={rf.get('dominant', '-')}", flush=True)


if __name__ == "__main__":
    main()
