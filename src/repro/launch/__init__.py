"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` must be executed as a script/module (it sets
XLA_FLAGS before importing jax) — do not import it from library code.
"""
from repro.launch.mesh import make_mesh_for, make_production_mesh

__all__ = ["make_mesh_for", "make_production_mesh"]
