"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Pod = AI-DC: the "pod" axis is the long-haul OTN boundary that
MatchRDMA manages; "data" x "model" is the intra-DC 2D layout.

``jax.sharding.AxisType`` only exists on newer JAX (>= 0.5); on older
installs meshes are built without explicit axis types (every axis was
implicitly Auto there, so behavior is unchanged).
"""
from __future__ import annotations

import jax

try:  # JAX >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed JAX
    AxisType = None


def _axis_type_kwargs(num_axes: int) -> dict:
    """axis_types kwargs when the installed JAX supports them."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh_for(par, devices=None):
    """Mesh from a ParallelConfig (tests / small runs pass explicit devices)."""
    import numpy as np
    shape = par.mesh_shape()
    axes = par.axis_names()
    if devices is not None:
        from jax.sharding import Mesh
        arr = np.asarray(devices).reshape(shape)
        return Mesh(arr, axes, **_axis_type_kwargs(len(axes)))
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
