"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060]. d_inner = 2*d_model = 2048,
headdim 64 -> 32 SSD heads. O(1) decode state => long_500k eligible.
"""
from repro.config.base import ModelConfig, SSD, MLP_NONE
from repro.config.registry import register

FULL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=((SSD, MLP_NONE),),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    block_pattern=((SSD, MLP_NONE),),
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=32,
    ssm_conv=4,
    ssm_chunk=32,
    tie_embeddings=True,
    subquadratic=True,
)

register(FULL, SMOKE)
