"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

Llama-architecture (SwiGLU, RMSNorm, RoPE) [arXiv:2401.02954; hf].
"""
from repro.config.base import ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=172,
    vocab_size=256,
    subquadratic=False,
)

register(FULL, SMOKE, parallel_overrides={"fsdp": True, "microbatches": 8})
