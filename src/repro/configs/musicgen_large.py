"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284; hf].
MusicGen uses a GPT-style decoder: LayerNorm + GELU MLP, MHA (kv == q heads).
The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, S, d_model); the backbone predicts codebook tokens (vocab 2048).
"""
from repro.config.base import ModelConfig, MLP_GELU
from repro.config.registry import register

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    default_mlp=MLP_GELU,
    norm="layernorm",
    embed_inputs=False,     # frame embeddings come from the (stubbed) EnCodec frontend
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    default_mlp=MLP_GELU,
    norm="layernorm",
    embed_inputs=False,
    subquadratic=False,
)

register(FULL, SMOKE)
