"""The paper's own experiment grid (Fig. 3 setup), as config objects.

Topology: two AI-DCs, 16 bidirectional 100 Gbps OTN links, intra-DC one-way
delay 1 µs, distance swept 1..1000 km (5 µs .. 5 ms one-way), message sizes
1 KB..8 MB, concurrency 1..64.
"""
from __future__ import annotations

import dataclasses

from repro.config.base import NetConfig

# Distance sweep (km) used in Fig. 3(b)-(d)
DISTANCES_KM = (1.0, 10.0, 50.0, 100.0, 300.0, 500.0, 1000.0)

# Message sizes (bytes) used in Fig. 3(b,e)
MESSAGE_SIZES = (1 << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20, 8 << 20)

# Parallel-message concurrency sweep
CONCURRENCY = (1, 4, 16, 64)

SCHEMES = ("dcqcn", "pseudo_ack", "themis", "matchrdma")

BASE_NET = NetConfig()


def net_at(distance_km: float, **over) -> NetConfig:
    return dataclasses.replace(BASE_NET, distance_km=distance_km, **over)
