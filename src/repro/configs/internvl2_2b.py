"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT + InternLM2 [arXiv:2404.16821; hf]. This entry specifies the LM
BACKBONE (internlm2-1.8b-shaped, vocab 92553 incl. image tokens). The ViT
frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(B, S, d_model) already projected into the LM space.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    embed_inputs=False,    # patch/frame embeddings from the stubbed ViT frontend
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="internvl2-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    embed_inputs=False,
    subquadratic=False,
)

register(FULL, SMOKE)
