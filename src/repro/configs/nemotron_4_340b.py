"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

GQA, squared-ReLU MLP [arXiv:2402.16819]. The heaviest assigned cell.
Optimizer states kept in bf16 + FSDP sharding so a single v5e pod
(256 x 16 GB) holds the training state — see DESIGN.md §5(5).
"""
from repro.config.base import ModelConfig, MLP_RELU2
from repro.config.registry import register

FULL = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    default_mlp=MLP_RELU2,
    norm="layernorm",
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke",
    family="dense",
    num_layers=2,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    d_ff=384,
    vocab_size=256,
    default_mlp=MLP_RELU2,
    norm="layernorm",
    subquadratic=False,
)

register(FULL, SMOKE, parallel_overrides={"fsdp": True,
                                          "opt_state_dtype": "bfloat16",
                                          "microbatches": 8})
