"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
from repro.config.base import ModelConfig, MLP_MOE
from repro.config.registry import register

FULL = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    default_mlp=MLP_MOE,
    num_experts=32,
    num_experts_per_tok=8,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    default_mlp=MLP_MOE,
    num_experts=8,
    num_experts_per_tok=4,
    tie_embeddings=True,
    subquadratic=False,
)

register(FULL, SMOKE)
