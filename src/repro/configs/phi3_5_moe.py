"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].
"""
from repro.config.base import ModelConfig, MLP_MOE
from repro.config.registry import register

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    default_mlp=MLP_MOE,
    norm="layernorm",
    num_experts=16,
    num_experts_per_tok=2,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    default_mlp=MLP_MOE,
    norm="layernorm",
    num_experts=4,
    num_experts_per_tok=2,
    subquadratic=False,
)

register(FULL, SMOKE, parallel_overrides={"fsdp": True, "microbatches": 4})
