"""Per-architecture configs (assigned pool) + the paper's netsim experiment grid.

Import any module to register its arch; ``repro.config.get_model_config``
does this lazily by id.
"""
