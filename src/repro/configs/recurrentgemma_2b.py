"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.

RG-LRU + local attention, pattern (recurrent, recurrent, local_attn) = 1:2
[arXiv:2402.19427; hf]. Griffin architecture: rglru width = 2560, local
window 2048, GeGLU MLP, logit softcap. O(1)+window decode state =>
long_500k eligible.
"""
from repro.config.base import ModelConfig, RGLRU, LOCAL_ATTN, MLP_SWIGLU
from repro.config.registry import register

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    # Griffin 1:2 pattern — two RG-LRU blocks then one local-attention block
    block_pattern=((RGLRU, MLP_SWIGLU), (RGLRU, MLP_SWIGLU), (LOCAL_ATTN, MLP_SWIGLU)),
    rglru_width=2560,
    rglru_conv=4,
    local_window=2048,
    head_dim=256,
    logit_softcap=30.0,
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    num_layers=3,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    block_pattern=((RGLRU, MLP_SWIGLU), (RGLRU, MLP_SWIGLU), (LOCAL_ATTN, MLP_SWIGLU)),
    rglru_width=64,
    rglru_conv=4,
    local_window=16,
    head_dim=32,
    logit_softcap=30.0,
    tie_embeddings=True,
    subquadratic=True,
)

register(FULL, SMOKE)
