"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (diagonal, per channel):
    r_t = sigmoid(x_t @ W_a + b_a)            recurrence gate
    i_t = sigmoid(x_t @ W_x + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full block: x -> {linear -> conv1d -> RG-LRU} gated by {linear -> GeLU},
then output linear. Sequence mode uses jax.lax.associative_scan (parallel,
O(log S) depth) — this is the oracle for repro.kernels.rglru_scan.

Note: Griffin's gate projections are block-diagonal; we use dense
projections (a strict superset in capacity) — recorded in DESIGN.md.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig

_C = 8.0
_SQRT_EPS = 1e-6


def init_rglru_block(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    sw = w ** -0.5
    return {
        "w_x": (jax.random.normal(ks[0], (d, w)) * s).astype(pd),
        "w_gate": (jax.random.normal(ks[1], (d, w)) * s).astype(pd),
        "conv_w": (jax.random.normal(ks[2], (cfg.rglru_conv, w)) * 0.1).astype(pd),
        "conv_b": jnp.zeros((w,), pd),
        "w_a": (jax.random.normal(ks[3], (w, w)) * sw).astype(pd),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": (jax.random.normal(ks[4], (w, w)) * sw).astype(pd),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a ~ U(0.9, 0.999)-ish at r=1
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "w_out": (jax.random.normal(ks[5], (w, d)) * sw).astype(pd),
    }


def _gates(p: dict, x: jax.Array):
    """x: [..., W] (post-conv). Returns (log_a, gated_input) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                # [..., W] <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, _SQRT_EPS))
    return log_a, beta * (i * xf)


def rglru_scan(p: dict, x: jax.Array,
               h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Sequence mode. x: [B, S, W] (post-conv). Returns (h [B,S,W], h_last)."""
    log_a, b = _gates(p, x)
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold initial state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x_t: jax.Array, h: jax.Array) -> jax.Array:
    """One decode step. x_t: [B, W] (post-conv); h: [B, W] f32."""
    log_a, b = _gates(p, x_t)
    return jnp.exp(log_a) * h.astype(jnp.float32) + b


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
               for i in range(k)) + b[None, None, :]


def apply_rglru_block(
    p: dict,
    xin: jax.Array,                  # [B, S, d_model]
    cfg: ModelConfig,
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", xin, p["w_gate"]))
    xr = jnp.einsum("...d,dw->...w", xin, p["w_x"])

    if mode == "decode":
        b = xin.shape[0]
        x_t = xr[:, 0]                                        # [B, W]
        window = jnp.concatenate([cache["conv"], x_t[:, None]], axis=1)
        conv_out = (jnp.einsum("bkw,kw->bw", window.astype(jnp.float32),
                               p["conv_w"].astype(jnp.float32))
                    + p["conv_b"].astype(jnp.float32)).astype(xin.dtype)
        h_new = rglru_step(p, conv_out, cache["h"])
        y = h_new.astype(xin.dtype)[:, None, :]               # [B,1,W]
        new_cache = {"conv": window[:, 1:], "h": h_new}
    else:
        conv_out = _causal_conv(xr, p["conv_w"], p["conv_b"])
        h, h_last = rglru_scan(p, conv_out)
        y = h
        new_cache = None
        if mode == "prefill":
            k = cfg.rglru_conv
            new_cache = {"conv": xr[:, -(k - 1):, :], "h": h_last}

    out = jnp.einsum("...w,wd->...d", y * gate, p["w_out"])
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
