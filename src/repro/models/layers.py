"""Basic layers: norms, rotary embeddings, MLPs, embedding/unembedding.

Pure-functional: ``init_*`` builds a param dict, ``apply`` is a free function.
Mixed precision: params live in ``param_dtype`` (usually bf16); norms, softmax
and router math run in f32; matmuls run in the activation dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> dict:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=_dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=_dtype(cfg.param_dtype))
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float) -> jax.Array:
    """RMSNorm / LayerNorm in f32, cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D] (or [..., 1, H, D] for decode); positions: [..., S]."""
    dt = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(ang)[..., :, None, :]                # [..., S, 1, d/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, kind: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    if kind == "swiglu":
        return {
            "w_gate": (jax.random.normal(k1, (d, f)) * scale_in).astype(pd),
            "w_up": (jax.random.normal(k2, (d, f)) * scale_in).astype(pd),
            "w_down": (jax.random.normal(k3, (f, d)) * scale_out).astype(pd),
        }
    # relu2 / gelu: classic 2-matrix MLP
    return {
        "w_up": (jax.random.normal(k1, (d, f)) * scale_in).astype(pd),
        "w_down": (jax.random.normal(k2, (f, d)) * scale_out).astype(pd),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g) * u
    elif kind == "relu2":
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, cfg: ModelConfig) -> dict:
    pd = _dtype(cfg.param_dtype)
    p = {}
    k1, k2 = jax.random.split(key)
    if cfg.embed_inputs:
        p["tok"] = (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(pd)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(pd)
    return p


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    return x.astype(_dtype(cfg.act_dtype))


def unembed(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits in f32 (loss-side numerics)."""
    if cfg.tie_embeddings and cfg.embed_inputs:
        w = p["tok"].T
    else:
        w = p["unembed"]
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
