"""Top-k token-choice Mixture-of-Experts with capacity-based dispatch.

Routing: softmax router (f32) -> top-k -> renormalize -> capacity-gated
scatter dispatch into per-expert buffers [E, C, d] -> batched SwiGLU experts
-> weighted combine. Tokens over capacity are dropped (their MoE output is 0,
residual stream carries them through) — GShard/Switch semantics.

The [E, C, d] buffers shard E over the "model" mesh axis (expert parallelism);
the scatter/gather are the dispatch/combine "all-to-all"s. The aux losses are
the standard load-balancing loss + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig


def _constrain_experts_to_model_axis(x: jax.Array) -> jax.Array:
    """Pin dim 0 (experts) to the "model" mesh axis when a mesh is ambient;
    no-op on single-device/smoke runs."""
    try:
        from jax.sharding import PartitionSpec as _P
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in (mesh.axis_names or ()):
            return x
        U = _P.UNCONSTRAINED
        return jax.lax.with_sharding_constraint(
            x, _P("model", *([U] * (x.ndim - 1))))
    except Exception:
        return x


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(pd),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(pd),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(pd),
    }


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: [..., d] (usually [B, S, d]). Returns (y, aux).

    With ``moe_group_by_batch`` the dispatch is vmapped over the batch dim:
    capacity is per-row, the [B, E, C, d] buffers shard their leading dim
    with the batch — routing never crosses the (pod, data) axes."""
    if cfg.moe_group_by_batch and x.ndim == 3:
        # GSPMD cannot batch-partition top_k / scatter-add: it all-gathers
        # the router probs and dispatch buffers across the batch axes (the
        # inter-DC catastrophe measured in EXPERIMENTS.md §Perf). shard_map
        # over the batch axes makes routing shard-local BY CONSTRUCTION;
        # expert compute stays auto. Requires expert weights replicated over
        # the batch axes (ShardingRules does this when moe_group_by_batch).
        from repro.parallel.compat import get_ambient_mesh, shard_map
        mesh = get_ambient_mesh()
        axes = tuple(a for a in ("pod", "data")
                     if mesh is not None and a in (mesh.axis_names or ()))
        if axes:
            from jax.sharding import PartitionSpec as P2

            def local_fn(xt, pp):
                b, s, d = xt.shape
                y, aux = _moe_tokens(pp, xt.reshape(b * s, d), cfg)
                aux = {k: jax.lax.pmean(v, axes) for k, v in aux.items()}
                return y.reshape(b, s, d), aux

            # FULL-manual shard_map (all mesh axes): expert weights are
            # replicated (EP->DP for grouped mode), so the entire MoE layer
            # is collective-free and shard-local by construction.
            fn = shard_map(
                local_fn, mesh=mesh,
                in_specs=(P2(axes, None, None),
                          jax.tree.map(lambda _: P2(), p)),
                out_specs=(P2(axes, None, None),
                           dict(moe_lb_loss=P2(), moe_z_loss=P2(),
                                moe_drop_frac=P2())),
                check_vma=False)
            return fn(x, p)
        # single-device / no-mesh fallback: per-row routing via vmap
        y, aux = jax.vmap(lambda row: _moe_tokens(p, row, cfg,
                                                  grouped=True))(x)
        return y, {k: v.mean() for k, v in aux.items()}
    orig_shape = x.shape
    y, aux = _moe_tokens(p, x.reshape(-1, orig_shape[-1]), cfg)
    return y.reshape(orig_shape), aux


def _moe_tokens(p: dict, xt: jax.Array, cfg: ModelConfig,
                grouped: bool = False) -> Tuple[jax.Array, dict]:
    """xt: [T, d] flat tokens. ``grouped``: running under vmap-over-batch —
    pin the expert dim of the dispatch buffers to the "model" axis so the
    exchange is an intra-pod model-axis all-to-all (proper expert
    parallelism), never a (pod, data) token gather."""
    d = xt.shape[-1]
    t = xt.shape[0]
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(int(cfg.moe_capacity_factor * t * k / e), k)

    # --- routing (f32) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # [T, E]
    gate_vals, idx = jax.lax.top_k(probs, k)                 # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- aux losses ---
    # load balance: E * sum_e f_e * p_e  (f: fraction dispatched, p: mean prob)
    onehot_top1_frac = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (t * k))
    mean_prob = probs.mean(axis=0)
    lb_loss = e * jnp.sum(onehot_top1_frac * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # --- capacity positions: pos of slot (t, j) inside expert idx[t, j] ---
    flat_e = idx.reshape(-1)                                 # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot           # count before me
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    safe_pos = jnp.where(keep, flat_pos, cap)                # cap -> dropped

    # --- dispatch: scatter tokens into [E, C+1, d]; last slot is the drop bin
    upd = jnp.repeat(xt, k, axis=0)                          # [T*k, d]
    buf = jnp.zeros((e, cap + 1, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(upd)
    buf = buf[:, :cap]                                       # [E, C, d]
    if grouped:
        buf = _constrain_experts_to_model_axis(buf)

    # --- experts (batched SwiGLU) ---
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # [E, C, d]
    if grouped:
        out = _constrain_experts_to_model_axis(out)

    # --- combine: gather back, weight by gates, zero dropped ---
    out_pad = jnp.concatenate([out, jnp.zeros((e, 1, d), out.dtype)], axis=1)
    gathered = out_pad[flat_e, safe_pos]                     # [T*k, d]
    w = (gate_vals.reshape(-1) * keep.astype(jnp.float32)).astype(gathered.dtype)
    y = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_drop_frac": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return y, aux
