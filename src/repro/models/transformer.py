"""Unified block-typed decoder-only transformer.

Every assigned architecture is an instance of this module: a repeating
``block_pattern`` of (mixer, mlp) pairs where mixer ∈ {attn, local_attn,
ssd, rglru} and mlp ∈ {swiglu, relu2, gelu, moe, none}.

Layers are grouped by pattern repetition and the groups are scanned with
``jax.lax.scan`` (stacked params, leading axis = n_groups) so the compiled
HLO contains ONE copy of the pattern body regardless of depth — essential
for the 96-layer configs. A remainder (num_layers % len(pattern)) is applied
unrolled. Rematerialization (``jax.checkpoint``) wraps the scan body.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import (
    ATTN, LOCAL_ATTN, RGLRU, SSD,
    MLP_MOE, MLP_NONE, ModelConfig,
)
from repro.models import attention as attn_lib
from repro.models.layers import apply_mlp, apply_norm, apply_rope, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import apply_rglru_block, init_rglru_block, init_rglru_cache
from repro.models.ssm import apply_ssd_block, init_ssd_block, init_ssd_cache

AUX_ZERO = {
    "moe_lb_loss": jnp.float32(0.0),
    "moe_z_loss": jnp.float32(0.0),
    "moe_drop_frac": jnp.float32(0.0),
}


# ---------------------------------------------------------------------------
# Attention sub-block
# ---------------------------------------------------------------------------

def init_attn(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hk = cfg.num_heads, cfg.num_kv_heads
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq * hd)) * s).astype(pd),
        "wk": (jax.random.normal(ks[1], (d, hk * hd)) * s).astype(pd),
        "wv": (jax.random.normal(ks[2], (d, hk * hd)) * s).astype(pd),
        "wo": (jax.random.normal(ks[3], (hq * hd, d)) * (hq * hd) ** -0.5).astype(pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), pd)
        p["bk"] = jnp.zeros((hk * hd,), pd)
        p["bv"] = jnp.zeros((hk * hd,), pd)
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    hq, hk = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("...d,de->...e", x, p["wq"])
    k = jnp.einsum("...d,de->...e", x, p["wk"])
    v = jnp.einsum("...d,de->...e", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    shp = x.shape[:-1]
    return (q.reshape(*shp, hq, hd), k.reshape(*shp, hk, hd),
            v.reshape(*shp, hk, hd))


def apply_attn(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mixer: str,
    *,
    mode: str,
    cache: Optional[dict],
    pos: Optional[jax.Array],
    max_len: int = 0,
) -> Tuple[jax.Array, Optional[dict]]:
    hd = cfg.resolved_head_dim
    local = mixer == LOCAL_ATTN
    tm = cfg.decode_k_time_minor and not local
    if mode == "decode":
        b = x.shape[0]
        q, k, v = _qkv(p, x[:, 0], cfg)                      # [B,H,hd]
        positions = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q[:, None], positions, cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], positions, cfg.rope_theta)[:, 0]
        slot = jnp.mod(pos, cache["k"].shape[1]) if local else pos
        if tm:
            # K cache is [B, Hk, hd, Smax]: write the new column at pos
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k[..., None].astype(cache["k"].dtype),
                slot, axis=3)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k[:, None].astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v[:, None].astype(cache["v"].dtype), slot, axis=1)
        if local:
            o = attn_lib.decode_local_attention(q, k_cache, v_cache, pos)
        elif tm:
            o = attn_lib.decode_attention_tm(q, k_cache, v_cache, pos)
        else:
            o = attn_lib.decode_attention(q, k_cache, v_cache, pos)
        o = o[:, None]                                       # [B,1,Hq,hd]
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        b, s, _ = x.shape
        q, k, v = _qkv(p, x, cfg)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if local:
            o = attn_lib.local_attention(q, k, v, window=cfg.local_window)
        else:
            o = attn_lib.chunked_causal_attention(q, k, v)
        new_cache = None
        if mode == "prefill":
            if local:
                w = cfg.local_window
                kk, vv = k[:, -w:], v[:, -w:]
                if s >= w:
                    # ring layout: slot = position % w
                    shift = s % w
                    kk = jnp.roll(kk, shift, axis=1)
                    vv = jnp.roll(vv, shift, axis=1)
                    new_cache = {"k": kk, "v": vv}
                else:
                    zk = jnp.zeros((b, w - s, *k.shape[2:]), k.dtype)
                    new_cache = {"k": jnp.concatenate([kk, zk], 1),
                                 "v": jnp.concatenate([vv, zk], 1)}
            else:
                assert max_len >= s
                zk = jnp.zeros((b, max_len - s, *k.shape[2:]), k.dtype)
                if tm:
                    k_tm = jnp.moveaxis(
                        jnp.concatenate([k, zk], 1), 1, 3)  # [B,Hk,hd,Smax]
                    new_cache = {"k": k_tm,
                                 "v": jnp.concatenate([v, zk], 1)}
                else:
                    new_cache = {"k": jnp.concatenate([k, zk], 1),
                                 "v": jnp.concatenate([v, zk], 1)}
    o = o.reshape(*o.shape[:2], cfg.num_heads * hd)
    return jnp.einsum("...e,ed->...d", o, p["wo"]), new_cache


def init_attn_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    length = cfg.local_window if mixer == LOCAL_ATTN else max_len
    shape = (batch, length, cfg.num_kv_heads, hd)
    if cfg.decode_k_time_minor and mixer != LOCAL_ATTN:
        k_shape = (batch, cfg.num_kv_heads, hd, length)      # time-minor
        return {"k": jnp.zeros(k_shape, dtype), "v": jnp.zeros(shape, dtype)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# One block = mixer + optional MLP, pre-norm residual
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ModelConfig, mixer: str, mlp: str) -> dict:
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"norm1": init_norm(cfg)}
    if mixer in (ATTN, LOCAL_ATTN):
        p["attn"] = init_attn(ks[0], cfg)
    elif mixer == SSD:
        p["ssd"] = init_ssd_block(ks[0], cfg)
    elif mixer == RGLRU:
        p["rglru"] = init_rglru_block(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if mlp != MLP_NONE:
        p["norm2"] = init_norm(cfg)
        if mlp == MLP_MOE:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg, mlp)
    return p


def apply_block(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mixer: str,
    mlp: str,
    *,
    mode: str,
    cache: Optional[dict],
    pos: Optional[jax.Array],
    max_len: int = 0,
) -> Tuple[jax.Array, dict, Optional[dict]]:
    h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    if mixer in (ATTN, LOCAL_ATTN):
        mx, new_cache = apply_attn(p["attn"], h, cfg, mixer, mode=mode,
                                   cache=cache, pos=pos, max_len=max_len)
    elif mixer == SSD:
        mx, new_cache = apply_ssd_block(p["ssd"], h, cfg, mode=mode, cache=cache)
    else:
        mx, new_cache = apply_rglru_block(p["rglru"], h, cfg, mode=mode, cache=cache)
    x = x + mx

    aux = dict(AUX_ZERO)
    if mlp != MLP_NONE:
        h2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if mlp == MLP_MOE:
            y, aux = apply_moe(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, mlp)
        x = x + y
    return x, aux, new_cache


def init_block_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Optional[dict]:
    if mixer in (ATTN, LOCAL_ATTN):
        return init_attn_cache(cfg, mixer, batch, max_len, dtype)
    if mixer == SSD:
        return init_ssd_cache(cfg, batch, dtype)
    if mixer == RGLRU:
        return init_rglru_cache(cfg, batch, dtype)
    return None


# ---------------------------------------------------------------------------
# The stacked / scanned backbone
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig):
    pat = cfg.block_pattern or ((ATTN, cfg.default_mlp),)
    n_groups = cfg.num_layers // len(pat)
    rem = cfg.num_layers % len(pat)
    return pat, n_groups, rem


def init_backbone(key: jax.Array, cfg: ModelConfig) -> dict:
    pat, n_groups, rem = _pattern(cfg)
    keys = jax.random.split(key, cfg.num_layers)
    # stacked groups: for each pattern position i, stack n_groups block trees
    groups = []
    for i, (mixer, mlp) in enumerate(pat):
        blocks = [init_block(keys[g * len(pat) + i], cfg, mixer, mlp)
                  for g in range(n_groups)]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs, 0), *blocks))
    rem_blocks = [
        init_block(keys[n_groups * len(pat) + j], cfg, *pat[j % len(pat)])
        for j in range(rem)
    ]
    return {"groups": tuple(groups), "rem": tuple(rem_blocks),
            "final_norm": init_norm(cfg)}


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    """Cache pytree matching the grouped layout (stacked leading n_groups)."""
    pat, n_groups, rem = _pattern(cfg)
    groups = []
    for mixer, _ in pat:
        one = init_block_cache(cfg, mixer, batch, max_len, dtype)
        groups.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups, *a.shape)).copy() if n_groups else a[None][:0],
            one))
    rem_caches = [init_block_cache(cfg, pat[j % len(pat)][0], batch, max_len, dtype)
                  for j in range(rem)]
    return {"groups": tuple(groups), "rem": tuple(rem_caches)}


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "block": save only block inputs


def apply_backbone(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str = "train",
    caches: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    max_len: int = 0,
    remat: str = "block",
    decode_cache_in_carry: bool = False,
) -> Tuple[jax.Array, dict, Optional[dict]]:
    """Runs all layers. Returns (hidden, aux, new_caches)."""
    pat, n_groups, rem = _pattern(cfg)

    def group_fwd(x, aux, group_params, group_caches):
        new_caches = []
        for i, (mixer, mlp) in enumerate(pat):
            c = None if group_caches is None else group_caches[i]
            x, aux_i, nc = apply_block(
                group_params[i], x, cfg, mixer, mlp,
                mode=mode, cache=c, pos=pos, max_len=max_len)
            aux = {k: aux[k] + aux_i[k] for k in aux}
            new_caches.append(nc)
        return x, aux, tuple(new_caches)

    aux = dict(AUX_ZERO)
    if n_groups > 0:
        if mode == "train":
            def body(carry, group_params):
                x, aux = carry
                x, aux, _ = group_fwd(x, aux, group_params, None)
                return (x, aux), None
            body = _remat_wrap(body, remat)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])
            new_group_caches = None
        elif mode == "decode":
            if decode_cache_in_carry:
                # caches ride in the scan CARRY and are updated in place via
                # dynamic slicing — the scan-xs/ys path materializes a fresh
                # stacked cache every step (full-cache copy per token);
                # the carry aliases (EXPERIMENTS.md §Perf hillclimb 1).
                def body_c(carry, inp):
                    x, aux, cch = carry
                    i, group_params = inp
                    group_caches = jax.tree.map(
                        lambda c: jax.lax.dynamic_index_in_dim(
                            c, i, 0, keepdims=False), cch)
                    x, aux, ncs = group_fwd(x, aux, group_params, group_caches)
                    cch = jax.tree.map(
                        lambda c, n: jax.lax.dynamic_update_index_in_dim(
                            c, n.astype(c.dtype), i, 0), cch, ncs)
                    return (x, aux, cch), None
                n_g = jax.tree.leaves(params["groups"])[0].shape[0]
                (x, aux, new_group_caches), _ = jax.lax.scan(
                    body_c, (x, aux, caches["groups"]),
                    (jnp.arange(n_g), params["groups"]))
            else:
                def body_d(carry, inp):
                    x, aux = carry
                    group_params, group_caches = inp
                    x, aux, ncs = group_fwd(x, aux, group_params, group_caches)
                    return (x, aux), ncs
                (x, aux), new_group_caches = jax.lax.scan(
                    body_d, (x, aux), (params["groups"], caches["groups"]))
        else:  # prefill: caches are produced, not consumed
            def body_p(carry, group_params):
                x, aux = carry
                x, aux, ncs = group_fwd(x, aux, group_params, None)
                return (x, aux), ncs
            (x, aux), new_group_caches = jax.lax.scan(
                body_p, (x, aux), params["groups"])
    else:
        new_group_caches = tuple()

    # remainder layers (unrolled)
    new_rem = []
    for j, bp in enumerate(params["rem"]):
        mixer, mlp = pat[j % len(pat)]
        c = None if (caches is None or mode != "decode") else caches["rem"][j]
        x, aux_j, nc = apply_block(bp, x, cfg, mixer, mlp, mode=mode,
                                   cache=c, pos=pos, max_len=max_len)
        aux = {k: aux[k] + aux_j[k] for k in aux}
        new_rem.append(nc)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    new_caches = None
    if mode in ("prefill", "decode"):
        new_caches = {"groups": new_group_caches, "rem": tuple(new_rem)}
    return x, aux, new_caches
