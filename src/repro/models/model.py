"""Public model API: build_model(cfg) -> Model(init, loss_fn, prefill, decode_step).

Input conventions
  embed_inputs=True  : batch["tokens"]  [B, S] int32
  embed_inputs=False : batch["embeds"]  [B, S, d_model] act_dtype
                       (modality-frontend stub output: EnCodec frames /
                        ViT patches, see DESIGN.md)
  batch["labels"] [B, S] int32, -1 = masked.

Loss is computed in sequence chunks so [B, S, vocab] logits are never
materialized (vocab up to 256k); the unembed matmul happens inside the
chunk loop in f32, and the logsumexp reduces over the (model-axis-sharded)
vocab dimension.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import embed_tokens, init_embed, unembed
from repro.models.transformer import (
    apply_backbone, init_backbone, init_caches,
)

LOSS_CHUNK = 512


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _unembed_weight(params: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings and cfg.embed_inputs:
        return params["embed"]["tok"].T
    return params["embed"]["unembed"]


def chunked_ce_loss(x: jax.Array, w_un: jax.Array, labels: jax.Array,
                    softcap: float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d]; labels [B, S] (-1 = pad). Returns (sum_loss, n_tokens)."""
    b, s, d = x.shape
    c = min(LOSS_CHUNK, s)
    assert s % c == 0
    nchunks = s // c
    xc = jnp.moveaxis(x.reshape(b, nchunks, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nchunks, c), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        xch, lch = inp
        logits = jnp.einsum("bcd,dv->bcv", xch.astype(jnp.float32),
                            w_un.astype(jnp.float32))
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lch, 0)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (lch >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - picked) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xc, lc))
    return tot, cnt


def build_model(cfg: ModelConfig, remat: str = "block",
                decode_cache_in_carry: bool = False) -> Model:
    act = jnp.dtype(cfg.act_dtype)

    def init(key: jax.Array) -> dict:
        k1, k2 = jax.random.split(key)
        return {"embed": init_embed(k1, cfg),
                "backbone": init_backbone(k2, cfg)}

    def _inputs_to_x(params, batch_or_tok):
        if cfg.embed_inputs:
            return embed_tokens(params["embed"], batch_or_tok, cfg)
        return batch_or_tok.astype(act)

    def loss_fn(params: dict, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, dict]:
        inp = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
        x = _inputs_to_x(params, inp)
        h, aux, _ = apply_backbone(params["backbone"], x, cfg,
                                   mode="train", remat=remat)
        tot, cnt = chunked_ce_loss(h, _unembed_weight(params, cfg),
                                   batch["labels"], cfg.logit_softcap)
        ce = tot / jnp.maximum(cnt, 1.0)
        loss = ce
        if cfg.num_experts:
            loss = (loss + cfg.router_aux_loss * aux["moe_lb_loss"]
                    + 1e-3 * aux["moe_z_loss"])
        metrics = {"loss": loss, "ce": ce, "tokens": cnt, **aux}
        return loss, metrics

    def init_cache(batch: int, max_len: int) -> dict:
        return init_caches(cfg, batch, max_len, act)

    def prefill(params: dict, inputs: jax.Array, max_len: int):
        """inputs: tokens [B,S] or embeds [B,S,d]. Returns (caches, last_logits)."""
        x = _inputs_to_x(params, inputs)
        h, _, caches = apply_backbone(params["backbone"], x, cfg,
                                      mode="prefill", max_len=max_len,
                                      remat="none")
        logits = unembed(params["embed"], h[:, -1], cfg)
        return caches, logits

    def decode_step(params: dict, caches: dict, inp: jax.Array, pos: jax.Array):
        """inp: token ids [B] (embed_inputs) or embeds [B,1,d]. pos: scalar.

        Returns (new_caches, logits [B, vocab])."""
        if cfg.embed_inputs:
            x = _inputs_to_x(params, inp[:, None])
        else:
            x = inp.astype(act)
        h, _, new_caches = apply_backbone(
            params["backbone"], x, cfg, mode="decode", caches=caches,
            pos=pos, remat="none",
            decode_cache_in_carry=decode_cache_in_carry)
        logits = unembed(params["embed"], h[:, 0], cfg)
        return new_caches, logits

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache)
