"""Attention: chunked causal GQA (flash-style, never materializes S×S),
banded local attention, and cached decode paths.

Layout conventions
  q        [B, S, Hq, Dh]
  k, v     [B, S, Hk, Dh]       (GQA: Hq = Hk * G)
  cache    k/v  [B, Smax, Hk, Dh] (rope pre-applied to cached K)
  local cache   ring buffer [B, W, Hk, Dh]

The chunked path is the numerical oracle for the Pallas flash kernel
(repro.kernels.flash_attention) — same online-softmax algorithm, pure jnp.
Query blocks are a static Python loop so each block sees a *static-length*
KV prefix (exactly-causal FLOPs, O(block²) memory); the KV prefix is
processed by a lax.scan with f32 running (m, l, acc).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    """[B, S, Hq, D] -> [B, S, Hk, G, D]."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, d)


def _merge_gqa(o: jax.Array) -> jax.Array:
    b, s, hk, g, d = o.shape
    return o.reshape(b, s, hk * g, d)


# ---------------------------------------------------------------------------
# Chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 1024,
    block_kv: int = 1024,
    softcap: float = 0.0,
) -> jax.Array:
    """Exact causal attention, computed block-by-block with online softmax."""
    b, s, hq, dh = q.shape
    hk = k.shape[2]
    g = hq // hk
    scale = dh ** -0.5

    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    if s % block_q or s % block_kv:
        blk = math.gcd(s, math.gcd(block_q, block_kv))
        block_q = block_kv = max(blk, 1)
    nq = s // block_q
    nk = s // block_kv

    qg = _split_gqa(q, hk)                                   # [b,s,hk,g,dh]
    kb = k.reshape(b, nk, block_kv, hk, dh)
    vb = v.reshape(b, nk, block_kv, hk, dh)

    out_blocks = []
    for i in range(nq):
        q_i = jax.lax.dynamic_slice_in_dim(qg, i * block_q, block_q, axis=1)
        # static-length causal prefix for this query block
        n_pref = (i * block_q) // block_kv + 1               # blocks 0..diag
        k_pref = kb[:, :n_pref]                              # [b,np,bk,hk,dh]
        v_pref = vb[:, :n_pref]

        m0 = jnp.full((b, hk, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, hk, g, block_q, dh), jnp.float32)

        q_pos = i * block_q + jnp.arange(block_q)

        def body(carry, inputs, _i=i):
            m, l, acc = carry
            j, k_j, v_j = inputs
            sblk = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                              preferred_element_type=jnp.float32) * scale
            if softcap > 0:
                sblk = softcap * jnp.tanh(sblk / softcap)
            k_pos = j * block_kv + jnp.arange(block_kv)
            mask = q_pos[:, None] >= k_pos[None, :]
            sblk = jnp.where(mask, sblk, NEG_INF)
            m_new = jnp.maximum(m, sblk.max(axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        xs = (jnp.arange(n_pref),
              jnp.moveaxis(k_pref, 1, 0), jnp.moveaxis(v_pref, 1, 0))
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
        o_i = acc / jnp.maximum(l[..., None], 1e-37)          # [b,hk,g,bq,dh]
        out_blocks.append(jnp.moveaxis(o_i, 3, 1))            # [b,bq,hk,g,dh]

    o = jnp.concatenate(out_blocks, axis=1)
    return _merge_gqa(o).astype(q.dtype)


# ---------------------------------------------------------------------------
# Banded (sliding-window) local attention
# ---------------------------------------------------------------------------

def local_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    softcap: float = 0.0,
) -> jax.Array:
    """Exact sliding-window causal attention: position t attends [t-W+1, t].

    Query block size = W; each block attends its own block and the previous
    one, masked to the exact band. Memory O(W²) per block.
    """
    b, s, hq, dh = q.shape
    hk = k.shape[2]
    g = hq // hk
    scale = dh ** -0.5

    if s <= window:
        return chunked_causal_attention(q, k, v, block_q=min(1024, s),
                                        block_kv=min(1024, s), softcap=softcap)
    w = window
    if s % w:
        # pad tail (causal: padded key positions are never attended by real queries)
        pad = w - s % w
        zq = jnp.zeros((b, pad, hq, dh), q.dtype)
        zk = jnp.zeros((b, pad, hk, dh), k.dtype)
        o = local_attention(jnp.concatenate([q, zq], 1),
                            jnp.concatenate([k, zk], 1),
                            jnp.concatenate([v, zk], 1),
                            window=window, softcap=softcap)
        return o[:, :s]
    nb = s // w
    qg = _split_gqa(q, hk).reshape(b, nb, w, hk, g, dh)
    kb = k.reshape(b, nb, w, hk, dh)
    vb = v.reshape(b, nb, w, hk, dh)
    # previous block (block -1 = zeros, fully masked anyway)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)               # [b,nb,2w,hk,dh]
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    sblk = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg, k2,
                      preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        sblk = softcap * jnp.tanh(sblk / softcap)
    q_pos = jnp.arange(w)[:, None]                           # within-block
    k_pos = jnp.arange(2 * w)[None, :] - w                   # relative to block start
    rel = q_pos - k_pos                                      # distance q - k
    band = (rel >= 0) & (rel < w)
    first_block = jnp.arange(nb)[:, None, None] == 0
    valid = band[None] & ~(first_block & (k_pos[None] < 0))
    sblk = jnp.where(valid[:, None, None], sblk, NEG_INF)
    p = jax.nn.softmax(sblk, axis=-1)
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p.astype(v2.dtype), v2)
    return o.reshape(b, s, hk * g, dh).astype(q.dtype)


def decode_attention_tm(
    q: jax.Array,          # [B, Hq, Dh] (rope applied at pos)
    k_cache_tm: jax.Array,  # [B, Hk, Dh, Smax]  (time-minor, dot-ready)
    v_cache: jax.Array,     # [B, Smax, Hk, Dh]
    pos: jax.Array,
    *,
    softcap: float = 0.0,
) -> jax.Array:
    """Decode against a TIME-MINOR K cache: QK^T contracts Dh with S free —
    no per-step transpose of the whole cache (EXPERIMENTS.md §Perf Cell A)."""
    b, hk, dh, smax = k_cache_tm.shape
    hq = q.shape[1]
    g = hq // hk
    scale = dh ** -0.5
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bhgd,bhds->bhgs", qg, k_cache_tm,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(smax)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,          # [B, Hq, Dh] (rope already applied at pos)
    k_cache: jax.Array,    # [B, Smax, Hk, Dh]
    v_cache: jax.Array,
    pos: jax.Array,        # scalar int32: index of the NEW token (already written)
    *,
    softcap: float = 0.0,
) -> jax.Array:
    b, smax, hk, dh = k_cache.shape
    hq = q.shape[1]
    g = hq // hk
    scale = dh ** -0.5
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(smax)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, dh).astype(q.dtype)


def decode_local_attention(
    q: jax.Array,           # [B, Hq, Dh]
    k_ring: jax.Array,      # [B, W, Hk, Dh] ring buffer (slot = pos % W)
    v_ring: jax.Array,
    pos: jax.Array,         # scalar: index of the NEW token (already written)
    *,
    softcap: float = 0.0,
) -> jax.Array:
    b, w, hk, dh = k_ring.shape
    hq = q.shape[1]
    g = hq // hk
    scale = dh ** -0.5
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_ring,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    # slot j holds absolute position p = pos - ((pos - j) mod W); valid if p >= 0
    slots = jnp.arange(w)
    slot_pos = pos - jnp.mod(pos - slots, w)
    valid = (slot_pos >= 0)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_ring.dtype), v_ring,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Reference (naive) attention — used only by tests as an oracle
# ---------------------------------------------------------------------------

def naive_causal_attention(q, k, v, *, window: int = 0, softcap: float = 0.0):
    b, s, hq, dh = q.shape
    hk = k.shape[2]
    qg = _split_gqa(q, hk)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * dh ** -0.5
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = i >= j
    if window:
        mask = mask & (i - j < window)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return _merge_gqa(o).astype(q.dtype)
