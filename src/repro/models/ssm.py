"""Mamba2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060 (ssd_minimal):
within-chunk quadratic "attention-like" term + inter-chunk linear recurrence
over states. This is the numerical oracle for the Pallas kernel
(repro.kernels.ssd_scan) and the path used by the dry-run.

Shapes (h = heads, p = headdim, n = state, g = groups (=1 here)):
  x   [B, S, h, p]     dt [B, S, h]     A [h] (negative)
  B,C [B, S, g, n]
  state H [B, h, n, p]
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import apply_norm


# ---------------------------------------------------------------------------
# Core SSD scan
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., T] -> [..., T, T]: out[i,j] = sum_{k=j+1..i} x_k (i>=j), -inf else."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,h,p], final_state [B,h,n,p]). All decays in f32."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    if s % chunk:
        # pad tail with dt=0 steps: exp(0·A)=1 and dt·B⊗x=0 leave the state
        # invariant, so the final state is exact; padded outputs are sliced off.
        pad = chunk - s % chunk
        padded = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, fin = ssd_chunked(padded(x), padded(dt), A, padded(B), padded(C),
                             chunk=chunk, init_state=init_state)
        return y[:, :s], fin
    nc = s // chunk
    rep = h // g

    dtf = dt.astype(jnp.float32)
    dA = dtf * A.astype(jnp.float32)[None, None, :]          # [b,s,h] (<0)
    xdt = (x * dt[..., None].astype(x.dtype))                # input scaled by dt

    # chunked views
    xc = xdt.reshape(b, nc, chunk, h, p)
    Bh = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n)
    dAc = dA.reshape(b, nc, chunk, h)
    dAcs = jnp.cumsum(dAc, axis=2)                           # [b,c,l,h]

    # 1) intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))            # [b,c,h,l,l]
    Sqk = jnp.einsum("bclhn,bckhn->bchlk", Ch, Bh,
                     preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchlk,bckhp->bclhp", (Sqk * L).astype(x.dtype), xc)

    # 2) per-chunk terminal states
    decay_to_end = jnp.exp(dAcs[:, :, -1:, :] - dAcs)        # [b,c,l,h]
    states = jnp.einsum("bclhn,bclh,bclhp->bchnp",
                        Bh.astype(jnp.float32), decay_to_end,
                        xc.astype(jnp.float32))              # [b,c,h,n,p]

    # 3) inter-chunk recurrence (f32 carry)
    lam = jnp.exp(dAcs[:, :, -1, :])                         # [b,c,h] chunk decay
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, lm = inp                                         # [b,h,n,p], [b,h]
        new = carry * lm[:, :, None, None] + st
        return new, carry                                    # emit state ENTERING the chunk

    final, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(lam, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # [b,c,h,n,p]

    # 4) inter-chunk contribution to outputs
    decay_from_start = jnp.exp(dAcs)                         # [b,c,l,h]
    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp",
                       Ch.astype(jnp.float32), h_prev, decay_from_start)

    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jax.Array,   # [B, h, n, p] f32
    x_t: jax.Array,     # [B, h, p]
    dt_t: jax.Array,    # [B, h]
    A: jax.Array,       # [h]
    B_t: jax.Array,     # [B, g, n]
    C_t: jax.Array,     # [B, g, n]
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step: H <- H*exp(dt·A) + dt·B⊗x ; y = C·H."""
    b, h, n, p = state.shape
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)    # [B,h,n]
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32)[None, :])       # [B,h]
    upd = (dtf[..., None] * Bh)[..., :, None] * x_t.astype(jnp.float32)[:, :, None, :]
    new_state = state * dA[..., None, None] + upd            # [B,h,n,p]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return new_state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    g = 1
    conv_ch = d_in + 2 * g * cfg.ssm_state
    return d_in, nheads, g, conv_ch


def init_ssd_block(key: jax.Array, cfg: ModelConfig) -> dict:
    """Projections are stored SEPARATELY (w_z, w_x, w_bc, w_dt) rather than
    as one fused [d, 2*d_in+2gn+h] matrix: mesh-axis partitions of a fused
    tensor would cut across the z/x/B/C/dt boundaries and force GSPMD
    reshards. XLA re-fuses the matmuls anyway."""
    d = cfg.d_model
    d_in, nheads, g, conv_ch = _dims(cfg)
    n = cfg.ssm_state
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d, d_in)) * s).astype(pd),
        "w_x": (jax.random.normal(ks[1], (d, d_in)) * s).astype(pd),
        "w_bc": (jax.random.normal(ks[2], (d, 2 * g * n)) * s).astype(pd),
        "w_dt": (jax.random.normal(ks[3], (d, nheads)) * s).astype(pd),
        "conv_x_w": (jax.random.normal(ks[4], (cfg.ssm_conv, d_in)) * 0.1).astype(pd),
        "conv_x_b": jnp.zeros((d_in,), pd),
        "conv_bc_w": (jax.random.normal(ks[5], (cfg.ssm_conv, 2 * g * n)) * 0.1).astype(pd),
        "conv_bc_b": jnp.zeros((2 * g * n,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (nheads,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm_scale": jnp.ones((d_in,), pd),
        "w_out": (jax.random.normal(ks[0], (d_in, d)) * d_in ** -0.5).astype(pd),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: xbc [B,S,Ch], w [K,Ch]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def apply_ssd_block(
    p: dict,
    xin: jax.Array,                 # [B, S, d_model]
    cfg: ModelConfig,
    *,
    mode: str = "train",            # train | prefill | decode
    cache: Optional[dict] = None,
    ) -> Tuple[jax.Array, Optional[dict]]:
    d_in, nheads, g, conv_ch = _dims(cfg)
    n = cfg.ssm_state
    hp = cfg.ssm_headdim

    z = jnp.einsum("...d,de->...e", xin, p["w_z"])
    xr = jnp.einsum("...d,de->...e", xin, p["w_x"])
    bc = jnp.einsum("...d,de->...e", xin, p["w_bc"])
    dt_raw = jnp.einsum("...d,de->...e", xin, p["w_dt"])
    A = -jnp.exp(p["A_log"])

    if mode == "decode":
        # xin: [B, 1, d]; cache: {"conv_x", "conv_bc", "ssm": [B,h,n,p] f32}
        b = xin.shape[0]
        win_x = jnp.concatenate([cache["conv_x"], xr[:, 0][:, None]], axis=1)
        win_bc = jnp.concatenate([cache["conv_bc"], bc[:, 0][:, None]], axis=1)
        cx = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", win_x.astype(jnp.float32),
                       p["conv_x_w"].astype(jnp.float32))
            + p["conv_x_b"].astype(jnp.float32)).astype(xin.dtype)
        cbc = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", win_bc.astype(jnp.float32),
                       p["conv_bc_w"].astype(jnp.float32))
            + p["conv_bc_b"].astype(jnp.float32)).astype(xin.dtype)
        x_t = cx.reshape(b, nheads, hp)
        B_t, C_t = jnp.split(cbc, 2, axis=-1)
        B_t = B_t.reshape(b, g, n)
        C_t = C_t.reshape(b, g, n)
        dt_t = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                               + p["dt_bias"][None, :])
        new_state, y = ssd_decode_step(cache["ssm"], x_t, dt_t, A, B_t, C_t)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * x_t.astype(jnp.float32)
        y = y.reshape(b, 1, d_in).astype(xin.dtype)
        new_cache = {"conv_x": win_x[:, 1:], "conv_bc": win_bc[:, 1:],
                     "ssm": new_state}
    else:
        b, s, _ = xin.shape
        cx = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"])
        cbc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
        x_ = cx.reshape(b, s, nheads, hp)
        B_, C_ = jnp.split(cbc, 2, axis=-1)
        B_ = B_.reshape(b, s, g, n)
        C_ = C_.reshape(b, s, g, n)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        y, final_state = ssd_chunked(x_, dt, A, B_, C_, chunk=cfg.ssm_chunk)
        y = (y.astype(jnp.float32)
             + p["D"][None, None, :, None] * x_.astype(jnp.float32))
        y = y.reshape(b, s, d_in).astype(xin.dtype)
        new_cache = None
        if mode == "prefill":
            k = cfg.ssm_conv
            new_cache = {"conv_x": xr[:, -(k - 1):, :],
                         "conv_bc": bc[:, -(k - 1):, :],
                         "ssm": final_state}

    # gated RMSNorm (mamba2's RMSNormGated, norm(x * silu(z)))
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    normed = apply_norm({"scale": p["norm_scale"]}, gated, "rmsnorm", 1e-5)
    out = jnp.einsum("...e,ed->...d", normed, p["w_out"])
    return out, new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in, nheads, g, conv_ch = _dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * g * cfg.ssm_state),
                             dtype),
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_state, cfg.ssm_headdim),
                         jnp.float32),
    }
