"""Elastic scaling, failure recovery, and straggler mitigation policies.

On a real cluster these hook into the cluster manager; here every decision
path is implemented and unit-tested, with the device-level effects realized
through JAX's resharding (device_put onto a new mesh) + the checkpoint
manager:

  * ``resharding_plan``     — mesh transition (e.g. pod loss 2->1, node loss
                              16x16 -> 16x12) with batch/LR rescaling rules.
  * ``FailureRecovery``     — wraps the train loop: on failure, restore the
                              latest checkpoint (possibly onto the surviving
                              mesh) and replay; bounded restarts.
  * ``StragglerMonitor``    — per-step deadline from a running p50; flags
                              persistent stragglers for replica eviction
                              (policy output = the new mesh spec).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.config.base import ParallelConfig, TrainConfig


@dataclass(frozen=True)
class ReshardingPlan:
    old_mesh: tuple
    new_mesh: tuple
    batch_scale: float        # keep global batch (1.0) or scale down
    lr_scale: float           # linear-scaling rule when batch changes
    reason: str


def resharding_plan(par: ParallelConfig, *, lost_pods: int = 0,
                    lost_data_rows: int = 0,
                    keep_global_batch: bool = True) -> ReshardingPlan:
    """Compute the mesh to run on after losing pods / data-axis rows.

    The model axis is never shrunk (param shards would be lost — a node
    failure inside a model-axis group is recovered by restarting the group
    from checkpoint, not by resharding)."""
    old = par.mesh_shape()
    pods = (par.pods if par.multi_pod else 1) - lost_pods
    data = par.data - lost_data_rows
    if pods < 1 or data < 1:
        raise ValueError("cannot reshard below one pod / one data row")
    new = (pods, data, par.model) if par.multi_pod else (data, par.model)
    frac = (pods * data) / ((par.pods if par.multi_pod else 1) * par.data)
    batch_scale = 1.0 if keep_global_batch else frac
    lr_scale = 1.0 if keep_global_batch else frac
    return ReshardingPlan(old_mesh=old, new_mesh=new,
                          batch_scale=batch_scale, lr_scale=lr_scale,
                          reason=f"lost_pods={lost_pods} lost_rows={lost_data_rows}")


@dataclass
class StragglerMonitor:
    """Deadline policy: a step slower than ``factor`` x running-p50 is a
    straggler event; ``evict_after`` consecutive events on the same replica
    triggers eviction (-> resharding_plan)."""
    factor: float = 3.0
    evict_after: int = 3
    window: int = 50
    _times: List[float] = field(default_factory=list)
    _consecutive: int = 0

    def observe(self, step_time_s: float) -> str:
        """Returns 'ok' | 'straggler' | 'evict'."""
        self._times.append(step_time_s)
        self._times = self._times[-self.window:]
        if len(self._times) < 5:
            return "ok"
        med = sorted(self._times)[len(self._times) // 2]
        if step_time_s > self.factor * med:
            self._consecutive += 1
            if self._consecutive >= self.evict_after:
                self._consecutive = 0
                return "evict"
            return "straggler"
        self._consecutive = 0
        return "ok"


class FailureRecovery:
    """Bounded-restart train-loop wrapper with checkpoint replay."""

    def __init__(self, ckpt_manager, max_restarts: int = 3):
        self.ckpt = ckpt_manager
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, train_fn: Callable[[int], int], start_step: int,
            total_steps: int) -> int:
        """``train_fn(start) -> last_step`` runs until done or raises.
        Returns the final step."""
        step = start_step
        while step < total_steps:
            try:
                step = train_fn(step)
            except Exception as e:  # noqa: BLE001 — any worker failure
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step
                else:
                    step = latest
        return step
