"""Train-step builder: loss + grad + AdamW under pjit with explicit shardings.

Two gradient-reduction paths across the pod (inter-DC) axis:
  * implicit (baseline): batch is sharded over ("pod","data"); XLA/GSPMD
    inserts the gradient all-reduce. The dry-run HLO of this path is what
    the roofline's collective term parses.
  * geo (MatchRDMA-aware): loss is computed per-pod mean, gradients cross
    the pod axis through ``hierarchical_grad_reduce`` (reduce-scatter intra-
    pod -> inter-pod exchange on 1/(data·model) shards -> all-gather), with
    optional int8 error-feedback compression — minimizing and shaping the
    bytes the OTN carries.

Microbatching = lax.scan gradient accumulation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models.model import Model
from repro.parallel.sharding import ShardingRules, named
from repro.train.optimizer import (
    AdamState, adam_update, clip_by_global_norm, init_adam,
)


def batch_specs(model: ModelConfig, rules: ShardingRules) -> dict:
    key = "tokens" if model.embed_inputs else "embeds"
    ndim = 2 if model.embed_inputs else 3
    return {key: rules.data_spec(ndim), "labels": rules.data_spec(2)}


def _split_microbatches(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def make_train_step(model: Model, par: ParallelConfig, train: TrainConfig,
                    mesh: Mesh):
    """Returns (jitted_step, init_fn) where
    step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    rules = ShardingRules(model.cfg, par)
    micro = max(par.microbatches, 1)

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    def step(params, opt_state, batch):
        if micro > 1:
            mb = _split_microbatches(batch, micro)

            def acc_body(carry, one):
                gsum, msum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, one)
                gsum = jax.tree.map(jnp.add, gsum, g)
                msum = {"loss": msum["loss"] + m["loss"],
                        "ce": msum["ce"] + m["ce"]}
                return (gsum, msum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.float32(0), "ce": jnp.float32(0)}
            (grads, msum), _ = jax.lax.scan(acc_body, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / micro, grads)
            metrics = {k: v / micro for k, v in msum.items()}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            metrics = {"loss": metrics["loss"], "ce": metrics["ce"]}

        grads, gnorm = clip_by_global_norm(grads, train.grad_clip)
        params, opt_state, om = adam_update(params, grads, opt_state, train)
        metrics = dict(metrics, grad_norm=gnorm, **om)
        return params, opt_state, metrics

    # shardings
    pspecs = rules.params_tree_specs  # function of tree
    bspec = batch_specs(model.cfg, rules)

    def init_fn(key):
        params = model.init(key)
        opt = init_adam(params, par.opt_state_dtype)
        return params, opt

    def jit_step(params_tree_example):
        ps = pspecs(params_tree_example)
        opt_ps = AdamState(step=P(), m=ps, v=ps)
        in_sh = (named(mesh, ps), named(mesh, opt_ps), named(mesh, bspec))
        out_sh = (named(mesh, ps), named(mesh, opt_ps), None)
        return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1))

    return step, init_fn, jit_step, rules


def lower_train_step(model: Model, par: ParallelConfig, train: TrainConfig,
                     mesh: Mesh, params_spec_tree, batch_specs_tree):
    """Dry-run entry: lower the train step from ShapeDtypeStructs only."""
    step, _, _, rules = make_train_step(model, par, train, mesh)
    ps = named(mesh, params_spec_tree)
    bs = named(mesh, batch_specs_tree)
    return step, rules
