"""AdamW + LR schedules, pure JAX, sharding-friendly.

Optimizer state mirrors the param tree (m, v per leaf) and therefore shards
exactly like the params (ZeRO-3 when fsdp=True). ``opt_state_dtype`` lets the
340B config keep m/v in bf16 (DESIGN.md §5(5)).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig


class AdamState(NamedTuple):
    step: jax.Array
    m: object     # pytree like params
    v: object


def init_adam(params, dtype: str = "float32") -> AdamState:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(
        step=jnp.int32(0),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to 10%."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float) -> Tuple[object, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adam_update(params, grads, state: AdamState, cfg: TrainConfig):
    """Returns (new_params, new_state, metrics). Math in f32; params and
    states cast back to their storage dtypes."""
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    corr1 = 1.0 - b1 ** step.astype(jnp.float32)
    corr2 = 1.0 - b2 ** step.astype(jnp.float32)

    # NOTE: three separate tree.maps (not one map returning tuples) — tuple
    # leaves would be ambiguous against structural tuples in the param tree
    # (e.g. the length-3 block-pattern groups); XLA CSEs the shared math.
    def new_m_fn(g, m):
        return (b1 * m.astype(jnp.float32)
                + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype)

    def new_v_fn(g, v):
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype)

    new_m = jax.tree.map(new_m_fn, grads, state.m)
    new_v = jax.tree.map(new_v_fn, grads, state.v)

    def new_p_fn(p, m, v):
        mhat = m.astype(jnp.float32) / corr1
        vhat = v.astype(jnp.float32) / corr2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
        return pf.astype(p.dtype)

    new_params = jax.tree.map(new_p_fn, params, new_m, new_v)
    return new_params, AdamState(step=step, m=new_m, v=new_v), {"lr": lr}
