from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.elastic import (
    FailureRecovery, ReshardingPlan, StragglerMonitor, resharding_plan,
)
from repro.train.optimizer import (
    AdamState, adam_update, clip_by_global_norm, global_norm, init_adam,
    lr_schedule,
)
from repro.train.train_step import batch_specs, make_train_step

__all__ = [
    "CheckpointManager", "SyntheticDataset", "FailureRecovery",
    "ReshardingPlan", "StragglerMonitor", "resharding_plan",
    "AdamState", "adam_update", "clip_by_global_norm", "global_norm",
    "init_adam", "lr_schedule", "batch_specs", "make_train_step",
]
