"""Deterministic synthetic data pipeline.

Markov-chain token streams (not uniform noise — gives the LM a learnable
signal so loss curves mean something) generated per-step from a counter-based
PRNG: step -> batch, fully deterministic, restart-safe (resume at step k
reproduces the exact batch k), and shardable (device_put with the batch
sharding).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, TrainConfig


def _markov_logits(vocab: int, order_dim: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((order_dim, order_dim)).astype(np.float32) * 2.0


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def _gen_tokens(key, batch: int, seq: int, vocab: int):
    """First-order Markov chain over a reduced state space, embedded in the
    full vocab (states map to token ids deterministically). The transition
    matrix comes from a FIXED key so every step draws from one language."""
    k = min(vocab, 257)
    # sharp transitions => low-entropy, learnable chain with repeated bigrams
    trans = jax.random.normal(jax.random.PRNGKey(7), (k, k)) * 4.0
    key1, key2 = jax.random.split(key)

    def step(state, kk):
        nxt = jax.random.categorical(kk, trans[state])
        return nxt, nxt

    init = jax.random.randint(key1, (batch,), 0, k)
    keys = jax.random.split(key2, seq)
    _, toks = jax.lax.scan(step, init, keys)
    toks = jnp.moveaxis(toks, 0, 1)                      # [batch, seq]
    # embed reduced states into the full vocab deterministically
    scale = max(vocab // k, 1)
    return (toks * scale) % vocab


class SyntheticDataset:
    """step -> batch dict. Deterministic, seekable."""

    def __init__(self, model: ModelConfig, train: TrainConfig,
                 sharding=None):
        self.model = model
        self.train = train
        self.sharding = sharding

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.train.seed), step)
        b, s = self.train.global_batch, self.train.seq_len
        toks = _gen_tokens(key, b, s + 1, self.model.vocab_size)
        toks = toks.astype(jnp.int32)
        if self.model.embed_inputs:
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        else:
            # modality-frontend stub: deterministic pseudo-embeddings from ids
            emb_key = jax.random.fold_in(key, 1)
            embeds = jax.random.normal(
                emb_key, (b, s, self.model.d_model), jnp.bfloat16)
            batch = {"embeds": embeds, "labels": toks[:, 1:]}
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding[k])
                     for k, v in batch.items()}
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
