"""Fault-tolerant checkpointing: atomic, keep-k, async, resume-capable.

Layout (one directory per step):
    <ckpt_dir>/step_000123/
        manifest.json      step, config fingerprint, tree structure, dtypes
        arrays.npz         flattened leaves (gathered to host)
    <ckpt_dir>/LATEST      -> "step_000123"  (atomic pointer file)

Writes go to ``step_X.tmp`` then os.replace() — a crash mid-write can never
corrupt the latest checkpoint (atomicity on POSIX rename). ``keep`` old
checkpoints are retained for rollback. ``async_save`` runs serialization on
a background thread so the train loop is not blocked (double-buffered via
jax.device_get before handing off).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if self._thread is not None:
            self._thread.join()        # previous async save must finish
            self._thread = None
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, paths, host_leaves, extra))
            self._thread.start()
        else:
            self._write(step, paths, host_leaves, extra)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, paths, host_leaves, extra) -> None:
        name = f"step_{step:09d}"
        final = os.path.join(self.dir, name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # store raw bytes: numpy's npz cannot represent bf16/f8 natively
        arrays = {f"a{i}": np.ascontiguousarray(leaf).view(np.uint8)
                  for i, leaf in enumerate(host_leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": [str(l.dtype) for l in host_leaves],
            "shapes": [list(l.shape) for l in host_leaves],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic publish
        # atomic LATEST pointer
        ptr_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(ptr_tmp, "w") as f:
            f.write(name)
        os.replace(ptr_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------- restore ----------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``like`` (values replaced).
        ``shardings``: optional matching tree of NamedShardings — this is the
        ELASTIC path: a checkpoint written on one mesh restores onto any
        other mesh (resharding happens in device_put)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        import ml_dtypes
        host = []
        for i in range(len(manifest["paths"])):
            raw = data[f"a{i}"]
            try:
                dt = np.dtype(manifest["dtypes"][i])
            except TypeError:
                dt = np.dtype(getattr(ml_dtypes, manifest["dtypes"][i]))
            host.append(raw.view(dt).reshape(manifest["shapes"][i]))
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(flat_like) == len(host), (
            f"checkpoint has {len(host)} leaves, target {len(flat_like)}")
        if shardings is not None:
            flat_sh = jax.tree_util.tree_flatten(shardings)[0]
            arrs = [jax.device_put(h, s) for h, s in zip(host, flat_sh)]
        else:
            # cast on device: numpy lacks cast kernels for bf16 & friends
            arrs = []
            for h, l in zip(host, flat_like):
                a = jax.device_put(h)
                if hasattr(l, "dtype") and a.dtype != l.dtype:
                    a = a.astype(l.dtype)
                arrs.append(a)
        return step, jax.tree_util.tree_unflatten(treedef, arrs)
