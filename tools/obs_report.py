#!/usr/bin/env python3
"""Summarize and diff netsim JSONL run manifests (docs/observability.md).

A manifest is written by ``run_experiment_batch``/``sweep_grid``
(``manifest_path=...``): one ``record: "header"`` line (git rev, plan
sha256 fingerprint, backend, grid summary) followed by one
``record: "launch"`` line per device launch (scheme, cell range,
compile/execute wall-clock split, XLA memory/cost figures).

Usage:
    python tools/obs_report.py summarize MANIFEST.jsonl
    python tools/obs_report.py diff OLD.jsonl NEW.jsonl

Pure stdlib on purpose — the CLI must work on a machine without the
simulator's dependencies (e.g. to inspect a manifest copied off a
cluster).
"""
from __future__ import annotations

import argparse
import json
import sys

_HEADER_KEYS = ("git_rev", "fingerprint", "backend", "n_devices",
                "trace_mode", "horizon_us", "steps", "n_cells", "schemes",
                "n_launches", "n_resumed", "timestamp")
_MEM_KEYS = ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes")


def load_manifest(path: str):
    """JSONL manifest -> (header dict, launch record list). Tolerates a
    missing header so partial files still summarize."""
    header, launches = {}, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("record") == "header":
                header = rec
            else:
                launches.append(rec)
    return header, launches


def _fmt_bytes(n) -> str:
    try:
        n = float(n)
    except (TypeError, ValueError):
        return "-"
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024.0 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024.0
    return f"{n:.1f}GB"


def _fmt_s(v) -> str:
    try:
        return f"{float(v):8.3f}"
    except (TypeError, ValueError):
        return "       -"


def _launch_key(rec: dict):
    return (rec.get("scheme"), rec.get("lo"), rec.get("hi"))


def summarize(path: str, out=sys.stdout) -> None:
    header, launches = load_manifest(path)
    print(f"manifest: {path}", file=out)
    for k in _HEADER_KEYS:
        if k in header:
            print(f"  {k:14s} {header[k]}", file=out)
    executed = [r for r in launches if not r.get("resumed")]
    resumed = len(launches) - len(executed)
    print(f"\nlaunches ({len(launches)} total, {resumed} resumed):",
          file=out)
    print(f"  {'scheme':12s} {'cells':>12s} {'compile_s':>9s} "
          f"{'execute_s':>9s} {'cached':>6s} {'temp':>9s} {'args':>9s}",
          file=out)
    for rec in launches:
        cells = f"[{rec.get('lo')}, {rec.get('hi')})"
        if rec.get("resumed"):
            print(f"  {rec.get('scheme', '?'):12s} {cells:>12s} "
                  f"{'(resumed from checkpoint)':>26s}", file=out)
            continue
        print(f"  {rec.get('scheme', '?'):12s} {cells:>12s} "
              f"{_fmt_s(rec.get('compile_s')):>9s} "
              f"{_fmt_s(rec.get('execute_s')):>9s} "
              f"{str(bool(rec.get('compile_cached'))).lower():>6s} "
              f"{_fmt_bytes(rec.get('temp_size_in_bytes')):>9s} "
              f"{_fmt_bytes(rec.get('argument_size_in_bytes')):>9s}",
              file=out)
    tot_c = sum(r.get("compile_s", 0.0) for r in executed)
    tot_e = sum(r.get("execute_s", 0.0) for r in executed)
    print(f"\ntotals: compile {tot_c:.3f}s  execute {tot_e:.3f}s  "
          f"(compile share "
          f"{tot_c / (tot_c + tot_e) * 100 if tot_c + tot_e else 0:.0f}%)",
          file=out)


def diff(path_a: str, path_b: str, out=sys.stdout) -> None:
    """Match launches across two manifests by (scheme, lo, hi) and print
    execute-time and memory deltas — the regression view for 'did this
    change make launches slower or fatter'."""
    ha, la = load_manifest(path_a)
    hb, lb = load_manifest(path_b)
    print(f"diff: {path_a} ({ha.get('git_rev', '?')}) -> "
          f"{path_b} ({hb.get('git_rev', '?')})", file=out)
    for k in ("backend", "n_devices", "trace_mode", "steps", "n_cells",
              "fingerprint"):
        va, vb = ha.get(k), hb.get(k)
        if va != vb:
            print(f"  {k}: {va} -> {vb}", file=out)
    a_by = {_launch_key(r): r for r in la if not r.get("resumed")}
    b_by = {_launch_key(r): r for r in lb if not r.get("resumed")}
    common = [k for k in a_by if k in b_by]
    print(f"\nmatched launches: {len(common)} "
          f"(only-old: {len(a_by) - len(common)}, "
          f"only-new: {len(b_by) - len(common)})", file=out)
    print(f"  {'scheme':12s} {'cells':>12s} {'exec_old':>9s} "
          f"{'exec_new':>9s} {'ratio':>6s} {'temp_old':>9s} "
          f"{'temp_new':>9s}", file=out)
    for key in common:
        ra, rb = a_by[key], b_by[key]
        ea, eb = ra.get("execute_s"), rb.get("execute_s")
        try:
            ratio = f"{float(eb) / float(ea):5.2f}x"
        except (TypeError, ValueError, ZeroDivisionError):
            ratio = "    -"
        cells = f"[{key[1]}, {key[2]})"
        print(f"  {key[0] or '?':12s} {cells:>12s} "
              f"{_fmt_s(ea):>9s} {_fmt_s(eb):>9s} {ratio:>6s} "
              f"{_fmt_bytes(ra.get('temp_size_in_bytes')):>9s} "
              f"{_fmt_bytes(rb.get('temp_size_in_bytes')):>9s}", file=out)
    for label, records in (("old", [a_by[k] for k in common]),
                           ("new", [b_by[k] for k in common])):
        tot_e = sum(r.get("execute_s", 0.0) for r in records)
        tot_c = sum(r.get("compile_s", 0.0) for r in records)
        print(f"totals[{label}]: compile {tot_c:.3f}s  "
              f"execute {tot_e:.3f}s", file=out)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Summarize / diff netsim JSONL run manifests")
    sub = p.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="print one manifest's header, "
                                          "per-launch table and totals")
    ps.add_argument("manifest")
    pd = sub.add_parser("diff", help="match two manifests' launches and "
                                     "print execute/memory deltas")
    pd.add_argument("old")
    pd.add_argument("new")
    args = p.parse_args(argv)
    if args.cmd == "summarize":
        summarize(args.manifest)
    else:
        diff(args.old, args.new)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
