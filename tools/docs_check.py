#!/usr/bin/env python
"""Docs lint (``make docs-check``): fail CI on documentation drift.

Four checks, all against the live code so the docs cannot silently rot:

  1. Intra-repo links in ``README.md`` and ``docs/*.md`` resolve — every
     relative ``[text](path)`` target must exist on disk (anchors are
     stripped; absolute http(s)/mailto links are skipped).
  2. Scheme-table completeness — every name in
     ``repro.netsim.schemes.available_schemes()`` appears in a table row of
     ``docs/scheme-api.md``, so registering a scheme without documenting it
     breaks the build.
  3. Hook coverage — every public hook method on ``Scheme`` (introspected,
     not hard-coded) is documented in ``docs/scheme-api.md``.
  4. Channel-model coverage — same pair of checks for the channel
     subsystem: every ``available_channel_models()`` name in a table row
     of ``docs/channel-models.md``, every public ``ChannelModel`` hook
     documented there.
  5. Topology-knob coverage — every multi-link ``NetConfig`` field
     (introspected: ``num_paths`` + every ``path_*`` / ``rdmacell_*``
     dataclass field) appears in a table row of ``docs/topology.md``, so
     adding a topology or rdmacell knob without documenting it breaks
     the build.
  6. Sites-knob coverage — same for the multi-site subsystem:
     ``num_sites`` + every ``site_*`` ``NetConfig`` field and every
     ``SiteEdge`` field in a table row of ``docs/sites.md``.
  7. Channel-knob coverage — every ``channel_*`` ``NetConfig`` field
     (the model-choice seed and the ``trace_replay`` schedule knobs) in
     a table row of ``docs/channel-models.md``.
  8. Failure-knob coverage — every ``failure_*`` ``NetConfig`` field and
     every ``FailureSchedule`` constructor field in a table row of
     ``docs/failures.md``, so adding a fault-injection knob without
     documenting it breaks the build.
  9. Soft/grad-knob coverage — every ``soft_*`` ``NetConfig`` field,
     every tunable knob in ``grad_tune.KNOB_BOUNDS`` /
     ``ADVERSARIAL_BOUNDS``, and every relaxation helper exported by
     ``repro.netsim.soft`` must appear in ``docs/differentiable.md``
     (knobs in a table row, helpers anywhere in the text), so growing
     the differentiable surface without documenting it breaks the
     build.
 10. Observability coverage — every obs ``NetConfig`` knob
     (``event_ring_slots`` + ``trace_window_*``) and every event-kind
     name in ``repro.netsim.obs.EVENT_KINDS`` in a table row of
     ``docs/observability.md``, so adding an obs knob or an event kind
     without documenting it breaks the build. (The ``emit_events`` hook
     itself is covered by the introspected Scheme-hook check on
     ``docs/scheme-api.md``.)

Exit status is the error count (0 = clean).

    PYTHONPATH=src python tools/docs_check.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEME_API_MD = os.path.join(ROOT, "docs", "scheme-api.md")
CHANNEL_MD = os.path.join(ROOT, "docs", "channel-models.md")
TOPOLOGY_MD = os.path.join(ROOT, "docs", "topology.md")
SITES_MD = os.path.join(ROOT, "docs", "sites.md")
FAILURES_MD = os.path.join(ROOT, "docs", "failures.md")
DIFFERENTIABLE_MD = os.path.join(ROOT, "docs", "differentiable.md")
OBSERVABILITY_MD = os.path.join(ROOT, "docs", "observability.md")

# [text](target) — excluding images' inner brackets is unnecessary here;
# nested ![alt](img) links resolve the same way
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def _md_files():
    files = [os.path.join(ROOT, "README.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_links(errors: list) -> None:
    for md in _md_files():
        base = os.path.dirname(md)
        text = open(md, encoding="utf-8").read()
        for target in _LINK_RE.findall(text):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(md, ROOT)}: broken intra-repo link "
                    f"-> {target}")


def check_registry_doc(errors: list, md_path: str, names, base_cls,
                       label: str, hint: str = "") -> None:
    """The shared registry-vs-doc check: every registered name appears in
    a table row of ``md_path``, and every public hook method on
    ``base_cls`` (introspected, not hard-coded — new hooks break the
    build until written up) is mentioned."""
    rel = os.path.relpath(md_path, ROOT)
    if not os.path.exists(md_path):
        errors.append(f"{rel} is missing")
        return
    text = open(md_path, encoding="utf-8").read()
    table_rows = [ln for ln in text.splitlines() if ln.lstrip().startswith("|")]
    for name in names:
        if not any(f"`{name}`" in row for row in table_rows):
            errors.append(
                f"{rel}: registered {label} {name!r} missing from the "
                f"table — document it{hint}")

    hooks = [m for m, v in vars(base_cls).items()
             if callable(v) and not m.startswith("_")]
    for hook in hooks:
        if f"`{hook}" not in text:
            errors.append(
                f"{rel}: {base_cls.__name__} hook {hook!r} undocumented")


def check_scheme_table(errors: list) -> None:
    from repro.netsim.schemes import Scheme, available_schemes
    check_registry_doc(errors, SCHEME_API_MD, available_schemes(), Scheme,
                       "scheme", hint=" (see docs/writing-a-scheme.md "
                       "step 6)")


def check_channel_table(errors: list) -> None:
    from repro.netsim.channel import ChannelModel, available_channel_models
    check_registry_doc(errors, CHANNEL_MD, available_channel_models(),
                       ChannelModel, "channel model")


def _check_knob_table(errors: list, md_path: str, knobs, label: str) -> None:
    """Shared knob-vs-doc check: every name in ``knobs`` must sit in a
    table row of ``md_path``."""
    rel = os.path.relpath(md_path, ROOT)
    if not os.path.exists(md_path):
        errors.append(f"{rel} is missing")
        return
    text = open(md_path, encoding="utf-8").read()
    table_rows = [ln for ln in text.splitlines()
                  if ln.lstrip().startswith("|")]
    for knob in knobs:
        if not any(f"`{knob}`" in row for row in table_rows):
            errors.append(
                f"{rel}: {label} knob {knob!r} missing from the table "
                f"— document it")


def check_topology_table(errors: list) -> None:
    """Every multi-link NetConfig knob must sit in a table row of
    docs/topology.md. The field list is introspected from the dataclass,
    so a new ``path_*``/``rdmacell_*`` knob fails the lint until it is
    written up."""
    import dataclasses

    from repro.config.base import NetConfig

    knobs = ["num_paths"] + sorted(
        f.name for f in dataclasses.fields(NetConfig)
        if f.name.startswith(("path_", "rdmacell_")))
    _check_knob_table(errors, TOPOLOGY_MD, knobs, "topology")


def check_sites_table(errors: list) -> None:
    """Every multi-site NetConfig knob (``num_sites`` + ``site_*``) and
    every ``SiteEdge`` field must sit in a table row of docs/sites.md —
    both introspected, so new site-graph knobs fail the lint until
    written up."""
    import dataclasses

    from repro.config.base import NetConfig
    from repro.netsim.topology import SiteEdge

    knobs = ["num_sites"] + sorted(
        f.name for f in dataclasses.fields(NetConfig)
        if f.name.startswith("site_"))
    knobs += [f.name for f in dataclasses.fields(SiteEdge)]
    _check_knob_table(errors, SITES_MD, knobs, "site-graph")


def check_channel_knobs(errors: list) -> None:
    """Every ``channel_*`` NetConfig knob (the PRNG seed and the
    trace_replay schedule fields) must sit in a table row of
    docs/channel-models.md."""
    import dataclasses

    from repro.config.base import NetConfig

    knobs = sorted(f.name for f in dataclasses.fields(NetConfig)
                   if f.name.startswith("channel_"))
    _check_knob_table(errors, CHANNEL_MD, knobs, "channel")


def check_failures_table(errors: list) -> None:
    """Every fault-injection knob — the ``failure_*`` ``NetConfig``
    fields and the ``FailureSchedule`` constructor fields — must sit in
    a table row of docs/failures.md. Both introspected, so a new outage
    knob fails the lint until written up."""
    import dataclasses

    from repro.config.base import NetConfig
    from repro.netsim.failures import FailureSchedule

    knobs = sorted(f.name for f in dataclasses.fields(NetConfig)
                   if f.name.startswith("failure_"))
    knobs += [f.name for f in dataclasses.fields(FailureSchedule)]
    _check_knob_table(errors, FAILURES_MD, knobs, "failure")


def check_soft_grad_knobs(errors: list) -> None:
    """Every ``soft_*`` ``NetConfig`` field and every tunable knob the
    gradient tuner knows about must sit in a table row of
    docs/differentiable.md, and every relaxation helper exported by
    ``repro.netsim.soft`` must be mentioned there — all introspected, so
    a new soft knob, tuner box, or helper fails the lint until written
    up."""
    import dataclasses

    from repro.config.base import NetConfig
    from repro.netsim import grad_tune, soft

    knobs = sorted(f.name for f in dataclasses.fields(NetConfig)
                   if f.name.startswith("soft_"))
    knobs += sorted(set(grad_tune.KNOB_BOUNDS)
                    | set(grad_tune.ADVERSARIAL_BOUNDS))
    _check_knob_table(errors, DIFFERENTIABLE_MD, knobs, "soft/grad")

    if os.path.exists(DIFFERENTIABLE_MD):
        rel = os.path.relpath(DIFFERENTIABLE_MD, ROOT)
        text = open(DIFFERENTIABLE_MD, encoding="utf-8").read()
        for helper in soft.__all__:
            if f"`{helper}" not in text:
                errors.append(
                    f"{rel}: soft helper {helper!r} undocumented")


def check_obs_table(errors: list) -> None:
    """Every observability knob — the ``event_*``/``trace_window_*``
    ``NetConfig`` fields — and every event-kind name in
    ``repro.netsim.obs.EVENT_KINDS`` must sit in a table row of
    docs/observability.md. Both introspected, so a new obs knob or event
    kind fails the lint until written up."""
    import dataclasses

    from repro.config.base import NetConfig
    from repro.netsim.obs import EVENT_KINDS

    knobs = sorted(f.name for f in dataclasses.fields(NetConfig)
                   if f.name.startswith(("event_", "trace_window")))
    knobs += sorted(EVENT_KINDS)
    _check_knob_table(errors, OBSERVABILITY_MD, knobs, "observability")


def main() -> int:
    errors: list = []
    check_links(errors)
    check_scheme_table(errors)
    check_channel_table(errors)
    check_topology_table(errors)
    check_sites_table(errors)
    check_channel_knobs(errors)
    check_failures_table(errors)
    check_soft_grad_knobs(errors)
    check_obs_table(errors)
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    n_files = len(_md_files())
    if not errors:
        print(f"docs-check: OK ({n_files} markdown files, links + scheme "
              f"table + hook coverage + channel-model table + topology "
              f"knobs + site-graph knobs + channel knobs + failure knobs "
              f"+ soft/grad knobs + obs knobs/event kinds)")
    return min(len(errors), 100)


if __name__ == "__main__":
    sys.exit(main())
