"""Multi-site topology subsystem (PR 7): SiteGraph/SiteEdge compilation
onto the link axis, the per-flow endpoint matrix, endpoint validation,
the two-site bit-identity guarantee, heterogeneous-endpoint batching,
and the launch-plan satellites (simulate_batch pad-and-shard, the
schedule-aware ``chunk_cells``, the ``_chunk_cells`` deprecation shim).

The goldens (tests/test_scheme_api.py) pin the default two-site world;
this file covers what is NEW on top of it."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config.base import NetConfig
from repro.netsim import (
    SiteEdge, SiteGraph, compile_site_graph, fluid, get_scheme,
    run_experiment_batch, simulate, simulate_batch, throughput_workload,
)
from repro.netsim.topology import validate_site_endpoints
from repro.netsim.workload import FlowSpec, Workload

HORIZON = 8_000.0
WL = throughput_workload(msg_size=1 << 20, concurrency=16, num_flows=4)

# The 3-site relay mesh the --sites-grid benchmark sweeps: a direct pair
# of 0->1 links plus a thin two-hop detour through relay site 2.
MESH = SiteGraph(num_sites=3, edges=(
    SiteEdge(0, 1),
    SiteEdge(0, 1, delay_scale=1.5),
    SiteEdge(0, 2, cap_frac=0.2),
    SiteEdge(2, 1, cap_frac=0.2),
))


def _mesh_cfg(**kw):
    base = NetConfig(distance_km=100.0, horizon_us=HORIZON, **kw)
    return MESH.to_net_config(base)


def _wl(*pairs, intra=0):
    flows = [FlowSpec(True, 1 << 20, 16, src_site=s, dst_site=d)
             for s, d in pairs]
    flows += [FlowSpec(False, 1 << 20, 16) for _ in range(intra)]
    return Workload(tuple(flows))


# ---------------------------------------------------------------------------
# Graph construction and validation
# ---------------------------------------------------------------------------

def test_site_graph_validation():
    with pytest.raises(ValueError, match="num_sites"):
        SiteGraph(num_sites=1, edges=(SiteEdge(0, 1),))
    with pytest.raises(ValueError, match="at least one edge"):
        SiteGraph(num_sites=3, edges=())
    with pytest.raises(ValueError, match="self-edge"):
        SiteGraph(num_sites=3, edges=(SiteEdge(1, 1),))
    with pytest.raises(ValueError, match="outside"):
        SiteGraph(num_sites=2, edges=(SiteEdge(0, 2),))
    with pytest.raises(TypeError, match="SiteEdge"):
        SiteGraph(num_sites=2, edges=((0, 1),))


def test_net_config_site_edges_validation():
    with pytest.raises(ValueError, match="num_sites"):
        NetConfig(num_sites=1).edge_pairs()
    with pytest.raises(ValueError, match="site_edges"):
        NetConfig(num_paths=2, site_edges=((0, 1),)).edge_pairs()
    with pytest.raises(ValueError, match="self-edge"):
        NetConfig(site_edges=((0, 0),)).edge_pairs()
    with pytest.raises(ValueError, match="outside"):
        NetConfig(num_sites=3, site_edges=((0, 3),)).edge_pairs()
    # defaults: every link implicitly serves the 0 -> 1 pair
    assert NetConfig(num_paths=3).edge_pairs() == ((0, 1),) * 3
    assert not NetConfig(num_paths=3).is_multisite
    assert NetConfig(num_sites=3, num_paths=1,
                     site_edges=((0, 2),)).is_multisite


def test_compile_site_graph_lowers_edges_onto_links():
    cfg = _mesh_cfg()
    assert cfg.num_paths == MESH.num_edges == 4
    assert cfg.num_sites == 3
    assert cfg.site_edges == ((0, 1), (0, 1), (0, 2), (2, 1))
    assert cfg.edge_pairs() == cfg.site_edges
    assert cfg.is_multisite
    assert cfg.path_delay_scale == (1.0, 1.5, 1.0, 1.0)
    # named edges take 0.2 + 0.2; the two unnamed split the remaining 0.6
    np.testing.assert_allclose(cfg.path_cap_frac, (0.3, 0.3, 0.2, 0.2))
    assert compile_site_graph(MESH, NetConfig()) == MESH.to_net_config(
        NetConfig())
    assert MESH.edges_between(0, 1) == (0, 1)
    assert MESH.edges_between(2, 1) == (3,)
    assert MESH.edges_between(1, 0) == ()


# ---------------------------------------------------------------------------
# Two-site invisibility: explicit (0, 1) edges emit the same program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ("dcqcn", "matchrdma", "rdmacell"))
def test_two_site_edges_bit_identical_to_plain_links(scheme):
    """num_sites=2 with every edge spelled out as (0, 1) must reproduce
    the plain multi-link run bit-for-bit: the endpoint mask is all-ones
    and multiplies the route matrix by exactly 1.0."""
    kw = dict(distance_km=100.0, horizon_us=HORIZON, num_paths=3,
              path_delay_scale=(1.0, 1.5, 2.0),
              path_cap_frac=(0.5, 0.3, 0.2))
    plain = NetConfig(**kw)
    sited = NetConfig(site_edges=((0, 1),) * 3, **kw)
    f_a, tr_a = simulate(plain, WL, get_scheme(scheme), HORIZON)
    f_b, tr_b = simulate(sited, WL, get_scheme(scheme), HORIZON)
    assert set(tr_a) == set(tr_b)
    for k in tr_a:
        np.testing.assert_array_equal(np.asarray(tr_a[k]),
                                      np.asarray(tr_b[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(f_a.delivered),
                                  np.asarray(f_b.delivered))


# ---------------------------------------------------------------------------
# Endpoint matrix semantics
# ---------------------------------------------------------------------------

def test_endpoint_matrix_masks_flows_onto_matching_edges():
    """Flows spray only over the links whose edge serves their site pair:
    a relay-only workload leaves the direct links dark and vice versa."""
    _, tr_relay = simulate(_mesh_cfg(), _wl((0, 2), (2, 1)),
                           get_scheme("dcqcn"), HORIZON)
    link_tx = np.asarray(tr_relay["link_tx"])
    assert float(link_tx[:, :2].max()) == 0.0   # direct 0->1 links dark
    assert float(link_tx[:, 2].sum()) > 0.0
    assert float(link_tx[:, 3].sum()) > 0.0
    _, tr_direct = simulate(_mesh_cfg(), _wl((0, 1), (0, 1)),
                            get_scheme("dcqcn"), HORIZON)
    link_tx = np.asarray(tr_direct["link_tx"])
    assert float(link_tx[:, 2:].max()) == 0.0   # relay legs dark
    assert float(link_tx[:, :2].sum()) > 0.0


def test_route_weights_bias_within_edge_set():
    """Explicit route weights still bias the split WITHIN a flow's edge
    set: weighting the slow 0->1 link to zero keeps everything on the
    fast one, never leaking onto relay edges."""
    wl = Workload(tuple(
        FlowSpec(True, 1 << 20, 16, route=(1.0, 0.0, 1.0, 1.0),
                 src_site=0, dst_site=1) for _ in range(4)))
    _, traces = simulate(_mesh_cfg(), wl, get_scheme("dcqcn"), HORIZON)
    link_tx = np.asarray(traces["link_tx"])
    assert float(link_tx[:, 0].sum()) > 0.0
    assert float(link_tx[:, 1:].max()) == 0.0


def test_multisite_conserves_and_completes():
    final, traces = simulate(_mesh_cfg(), _wl((0, 1), (0, 1), (0, 2), (2, 1),
                                              intra=2),
                             get_scheme("matchrdma"), HORIZON)
    assert float(np.max(np.asarray(traces["cons_err"]))) < 1e-3
    assert float(np.sum(np.asarray(final.delivered))) > 0


def test_unreachable_endpoints_raise():
    wl = _wl((1, 0))   # no edge serves 1 -> 0 in the mesh
    with pytest.raises(ValueError, match=r"1 -> 0"):
        simulate(_mesh_cfg(), wl, get_scheme("dcqcn"), HORIZON)
    with pytest.raises(ValueError, match="match no edge"):
        simulate_batch([_mesh_cfg()], wl, get_scheme("dcqcn"), HORIZON)
    # the host-side checker is reachable directly too
    from repro.netsim.workload import WorkloadParams
    validate_site_endpoints(_mesh_cfg(), WorkloadParams.of(_wl((0, 2))))


def test_multisite_requires_link_axis():
    cfg = NetConfig(num_sites=3, num_paths=1, site_edges=((0, 2),))
    with pytest.raises(ValueError, match="num_paths"):
        simulate(cfg, _wl((0, 2)), get_scheme("dcqcn"), HORIZON)


# ---------------------------------------------------------------------------
# Batching: heterogeneous endpoints in one compiled program
# ---------------------------------------------------------------------------

def test_heterogeneous_endpoint_batch_single_compile():
    """src/dst sites are traced WorkloadParams leaves: scenarios whose
    flows talk to different sites vmap into ONE compiled program, and
    each cell's traffic lands on its own edge set."""
    cfgs = [_mesh_cfg(), _mesh_cfg()]
    wls = [_wl((0, 1), (0, 1)), _wl((0, 2), (2, 1))]
    n0 = fluid._run_traced_batch._cache_size()
    _, traces = simulate_batch(cfgs, wls, get_scheme("dcqcn"), HORIZON)
    assert fluid._run_traced_batch._cache_size() - n0 <= 1, \
        "endpoint variation recompiled per cell — endpoints are not traced"
    link_tx = np.asarray(traces["link_tx"])   # [B, T, L]
    assert float(link_tx[0, :, 2:].max()) == 0.0
    assert float(link_tx[0, :, :2].sum()) > 0.0
    assert float(link_tx[1, :, :2].max()) == 0.0
    assert float(link_tx[1, :, 2:].sum()) > 0.0


def test_mixed_num_sites_batch_rejected():
    cfgs = [_mesh_cfg(),
            SiteGraph(num_sites=4, edges=MESH.edges).to_net_config(
                NetConfig(distance_km=100.0, horizon_us=HORIZON))]
    with pytest.raises(ValueError, match="num_sites"):
        simulate_batch(cfgs, _wl((0, 1)), get_scheme("dcqcn"), HORIZON)


def test_sites_streaming_rows_finite():
    rows = run_experiment_batch(
        [_mesh_cfg()], _wl((0, 1), (0, 2), (2, 1), intra=1),
        "matchrdma", HORIZON, trace_mode="metrics")
    (row,) = rows
    assert np.isfinite(row["throughput_gbps"])
    assert row["throughput_gbps"] > 0.0


# ---------------------------------------------------------------------------
# Satellite: simulate_batch pads ragged batches onto the device grid
# ---------------------------------------------------------------------------

_SUBPROC_PAD = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.config.base import NetConfig
    from repro.netsim import get_scheme, simulate_batch, throughput_workload
    assert len(jax.devices()) == 4
    wl = throughput_workload(1 << 20, 16, num_flows=4)
    # 3 scenarios on 4 devices: simulate_batch used to silently fall back
    # to a single-device launch when the device count did not divide the
    # batch — now it pads with a replica of the last cell, shards, and
    # strips the pad from every output leaf
    cfgs = [NetConfig(distance_km=d, horizon_us=6_000.0)
            for d in (50.0, 100.0, 200.0)]
    f4, tr4 = simulate_batch(cfgs, wl, get_scheme("dcqcn"), 6_000.0)
    f1, tr1 = simulate_batch(cfgs, wl, get_scheme("dcqcn"), 6_000.0,
                             devices=jax.devices()[:1])
    assert np.asarray(f4.delivered).shape[0] == 3
    for k in tr4:
        a, b = np.asarray(tr4[k]), np.asarray(tr1[k])
        assert a.shape == b.shape, (k, a.shape, b.shape)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3, err_msg=k)
    np.testing.assert_allclose(np.asarray(f4.delivered),
                               np.asarray(f1.delivered), rtol=1e-5)
    print("SIM_BATCH_PAD_OK")
""")


def test_simulate_batch_pads_ragged_batch_onto_devices():
    """Satellite pin: a 3-cell simulate_batch on 4 forced host devices
    shards (pad-and-strip) and matches the single-device run."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_PAD],
                       capture_output=True, text=True, cwd=".", timeout=600)
    assert "SIM_BATCH_PAD_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Satellite: launch-plan sizing knows about schedule tables; the old
# private alias warns
# ---------------------------------------------------------------------------

def test_chunk_cells_accounts_for_schedule_floats():
    from repro.netsim import runner
    t = 100_000
    base = runner.chunk_cells(t, "full")
    # a fat [L, K, 3] schedule rides per cell -> smaller chunks
    sched = 4 * 50_000 * 3
    small = runner.chunk_cells(t, "full", schedule_floats=sched)
    assert small < base
    assert small * (t * runner._TRACE_KEYS_EST + sched) \
        <= runner.MAX_TRACE_FLOATS
    # metrics mode is normally width-agnostic, but a schedule big enough
    # to dominate memory still caps the chunk
    assert runner.chunk_cells(t, "metrics") == runner.METRICS_CHUNK_CELLS
    huge = 4 * 1_000_000 * 3
    capped = runner.chunk_cells(t, "metrics", schedule_floats=huge)
    assert capped < runner.METRICS_CHUNK_CELLS
    assert capped * huge <= runner.MAX_TRACE_FLOATS
    # zero/negative schedule footprints are inert
    assert runner.chunk_cells(t, "full", schedule_floats=0) == base


def test_chunk_cells_deprecated_alias_warns():
    from repro.netsim import runner
    with pytest.warns(DeprecationWarning, match="_chunk_cells"):
        fn = runner._chunk_cells
    assert fn is runner.chunk_cells
    with pytest.raises(AttributeError):
        runner.no_such_attribute_here
