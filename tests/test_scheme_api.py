"""Scheme API: golden parity vs the pre-refactor monolith (paper schemes)
and the first-registration pins (related-work pack), the registry,
custom-scheme end-to-end plumbing, the unified workload/Scenario axis, and
the deprecated string entrypoints."""
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import NetConfig
from repro.netsim import (
    SCHEMES, Scenario, Scheme, available_schemes, batch_padding, get_scheme,
    register_scheme, run_experiment, run_experiment_batch, simulate,
    simulate_batch, sweep_grid, throughput_workload,
)
from repro.netsim.schemes import ALL_SCHEMES, RELATED_SCHEMES, unregister_scheme
from repro.netsim.workload import (
    WorkloadParams, congestion_workload, stack_workload_params,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")
WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


# ---------------------------------------------------------------------------
# Golden parity. For the paper's four schemes the pin is the pre-refactor
# string-switched monolith (PR 1, commit 98b8c0e): the hook decomposition
# must emit the numerically identical program. For the related-work pack
# (geopipe, sdr_rdma) the pin is their first registered implementation.
# Traces captured by tests/golden/generate_goldens.py.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_golden_parity_sequential(golden, scheme):
    cfg = NetConfig(distance_km=100.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    final, traces = simulate(cfg, wl, get_scheme(scheme), 10_000.0)
    golden_keys = {k.rsplit("/", 1)[1] for k in golden.files
                   if k.startswith(f"seq/{scheme}/traces/")}
    assert set(traces) == golden_keys, \
        f"{scheme} trace-key set drifted — regenerate goldens deliberately"
    for k, v in traces.items():
        ref = golden[f"seq/{scheme}/traces/{k}"]
        np.testing.assert_array_equal(
            ref, np.asarray(v), err_msg=f"{scheme}/{k} diverged bit-for-bit")
    for k in ("sent", "acked", "delivered", "done_at_us"):
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/final/{k}"],
            np.asarray(getattr(final, k)),
            err_msg=f"{scheme} final.{k} diverged")


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_golden_parity_batched(golden, scheme):
    cfgs = [NetConfig(distance_km=d) for d in (1.0, 300.0)]
    final, traces = simulate_batch(cfgs, WL, get_scheme(scheme), 8_000.0)
    keys = [k.rsplit("/", 1)[1] for k in golden.files
            if k.startswith(f"batch/{scheme}/traces/")]
    assert set(keys) == set(traces), f"{scheme} batched trace-key set drifted"
    for k in keys:
        np.testing.assert_array_equal(
            golden[f"batch/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"batched {scheme}/{k} diverged bit-for-bit")
    np.testing.assert_array_equal(
        golden[f"batch/{scheme}/final/delivered"],
        np.asarray(final.delivered))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_builtins_registered():
    names = available_schemes()
    for s in SCHEMES:
        assert s in names
        assert get_scheme(s).name == s
    # instances pass through untouched
    inst = get_scheme("matchrdma")
    assert get_scheme(inst) is inst


def test_registry_lists_all_seven():
    """The shipped registry is exactly the paper's four plus the
    related-work pack, every name round-trips through ``get_scheme``, and
    the seven are what ``available_schemes`` advertises (tests that
    register extras clean up after themselves)."""
    assert len(ALL_SCHEMES) == 7
    assert set(ALL_SCHEMES) == set(SCHEMES) | set(RELATED_SCHEMES)
    assert set(available_schemes()) == set(ALL_SCHEMES), \
        "registry leak: some test registered a scheme without cleanup"
    for name in ALL_SCHEMES:
        inst = get_scheme(name)
        assert inst.name == name
        assert get_scheme(inst) is inst              # instance passthrough
        assert get_scheme(inst.name) is inst         # name round-trip


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_streaming_full_equivalence_all_schemes(scheme):
    """Every registered scheme — related-work pack included — survives the
    streaming/full equivalence check: ``trace_mode="metrics"`` rows match
    the materialized-trace extraction (tight for means/max/pause, bounded
    relative error for the histogram-inverted p99), and the scheme's
    streamed columns are present and finite. This is the ONE copy of the
    parity check (it superseded the PR 3 four-scheme version in
    tests/test_streaming_metrics.py)."""
    cfgs = [NetConfig(distance_km=d) for d in (100.0, 700.0)]
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    full = run_experiment_batch(cfgs, wl, scheme, 10_000.0)
    stream = run_experiment_batch(cfgs, wl, scheme, 10_000.0,
                                  trace_mode="metrics")
    for f, s in zip(full, stream):
        for m in ("throughput_gbps", "intra_thr_gbps", "mean_buffer_mb",
                  "peak_buffer_mb", "pause_ratio", "goodput_bytes",
                  "completion_frac"):
            rel = abs(f[m] - s[m]) / max(abs(f[m]), abs(s[m]), 1e-4)
            assert rel < 1e-3, (scheme, f["distance_km"], m, f[m], s[m])
        # p99 comes from the fixed-bin log-histogram: bin-ratio-bounded
        p99_rel = (abs(f["p99_buffer_mb"] - s["p99_buffer_mb"])
                   / max(abs(f["p99_buffer_mb"]), abs(s["p99_buffer_mb"]),
                         1e-3))
        assert p99_rel < 0.1, (scheme, f["p99_buffer_mb"], s["p99_buffer_mb"])
        # congestion workload has no finite flows: FCT is NaN either way
        assert np.isnan(f["avg_fct_us"]) == np.isnan(s["avg_fct_us"])
        # streamed scheme columns exist beyond the engine metric set and
        # carry finite values
        extra_cols = set(s) - set(f)
        assert extra_cols, f"{scheme} streamed no scheme-specific columns"
        assert all(np.isfinite(s[c]) for c in extra_cols), (scheme, s)


def test_related_knobs_sweep_batchwide():
    """The related-work knobs are traced ``NetParams`` leaves: a knob grid
    runs as ONE compiled launch (no per-cell recompile) and the knob
    actually bites — a tighter geopipe credit window / sdr receive window
    throttles throughput monotonically."""
    from repro.netsim import fluid
    wl = throughput_workload(msg_size=4 << 20, concurrency=8, num_flows=4)

    cfgs = [NetConfig(distance_km=100.0, geopipe_credit_bdp_frac=f)
            for f in (0.02, 0.08, 1.0)]
    n0 = fluid._run_traced_batch._cache_size()
    rows = run_experiment_batch(cfgs, wl, "geopipe", 10_000.0,
                                trace_mode="metrics")
    assert fluid._run_traced_batch._cache_size() - n0 <= 1, \
        "knob grid recompiled per cell — the knobs are not traced leaves"
    thr = [r["throughput_gbps"] for r in rows]
    assert thr[0] < thr[1] < thr[2], thr

    cfgs = [NetConfig(distance_km=100.0, sdr_window_bdp_frac=f)
            for f in (0.02, 0.1, 1.0)]
    rows = run_experiment_batch(cfgs, wl, "sdr_rdma", 10_000.0,
                                trace_mode="metrics")
    thr = [r["throughput_gbps"] for r in rows]
    assert thr[0] < thr[1] < thr[2], thr
    # ack coalescing: a coarser interval strictly grows the held-back lag
    cfgs = [NetConfig(distance_km=100.0, sdr_ack_coalesce_us=u)
            for u in (5.0, 500.0)]
    rows = run_experiment_batch(cfgs, wl, "sdr_rdma", 10_000.0,
                                trace_mode="metrics")
    assert rows[0]["mean_ack_lag_mb"] < rows[1]["mean_ack_lag_mb"]


def test_geopipe_default_is_pfc_free_under_congestion():
    """GeoPipe's identity: with the default credit window provisioned
    inside the segment buffer, a downstream intra-DC burst never drives the
    long-haul pause ratio above zero — while conventional e2e DCQCN pauses
    — and throughput still clears the DCQCN baseline (the credit gate
    replaces the long CNP loop)."""
    cfg = NetConfig(distance_km=100.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=12_000.0)
    rows = sweep_grid([cfg], wl, ("geopipe", "dcqcn"),
                      horizon_us=12_000.0, trace_mode="metrics")
    gp, dc = rows[0], rows[1]
    assert gp["pause_ratio"] == 0.0, gp
    assert dc["pause_ratio"] > 0.0, dc
    assert gp["throughput_gbps"] > dc["throughput_gbps"]
    assert gp["peak_buffer_mb"] < dc["peak_buffer_mb"]


def test_unknown_scheme_is_a_loud_error():
    with pytest.raises(ValueError, match="unknown scheme 'nope'"):
        get_scheme("nope")
    with pytest.raises(ValueError, match="unknown scheme"):
        simulate(NetConfig(), WL, get_scheme, 1_000.0)  # non-str non-Scheme


def test_duplicate_registration_rejected():
    name = "_test_dup_scheme"
    try:
        register_scheme(name, Scheme())
        with pytest.raises(ValueError, match="already registered"):
            register_scheme(name, Scheme())
        register_scheme(name, Scheme(), override=True)   # explicit wins
    finally:
        unregister_scheme(name)
    assert name not in available_schemes()


def test_custom_scheme_end_to_end():
    """A toy scheme registers via the decorator and runs through simulate,
    run_experiment_batch and sweep_grid WITHOUT any fluid.py change: a
    per-flow sender rate cap, visible as capped throughput."""
    cap_bps = 10e9 / 8.0     # 10 Gbps per flow

    name = "_test_toy_cap"
    try:
        @register_scheme(name)
        class ToyCapScheme(Scheme):
            def sender_rate(self, ctx, state, base_rate):
                return jnp.minimum(jnp.minimum(state.cc.rc, base_rate),
                                   cap_bps)

        cfgs = [NetConfig(distance_km=1.0)]
        rows = sweep_grid(cfgs, WL, (name, "dcqcn"), horizon_us=20_000.0)
        toy, dcqcn = rows[0], rows[1]
        assert toy["scheme"] == name
        # 4 flows x 10 Gbps cap (+5% fluid tolerance); strictly below the
        # uncapped baseline at 1 km
        assert toy["throughput_gbps"] <= 4 * 10.0 * 1.05
        assert toy["throughput_gbps"] < dcqcn["throughput_gbps"]
        assert toy["throughput_gbps"] > 1.0     # and it actually flows
    finally:
        unregister_scheme(name)


# ---------------------------------------------------------------------------
# Unified workload axis
# ---------------------------------------------------------------------------

def test_workload_padding_mask_shapes():
    wls = [throughput_workload(1 << 20, 1, num_flows=3),
           congestion_workload(num_inter=4, num_intra=4)]
    stacked = stack_workload_params(wls)
    fmax = max(w.num_flows for w in wls)
    for name, leaf in zip(WorkloadParams._fields, stacked):
        if name == "route":
            assert leaf.shape == (2, fmax, 1)  # symmetric default: width 1
        else:
            assert leaf.shape == (2, fmax)
    np.testing.assert_array_equal(stacked.active_mask.sum(axis=1),
                                  [w.num_flows for w in wls])
    # padded flows are inert: no inter-DC membership, zero bytes
    pad = stacked.active_mask == 0
    assert (stacked.is_inter[pad] == 0).all()
    assert (stacked.total_bytes[pad] == 0).all()


def test_padded_workload_batch_matches_sequential():
    """A heterogeneous (config x workload) grid run as ONE vmapped launch
    must match per-cell sequential runs — including the cell whose flow
    array was padded up by the active_mask."""
    cfgs = [NetConfig(distance_km=100.0), NetConfig(distance_km=300.0)]
    wls = [throughput_workload(1 << 20, 1, num_flows=3),      # padded cell
           congestion_workload(num_inter=4, num_intra=4,
                               burst_start_us=3_000.0, burst_len_us=4_000.0,
                               horizon_us=12_000.0)]
    pad, hist = batch_padding(cfgs)
    rows = run_experiment_batch(cfgs, wls, "matchrdma", 12_000.0)
    for i, (c, w) in enumerate(zip(cfgs, wls)):
        ref = run_experiment(c, w, get_scheme("matchrdma"), 12_000.0,
                             delay_pad=pad, history_slots=hist)
        for m in ("throughput_gbps", "peak_buffer_mb", "mean_buffer_mb",
                  "pause_ratio", "completion_frac", "goodput_bytes"):
            a, b = rows[i][m], ref[m]
            rel = abs(a - b) / max(abs(a), abs(b), 1e-4)
            assert rel < 1e-3, (i, m, a, b)


def test_scenario_sweep_grid_joint_launch():
    """Scenario cells (config AND workload per cell) through sweep_grid:
    row order is scenario-major, schemes resolve by name, cells keep their
    own workload semantics (the finite-flow cell reports FCT)."""
    scens = [
        Scenario(NetConfig(distance_km=100.0),
                 throughput_workload(1 << 20, 1, num_flows=4)),
        Scenario(NetConfig(distance_km=300.0, num_otn_links=4),
                 congestion_workload(num_inter=4, num_intra=4,
                                     burst_start_us=3_000.0,
                                     burst_len_us=4_000.0,
                                     horizon_us=12_000.0)),
    ]
    rows = sweep_grid(scens, ("dcqcn", "matchrdma"), horizon_us=12_000.0)
    assert [r["scheme"] for r in rows] == ["dcqcn", "matchrdma"] * 2
    assert [r["distance_km"] for r in rows] == [100.0, 100.0, 300.0, 300.0]
    for r in rows:
        assert np.isfinite(r["throughput_gbps"])
    # keyword spelling and workload-carrying cells are mutually exclusive
    with pytest.raises(ValueError, match="carry their own workloads"):
        sweep_grid(scens, throughput_workload(1 << 20, 1),
                   ("dcqcn",), horizon_us=5_000.0)


def test_custom_extra_state_without_traces_hook():
    """A scheme replacing the default extra-state pytree (here: None) must
    run end-to-end without overriding extra_traces — the default trace
    hook degrades to {} instead of dereferencing the MatchRDMA block."""
    class BareScheme(Scheme):
        def init_extra_state(self, cfg, params, num_flows, **kw):
            return None

    _, traces = simulate(NetConfig(distance_km=1.0), WL, BareScheme(),
                         2_000.0)
    assert "q_dst" in traces and "budget" not in traces


def test_sweep_grid_lenient_call_shapes():
    """A lone scheme name is a 1-scheme sweep; a forgotten schemes arg
    with a stray workload fails with the purpose-built message."""
    scens = [Scenario(NetConfig(distance_km=1.0), WL)]
    rows = sweep_grid(scens, "dcqcn", horizon_us=2_000.0)
    assert [r["scheme"] for r in rows] == ["dcqcn"]
    rows = sweep_grid([NetConfig(distance_km=1.0)], WL, "dcqcn",
                      horizon_us=2_000.0)
    assert [r["scheme"] for r in rows] == ["dcqcn"]
    with pytest.raises(ValueError, match="carry their own workloads"):
        sweep_grid(scens, WL, horizon_us=2_000.0)


def test_export_sweep_rows_strict_json(tmp_path):
    """NaN metrics (throughput-only workloads have no FCT) must export as
    null, keeping the JSON artifact parseable by strict readers."""
    import json

    from benchmarks.report import export_sweep_rows
    rows = [{"scheme": "dcqcn", "distance_km": 1.0,
             "avg_fct_us": float("nan"), "throughput_gbps": 1.0}]
    csv_p, json_p = str(tmp_path / "r.csv"), str(tmp_path / "r.json")
    export_sweep_rows(rows, csv_path=csv_p, json_path=json_p)
    loaded = json.load(open(json_p))          # strict parse must succeed
    assert loaded[0]["avg_fct_us"] is None
    assert loaded[0]["throughput_gbps"] == 1.0
    assert open(csv_p).readline().startswith("scheme,distance_km")


def test_unregistered_instance_labeled_and_cached():
    """A Scheme instance used directly (never registered) still yields
    labeled metric rows, and two equivalent instances share one compiled
    scan (value-based eq/hash on the jit static arg). ``run_experiment``
    delegates to the batched runner, so the batch jit cache is the one that
    must not grow."""
    from repro.netsim.fluid import _run_traced_batch
    from repro.netsim.schemes import DcqcnScheme

    cfg = NetConfig(distance_km=1.0)
    r = run_experiment(cfg, WL, DcqcnScheme(), 2_000.0)
    assert r["scheme"] == "DcqcnScheme"
    n0 = _run_traced_batch._cache_size()
    run_experiment(cfg, WL, DcqcnScheme(), 2_000.0)   # fresh instance
    assert _run_traced_batch._cache_size() == n0, \
        "equivalent instance recompiled"


def test_sweep_grid_requires_schemes():
    """Omitting schemes must be a loud error, not an empty row list."""
    with pytest.raises(ValueError, match="no schemes given"):
        sweep_grid([NetConfig()], WL, horizon_us=2_000.0)
    with pytest.raises(ValueError, match="no schemes given"):
        sweep_grid([Scenario(NetConfig(), WL)], horizon_us=2_000.0)


def test_workload_batch_size_mismatch_rejected():
    cfgs = [NetConfig(), NetConfig(distance_km=200.0)]
    wls = [throughput_workload(1 << 20, 1)] * 3
    with pytest.raises(ValueError, match="3 workloads for 2 scenarios"):
        run_experiment_batch(cfgs, wls, "dcqcn", 5_000.0)


# ---------------------------------------------------------------------------
# Deprecated string entrypoints
# ---------------------------------------------------------------------------

def test_string_scheme_shims_warn_but_work():
    cfg = NetConfig(distance_km=1.0)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r = run_experiment(cfg, WL, "dcqcn", 2_000.0)
        _, traces = simulate(cfg, WL, "dcqcn", 2_000.0)
    assert r["scheme"] == "dcqcn" and "q_dst" in traces
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 2
    assert "get_scheme" in str(dep[0].message)
    # the batched grid APIs keep names first-class: no warning
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        run_experiment_batch([cfg], WL, "dcqcn", 2_000.0)
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
