"""Gradient correctness of the differentiable (soft-step) engine.

Three families (docs/differentiable.md):

  * finiteness — ``jax.grad`` of the streamed Fig. 3 surrogate w.r.t.
    EVERY traced ``NetParams``/``WorkloadParams`` leaf is finite, for all
    seven schemes (metrics mode, soft engine);
  * finite differences — central-difference quotients match ``jax.grad``
    leaf by leaf for the FD-checked knobs (one batched launch evaluates
    every ±eps perturbation: the knobs are traced leaves, so the whole FD
    battery is two compiled programs — one [2K]-cell forward, one B=1
    backward);
  * single compile — a ``slot_us`` sweep adds ZERO jit-cache entries
    beyond its first launch, and traced-slot batch results match the
    static-slot single-cell engine at matching values.

FD exemptions (finiteness-only, asserted but not FD-compared):
``flap_period_us`` (the dip phase enters through ``mod()`` — a knob-space
jump the relaxation deliberately keeps), workload ``period_us``/``duty``
(same ``mod()`` structure) and the discrete workload leaves
(``is_inter``/``active_mask``/``route``/sites). ``total_bytes`` rides the
straight-through estimator: at the throughput workload's unbounded flow
sizes both FD and AD are exactly zero (the clipped sigmoid saturates).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import NetConfig
from repro.netsim import get_scheme
from repro.netsim.fluid import (
    WARMUP_FRAC, _run_traced_batch, _run_traced_batch_impl,
    as_workload_batch, batch_padding, batch_template, stack_net_params,
)
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import throughput_workload

# Base point chosen OFF every integer boundary of the hard structure:
# distance 96 km -> 480 µs (96 delay steps, boundaries at 477.5/482.5),
# slot_us 112 -> 22.4 steps/slot. Impairments engaged so the channel
# knobs are live. soft_temp 0.3: warm enough for stable tangents, cold
# enough to stay near the hard trajectory.
BASE = dict(distance_km=96.0, slot_us=112.0, horizon_us=4000.0,
            soft_step=True, soft_temp=0.3,
            loss_rate=0.01, loss_burst_len=4.0, jitter_us=20.0,
            flap_period_us=1000.0, flap_depth=0.3)

# (leaf name, central-difference eps) — eps small vs the knob, large
# enough that the f32 objective difference rises above roundoff, and
# never crossing a steps-per-slot / delay-step rounding boundary.
FD_NET_KNOBS = [
    ("one_way_delay_us", 0.5),
    ("otn_capacity_gbps", 0.05),
    ("dst_dc_gbps", 0.05),
    ("nic_gbps", 0.05),
    ("pfc_xoff_kb", 1.0),
    ("pfc_xon_kb", 1.0),
    ("otn_buffer_bdp_frac", 0.002),
    ("ecn_kmin_kb", 0.5),
    ("ecn_kmax_kb", 0.5),
    ("queue_thresh_kb", 0.5),
    ("budget_floor_mbps", 1.0),
    ("budget_headroom", 0.003),
    # tiny eps: the credit loop's objective has strong curvature at the
    # 1e-3 scale (FD converges to AD only below eps ~ 5e-4)
    ("geopipe_credit_bdp_frac", 0.0005),
    ("sdr_window_bdp_frac", 0.002),
    ("sdr_ack_coalesce_us", 0.5),
    ("sdr_retx_budget_frac", 0.002),
    ("loss_rate", 0.002),
    ("loss_burst_len", 0.25),
    ("jitter_us", 1.0),
    ("flap_depth", 0.01),
    ("rdmacell_token_bucket_us", 1.0),
    ("rdmacell_rob_limit_mb", 0.05),
    ("slot_us", 0.4),
    ("soft_temp", 0.005),
]
FD_WL_KNOBS = [
    ("window", 4096.0),
    ("total_bytes", 4096.0),
    ("start_us", 0.5),
]

# Knobs acting through per-step random draws (the Gilbert–Elliott chain,
# the jitter hold, the flap dip): the objective is smooth only in
# expectation — under common random numbers it is a staircase of a few
# thousand micro-gates, so the FD secant carries realization noise the
# pointwise AD slope does not. Checked for sign + order of magnitude.
STOCHASTIC_KNOBS = {"loss_rate", "loss_burst_len", "jitter_us",
                    "flap_depth"}


def _harness(scheme, channel=None, **over):
    cfg = NetConfig(**{**BASE, **over})
    wl = throughput_workload(8e6, 4, num_flows=4)
    cfgs = [cfg]
    tmpl = batch_template(cfgs)
    n_steps = tmpl.horizon_steps(None)
    dp, hs = batch_padding(cfgs)
    warm = int(n_steps * WARMUP_FRAC)
    n_warm = max(n_steps - warm, 1)
    params = stack_net_params(cfgs)
    wlp = as_workload_batch(wl, 1)

    def objvec(p, w):
        """Per-cell smooth objective from the streamed sums ([B])."""
        _, acc = _run_traced_batch_impl(
            tmpl, p, w, scheme, n_steps, 0, dp, hs,
            mode="metrics", warm=warm, channel=channel)
        s = acc.sum_s
        return (s["thr_inter"] / n_warm * 8.0 / 1e9
                - 0.5 * s["q_dst"] / n_warm / 1e6
                - s["pause_dst"] / n_warm)

    return params, wlp, objvec


def _tile(batch, n):
    """Repeat every [1, ...] leaf of a stacked pytree to [n, ...]."""
    return jax.tree.map(
        lambda x: np.repeat(np.asarray(x), n, axis=0), batch)


# ---------------------------------------------------------------------------
# finiteness: every scheme, every traced leaf
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_grad_finite_every_leaf(scheme):
    channel = "impaired" if scheme in ("dcqcn", "matchrdma") else None
    params, wlp, objvec = _harness(get_scheme(scheme), channel,
                                   horizon_us=3000.0)
    gp, gw = jax.jit(jax.grad(lambda p, w: objvec(p, w)[0],
                              argnums=(0, 1)))(params, wlp)
    for tree, kind in ((gp, "net"), (gw, "workload")):
        for name, leaf in zip(tree._fields, tree):
            if leaf is None:
                continue
            a = np.asarray(leaf)
            assert np.all(np.isfinite(a)), \
                f"{scheme}: non-finite grad in {kind} leaf {name!r}: {a}"


# ---------------------------------------------------------------------------
# finite differences vs jax.grad
# ---------------------------------------------------------------------------
def _fd_battery(scheme_name, channel):
    scheme = get_scheme(scheme_name)
    # FD runs WARM (temp 1.0): the quotient needs smooth terrain over a
    # finite eps — band-shaped sigmoid gates at temp 0.3 put curvature at
    # the eps scale. Convergence to the hard engine as the temperature
    # drops is pinned separately (tests/test_soft_convergence.py).
    params, wlp, objvec = _harness(scheme, channel, soft_temp=1.0)
    kn, kw = len(FD_NET_KNOBS), len(FD_WL_KNOBS)
    b = 2 * (kn + kw)
    pb = _tile(params, b)
    wb = _tile(wlp, b)
    pleaves = pb._asdict()
    for i, (name, eps) in enumerate(FD_NET_KNOBS):
        pleaves[name][2 * i] += eps
        pleaves[name][2 * i + 1] -= eps
    wleaves = wb._asdict()
    for j, (name, eps) in enumerate(FD_WL_KNOBS):
        i = kn + j
        # uniform shift of every real flow: FD then matches the grad leaf
        # SUMMED over flows
        wleaves[name][2 * i] += eps * np.asarray(wb.active_mask[2 * i])
        wleaves[name][2 * i + 1] -= eps * np.asarray(wb.active_mask[2 * i])
    pb = type(params)(**pleaves)
    wb = type(wlp)(**wleaves)
    obj = np.asarray(jax.jit(objvec)(pb, wb), np.float64)

    gp, gw = jax.jit(jax.grad(lambda p, w: objvec(p, w)[0],
                              argnums=(0, 1)))(params, wlp)
    rows = []
    for i, (name, eps) in enumerate(FD_NET_KNOBS):
        fd = (obj[2 * i] - obj[2 * i + 1]) / (2.0 * eps)
        ad = float(np.asarray(getattr(gp, name)).sum())
        rows.append((name, eps, fd, ad))
    for j, (name, eps) in enumerate(FD_WL_KNOBS):
        i = kn + j
        fd = (obj[2 * i] - obj[2 * i + 1]) / (2.0 * eps)
        ad = float(np.asarray(getattr(gw, name)).sum())
        rows.append((name, eps, fd, ad))
    return rows, float(np.mean(np.abs(obj)))


def _check_fd(rows, obj_scale, scheme_name):
    bad = []
    for name, eps, fd, ad in rows:
        assert np.isfinite(fd) and np.isfinite(ad), (scheme_name, name)
        # the FD quotient carries ~(f32 objective noise)/eps of roundoff;
        # below that floor agreement is vacuous either way
        floor = 3e-5 * max(obj_scale, 1.0) / eps
        if max(abs(fd), abs(ad)) <= max(floor, 1e-9):
            continue
        rel = abs(fd - ad) / max(abs(fd), abs(ad))
        gate = 0.75 if name in STOCHASTIC_KNOBS else 0.25
        if name in STOCHASTIC_KNOBS and fd * ad > 0:
            continue  # same sign: magnitude noise is realization noise
        if rel > gate and abs(fd - ad) > floor:
            bad.append(f"{name}: fd={fd:.4e} ad={ad:.4e} rel={rel:.2f}")
    assert not bad, f"{scheme_name} FD mismatches:\n  " + "\n  ".join(bad)


@pytest.mark.parametrize("scheme", ["dcqcn", "matchrdma"])
def test_fd_matches_grad(scheme):
    rows, scale = _fd_battery(scheme, "impaired")
    _check_fd(rows, scale, scheme)


def test_fd_matches_grad_scheme_knobs():
    """The related-work schemes' own knobs, FD-checked under the scheme
    that consumes them (they are structurally dead under dcqcn)."""
    for scheme_name, knobs in (
            ("geopipe", ("geopipe_credit_bdp_frac",)),
            ("sdr_rdma", ("sdr_window_bdp_frac", "sdr_ack_coalesce_us",
                          "sdr_retx_budget_frac"))):
        rows, scale = _fd_battery(scheme_name, None)
        keep = [r for r in rows if r[0] in knobs]
        assert len(keep) == len(knobs)
        _check_fd(keep, scale, scheme_name)


# ---------------------------------------------------------------------------
# traced steps-per-slot: one compile per scheme across a slot_us sweep
# ---------------------------------------------------------------------------
def test_slot_sweep_single_compile_and_static_parity():
    from repro.netsim import run_experiment, run_experiment_batch

    wl = throughput_workload(8e6, 4, num_flows=4)
    scheme = get_scheme("matchrdma")
    slots = (50.0, 100.0, 200.0, 400.0)
    cfgs = [NetConfig(distance_km=100.0, horizon_us=6000.0, slot_us=s)
            for s in slots]
    cfgs2 = [NetConfig(distance_km=100.0, horizon_us=6000.0, slot_us=s)
             for s in (64.0, 112.0, 250.0, 320.0)]
    # ring SIZES stay keyed by the static slot_us twin: pin both launches
    # (and the single-cell references) to the union padding so the only
    # thing that varies across the sweep is the traced leaf
    dp, hs = batch_padding(cfgs + cfgs2)
    rows = run_experiment_batch(cfgs, wl, scheme, 6000.0,
                                trace_mode="metrics",
                                delay_pad=dp, history_slots=hs)
    before = _run_traced_batch._cache_size()
    # a DIFFERENT slot population, same batch shape: zero new compiles —
    # slot_us is a traced NetParams leaf, steps-per-slot is traced too
    rows2 = run_experiment_batch(cfgs2, wl, scheme, 6000.0,
                                 trace_mode="metrics",
                                 delay_pad=dp, history_slots=hs)
    assert _run_traced_batch._cache_size() == before, \
        "slot_us sweep recompiled — steps-per-slot must be traced"
    assert len(rows2) == 4

    # traced-slot batch vs the single-cell engine at matching values: the
    # B=1 path builds its template FROM that slot value, so agreement here
    # pins the traced wrap/boundary arithmetic against the static one
    for s, row in zip(slots, rows):
        ref = run_experiment(cfgs[slots.index(s)], wl, scheme, 6000.0,
                             trace_mode="metrics",
                             delay_pad=dp, history_slots=hs)
        for k in ("throughput_gbps", "pause_ratio", "mean_buffer_mb"):
            assert np.isclose(row[k], ref[k], rtol=1e-5, atol=1e-9), \
                (s, k, row[k], ref[k])
