"""Sharding rules, compression, multi-device collectives (subprocess)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from _hypo import given, settings, st

from repro.config import get_model_config, get_parallel_config, list_archs
from repro.models import build_model
from repro.parallel.compression import (
    compress_with_feedback, dequantize_int8, quantize_int8,
)
from repro.parallel.sharding import ShardingRules


# ------------------------- sharding rules -------------------------

@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    """Every sharded dimension must divide by its mesh axis size for the
    FULL config on the production mesh — the invariant the dry-run needs."""
    model_cfg = get_model_config(arch)
    par = get_parallel_config(arch, multi_pod=multi_pod)
    model = build_model(model_cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    rules = ShardingRules(model_cfg, par)
    specs = rules.params_tree_specs(params)
    sizes = {"pod": par.pods, "data": par.data, "model": par.model}

    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for arr, spec in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert arr.shape[dim] % total == 0, (arch, spec, arr.shape, dim)


@pytest.mark.parametrize("arch", ["deepseek-67b", "recurrentgemma-2b",
                                  "mamba2-370m", "qwen1.5-0.5b"])
def test_cache_specs_divisible(arch):
    from repro.models.transformer import init_caches
    model_cfg = get_model_config(arch)
    par = get_parallel_config(arch, multi_pod=False)
    rules = ShardingRules(model_cfg, par)
    caches = jax.eval_shape(
        lambda: init_caches(model_cfg, 128, 32768, jnp.bfloat16))
    specs = rules.cache_tree_specs(caches)
    sizes = {"pod": par.pods, "data": par.data, "model": par.model}
    flat_c = jax.tree.leaves(caches)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for arr, spec in zip(flat_c, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert arr.shape[dim] % total == 0, (arch, spec, arr.shape)


# ------------------------- compression -------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 5), st.integers(3, 4000))
def test_quantize_roundtrip_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 10)
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale, x.shape, jnp.float32)
    # per-chunk max-abs scaling: |err| <= scale/2 per chunk
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(scale).max() / 2 + 1e-6
    assert err.max() <= bound


def test_error_feedback_accumulates_residual():
    x = jnp.asarray(np.linspace(-1, 1, 100).astype(np.float32))
    err = jnp.zeros_like(x)
    q, scale, err2 = compress_with_feedback(x, err)
    deq = dequantize_int8(q, scale, x.shape, jnp.float32)
    np.testing.assert_allclose(np.asarray(err2), np.asarray(x - deq),
                               atol=1e-6)


def test_error_feedback_unbiased_over_time():
    """Repeatedly compressing the SAME gradient with error feedback must
    recover the true value in the long-run average (the EF guarantee)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, scale, err = compress_with_feedback(g, err)
        total = total + dequantize_int8(q, scale, g.shape, jnp.float32)
    avg = total / n
    assert float(jnp.abs(avg - g).max()) < 5e-3


# ------------------------- multi-device (subprocess) -------------------------

# The subprocess scripts drive the multi-device code through the
# version-compat shims (repro.parallel.compat): jax.shard_map / set_mesh /
# AxisType meshes on new JAX, jax.experimental.shard_map + the Mesh context
# on 0.4.x — they RUN (not skip) on every supported install.

_SUBPROC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.parallel import make_hierarchical_allreduce
    from repro.parallel.compat import make_mesh, set_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"a": jnp.arange(37, dtype=jnp.float32) * 0.1,
         "b": jnp.ones((5, 3), jnp.bfloat16)}
    errs = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), g)
    with set_mesh(mesh):
        out, _ = jax.jit(make_hierarchical_allreduce(mesh))(g, errs)
        assert float(jnp.abs(out["a"] - g["a"]).max()) < 1e-6
        outc, ne = jax.jit(make_hierarchical_allreduce(mesh, compress=True))(g, errs)
        rel = float(jnp.abs(outc["a"] - g["a"]).max() / jnp.abs(g["a"]).max())
        assert rel < 0.02, rel
    print("MULTIDEVICE_OK")
""")


def test_hierarchical_allreduce_8dev():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "MULTIDEVICE_OK" in r.stdout, r.stdout + r.stderr


_SUBPROC_MOE = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.config import get_model_config
    from repro.models.moe import apply_moe, init_moe
    from repro.parallel.compat import make_mesh, set_mesh
    cfg = dataclasses.replace(
        get_model_config("phi3.5-moe-42b-a6.6b", smoke=True),
        act_dtype="float32", param_dtype="float32", moe_capacity_factor=8.0)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    y_flat, _ = apply_moe(p, x, cfg)                 # ungrouped reference
    cfg_g = dataclasses.replace(cfg, moe_group_by_batch=True)
    with set_mesh(mesh):
        y_grp, aux = jax.jit(lambda x, p: apply_moe(p, x, cfg_g))(x, p)
    err = float(jnp.abs(y_flat - y_grp).max())
    assert err < 1e-5, err
    print("MOE_SHARDMAP_OK")
""")


def test_grouped_moe_shardmap_8dev():
    """The §Perf hillclimb path: full-manual shard_map MoE routing must match
    the flat dispatch exactly when capacity is ample (8-device mesh)."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_MOE],
                       capture_output=True, text=True, cwd=".", timeout=300)
    assert "MOE_SHARDMAP_OK" in r.stdout, r.stdout + r.stderr


def test_compat_shard_map_single_device():
    """The compat shim itself, in-process on one device: shard_map over a
    trivial mesh reduces correctly whichever JAX generation is installed."""
    from repro.parallel.compat import get_ambient_mesh, set_mesh, shard_map
    mesh = jax.make_mesh((1,), ("x",))
    f = shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                  in_specs=P("x"), out_specs=P(None))
    out = f(jnp.arange(4, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.arange(4, dtype=np.float32))
    assert get_ambient_mesh() is None
    with set_mesh(mesh):
        amb = get_ambient_mesh()
        assert amb is not None and "x" in amb.axis_names
    assert get_ambient_mesh() is None
