"""AICB-like traffic model: analytic collective-size checks."""
import numpy as np
import pytest

from repro.config import get_model_config, get_parallel_config
from repro.config.base import ParallelConfig, TrainConfig
from repro.traffic import (
    iteration_profile, pp_stage_bytes, step_traffic, training_workload,
)

TC = TrainConfig(global_batch=256, seq_len=4096)


def test_dp_bytes_formula():
    m = get_model_config("qwen1.5-0.5b")
    par = get_parallel_config("qwen1.5-0.5b", multi_pod=False)
    t = step_traffic(m, par, TC)
    assert abs(t.dp_grad_bytes - 2 * m.param_count() * 2) < 1e-3


def test_hierarchical_beats_flat_interpod():
    m = get_model_config("deepseek-67b")
    p_h = ParallelConfig(multi_pod=True, hierarchical_allreduce=True, fsdp=True)
    p_f = ParallelConfig(multi_pod=True, hierarchical_allreduce=False, fsdp=True)
    t_h = step_traffic(m, p_h, TC)
    t_f = step_traffic(m, p_f, TC)
    assert t_h.inter_pod_bytes < t_f.inter_pod_bytes / 100


def test_compression_halves_interpod():
    m = get_model_config("deepseek-67b")
    p = ParallelConfig(multi_pod=True, pod_compression="int8")
    p0 = ParallelConfig(multi_pod=True)
    assert (step_traffic(m, p, TC).inter_pod_bytes
            == 0.5 * step_traffic(m, p0, TC).inter_pod_bytes)


def test_moe_has_ep_bytes():
    m = get_model_config("phi3.5-moe-42b-a6.6b")
    par = get_parallel_config("phi3.5-moe-42b-a6.6b", multi_pod=True)
    t = step_traffic(m, par, TC)
    assert t.ep_alltoall_bytes > 0
    dense = get_model_config("deepseek-67b")
    td = step_traffic(dense, get_parallel_config("deepseek-67b", multi_pod=True), TC)
    assert td.ep_alltoall_bytes == 0


def test_comm_frac_bounded():
    for arch in ("deepseek-67b", "mamba2-370m", "nemotron-4-340b"):
        m = get_model_config(arch)
        par = get_parallel_config(arch, multi_pod=True)
        t = step_traffic(m, par, TC)
        assert 0.0 < t.comm_frac < 1.0


def test_iteration_profile_and_workload():
    m = get_model_config("granite-moe-1b-a400m")
    par = get_parallel_config("granite-moe-1b-a400m", multi_pod=True)
    prof = iteration_profile(m, par, TC)
    assert prof.comm_us > 0 and prof.iter_us > prof.comm_us
    wl = training_workload(m, par, TC, num_flows=8, with_intra=4)
    assert wl.num_flows == 12
    arrays = wl.arrays()
    assert arrays["is_inter"].sum() == 8
    assert (arrays["duty"][arrays["is_inter"] > 0] <= 1.0).all()


def test_pp_stage_bytes():
    m = get_model_config("qwen1.5-0.5b")
    b = pp_stage_bytes(m, TC, microbatches=8)
    expected = 2 * 8 * (256 * 4096 / 8) * m.d_model * 2
    assert abs(b - expected) < 1.0
