"""The channel subsystem: zero-impairment bit-identity against the goldens,
the loss-repair path (conservation, retransmit accounting, sdr_rdma's
repair-latency advantage), impairment-knob grids compiling once per scheme,
the O(B) streaming guarantee with a channel enabled, model physics
(loss/jitter/flap), determinism, and the registry."""
import os

import numpy as np
import pytest

from repro.config.base import NetConfig
from repro.netsim import (
    CHANNEL_MODELS, ChannelModel, available_channel_models, fluid,
    get_channel_model, get_scheme, register_channel_model,
    run_experiment_batch, simulate, simulate_batch, throughput_workload,
)
from repro.netsim.channel import unregister_channel_model
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import congestion_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")
WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
CWL = congestion_workload(num_inter=4, num_intra=4, burst_start_us=3_000.0,
                          burst_len_us=4_000.0, horizon_us=12_000.0)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


# ---------------------------------------------------------------------------
# Zero-impairment identity: the channel subsystem must be invisible at its
# defaults. The ideal channel is the same program as the pre-channel engine;
# bernoulli_loss with loss_rate=0 must still produce bit-identical values
# (the impaired branches join the dataflow through where() selects whose
# pass-through branch is the original tensor).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("channel", ["ideal", "bernoulli_loss"])
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_zero_impairment_identity_vs_goldens(golden, scheme, channel):
    cfg = NetConfig(distance_km=100.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    final, traces = simulate(cfg, wl, get_scheme(scheme), 10_000.0,
                             channel=channel)
    golden_keys = {k.rsplit("/", 1)[1] for k in golden.files
                   if k.startswith(f"seq/{scheme}/traces/")}
    # a lossy model adds chan_* keys; every GOLDEN key must stay bit-equal
    assert golden_keys <= set(traces)
    if channel == "ideal":
        assert set(traces) == golden_keys, \
            "the ideal channel must not add trace keys"
    for k in golden_keys:
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"{scheme}/{k} diverged bit-for-bit under "
                    f"channel={channel}")
    for k in ("sent", "acked", "delivered", "done_at_us"):
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/final/{k}"],
            np.asarray(getattr(final, k)),
            err_msg=f"{scheme} final.{k} diverged under channel={channel}")


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_zero_impairment_identity_batched(golden, scheme):
    cfgs = [NetConfig(distance_km=d) for d in (1.0, 300.0)]
    final, traces = simulate_batch(cfgs, WL, get_scheme(scheme), 8_000.0,
                                   channel="bernoulli_loss")
    keys = {k.rsplit("/", 1)[1] for k in golden.files
            if k.startswith(f"batch/{scheme}/traces/")}
    for k in keys:
        np.testing.assert_array_equal(
            golden[f"batch/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"batched {scheme}/{k} diverged under zero loss")
    np.testing.assert_array_equal(
        golden[f"batch/{scheme}/final/delivered"],
        np.asarray(final.delivered))


def test_ideal_rows_carry_no_channel_columns():
    rows = run_experiment_batch([NetConfig(distance_km=10.0)], WL, "dcqcn",
                                4_000.0, trace_mode="metrics")
    assert "goodput_gbps" not in rows[0]
    assert "p99_repair_latency_us" not in rows[0]


# ---------------------------------------------------------------------------
# The loss-repair path
# ---------------------------------------------------------------------------

def test_loss_bites_and_repairs():
    """Under real loss: wire > goodput (drops burn capacity), repair
    traffic flows (retx_frac > 0), and the conservation residual still
    holds — lost bytes live in exactly one ledger at every step."""
    cfg = NetConfig(distance_km=100.0, loss_rate=0.02, loss_burst_len=4.0)
    final, traces = simulate(cfg, WL, get_scheme("dcqcn"), 12_000.0,
                             channel="bernoulli_loss")
    lost = float(np.asarray(traces["chan_lost"]).sum())
    retx = float(np.asarray(traces["chan_retx"]).sum())
    assert lost > 0 and retx > 0
    assert float(np.asarray(traces["cons_err"]).max()) < 1e-4
    rows = run_experiment_batch([cfg], WL, "dcqcn", 12_000.0,
                                trace_mode="metrics",
                                channel="bernoulli_loss")
    r = rows[0]
    assert r["wire_gbps"] > r["goodput_gbps"] > 0
    assert 0 < r["retx_frac"] < 0.5
    assert r["p99_repair_latency_us"] > 0


@pytest.mark.parametrize("scheme", ("matchrdma", "sdr_rdma", "geopipe"))
def test_conservation_under_full_impairments(scheme):
    """Loss + jitter + flap composed: the per-flow conservation residual
    stays at float noise for schemes with their own release/extra-state
    machinery — impairments must not create or destroy bytes."""
    cfg = NetConfig(distance_km=100.0, loss_rate=0.01, loss_burst_len=4.0,
                    jitter_us=20.0, flap_period_us=2_000.0, flap_depth=0.5)
    _, traces = simulate(cfg, CWL, get_scheme(scheme), 12_000.0,
                         channel="impaired")
    assert float(np.asarray(traces["cons_err"]).max()) < 1e-4


def test_sdr_rdma_repairs_faster_than_dcqcn():
    """The acceptance pin: under the bernoulli_loss grid, sdr_rdma's
    reserved retransmit budget achieves strictly lower p99 repair latency
    than e2e dcqcn at every equal loss rate — the selective-repeat window
    plus the budget reservation is exactly what the scheme exists for.
    (Loss rates high enough that every realization leaves both schemes
    with pending repairs — at ~0.1% loss a short horizon can hand dcqcn a
    loss-free warm window and nothing to compare.)"""
    cfgs = [NetConfig(distance_km=50.0, loss_rate=lr, loss_burst_len=4.0)
            for lr in (0.02, 0.05)]
    rows = {s: run_experiment_batch(cfgs, CWL, s, 12_000.0,
                                    trace_mode="metrics",
                                    channel="bernoulli_loss")
            for s in ("dcqcn", "sdr_rdma")}
    for i, cfg in enumerate(cfgs):
        dc, sdr = rows["dcqcn"][i], rows["sdr_rdma"][i]
        assert 0 < sdr["p99_repair_latency_us"] \
            < dc["p99_repair_latency_us"], \
            (cfg.loss_rate, sdr["p99_repair_latency_us"],
             dc["p99_repair_latency_us"])


def test_sdr_retx_budget_engages_on_loss():
    """Without congestion, sdr_rdma's repair budget must still engage on
    real loss (the degradation EWMA hears loss notifications, not only
    CNPs) — visible as a nonzero streamed retransmit reservation."""
    cfg = NetConfig(distance_km=100.0, loss_rate=0.02, loss_burst_len=4.0)
    r = run_experiment_batch([cfg], WL, "sdr_rdma", 12_000.0,
                             trace_mode="metrics",
                             channel="bernoulli_loss")[0]
    r0 = run_experiment_batch([NetConfig(distance_km=100.0)], WL,
                              "sdr_rdma", 12_000.0, trace_mode="metrics",
                              channel="bernoulli_loss")[0]
    assert r["mean_retx_reserve_frac"] > r0["mean_retx_reserve_frac"]
    assert r["mean_retx_reserve_frac"] > 0


# ---------------------------------------------------------------------------
# Batched impairment grids: one compile per scheme, O(B) memory
# ---------------------------------------------------------------------------

def test_impairment_grid_single_compile():
    """A loss_rate x jitter_us grid sweeps batch-wide through the launch
    plan in ONE compiled program per scheme — the impairment knobs are
    traced NetParams leaves, the model is a static arg shared by every
    cell (the acceptance pin)."""
    cfgs = [NetConfig(distance_km=50.0, loss_rate=lr, jitter_us=j)
            for lr in (0.0, 0.005, 0.02) for j in (0.0, 25.0)]
    n0 = fluid._run_traced_batch._cache_size()
    rows = run_experiment_batch(cfgs, WL, "dcqcn", 6_000.0,
                                trace_mode="metrics", channel="impaired")
    assert fluid._run_traced_batch._cache_size() - n0 <= 1, \
        "impairment grid recompiled per cell — knobs are not traced leaves"
    assert len(rows) == len(cfgs)
    assert all(np.isfinite(r["goodput_gbps"]) for r in rows)
    # the knobs bite inside one launch: the lossiest cell repairs the most
    by_loss = {c.loss_rate: r for c, r in zip(cfgs, rows)
               if c.jitter_us == 0.0}
    assert by_loss[0.02]["retx_frac"] > by_loss[0.0]["retx_frac"] == 0.0


def test_metrics_mode_no_bt_buffer_with_channel():
    """The O(B) guarantee survives the channel subsystem: with loss +
    jitter enabled, a streaming batch launch still allocates no [B, T]
    buffer anywhere in the jaxpr (the acceptance pin; the positive
    control lives in tests/test_streaming_metrics.py)."""
    import jax
    import jax.numpy as jnp

    from test_streaming_metrics import _max_buffer_elems

    from repro.config.base import batch_template, stack_net_params
    from repro.netsim.workload import WorkloadParams, as_workload_batch

    cfgs = [NetConfig(distance_km=d, loss_rate=0.01, jitter_us=20.0)
            for d in (1.0, 5.0, 10.0, 2.0)]
    steps, b = 2000, len(cfgs)
    wlp = as_workload_batch(CWL, b)
    wlp = WorkloadParams(*(jnp.asarray(np.asarray(v)) for v in wlp))
    tmpl = batch_template(cfgs)
    params = stack_net_params(cfgs)
    pad, hist = fluid.batch_padding(cfgs)
    jx = jax.make_jaxpr(lambda p, w: fluid._run_traced_batch(
        tmpl, p, w, get_scheme("sdr_rdma"), steps, 0, pad, hist,
        "metrics", 1, steps // 10, get_channel_model("impaired")))(
        params, wlp)
    assert _max_buffer_elems(jx) < b * steps, \
        "streaming mode with a channel materialized an O(B*T) buffer"


def test_channel_columns_streaming_full_parity():
    """goodput/wire/retx_frac agree tightly between streamed accumulators
    and materialized traces; the histogram-inverted p99 repair latency is
    bin-ratio bounded — impairment sweeps are trace-mode agnostic."""
    cfgs = [NetConfig(distance_km=d, loss_rate=0.01, loss_burst_len=4.0)
            for d in (50.0, 300.0)]
    full = run_experiment_batch(cfgs, CWL, "sdr_rdma", 12_000.0,
                                channel="bernoulli_loss")
    stream = run_experiment_batch(cfgs, CWL, "sdr_rdma", 12_000.0,
                                  trace_mode="metrics",
                                  channel="bernoulli_loss")
    for f, s in zip(full, stream):
        for m in ("goodput_gbps", "wire_gbps", "retx_frac"):
            rel = abs(f[m] - s[m]) / max(abs(f[m]), abs(s[m]), 1e-4)
            assert rel < 1e-3, (m, f[m], s[m])
        p99 = (abs(f["p99_repair_latency_us"] - s["p99_repair_latency_us"])
               / max(f["p99_repair_latency_us"],
                     s["p99_repair_latency_us"], 1e-3))
        assert p99 < 0.1, (f["p99_repair_latency_us"],
                           s["p99_repair_latency_us"])


# ---------------------------------------------------------------------------
# Model physics + determinism
# ---------------------------------------------------------------------------

def test_loss_rate_monotone_in_goodput_gap():
    """More loss burns more wire capacity: the wire-vs-goodput gap grows
    monotonically with loss_rate inside one batched launch."""
    cfgs = [NetConfig(distance_km=50.0, loss_rate=lr, loss_burst_len=4.0)
            for lr in (0.0, 0.01, 0.05)]
    rows = run_experiment_batch(cfgs, WL, "dcqcn", 12_000.0,
                                trace_mode="metrics",
                                channel="bernoulli_loss")
    gaps = [r["wire_gbps"] - r["goodput_gbps"] for r in rows]
    assert gaps[0] == 0.0
    assert gaps[0] < gaps[1] < gaps[2], gaps


def test_jitter_holds_and_releases_bytes():
    """Jitter defers fluid without destroying it: completion still
    reaches 1.0 on a finite workload and conservation holds."""
    from repro.netsim.workload import mixed_fct_workload
    wl = mixed_fct_workload(msg_size=256 << 10, num_inter=4, num_intra=2,
                            num_background=2, request_start_us=2_000.0)
    cfg = NetConfig(distance_km=50.0, jitter_us=40.0)
    _, traces = simulate(cfg, wl, get_scheme("dcqcn"), 20_000.0,
                         channel="jitter")
    assert float(np.asarray(traces["cons_err"]).max()) < 1e-4
    r = run_experiment_batch([cfg], wl, "dcqcn", 20_000.0,
                             trace_mode="metrics", channel="jitter")[0]
    assert r["completion_frac"] == 1.0


def test_otn_flap_throttles_when_line_is_bottleneck():
    """Protection-switch dips cut throughput monotonically with depth when
    the OTN line is the path bottleneck."""
    wl = throughput_workload(4 << 20, 8, num_flows=4)
    cfgs = [NetConfig(distance_km=100.0, num_otn_links=4,
                      flap_period_us=2_000.0, flap_depth=d)
            for d in (0.0, 0.5, 0.9)]
    rows = run_experiment_batch(cfgs, wl, "dcqcn", 12_000.0,
                                trace_mode="metrics", channel="otn_flap")
    thr = [r["throughput_gbps"] for r in rows]
    assert thr[0] > thr[1] > thr[2], thr


def test_channel_runs_are_deterministic():
    """Counter-based keys: identical (seed, scenario, step) -> identical
    realization, run to run; a different channel_seed decorrelates."""
    cfg = NetConfig(distance_km=100.0, loss_rate=0.02, jitter_us=20.0)
    a = run_experiment_batch([cfg], WL, "dcqcn", 8_000.0,
                             trace_mode="metrics", channel="impaired")[0]
    b = run_experiment_batch([cfg], WL, "dcqcn", 8_000.0,
                             trace_mode="metrics", channel="impaired")[0]
    for k, v in a.items():
        if isinstance(v, float) and np.isfinite(v):
            assert v == b[k], k
    import dataclasses
    cfg2 = dataclasses.replace(cfg, channel_seed=123)
    c = run_experiment_batch([cfg2], WL, "dcqcn", 8_000.0,
                             trace_mode="metrics", channel="impaired")[0]
    assert c["goodput_gbps"] != a["goodput_gbps"]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_builtin_models_registered():
    assert set(CHANNEL_MODELS) <= set(available_channel_models())
    for name in CHANNEL_MODELS:
        inst = get_channel_model(name)
        assert inst.name == name
        assert get_channel_model(inst) is inst        # instance passthrough
    assert get_channel_model(None).name == "ideal"    # None = the default
    assert get_channel_model("ideal").is_ideal
    assert not get_channel_model("impaired").is_ideal


def test_unknown_channel_is_a_loud_error():
    with pytest.raises(ValueError, match="unknown channel model 'nope'"):
        get_channel_model("nope")


def test_duplicate_channel_registration_rejected():
    name = "_test_dup_channel"
    try:
        register_channel_model(name, ChannelModel())
        with pytest.raises(ValueError, match="already registered"):
            register_channel_model(name, ChannelModel())
        register_channel_model(name, ChannelModel(), override=True)
    finally:
        unregister_channel_model(name)
    assert name not in available_channel_models()


def test_custom_channel_end_to_end():
    """A toy model registers via the decorator and runs through the
    engine WITHOUT any fluid.py change: a fixed 50% capacity cut on the
    long haul, visible as halved throughput when the line is the
    bottleneck."""
    import jax.numpy as jnp

    from repro.netsim.channel import ChannelEffects

    name = "_test_half_line"
    try:
        @register_channel_model(name)
        class HalfLine(ChannelModel):
            is_ideal = False

            def apply_impairments(self, ctx, chan, inp):
                return ChannelEffects(arrivals=inp.pipe_out,
                                      lost=jnp.zeros_like(inp.pipe_out),
                                      cap_src=inp.cap_src * 0.5, chan=chan)

        wl = throughput_workload(4 << 20, 8, num_flows=4)
        cfg = NetConfig(distance_km=100.0, num_otn_links=4)
        half = run_experiment_batch([cfg], wl, "dcqcn", 10_000.0,
                                    trace_mode="metrics", channel=name)[0]
        ideal = run_experiment_batch([cfg], wl, "dcqcn", 10_000.0,
                                     trace_mode="metrics")[0]
        assert half["throughput_gbps"] < 0.6 * ideal["throughput_gbps"]
    finally:
        unregister_channel_model(name)
