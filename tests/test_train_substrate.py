"""Optimizer, data pipeline, checkpointing, elastic policies."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_model_config
from repro.config.base import ModelConfig, ParallelConfig, TrainConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.elastic import FailureRecovery, StragglerMonitor, resharding_plan
from repro.train.optimizer import (
    adam_update, clip_by_global_norm, init_adam, lr_schedule,
)


# ------------------------- optimizer -------------------------

def test_adam_first_step_matches_reference():
    cfg = TrainConfig(lr=1e-2, warmup_steps=1, total_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 0.5)}
    st = init_adam(params)
    new_p, st2, m = adam_update(params, grads, st, cfg)
    # step 1 with bias correction: update = lr * g/|g| (adam first step) = lr
    lr1 = float(lr_schedule(cfg, jnp.int32(1)))
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - lr1, rtol=1e-4)


def test_lr_schedule_shape():
    cfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] < lrs[2]                       # warmup rising
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] < 0.2 * max(lrs)              # decayed


def test_grad_clip():
    g = {"a": jnp.full((3,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    n2 = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(n2 - 1.0) < 1e-5
    assert float(norm) > 100.0


def test_training_reduces_loss():
    """A few hundred steps on the Markov stream must beat the unigram floor."""
    from repro.models import build_model
    cfg = get_model_config("qwen1.5-0.5b", smoke=True)
    tc = TrainConfig(global_batch=8, seq_len=128, lr=3e-3, warmup_steps=10,
                     total_steps=120)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adam(params)
    data = SyntheticDataset(cfg, tc)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt, _ = adam_update(params, grads, opt, tc)
        return params, opt, loss

    first = None
    for i in range(120):
        params, opt, loss = step(params, opt, data.batch_at(i))
        if i == 0:
            first = float(loss)
    assert float(loss) < first - 0.5, (first, float(loss))


# ------------------------- data -------------------------

def test_data_deterministic_and_seekable():
    cfg = get_model_config("qwen1.5-0.5b", smoke=True)
    tc = TrainConfig(global_batch=4, seq_len=64, seed=7)
    d1 = SyntheticDataset(cfg, tc)
    d2 = SyntheticDataset(cfg, tc)
    b1 = d1.batch_at(13)
    b2 = d2.batch_at(13)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(14)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])


def test_data_is_learnable_markov():
    """Bigram distribution must be far from uniform (signal exists)."""
    cfg = get_model_config("qwen1.5-0.5b", smoke=True)
    tc = TrainConfig(global_batch=8, seq_len=256)
    d = SyntheticDataset(cfg, tc)
    toks = np.asarray(d.batch_at(0)["tokens"]).reshape(-1)
    # successive-token mutual information proxy: repeated bigrams
    big = set(zip(toks[:-1], toks[1:]))
    assert len(big) < 0.5 * (len(toks) - 1)


# ------------------------- checkpointing -------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    mgr.save(7, tree)
    step, restored = mgr.restore(None, tree)
    assert step == 7
    assert jnp.array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = {"a": jnp.arange(10, dtype=jnp.float32)}
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_restore_resharding_path(tmp_path):
    """Restore with explicit shardings (the elastic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    step, restored = mgr.restore(3, tree, shardings=sh)
    assert jnp.array_equal(restored["w"], tree["w"])


# ------------------------- elastic -------------------------

def test_resharding_plan_pod_loss():
    par = ParallelConfig(multi_pod=True)
    plan = resharding_plan(par, lost_pods=1)
    assert plan.new_mesh == (1, 16, 16)
    assert plan.batch_scale == 1.0


def test_resharding_plan_rejects_impossible():
    par = ParallelConfig(multi_pod=False)
    with pytest.raises(ValueError):
        resharding_plan(par, lost_data_rows=16)


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0, evict_after=2)
    for _ in range(10):
        assert mon.observe(0.1) == "ok"
    assert mon.observe(1.0) == "straggler"
    assert mon.observe(1.0) == "evict"


def test_failure_recovery_replays_from_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"step": jnp.int32(0)}
    calls = {"n": 0}

    def train_fn(start):
        calls["n"] += 1
        for s in range(start, 10):
            if s == 5 and calls["n"] == 1:
                mgr.save(5, state)
                raise RuntimeError("simulated node failure")
        return 10

    rec = FailureRecovery(mgr, max_restarts=2)
    final = rec.run(train_fn, 0, 10)
    assert final == 10
    assert calls["n"] == 2


def test_failure_recovery_bounded():
    class NoCkpt:
        def latest_step(self):
            return None

    def always_fail(start):
        raise RuntimeError("boom")

    rec = FailureRecovery(NoCkpt(), max_restarts=2)
    with pytest.raises(RuntimeError):
        rec.run(always_fail, 0, 10)
