"""Slot ring + slot-weighted / periodic rate estimation."""
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.core.estimator import periodic_estimate, slot_weighted_estimate
from repro.core.slots import SlotObs, classify_slot, init_ring, ordered_history, push_slot

CFG = NetConfig()


def _obs(rate, ack=1.0, cnp=0.0, q=0.0):
    return SlotObs(egress_rate=jnp.float32(rate), ack_delay_us=jnp.float32(ack),
                   cnp_count=jnp.float32(cnp), local_queue=jnp.float32(q))


def _fill(ring, rates, **kw):
    for r in rates:
        ring = push_slot(ring, _obs(r, **kw), CFG)
    return ring


def test_classify_slot_levels():
    assert float(classify_slot(_obs(1.0), CFG)) == 0.0
    assert float(classify_slot(_obs(1.0, ack=100.0), CFG)) == 1.0
    assert float(classify_slot(_obs(1.0, ack=100.0, cnp=3.0), CFG)) == 2.0
    assert float(classify_slot(_obs(1.0, ack=100.0, cnp=3.0, q=1e9), CFG)) == 3.0


def test_ring_ordering_and_validity():
    ring = init_ring(16)
    ring = _fill(ring, range(20))            # wraps
    rates, cong, busy, valid = ordered_history(ring)
    assert float(valid.min()) == 1.0         # fully wrapped => all valid
    np.testing.assert_allclose(np.asarray(rates), np.arange(4, 20))


def test_partial_ring_validity():
    ring = init_ring(16)
    ring = _fill(ring, [5.0] * 4)
    _, _, _, valid = ordered_history(ring)
    assert float(valid.sum()) == 4.0


def test_stable_windows_weighted_higher():
    """History = old jittery low-rate slots + recent stable high-rate windows;
    the weighted estimate must sit near the stable rate."""
    ring = init_ring(32)
    rng = np.random.default_rng(0)
    jitter = 50.0 + 45.0 * rng.standard_normal(16)           # CV >> thresh
    ring = _fill(ring, jitter.tolist())
    ring = _fill(ring, [100.0] * 16)                          # stable
    est = slot_weighted_estimate(ring, CFG)
    assert abs(float(est.rate) - 100.0) < 15.0
    assert float(est.stable_frac) >= 0.5


def test_capability_only_from_busy_slots():
    ring = init_ring(32)
    ring = _fill(ring, [10.0] * 16, q=0.0)                    # idle: low egress
    ring = _fill(ring, [90.0] * 16, q=1e9)                    # busy: capability
    est = slot_weighted_estimate(ring, CFG)
    assert float(est.have_capability) == 1.0
    assert abs(float(est.capability) - 90.0) < 1.0
    # the plain estimate blends both
    assert float(est.rate) < 90.0


def test_periodic_predictor_fires_on_recurrence():
    """Rates repeat with period 16 slots; the predictor should forecast the
    NEXT phase's rates rather than the blended mean."""
    cfg = NetConfig()
    period = 16
    pattern = [100.0] * 8 + [20.0] * 8
    ring = init_ring(64)
    ring = _fill(ring, pattern * 4)
    est = periodic_estimate(ring, cfg, period_slots=period)
    assert float(est.recurrent) == 1.0
    # current window = the 20.0 phase; next-phase forecast = 100.0
    assert abs(float(est.rate) - 100.0) < 1.0


def test_periodic_predictor_falls_back_without_recurrence():
    cfg = NetConfig()
    rng = np.random.default_rng(1)
    ring = init_ring(64)
    ring = _fill(ring, rng.uniform(10, 200, 64).tolist())
    est = periodic_estimate(ring, cfg, period_slots=16)
    base = slot_weighted_estimate(ring, cfg)
    if float(est.recurrent) == 0.0:
        np.testing.assert_allclose(float(est.rate), float(base.rate), rtol=1e-6)
