"""The ``trace_replay`` channel model (PR 7): deterministic replay of a
recorded per-edge impairment schedule. Pins (a) bit-exact determinism —
the same schedule reproduces the same realization; (b) the schedule
actually biting where recorded (loss window timing, capacity dips,
deferral conservation); (c) the no-schedule and all-neutral-slot
structural identities; (d) cross-mode channel-column parity (satellite);
(e) the schedule riding as a traced leaf (single compile, batch shape
validation) and the JSON round-trip helpers."""
import os

import numpy as np
import pytest

from repro.config.base import NetConfig, stack_net_params
from repro.netsim import (
    fluid, get_channel_model, get_scheme, run_experiment_batch, simulate,
    simulate_batch, throughput_workload,
)
from repro.netsim.channel import (
    load_schedule_json, save_schedule_json, schedule_from_arrays,
)
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import congestion_workload, mixed_fct_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")
WL = throughput_workload(msg_size=1 << 20, concurrency=16, num_flows=4)
HORIZON = 8_000.0
K = 8
SLOT_US = HORIZON / K


def _cfg(timeline, **kw):
    """One link driven by an explicit K-slot schedule (one slot per
    HORIZON/K µs, so the whole recording plays exactly once)."""
    return NetConfig(distance_km=100.0, horizon_us=HORIZON,
                     channel_schedule=(timeline,),
                     channel_schedule_dt_us=SLOT_US, **kw)


def _timeline(loss=(), defer=(), cap=()):
    l = np.zeros(K, np.float32)
    d = np.zeros(K, np.float32)
    c = np.ones(K, np.float32)
    for i, v in loss:
        l[i] = v
    for i, v in defer:
        d[i] = v
    for i, v in cap:
        c[i] = v
    return schedule_from_arrays(l, d, c)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


# ---------------------------------------------------------------------------
# Determinism: no PRNG anywhere in the model
# ---------------------------------------------------------------------------

def test_replay_is_bit_deterministic():
    cfg = _cfg(_timeline(loss=[(2, 0.3)], defer=[(4, 0.4)],
                         cap=[(5, 0.5)]))
    f_a, tr_a = simulate(cfg, WL, get_scheme("matchrdma"), HORIZON,
                         channel="trace_replay")
    f_b, tr_b = simulate(cfg, WL, get_scheme("matchrdma"), HORIZON,
                         channel="trace_replay")
    assert set(tr_a) == set(tr_b)
    for k in tr_a:
        np.testing.assert_array_equal(np.asarray(tr_a[k]),
                                      np.asarray(tr_b[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(f_a.delivered),
                                  np.asarray(f_b.delivered))


# ---------------------------------------------------------------------------
# The schedule bites where recorded
# ---------------------------------------------------------------------------

def test_replay_reproduces_loss_window():
    """Loss recorded only in slot 2 must drop bytes only inside slot 2's
    simulated-time window — replay is time-indexed, not sampled."""
    cfg = _cfg(_timeline(loss=[(2, 0.25)]))
    _, traces = simulate(cfg, WL, get_scheme("dcqcn"), HORIZON,
                         channel="trace_replay")
    lost = np.asarray(traces["chan_lost"])             # [T] bytes/step
    steps_per_slot = int(round(SLOT_US / cfg.dt_us))
    in_window = lost[2 * steps_per_slot:3 * steps_per_slot]
    outside = np.concatenate([lost[:2 * steps_per_slot],
                              lost[3 * steps_per_slot:]])
    assert float(in_window.sum()) > 0.0
    assert float(outside.sum()) == 0.0
    assert float(np.max(np.asarray(traces["cons_err"]))) < 1e-3


def test_replay_cap_dip_throttles_wire():
    """A recorded 60% capacity dip must show as lower wire throughput
    inside the dip window than in the clean run's same window (the OTN
    line is the path bottleneck here, as in the otn_flap physics test)."""
    wl = throughput_workload(4 << 20, 8, num_flows=4)
    dip = _cfg(_timeline(cap=[(3, 0.4), (4, 0.4)]), num_otn_links=4)
    clean = _cfg(_timeline(), num_otn_links=4)
    _, tr_dip = simulate(dip, wl, get_scheme("dcqcn"), HORIZON,
                         channel="trace_replay")
    _, tr_clean = simulate(clean, wl, get_scheme("dcqcn"), HORIZON,
                           channel="trace_replay")
    steps_per_slot = int(round(SLOT_US / dip.dt_us))
    sl = slice(3 * steps_per_slot, 5 * steps_per_slot)
    wire_dip = float(np.asarray(tr_dip["chan_wire"])[sl].sum())
    wire_clean = float(np.asarray(tr_clean["chan_wire"])[sl].sum())
    assert wire_dip < 0.7 * wire_clean, (wire_dip, wire_clean)


def test_replay_defer_conserves_and_completes():
    """Recorded deferral (delay jitter) holds fluid without destroying
    it: conservation includes the deferral buffer and a finite workload
    still completes."""
    wl = mixed_fct_workload(msg_size=256 << 10, num_inter=4, num_intra=2,
                            num_background=2, request_start_us=2_000.0)
    cfg = NetConfig(distance_km=50.0, horizon_us=20_000.0,
                    channel_schedule=(
                        _timeline(defer=[(i, 0.5) for i in range(2, 6)]),),
                    channel_schedule_dt_us=20_000.0 / K)
    _, traces = simulate(cfg, wl, get_scheme("dcqcn"), 20_000.0,
                         channel="trace_replay")
    assert float(np.asarray(traces["cons_err"]).max()) < 1e-4
    r = run_experiment_batch([cfg], wl, "dcqcn", 20_000.0,
                             trace_mode="metrics",
                             channel="trace_replay")[0]
    assert r["completion_frac"] == 1.0


def test_replay_per_edge_schedules_are_independent():
    """At L=2 each link replays its OWN row of the [L, K, 3] table: flows
    routed onto the clean link lose nothing, flows routed onto the lossy
    link lose bytes."""
    from repro.netsim.workload import FlowSpec, Workload
    lossy = _timeline(loss=[(i, 0.2) for i in range(K)])
    clean = _timeline()
    kw = dict(distance_km=100.0, horizon_us=HORIZON, num_paths=2,
              channel_schedule=(lossy, clean),
              channel_schedule_dt_us=SLOT_US)
    wl_clean = Workload(tuple(FlowSpec(True, 1 << 20, 16, route=(0.0, 1.0))
                              for _ in range(4)))
    wl_lossy = Workload(tuple(FlowSpec(True, 1 << 20, 16, route=(1.0, 0.0))
                              for _ in range(4)))
    _, tr_c = simulate(NetConfig(**kw), wl_clean, get_scheme("dcqcn"),
                       HORIZON, channel="trace_replay")
    _, tr_l = simulate(NetConfig(**kw), wl_lossy, get_scheme("dcqcn"),
                       HORIZON, channel="trace_replay")
    assert float(np.asarray(tr_c["chan_lost"]).sum()) == 0.0
    assert float(np.asarray(tr_l["chan_lost"]).sum()) > 0.0


# ---------------------------------------------------------------------------
# Structural identities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_no_schedule_identity_vs_goldens(golden, scheme):
    """trace_replay with NO schedule is a structural pass-through: every
    golden trace key stays bit-identical (the channel machinery exists
    but never touches a byte)."""
    cfg = NetConfig(distance_km=100.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    final, traces = simulate(cfg, wl, get_scheme(scheme), 10_000.0,
                             channel="trace_replay")
    golden_keys = {k.rsplit("/", 1)[1] for k in golden.files
                   if k.startswith(f"seq/{scheme}/traces/")}
    assert golden_keys <= set(traces)
    for k in golden_keys:
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"{scheme}/{k} diverged bit-for-bit under "
                    f"trace_replay with no schedule")
    for k in ("sent", "acked", "delivered", "done_at_us"):
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/final/{k}"],
            np.asarray(getattr(final, k)),
            err_msg=f"{scheme} final.{k} diverged")


def test_neutral_slots_bit_identical_to_no_schedule():
    """An all-(0, 0, 1) schedule must produce the same bits as no
    schedule at all: every impairment joins the dataflow through a
    where() whose clean branch returns the original tensor."""
    neutral = _cfg(_timeline())
    empty = NetConfig(distance_km=100.0, horizon_us=HORIZON)
    _, tr_n = simulate(neutral, WL, get_scheme("matchrdma"), HORIZON,
                       channel="trace_replay")
    _, tr_e = simulate(empty, WL, get_scheme("matchrdma"), HORIZON,
                       channel="trace_replay")
    assert set(tr_n) == set(tr_e)
    for k in tr_n:
        np.testing.assert_array_equal(np.asarray(tr_n[k]),
                                      np.asarray(tr_e[k]), err_msg=k)


# ---------------------------------------------------------------------------
# Cross-mode parity (satellite): replay is trace-mode agnostic
# ---------------------------------------------------------------------------

def test_channel_columns_cross_mode_parity():
    """goodput/wire/retx_frac agree across full, decimate and metrics
    modes under a replayed schedule; the histogram-inverted p99 is
    bin-ratio bounded."""
    cwl = congestion_workload(num_inter=4, num_intra=4,
                              burst_start_us=3_000.0,
                              burst_len_us=4_000.0, horizon_us=12_000.0)
    tl = _timeline(loss=[(2, 0.1), (5, 0.05)], defer=[(3, 0.3)],
                   cap=[(6, 0.6)])
    cfgs = [NetConfig(distance_km=d, horizon_us=12_000.0,
                      channel_schedule=(tl,),
                      channel_schedule_dt_us=12_000.0 / K)
            for d in (50.0, 300.0)]
    full = run_experiment_batch(cfgs, cwl, "sdr_rdma", 12_000.0,
                                channel="trace_replay")
    dec = run_experiment_batch(cfgs, cwl, "sdr_rdma", 12_000.0,
                               trace_mode="decimate", decimate=8,
                               channel="trace_replay")
    stream = run_experiment_batch(cfgs, cwl, "sdr_rdma", 12_000.0,
                                  trace_mode="metrics",
                                  channel="trace_replay")
    for f, d, s in zip(full, dec, stream):
        for m in ("goodput_gbps", "wire_gbps", "retx_frac"):
            hi = max(abs(f[m]), abs(d[m]), abs(s[m]), 1e-4)
            assert abs(f[m] - s[m]) / hi < 1e-3, (m, f[m], s[m])
            assert abs(f[m] - d[m]) / hi < 1e-3, (m, f[m], d[m])
        p99 = (abs(f["p99_repair_latency_us"] - s["p99_repair_latency_us"])
               / max(f["p99_repair_latency_us"],
                     s["p99_repair_latency_us"], 1e-3))
        assert p99 < 0.1, (f["p99_repair_latency_us"],
                           s["p99_repair_latency_us"])


# ---------------------------------------------------------------------------
# The schedule is a traced leaf
# ---------------------------------------------------------------------------

def test_schedule_value_grid_single_compile():
    """Equal-K schedules with different VALUES are one jaxpr: the table
    is a traced NetParams leaf, K is the only static part."""
    cfgs = [_cfg(_timeline(loss=[(2, lr)], cap=[(5, c)]))
            for lr in (0.0, 0.1) for c in (1.0, 0.5)]
    n0 = fluid._run_traced_batch._cache_size()
    rows = run_experiment_batch(cfgs, WL, "dcqcn", HORIZON,
                                trace_mode="metrics",
                                channel="trace_replay")
    assert fluid._run_traced_batch._cache_size() - n0 <= 1, \
        "schedule values recompiled per cell — the table is not traced"
    assert len(rows) == len(cfgs)
    # cells are ordered (lr, cap): (0, 1), (0, .5), (.1, 1), (.1, .5) —
    # the values bite inside the one launch
    assert rows[2]["retx_frac"] > rows[0]["retx_frac"] == 0.0


def test_schedule_len_mismatch_across_batch_raises():
    a = _cfg(_timeline())
    b = NetConfig(distance_km=100.0, horizon_us=HORIZON,
                  channel_schedule=(schedule_from_arrays(
                      np.zeros(K + 4, np.float32)),),
                  channel_schedule_dt_us=SLOT_US)
    with pytest.raises(ValueError, match="schedule"):
        stack_net_params([a, b])
    with pytest.raises(ValueError, match="schedule"):
        simulate_batch([a, b], WL, get_scheme("dcqcn"), HORIZON,
                       channel="trace_replay")


def test_schedule_shape_validation():
    with pytest.raises(ValueError, match="channel_schedule"):
        NetConfig(num_paths=2, channel_schedule=(_timeline(),)).schedule_len
    with pytest.raises(ValueError):
        NetConfig(channel_schedule=(
            _timeline(), _timeline())).schedule_len
    assert NetConfig().schedule_len == 0
    assert _cfg(_timeline()).schedule_len == K
    assert NetConfig().schedule_array().shape == (1, 0, 3)
    assert _cfg(_timeline()).schedule_array().shape == (1, K, 3)


# ---------------------------------------------------------------------------
# Schedule I/O helpers
# ---------------------------------------------------------------------------

def test_schedule_json_roundtrip(tmp_path):
    sched = (_timeline(loss=[(1, 0.2)], defer=[(2, 0.3)], cap=[(3, 0.5)]),
             _timeline())
    path = tmp_path / "recorded.json"
    save_schedule_json(path, sched, dt_us=125.0, note="unit fixture")
    loaded, dt = load_schedule_json(path)
    assert dt == 125.0
    np.testing.assert_allclose(np.asarray(loaded, np.float32),
                               np.asarray(sched, np.float32))
    # a loaded schedule drops straight into NetConfig
    cfg = NetConfig(num_paths=2, channel_schedule=loaded,
                    channel_schedule_dt_us=dt)
    assert cfg.schedule_len == K


def test_schedule_from_arrays_validation():
    with pytest.raises(ValueError, match="lengths differ"):
        schedule_from_arrays([0.1, 0.2], defer=[0.0])
    tl = schedule_from_arrays([0.1, 0.2])
    np.testing.assert_allclose(np.asarray(tl, np.float32),
                               [[0.1, 0.0, 1.0], [0.2, 0.0, 1.0]],
                               rtol=1e-6)


def test_save_schedule_rejects_bad_shape(tmp_path):
    with pytest.raises(ValueError, match="L, K, 3"):
        save_schedule_json(tmp_path / "x.json", ((0.1, 0.2),))
