"""DCQCN machine + THEMIS scale + budget-gated pseudo-ACK."""
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.core.cc_proxy import init_dcqcn, step_dcqcn, themis_rtt_scale
from repro.core.pseudo_ack import init_pseudo_ack, step_pseudo_ack

CFG = NetConfig()
LINE = 50e9  # bytes/s


def test_dcqcn_cut_on_cnp():
    st = init_dcqcn(2, LINE)
    cnp = jnp.asarray([1.0, 0.0])
    st2 = step_dcqcn(st, cnp, jnp.zeros(2), CFG)
    assert float(st2.rc[0]) < float(st.rc[0])
    assert float(st2.rc[1]) == float(st.rc[1])
    assert float(st2.rt[0]) == float(st.rc[0])    # target = pre-cut rate


def test_dcqcn_recovers_after_cuts():
    st = init_dcqcn(1, LINE)
    for _ in range(20):
        st = step_dcqcn(st, jnp.ones(1), jnp.zeros(1), CFG)
    low = float(st.rc[0])
    # clear for 100 ms of sim time
    steps = int(100_000 / CFG.dt_us)
    sent = jnp.full((1,), LINE * CFG.dt_us * 1e-6)
    for _ in range(steps):
        st = step_dcqcn(st, jnp.zeros(1), sent, CFG)
    assert float(st.rc[0]) > 2.0 * low


def test_dcqcn_rate_floor():
    st = init_dcqcn(1, LINE)
    for _ in range(500):
        st = step_dcqcn(st, jnp.ones(1), jnp.zeros(1), CFG)
    assert float(st.rc[0]) >= CFG.min_rate_mbps * 1e6 / 8.0 - 1.0


def test_themis_scale_monotone_clipped():
    r = themis_rtt_scale(jnp.asarray([1.0, 10.0, 1000.0, 1e7]))
    rn = np.asarray(r)
    assert (np.diff(rn) >= 0).all()
    assert rn[0] >= 1.0 and rn[-1] <= 8.0


def test_pseudo_ack_ungated_releases_everything():
    st = init_pseudo_ack(2)
    accepted = jnp.asarray([1000.0, 5000.0])
    st2, packed = step_pseudo_ack(st, accepted, jnp.zeros(2), 1e-6, gated=False)
    np.testing.assert_allclose(np.asarray(packed), [1000.0, 5000.0])


def test_pseudo_ack_gated_respects_budget_rate():
    st = init_pseudo_ack(1)
    share = jnp.asarray([1e6])             # 1 MB/s
    dt = 1e-3
    total = jnp.asarray([1e9])             # huge backlog
    for _ in range(10):
        st, packed = step_pseudo_ack(st, total, share, dt, gated=True)
    # after 10 ms at 1 MB/s: ~10 KB (+ burst cap 2 ms)
    assert float(packed[0]) <= 1e6 * (10 * dt + 2.5e-3)
    assert float(packed[0]) >= 1e6 * 10 * dt * 0.9


def test_pseudo_ack_burst_cap():
    """Idle credits must not bank an unbounded burst."""
    st = init_pseudo_ack(1)
    share = jnp.asarray([1e9])
    # accrue credits with no backlog for 1 s of sim time
    for _ in range(1000):
        st, _ = step_pseudo_ack(st, jnp.zeros(1), share, 1e-3, gated=True)
    assert float(st.credits[0]) <= 1e9 * 2e-3 + 1.0   # max_burst_s = 2 ms
