"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import flash_attention, rglru_recurrence, ssd_scan
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import attention_ref, rglru_ref, ssd_ref


@pytest.mark.parametrize("b,s,hq,hk,d,bq,bk", [
    (2, 256, 8, 2, 64, 64, 64),
    (1, 512, 4, 4, 128, 128, 256),
    (2, 128, 6, 2, 32, 128, 32),
    (1, 128, 2, 1, 256, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hk, d, bq, bk, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    out = flash_attention_fwd(q, k, v, block_q=bq, block_kv=bk, interpret=True)
    ref = attention_ref(q, k, v)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = flash_attention_fwd(q, k, v, block_q=64, block_kv=64, softcap=20.0,
                              interpret=True)
    ref = attention_ref(q, k, v, softcap=20.0)
    assert float(jnp.abs(out - ref).max()) < 2e-6


def test_flash_attention_grad_matches_oracle():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 32))
    g1 = jax.grad(lambda q, k, v: flash_attention(q, k, v, 64, 64).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.abs(a - b).max()) < 5e-6


@pytest.mark.parametrize("b,s,h,p,g,n,ck", [
    (2, 96, 4, 32, 1, 16, 32),
    (1, 256, 8, 64, 1, 128, 128),
    (2, 100, 4, 32, 2, 16, 32),      # padding path + groups
    (1, 64, 2, 16, 1, 8, 64),
])
def test_ssd_scan_sweep(b, s, h, p, g, n, ck):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y = ssd_scan(x, dt, A, B, C, chunk=ck)
    yr = ssd_ref(x, dt, A, B, C)
    scale = float(jnp.abs(yr).max()) + 1e-6
    assert float(jnp.abs(y - yr).max()) / scale < 1e-4


@pytest.mark.parametrize("b,s,w,bs,bw", [
    (2, 128, 256, 32, 128),
    (1, 300, 64, 256, 512),          # non-divisible fallback blocks
    (3, 64, 512, 64, 256),
])
def test_rglru_scan_sweep(b, s, w, bs, bw):
    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (b, s, w))) * 0.2 + 0.79
    bb = jax.random.normal(jax.random.fold_in(key, 1), (b, s, w))
    h = rglru_recurrence(a, bb, block_s=bs, block_w=bw)
    hr = rglru_ref(a, bb)
    assert float(jnp.abs(h - hr).max()) < 1e-5


def test_ssd_kernel_agrees_with_model_path():
    """Kernel vs the model's chunked implementation (same algorithm)."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    b, s, h, p, g, n = 2, 128, 4, 32, 1, 32
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y_kernel = ssd_scan(x, dt, A, B, C, chunk=64)
    y_model, _ = ssd_chunked(x, dt, A, B, C, chunk=64)
    assert float(jnp.abs(y_kernel - y_model).max()) < 1e-4
