"""Streaming in-scan metrics (``trace_mode="metrics"``), the chunked /
device-sharded launch plan, and the O(B) memory guarantee.

Covers: the vmap-independent metric oracle (the per-scheme streaming/full
parity check lives in tests/test_scheme_api.py, parametrized over all six
registered schemes), the jaxpr proof that metrics mode allocates no [B, T]
buffer, chunked kilocell sweeps sharing one compiled program,
sharded-vs-single-device equivalence (subprocess, 4 forced host devices),
the B=1 delegation of ``run_experiment``, and the bench JSON dedupe."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import NetConfig, batch_template, stack_net_params
from repro.netsim import (
    get_scheme, run_experiment, run_experiment_batch, simulate,
    simulate_batch, sweep_grid, throughput_workload,
)
from repro.netsim import fluid, runner
from repro.netsim.workload import (
    WorkloadParams, as_workload_batch, congestion_workload,
)

WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
CWL = congestion_workload(num_inter=4, num_intra=4, burst_start_us=3_000.0,
                          burst_len_us=4_000.0, horizon_us=12_000.0)

TIGHT = ("throughput_gbps", "intra_thr_gbps", "mean_buffer_mb",
         "peak_buffer_mb", "pause_ratio", "goodput_bytes",
         "completion_frac")


def _rel(a, b, floor=1e-4):
    return abs(a - b) / max(abs(a), abs(b), floor)


# ---------------------------------------------------------------------------
# Parity: streaming reductions == trace-materialized metrics. The per-scheme
# streaming/full equivalence check lives in tests/test_scheme_api.py
# (test_streaming_full_equivalence_all_six — parametrized over ALL six
# registered schemes); this module keeps the vmap-independent oracle.
# ---------------------------------------------------------------------------

def test_batch_metrics_match_unbatched_simulate_oracle():
    """``run_experiment`` now delegates to the batched engine, so the old
    batch-vs-sequential metric tests compare batch against batch. Keep one
    INDEPENDENT oracle: metrics computed by hand here from the truly
    unbatched ``simulate()`` traces (no vmap anywhere) must match the
    batch rows — a vmap-level masking/padding regression cannot hide."""
    cfgs = [NetConfig(distance_km=d) for d in (100.0, 300.0)]
    pad, hist = fluid.batch_padding(cfgs)
    rows = run_experiment_batch(cfgs, CWL, "matchrdma", 12_000.0)
    for cfg, row in zip(cfgs, rows):
        _, traces = simulate(cfg, CWL, get_scheme("matchrdma"), 12_000.0,
                             delay_pad=pad, history_slots=hist)
        thr = np.asarray(traces["thr_inter"])
        warm = int(thr.shape[0] * 0.1)
        q = np.asarray(traces["q_dst"])
        pause = np.asarray(traces["pause_dst"])
        assert _rel(row["throughput_gbps"],
                    float(thr[warm:].mean()) * 8.0 / 1e9) < 1e-3
        assert _rel(row["peak_buffer_mb"], float(q.max()) / 1e6) < 1e-3
        assert _rel(row["mean_buffer_mb"],
                    float(q[warm:].mean()) / 1e6) < 1e-3
        assert _rel(row["pause_ratio"], float(pause[warm:].mean())) < 1e-3


def test_streaming_rows_carry_scheme_columns():
    """Scheme-streamed reductions (``Scheme.finalize_metrics``) join the
    rows in metrics mode only — each builtin streams its own diagnostic."""
    cfgs = [NetConfig(distance_km=100.0)]
    expect = {"dcqcn": "mean_cc_rate_gbps",
              "themis": "mean_cc_rate_gbps",
              "pseudo_ack": "mean_pseudo_lead_mb",
              "matchrdma": "mean_budget_at_src_gbps"}
    for scheme, col in expect.items():
        s = run_experiment_batch(cfgs, WL, scheme, 6_000.0,
                                 trace_mode="metrics")[0]
        f = run_experiment_batch(cfgs, WL, scheme, 6_000.0)[0]
        assert col in s and np.isfinite(s[col]), (scheme, col)
        assert "mean_budget_gbps" in s      # inherited default accumulator
        assert col not in f                 # full mode keeps the legacy set


# ---------------------------------------------------------------------------
# The O(B) guarantee: no [B, T] buffer exists anywhere in the program
# ---------------------------------------------------------------------------

def _walk_jaxprs(obj):
    """Yield every (sub)jaxpr reachable from a jaxpr/closed-jaxpr —
    pjit/scan/cond bodies included."""
    if hasattr(obj, "jaxpr"):              # ClosedJaxpr
        obj = obj.jaxpr
    if not hasattr(obj, "eqns"):
        return
    yield obj
    for eqn in obj.eqns:
        for v in eqn.params.values():
            for x in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(x, "jaxpr") or hasattr(x, "eqns"):
                    yield from _walk_jaxprs(x)


def _max_buffer_elems(jaxpr) -> int:
    best = 0
    for j in _walk_jaxprs(jaxpr):
        for eqn in j.eqns:
            for v in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None) is not None:
                    best = max(best, int(np.prod(aval.shape) or 1))
    return best


def test_metrics_mode_allocates_no_bt_buffers():
    """Walk the WHOLE jaxpr of a streaming batch launch (scan body, vmap,
    pjit — everything): no intermediate or output may reach B*T elements.
    Full mode on the same grid is the positive control — its stacked trace
    output is exactly [B, T]."""
    cfgs = [NetConfig(distance_km=d) for d in (1.0, 5.0, 10.0, 2.0)]
    steps, b = 2000, len(cfgs)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=1_000.0, burst_len_us=2_000.0,
                             horizon_us=10_000.0)
    wlp = as_workload_batch(wl, b)
    wlp = WorkloadParams(*(jnp.asarray(np.asarray(v)) for v in wlp))
    tmpl = batch_template(cfgs)
    params = stack_net_params(cfgs)
    pad, hist = fluid.batch_padding(cfgs)
    scheme = get_scheme("matchrdma")

    def trace(mode):
        return jax.make_jaxpr(
            lambda p, w: fluid._run_traced_batch(
                tmpl, p, w, scheme, steps, 0, pad, hist, mode, 1,
                steps // 10))(params, wlp)

    assert _max_buffer_elems(trace("metrics")) < b * steps, \
        "streaming mode materialized an O(B*T) buffer"
    assert _max_buffer_elems(trace("full")) >= b * steps, \
        "positive control failed: the detector missed the [B, T] traces"


# ---------------------------------------------------------------------------
# Launch plan: chunking + sharding
# ---------------------------------------------------------------------------

def test_chunked_matches_unchunked():
    cfgs = [NetConfig(distance_km=d)
            for d in (1.0, 40.0, 80.0, 120.0, 160.0)]
    a = run_experiment_batch(cfgs, WL, "dcqcn", 6_000.0,
                             trace_mode="metrics", chunk_cells=2)
    b = run_experiment_batch(cfgs, WL, "dcqcn", 6_000.0,
                             trace_mode="metrics")
    assert len(a) == len(b) == len(cfgs)
    for ra, rb in zip(a, b):
        for m in TIGHT:
            assert _rel(ra[m], rb[m]) < 1e-6, (m, ra[m], rb[m])


def test_chunked_kilocell_sweep_single_compile():
    """A >1000-cell grid in streaming mode: bounded memory (256-cell
    launches, O(chunk) accumulators), ONE compiled program across all
    chunks (the padded trailing chunk shares the shape), row order
    preserved."""
    dists = np.linspace(1.0, 20.0, 1008)
    cfgs = [NetConfig(distance_km=float(d)) for d in dists]
    n0 = fluid._run_traced_batch._cache_size()
    rows = sweep_grid(cfgs, WL, ("matchrdma",), horizon_us=1_500.0,
                      trace_mode="metrics", chunk_cells=256)
    assert len(rows) == len(cfgs)
    assert fluid._run_traced_batch._cache_size() - n0 == 1, \
        "chunked launches did not share one compiled program"
    assert all(np.isfinite(r["throughput_gbps"]) for r in rows)
    assert [r["distance_km"] for r in rows] == [float(d) for d in dists]


def test_auto_chunk_bounds_full_mode_traces():
    """The auto chunk size keeps a full-trace launch's [B_chunk, T] block
    under the MAX_TRACE_FLOATS budget, and streaming launches use the flat
    cell ceiling (rounded up to a device multiple)."""
    t = 100_000
    chunk = runner.chunk_cells(t, "full", 1, None, 1)
    assert chunk * t * runner._TRACE_KEYS_EST <= runner.MAX_TRACE_FLOATS
    assert chunk >= 1
    assert runner.chunk_cells(t, "metrics", 1, None, 1) \
        == runner.METRICS_CHUNK_CELLS
    assert runner.chunk_cells(t, "metrics", 1, 30, 4) == 32


_SUBPROC_SHARDED = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.config.base import NetConfig
    from repro.netsim import run_experiment_batch, throughput_workload
    assert len(jax.devices()) == 4
    wl = throughput_workload(1 << 20, 1, num_flows=4)
    # 6 cells on 4 devices: the launch plan must pad to 8 so the device
    # count evenly splits the batch, then drop the padding rows
    cfgs = [NetConfig(distance_km=d)
            for d in (1.0, 50.0, 100.0, 200.0, 400.0, 800.0)]
    multi = run_experiment_batch(cfgs, wl, "matchrdma", 8_000.0,
                                 trace_mode="metrics")
    assert len(multi) == len(cfgs)
    single = run_experiment_batch(cfgs, wl, "matchrdma", 8_000.0,
                                  trace_mode="metrics",
                                  devices=jax.devices()[:1])
    for a, b in zip(multi, single):
        for k, va in a.items():
            if not isinstance(va, float) or not np.isfinite(va):
                continue
            vb = b[k]
            assert abs(va - vb) <= 1e-6 * max(abs(va), abs(vb), 1e-9), \\
                (k, va, vb)
    print("SHARDED_OK")
""")


def test_sharded_matches_single_device():
    """The scenario axis sharded over 4 (forced host) devices must produce
    the same rows as the single-device launch — sharding only places the
    embarrassingly parallel [B] axis, it never changes the program."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_SHARDED],
                       capture_output=True, text=True, cwd=".", timeout=300)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Mode plumbing + single-cell delegation
# ---------------------------------------------------------------------------

def test_trace_mode_validation():
    with pytest.raises(ValueError, match="unknown trace_mode"):
        simulate(NetConfig(distance_km=1.0), WL, get_scheme("dcqcn"),
                 1_000.0, trace_mode="bogus")
    with pytest.raises(ValueError, match="decimate must be"):
        simulate_batch([NetConfig(distance_km=1.0)], WL, "dcqcn", 1_000.0,
                       trace_mode="decimate", decimate=0)


def test_decimate_mode_keeps_every_kth_step():
    cfg = NetConfig(distance_km=10.0)
    _, full = simulate(cfg, WL, get_scheme("dcqcn"), 5_000.0)
    _, dec = simulate(cfg, WL, get_scheme("dcqcn"), 5_000.0,
                      trace_mode="decimate", decimate=5)
    steps = np.asarray(full["q_dst"]).shape[0]
    assert np.asarray(dec["q_dst"]).shape[0] == steps // 5
    # block k keeps the trace of its LAST step: index k*5 + 4 of the full run
    np.testing.assert_array_equal(np.asarray(dec["q_dst"]),
                                  np.asarray(full["q_dst"])[4::5])


def test_run_experiment_delegates_to_batch():
    """Single-cell metrics ARE the batch-wide path at B=1: identical row
    (bit-for-bit — same code), and the hand-kept single-cell extractor is
    gone."""
    cfg = NetConfig(distance_km=100.0)
    row = run_experiment(cfg, WL, get_scheme("dcqcn"), 6_000.0)
    batch_row = run_experiment_batch([cfg], WL, "dcqcn", 6_000.0)[0]
    assert set(row) == set(batch_row)
    for k, v in row.items():
        if isinstance(v, float) and np.isnan(v):
            assert np.isnan(batch_row[k]), k
        else:
            assert v == batch_row[k], k
    assert not hasattr(runner, "_metrics_row"), \
        "_metrics_row resurrected — the metric set must have ONE definition"
    srow = run_experiment(cfg, WL, get_scheme("matchrdma"), 6_000.0,
                          trace_mode="metrics")
    assert "mean_budget_gbps" in srow


# ---------------------------------------------------------------------------
# Streaming quantile + bench record hygiene
# ---------------------------------------------------------------------------

def test_hist_quantile_bounded_error():
    """Inverting the fixed-bin log-histogram bounds the quantile estimate's
    relative error by the bin ratio, independent of sample count."""
    from repro.netsim.fluid import HIST_BINS, _hist_bin_index, hist_quantile
    rng = np.random.default_rng(0)
    vals = np.exp(rng.uniform(np.log(1e3), np.log(1e9),
                              size=20_000)).astype(np.float32)
    idx = np.asarray(_hist_bin_index(jnp.asarray(vals)))
    hist = np.bincount(idx, minlength=HIST_BINS).astype(np.float64)
    for q in (0.5, 0.9, 0.99):
        est = float(hist_quantile(hist, q))
        ref = float(np.quantile(vals, q))
        assert abs(est - ref) / ref < 0.08, (q, est, ref)
    # the zero bin: all-below-min samples invert to exactly 0
    zhist = np.zeros(HIST_BINS)
    zhist[0] = 100.0
    assert float(hist_quantile(zhist, 0.99)) == 0.0


def test_bench_append_stamps_rev_and_dedupes(tmp_path, monkeypatch):
    """BENCH json appends: every record carries a git rev, and re-running
    at the same (grid, backend, rev) replaces the entry instead of
    stacking near-duplicates."""
    from benchmarks import netsim_sweep_bench as bench
    p = tmp_path / "bench.json"
    monkeypatch.setattr(bench, "BENCH_PATH", str(p))
    rec = {"grid": {"cells": 4}, "backend": "cpu",
           "git_rev": bench._git_rev(), "speedup_warm": 1.0}
    assert rec["git_rev"]               # stamped, non-empty
    bench._append_record(dict(rec))
    bench._append_record(dict(rec, speedup_warm=2.0))
    hist = json.load(open(p))
    assert len(hist) == 1 and hist[0]["speedup_warm"] == 2.0
    assert "timestamp" in hist[0]
    bench._append_record(dict(rec, git_rev=rec["git_rev"] + "x"))
    assert len(json.load(open(p))) == 2
