"""Gradient tuner vs the zeroth-order hillclimb (docs/differentiable.md).

Two pins on the same tuning cell (matchrdma, 100 km, 6 ms horizon,
congestion workload, budget_headroom knob):

  * the seeded ``benchmarks.hillclimb.netsim_tune`` candidate output —
    value, score, and its evaluation count — so the baseline cannot
    silently drift under the comparison;
  * the grad tuner reaches AT LEAST the hillclimb objective on the same
    cell in strictly FEWER simulator evaluations (the headline claim the
    ``bench-grad`` record in BENCH_netsim_sweep.json tracks).

The surrogate-improvement check pins the mechanism, not just the
outcome: each Adam step must not decrease the soft surrogate by more
than noise, i.e. the gradient signal through the scan is real.
"""
import numpy as np
import pytest

from benchmarks.hillclimb import netsim_tune
from repro.netsim import grad_tune

DISTS = (100.0,)
HORIZON = 6_000.0


@pytest.fixture(scope="module")
def hillclimb_result():
    return netsim_tune("headroom", iters=2, dists=DISTS, horizon_us=HORIZON)


@pytest.fixture(scope="module")
def grad_result():
    return grad_tune.tune(knobs=("budget_headroom",), dists=DISTS,
                          horizon_us=HORIZON, steps=4)


def test_hillclimb_seeded_pin(hillclimb_result):
    val, score, evals = hillclimb_result
    # seeded bracket search: 5 candidates x 2 iters, lands on the lower
    # wall of the headroom box on this cell
    assert evals == 10
    assert val == pytest.approx(0.85, abs=1e-9)
    assert score == pytest.approx(267.392, abs=0.5)


def test_grad_tuner_beats_hillclimb_on_evals(hillclimb_result, grad_result):
    _, hc_score, hc_evals = hillclimb_result
    res = grad_result
    assert res.sim_evals < hc_evals, (res.sim_evals, hc_evals)
    # >= up to float noise: same true objective reached with fewer evals
    assert res.objective >= hc_score - 1e-6, (res.objective, hc_score)
    lo, hi = grad_tune.KNOB_BOUNDS["budget_headroom"]
    assert lo <= res.knobs["budget_headroom"] <= hi


def test_grad_tuner_surrogate_improves(grad_result):
    surr = [h["surrogate"] for h in grad_result.history]
    assert len(surr) == 4
    assert np.all(np.isfinite(surr))
    # Adam follows a real slope: monotone non-decreasing up to tiny noise
    assert all(b >= a - 1e-3 for a, b in zip(surr, surr[1:])), surr
    assert surr[-1] > surr[0], surr


def test_grad_tuner_honest_eval_accounting(grad_result):
    # 2 per Adam step (forward+backward) + 1 final hard-engine scoring
    assert grad_result.sim_evals == 2 * 4 + 1


def test_adversarial_mode_knob_validation():
    with pytest.raises(ValueError, match="unknown knob"):
        grad_tune.tune(knobs=("budget_headroom",), adversarial=True)
