"""Metric-parity bugfix pins (PR 6 satellites).

1. Decimate-mode channel columns must agree with full-trace AND streamed
   modes: decimated ``chan_*`` samples are block SUMS
   (``fluid.DECIMATE_SUM_KEYS``) and the extractor normalizes by SIMULATED
   time (``n_samples * decimate * dt_s``). Pre-fix, the decimated path
   summed single-step subsamples and divided by sampled time — a noisy,
   decimation-dependent estimate that drifted from the streamed twin.

2. Completed/unbounded-flow sentinels are the shared helpers
   ``fluid.is_unfinished`` / ``workload.is_unbounded`` — not re-derived
   magic literals (``fct < 1e29``, ``total < BIG / 2``) that silently
   drift from the engine's own INF/BIG definitions.
"""
import numpy as np
import pytest

from repro.config.base import NetConfig
from repro.netsim import get_scheme, run_experiment_batch
from repro.netsim import runner
from repro.netsim.fluid import INF, is_unfinished
from repro.netsim.workload import (
    BIG, FlowSpec, Workload, is_unbounded, stack_workload_params,
    throughput_workload,
)

WL = throughput_workload(msg_size=1 << 20, concurrency=16, num_flows=4)


# ---------------------------------------------------------------------------
# Satellite 1: decimate-mode channel-column parity
# ---------------------------------------------------------------------------

def test_channel_columns_agree_across_trace_modes():
    """goodput/wire/retx columns from a lossy run must agree across
    full / decimate / streamed modes to float tolerance — not just in
    expectation. Geometry aligns the warm cutoffs exactly: 2000 steps,
    decimate 5 -> 400 samples, and the 10% warm cutoff lands on step 200
    in both clocks, so the windows match and the comparison is exact."""
    cfg = NetConfig(distance_km=200.0, horizon_us=10_000.0, loss_rate=1e-4)
    sch = get_scheme("matchrdma")
    (full,) = run_experiment_batch([cfg], WL, sch, 10_000.0,
                                   trace_mode="full", channel="impaired")
    (dec,) = run_experiment_batch([cfg], WL, sch, 10_000.0,
                                  trace_mode="decimate", decimate=5,
                                  channel="impaired")
    (stream,) = run_experiment_batch([cfg], WL, sch, 10_000.0,
                                     trace_mode="metrics",
                                     channel="impaired")
    for k in ("goodput_gbps", "wire_gbps", "retx_frac"):
        assert full[k] == pytest.approx(dec[k], rel=1e-5), \
            (k, full[k], dec[k])
        assert stream[k] == pytest.approx(dec[k], rel=1e-4), \
            (k, stream[k], dec[k])


def test_channel_cols_normalize_by_simulated_time():
    """Unit pin of the extractor itself: the same per-step byte totals,
    presented once as 100 full-rate samples and once as 20 block-sum
    samples of 5 steps each, must yield identical Gbps columns."""
    dt_s = 5e-6
    rng = np.random.default_rng(0)
    wire = rng.uniform(1e4, 2e4, size=(1, 100))
    lost = rng.uniform(0.0, 10.0, size=(1, 100))
    traces_full = {"chan_wire": wire, "chan_lost": lost,
                   "chan_retx": lost.copy(),
                   "chan_repair_wait_us": np.zeros((1, 100))}
    blocks = {k: v.reshape(1, 20, 5).sum(axis=2)
              for k, v in traces_full.items() if k != "chan_repair_wait_us"}
    blocks["chan_repair_wait_us"] = np.zeros((1, 20))
    a = runner._channel_cols_from_traces(traces_full, 0, dt_s)
    b = runner._channel_cols_from_traces(blocks, 0, dt_s, decimate=5)
    for k in ("goodput_gbps", "wire_gbps", "retx_frac"):
        np.testing.assert_allclose(a[k], b[k], rtol=1e-12, err_msg=k)


# ---------------------------------------------------------------------------
# Satellite 2: one shared completed/unbounded sentinel
# ---------------------------------------------------------------------------

def test_sentinel_helpers_semantics():
    assert is_unfinished(np.float32(INF))
    assert is_unfinished(INF / 2)                  # boundary is unfinished
    assert not is_unfinished(np.float32(1e5))
    # f32 round-trip of the sentinels stays on the right side
    assert is_unfinished(np.float32(INF) * np.float32(1.0))
    assert is_unbounded(np.float32(BIG))
    assert not is_unbounded(np.float32(1e12))


def test_flow_metrics_use_shared_sentinels():
    """The metric extractor must classify by the HELPERS' threshold
    (INF/2), not a re-derived literal: a done_at strictly below INF/2
    counts as completed even if it exceeds the old ``1e29`` magic cutoff,
    and a never-finishing flow (done_at == INF) never does."""
    wl = Workload((
        FlowSpec(True, 1 << 20, 4, total_bytes=1e6),   # completes normally
        FlowSpec(True, 1 << 20, 4, total_bytes=1e6),   # never completes
        FlowSpec(True, 1 << 20, 4, total_bytes=1e6),   # below-INF/2 oddball
        FlowSpec(True, 1 << 20, 4),                    # unbounded (BIG)
    ))
    wlp = stack_workload_params([wl])
    final_np = {
        "delivered": np.array([[1e6, 5e5, 1e6, 5e9]], np.float32),
        "done_at_us": np.array([[5_000.0, INF, 2e29, INF]], np.float32),
    }
    goodput, avg_fct, completion = runner._flow_metrics(wlp, final_np)
    # 3 finite inter flows; the oddball done_at (2e29 < INF/2) must count
    assert completion[0] == pytest.approx(2.0 / 3.0)
    assert goodput[0] == pytest.approx(1e6 + 5e5 + 1e6 + 5e9)
    assert np.isfinite(avg_fct[0])
