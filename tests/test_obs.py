"""Observability-layer tests (docs/observability.md).

The load-bearing claim: the obs machinery is *additive*. With the obs
knobs SET but ``trace_mode != "window"`` every scheme stays bit-identical
to the goldens (the knobs are static config fields the non-window modes
never read), and window mode itself streams — its jaxpr holds no [B, T]
buffer, only the O(B·W) ring + O(B·E) event ring.
"""
import json
import os
import sys

import dataclasses
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro.config.base import NetConfig
from repro.netsim import (
    EVENT_KINDS, decode_events, get_scheme, read_manifest, simulate,
    simulate_batch, sweep_grid, timeline_from_window, unroll_window,
    write_manifest,
)
from repro.netsim.fluid import WindowAux
from repro.netsim.obs.events import (event_count, init_event_ring,
                                     kind_name, push_events)
from repro.netsim.obs.timeline import timeline_cell
from repro.netsim.schemes import ALL_SCHEMES, Scheme
from repro.netsim.workload import congestion_workload, throughput_workload

from test_streaming_metrics import _max_buffer_elems  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")

# the golden scenarios, verbatim from tests/golden/generate_goldens.py
SEQ_CFG_KW = dict(distance_km=100.0)
SEQ_WL_KW = dict(num_inter=4, num_intra=4, burst_start_us=3_000.0,
                 burst_len_us=4_000.0, horizon_us=10_000.0)
SEQ_HORIZON_US = 10_000.0
BATCH_DISTS = (1.0, 300.0)
BATCH_HORIZON_US = 8_000.0

# a scenario hot enough to actually fire events (the golden congestion
# workload is too gentle for matchrdma's brake at 100 km)
HOT_WL_KW = dict(num_inter=8, num_intra=8, burst_start_us=2_000.0,
                 burst_len_us=6_000.0, horizon_us=12_000.0)
HOT_HORIZON_US = 12_000.0


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def _obs_cfg(**kw):
    """A config with the obs knobs SET (ring sized, window shrunk) — the
    non-window modes must not read them."""
    return dataclasses.replace(NetConfig(**kw), event_ring_slots=32,
                               trace_window_steps=64)


# ---------------------------------------------------------------------------
# obs-off bit-identity: knobs set, mode != window -> goldens untouched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_obs_knobs_do_not_perturb_sequential_goldens(golden, scheme):
    wl = congestion_workload(**SEQ_WL_KW)
    final, traces = simulate(_obs_cfg(**SEQ_CFG_KW), wl, get_scheme(scheme),
                             SEQ_HORIZON_US)
    golden_keys = {k.rsplit("/", 1)[1] for k in golden.files
                   if k.startswith(f"seq/{scheme}/traces/")}
    assert set(traces) == golden_keys
    for k, v in traces.items():
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/traces/{k}"], np.asarray(v),
            err_msg=f"{scheme}/{k}: obs knobs perturbed a full-mode run")
    for k in ("sent", "acked", "delivered", "done_at_us"):
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/final/{k}"],
            np.asarray(getattr(final, k)),
            err_msg=f"{scheme} final.{k}: obs knobs perturbed the run")


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_obs_knobs_do_not_perturb_batched_goldens(golden, scheme):
    cfgs = [_obs_cfg(distance_km=d) for d in BATCH_DISTS]
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    final, traces = simulate_batch(cfgs, wl, get_scheme(scheme),
                                   BATCH_HORIZON_US)
    keys = {k.rsplit("/", 1)[1] for k in golden.files
            if k.startswith(f"batch/{scheme}/traces/")}
    assert set(traces) == keys
    for k in keys:
        np.testing.assert_array_equal(
            golden[f"batch/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"{scheme}/{k}: obs knobs perturbed a batched run")
    np.testing.assert_array_equal(
        golden[f"batch/{scheme}/final/delivered"],
        np.asarray(final.delivered))


def _trace_batch(cfgs, wl, steps, mode):
    from repro.config.base import batch_template, stack_net_params
    from repro.netsim import fluid
    from repro.netsim.workload import WorkloadParams, as_workload_batch
    wlp = as_workload_batch(wl, len(cfgs))
    wlp = WorkloadParams(*(jnp.asarray(np.asarray(v)) for v in wlp))
    tmpl = batch_template(cfgs)
    params = stack_net_params(cfgs)
    pad, hist = fluid.batch_padding(cfgs)
    return jax.make_jaxpr(
        lambda p, w: fluid._run_traced_batch(
            tmpl, p, w, get_scheme("dcqcn"), steps, 0, pad, hist, mode, 1,
            steps // 10))(params, wlp)


def test_obs_knobs_leave_full_mode_jaxpr_unchanged():
    """Stronger than value-identity: the traced program of a full-mode run
    is textually identical with and without the obs knobs — the window/
    ring machinery is entirely gated behind ``mode == 'window'``."""
    wl = congestion_workload(**SEQ_WL_KW)
    steps = NetConfig(**SEQ_CFG_KW).horizon_steps(SEQ_HORIZON_US)
    jaxprs = [str(_trace_batch([cfg], wl, steps, "full"))
              for cfg in (NetConfig(**SEQ_CFG_KW), _obs_cfg(**SEQ_CFG_KW))]
    assert jaxprs[0] == jaxprs[1]


# ---------------------------------------------------------------------------
# window mode: streaming footprint, parity, ring contents
# ---------------------------------------------------------------------------

def test_window_mode_allocates_no_bt_buffers():
    """Window mode's jaxpr may hold O(B·W) + O(B·E) buffers but never the
    full [B, T] trace block. Full mode on the same grid is the positive
    control."""
    cfgs = [_obs_cfg(distance_km=d) for d in (1.0, 5.0, 10.0, 2.0)]
    steps, b = 2000, len(cfgs)
    w = cfgs[0].trace_window_steps
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=1_000.0, burst_len_us=5_000.0,
                             horizon_us=steps * cfgs[0].dt_us)
    assert w < steps  # else the bound below is vacuous
    win_max = _max_buffer_elems(_trace_batch(cfgs, wl, steps, "window"))
    full_max = _max_buffer_elems(_trace_batch(cfgs, wl, steps, "full"))
    assert full_max >= b * steps
    assert win_max < b * steps, \
        f"window mode materialized a [B,T]-sized buffer ({win_max} elems)"


def test_window_matches_metrics_and_full():
    """One seq run, three claims: (a) the streamed accumulators under
    window mode equal metrics mode bit-for-bit; (b) the trace ring's
    unrolled rows equal the last W steps of a full-mode run bit-for-bit;
    (c) the final state is identical across all three modes."""
    cfg = _obs_cfg(**SEQ_CFG_KW)
    wl = congestion_workload(**SEQ_WL_KW)
    scheme = get_scheme("dcqcn")
    steps = cfg.horizon_steps(SEQ_HORIZON_US)
    w = cfg.trace_window_steps

    fin_w, aux = simulate(cfg, wl, scheme, SEQ_HORIZON_US,
                          trace_mode="window")
    assert isinstance(aux, WindowAux)
    fin_m, acc = simulate(cfg, wl, scheme, SEQ_HORIZON_US,
                          trace_mode="metrics")
    fin_f, traces = simulate(cfg, wl, scheme, SEQ_HORIZON_US)

    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), aux.acc, acc)
    step_idx, ordered = unroll_window(aux.window, steps, w)
    np.testing.assert_array_equal(step_idx, np.arange(steps - w, steps))
    assert set(ordered) == set(traces)
    for k in traces:
        np.testing.assert_array_equal(
            np.asarray(traces[k])[-w:], ordered[k],
            err_msg=f"window ring diverged from full-mode tail at {k}")
    for fin in (fin_m, fin_f):
        np.testing.assert_array_equal(np.asarray(fin_w.delivered),
                                      np.asarray(fin.delivered))


def test_sweep_grid_window_rows_equal_metrics_rows():
    cfgs = [_obs_cfg(distance_km=d) for d in (100.0, 300.0)]
    wl = congestion_workload(**HOT_WL_KW)
    rows_w = sweep_grid(cfgs, wl, ("dcqcn", "matchrdma"), HOT_HORIZON_US,
                        trace_mode="window")
    rows_m = sweep_grid(cfgs, wl, ("dcqcn", "matchrdma"), HOT_HORIZON_US,
                        trace_mode="metrics")
    assert len(rows_w) == len(rows_m) == 4
    for a, b in zip(rows_w, rows_m):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k] or (a[k] != a[k] and b[k] != b[k]), \
                f"window/metrics row divergence at {k}"


# ---------------------------------------------------------------------------
# event ring semantics
# ---------------------------------------------------------------------------

def test_ring_overflow_evicts_oldest():
    """Scripted one-event-per-step pushes through a 4-slot ring inside a
    scan: count stays monotone past the capacity, survivors are exactly
    the last 4 events, oldest-first."""
    slots, n = 4, 11

    def step(ring, t):
        ring = push_events(ring, slots, t.astype(jnp.float32) * 5.0,
                           [("pfc_xoff", 7, t.astype(jnp.float32),
                             jnp.asarray(True))])
        return ring, ring.count

    ring, counts = jax.lax.scan(step, init_event_ring(slots),
                                jnp.arange(n))
    counts = np.asarray(counts)
    assert list(counts) == list(range(1, n + 1))  # monotone, never clipped
    assert int(event_count(ring)) == n
    evs = decode_events(ring, slots)
    assert len(evs) == slots
    assert [e["value"] for e in evs] == [float(v) for v in range(n - slots, n)]
    assert [e["t_us"] for e in evs] == [v * 5.0 for v in range(n - slots, n)]
    assert all(e["kind"] == "pfc_xoff" and e["obj"] == 7 for e in evs)


def test_ring_partial_firing_and_trash_slot():
    """Non-fired candidates land in the discard slot and never disturb the
    ring; multiple candidates in one step keep candidate order."""
    slots = 8

    def step(ring, t):
        fired_a = (t % 3) == 0
        fired_b = (t % 4) == 0
        ring = push_events(ring, slots, t.astype(jnp.float32), [
            ("pfc_xoff", 0, jnp.float32(1.0), fired_a),
            ("pfc_xon", 1, jnp.float32(2.0), fired_b),
        ])
        return ring, None

    ring, _ = jax.lax.scan(step, init_event_ring(slots), jnp.arange(6))
    # t=0: both; t=3: a; t=4: b -> 4 events total
    evs = decode_events(ring, slots)
    assert [(e["t_us"], e["kind"]) for e in evs] == [
        (0.0, "pfc_xoff"), (0.0, "pfc_xon"),
        (3.0, "pfc_xoff"), (4.0, "pfc_xon")]


def test_push_events_rejects_unknown_kind():
    ring = init_event_ring(4)
    with pytest.raises(ValueError, match="unknown event kind"):
        push_events(ring, 4, jnp.float32(0.0),
                    [("not_a_kind", 0, jnp.float32(0.0),
                      jnp.asarray(True))])


def test_window_mode_rejects_undersized_ring():
    """slots < number of per-step candidates is a config error caught at
    trace time, not a silent drop."""
    cfg = dataclasses.replace(NetConfig(**SEQ_CFG_KW), event_ring_slots=1)
    wl = congestion_workload(**SEQ_WL_KW)
    with pytest.raises(ValueError, match="event_ring_slots"):
        simulate(cfg, wl, get_scheme("dcqcn"), SEQ_HORIZON_US,
                 trace_mode="window")


def test_events_fire_pfc_and_brake():
    """The acceptance scenario: under the hot congestion workload at
    100 km, dcqcn must log PFC pause edges and matchrdma must log its
    proxy-brake engagements."""
    cfg = _obs_cfg(**SEQ_CFG_KW)
    wl = congestion_workload(**HOT_WL_KW)
    slots = cfg.event_ring_slots
    _, aux = simulate(cfg, wl, get_scheme("dcqcn"), HOT_HORIZON_US,
                      trace_mode="window")
    kinds_dcqcn = {e["kind"] for e in decode_events(aux.events, slots)}
    assert "pfc_xoff" in kinds_dcqcn and "pfc_xon" in kinds_dcqcn
    _, aux = simulate(cfg, wl, get_scheme("matchrdma"), HOT_HORIZON_US,
                      trace_mode="window")
    kinds_mr = {e["kind"] for e in decode_events(aux.events, slots)}
    assert "scheme_brake" in kinds_mr
    for evs in (kinds_dcqcn, kinds_mr):
        assert evs <= set(EVENT_KINDS)


def test_scheme_emit_events_default_empty_and_kind_names():
    assert Scheme.emit_events(object.__new__(Scheme), None, None, None,
                              {}) == ()
    for name, code in EVENT_KINDS.items():
        assert kind_name(code) == name
    assert kind_name(999).startswith("kind_")


# ---------------------------------------------------------------------------
# manifest + report + timeline round-trips
# ---------------------------------------------------------------------------

def test_manifest_roundtrip_and_obs_report(tmp_path):
    import io

    from tools import obs_report

    header = {"record": "header", "manifest_version": 1,
              "git_rev": "deadbee", "fingerprint": "f" * 16,
              "backend": "cpu", "n_devices": 1, "trace_mode": "window",
              "decimate": 1, "horizon_us": 1000.0, "steps": 200,
              "warm_steps": 20, "n_cells": 2, "schemes": ["dcqcn"],
              "n_launches": 2, "n_resumed": 0,
              "total_compile_s": 3.5, "total_execute_s": 0.25}
    launches = [
        {"record": "launch", "scheme": "dcqcn", "lo": 0, "hi": 1,
         "pad_to": 1, "n_real": 1, "compile_s": 2.0, "execute_s": 0.1,
         "temp_size_in_bytes": 1 << 20,
         "argument_size_in_bytes": 1 << 10},
        {"record": "launch", "scheme": "dcqcn", "lo": 1, "hi": 2,
         "pad_to": 1, "n_real": 1, "compile_s": 1.5, "execute_s": 0.15,
         "compile_cached": True},
    ]
    path = str(tmp_path / "manifest.jsonl")
    write_manifest(path, header, launches)
    h2, l2 = read_manifest(path)
    assert h2["fingerprint"] == header["fingerprint"]
    assert len(l2) == 2 and l2[1]["compile_cached"] is True

    buf = io.StringIO()
    obs_report.summarize(path, out=buf)
    text = buf.getvalue()
    assert "deadbee" in text and "totals:" in text and "dcqcn" in text

    # a second manifest with slower execute -> diff must flag the ratio
    launches_b = [dict(rec, execute_s=rec.get("execute_s", 0.0) * 2.0)
                  for rec in launches]
    path_b = str(tmp_path / "manifest_b.jsonl")
    write_manifest(path_b, dict(header, git_rev="cafef00"), launches_b)
    buf = io.StringIO()
    obs_report.diff(path, path_b, out=buf)
    text = buf.getvalue()
    assert "matched launches: 2" in text
    assert "2.00x" in text
    assert "deadbee" in text and "cafef00" in text  # both revs surfaced


def test_timeline_export_valid_chrome_trace(tmp_path):
    cfg = _obs_cfg(**SEQ_CFG_KW)
    wl = congestion_workload(**HOT_WL_KW)
    steps = cfg.horizon_steps(HOT_HORIZON_US)
    recs = []
    for pid, scheme in enumerate(("dcqcn", "matchrdma")):
        _, aux = simulate(cfg, wl, get_scheme(scheme), HOT_HORIZON_US,
                          trace_mode="window")
        recs.extend(timeline_cell(
            pid, label=scheme, dt_us=cfg.dt_us, steps=steps,
            window_steps=cfg.trace_window_steps, window=aux.window,
            events=decode_events(aux.events, cfg.event_ring_slots)))
    path = str(tmp_path / "timeline.json")
    from repro.netsim import export_timeline
    export_timeline(path, {"traceEvents": recs, "displayTimeUnit": "ms"})
    doc = json.loads(open(path).read())
    evs = doc["traceEvents"]
    assert evs and doc["displayTimeUnit"] == "ms"
    phases = {r["ph"] for r in evs}
    assert {"M", "C", "i"} <= phases
    names = {r["name"] for r in evs if r["ph"] == "i"}
    assert "pfc_xoff" in names and "scheme_brake" in names
    # counter samples live inside the window's absolute step range
    ts = [r["ts"] for r in evs if r["ph"] == "C"]
    lo = (steps - cfg.trace_window_steps) * cfg.dt_us
    assert min(ts) >= lo and max(ts) <= steps * cfg.dt_us
    # instant events carry args with the raw value
    inst = [r for r in evs if r["ph"] == "i"]
    assert all("args" in r and "value" in r["args"] for r in inst)


def test_timeline_from_window_batched(tmp_path):
    cfgs = [_obs_cfg(distance_km=d) for d in (100.0, 300.0)]
    wl = congestion_workload(**HOT_WL_KW)
    _, aux = simulate_batch(cfgs, wl, get_scheme("dcqcn"), HOT_HORIZON_US,
                            trace_mode="window")
    doc = timeline_from_window(
        aux, dt_us=cfgs[0].dt_us,
        steps=cfgs[0].horizon_steps(HOT_HORIZON_US),
        window_steps=cfgs[0].trace_window_steps,
        event_ring_slots=cfgs[0].event_ring_slots,
        labels=[f"{c.distance_km:.0f}km" for c in cfgs])
    pids = {r["pid"] for r in doc["traceEvents"]}
    assert pids == {0, 1}  # one Perfetto process per cell
    names = {r["name"] for r in doc["traceEvents"]
             if r["ph"] == "i" and r["pid"] == 0}
    assert "pfc_xoff" in names  # 100 km cell congests
