"""Eq. (1) reservoir model: the B_req bound must dominate the simulated queue."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core.reservoir import (
    buffer_bound_e2e_vs_segmented, queue_trajectory, rate_mismatch_integral,
    required_buffer,
)

DT = 1e-4  # 100 µs


def test_mismatch_integral_constant_rates():
    r_in = jnp.full((100,), 10.0)
    r_out = jnp.full((100,), 4.0)
    w = rate_mismatch_integral(r_in, r_out, DT, tau_steps=10)
    # 6 bytes/s excess * 10 steps * 1e-4 s
    np.testing.assert_allclose(w[0], 6.0 * 10 * DT, rtol=1e-6)


def test_bound_dominates_queue_when_tau_covers_horizon():
    """With τ = horizon, B_req >= peak queue for ANY rate pair (the queue can
    never exceed the total windowed excess)."""
    rng = np.random.default_rng(0)
    r_in = jnp.asarray(rng.uniform(0, 100, 500).astype(np.float32))
    r_out = jnp.asarray(rng.uniform(0, 80, 500).astype(np.float32))
    b_req = required_buffer(r_in, r_out, DT, tau_steps=500)
    peak = float(queue_trajectory(r_in, r_out, DT).max())
    assert float(b_req) >= peak - 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 400), st.integers(0, 3))
def test_bound_dominates_queue_property(tau_extra, seed):
    """If the drain never falls below its window value for longer than τ the
    windowed bound still dominates a FRESH queue (q starts empty within the
    window). Property: peak over any τ window of the queue started empty is
    ≤ sup_t windowed integral."""
    rng = np.random.default_rng(seed)
    n = 400
    r_in = jnp.asarray(rng.uniform(0, 50, n).astype(np.float32))
    r_out = jnp.asarray(rng.uniform(0, 50, n).astype(np.float32))
    tau = tau_extra
    b_req = float(required_buffer(r_in, r_out, DT, tau_steps=max(tau, 1)))
    # queue growth over any window of length tau starting from empty
    qs = queue_trajectory(r_in, r_out, DT)
    qs_np = np.asarray(qs)
    growth = []
    for t0 in range(0, n - tau, 17):
        window_growth = qs_np[t0:t0 + tau] - (qs_np[t0 - 1] if t0 else 0.0)
        if len(window_growth):
            growth.append(window_growth.max())
    if growth:
        assert b_req >= max(0.0, max(growth)) - 1e-4


def test_segmented_tau_smaller_than_e2e():
    b_e2e, b_seg = buffer_bound_e2e_vs_segmented(
        peak_rate=200e9 / 8, matched_rate=50e9 / 8,
        one_way_delay_us=500.0, slot_us=100.0)
    assert b_seg < b_e2e
    # τ_seg/τ_e2e = (D + slot)/(2D) = 0.6 at these numbers
    np.testing.assert_allclose(b_seg / b_e2e, 0.6, rtol=1e-6)


def test_queue_trajectory_never_negative():
    r_in = jnp.asarray([0.0, 100.0, 0.0, 0.0, 50.0])
    r_out = jnp.asarray([10.0, 10.0, 1000.0, 1000.0, 10.0])
    qs = queue_trajectory(r_in, r_out, DT)
    assert float(qs.min()) >= 0.0
