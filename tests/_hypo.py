"""Property-test compatibility layer: real hypothesis when installed, a
deterministic mini fallback otherwise.

The tier-1 suite must collect and run on a bare container that cannot
``pip install`` (see requirements-dev.txt for the full-fidelity dev env).
When ``hypothesis`` is importable we re-export it untouched; otherwise a
tiny deterministic generator provides the same ``@settings/@given/st.*``
surface the suite uses (integers, sampled_from, floats, booleans). The
fallback draws from seeded ``random.Random`` streams so failures are
reproducible, and runs ``max_examples`` examples per test just like the
real thing (no shrinking, no database).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random
    from types import SimpleNamespace

    _DEFAULT_MAX_EXAMPLES = 10
    _SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    st = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                         floats=_floats, booleans=_booleans)

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._mini_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper():
                n = (getattr(wrapper, "_mini_max_examples", None)
                     or getattr(fn, "_mini_max_examples", None)
                     or _DEFAULT_MAX_EXAMPLES)
                for i in range(n):
                    rng = random.Random(_SEED + i)
                    args = [s.draw(rng) for s in strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # no functools.wraps: pytest must see a ZERO-arg function, not
            # fn's strategy parameters (it would demand fixtures for them)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
