"""Batched scenario engine: batch/sequential equivalence on BOTH scenario
axes (config grids and padded workload grids), the static-vs-traced config
split, heterogeneous per-scenario grids, and the Fig. 3 scheme-ordering
regression at 1000 km."""
import dataclasses

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.config.base import NetConfig, NetParams, stack_net_params
from repro.netsim import (
    batch_padding, congestion_workload, get_scheme, run_experiment,
    run_experiment_batch, simulate, simulate_batch, sweep, sweep_grid,
    throughput_workload,
)

WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
DISTS = (1.0, 100.0, 1000.0)
METRICS = ("throughput_gbps", "peak_buffer_mb", "mean_buffer_mb",
           "pause_ratio")


def _rel(a, b):
    # 1e-4 absolute floor: sub-kilobyte buffer noise must not register as
    # relative error between a zero and a near-zero cell
    return abs(a - b) / max(abs(a), abs(b), 1e-4)


def test_batch_matches_sequential_grid():
    """simulate_batch over a 3-distance grid must reproduce per-cell
    simulate metrics within 1e-3 relative tolerance, for both an e2e and
    the segmented scheme (the acceptance bar of the batched engine)."""
    cfgs = [NetConfig(distance_km=d) for d in DISTS]
    pad, hist = batch_padding(cfgs)
    for scheme in ("dcqcn", "matchrdma"):
        batch_rows = run_experiment_batch(cfgs, WL, scheme, 60_000.0)
        for cfg, row in zip(cfgs, batch_rows):
            ref = run_experiment(cfg, WL, get_scheme(scheme), 60_000.0,
                                 delay_pad=pad, history_slots=hist)
            for m in METRICS:
                assert _rel(row[m], ref[m]) < 1e-3, (scheme, cfg.distance_km,
                                                     m, row[m], ref[m])


def test_batch_traces_match_sequential_traces():
    """Stronger than metric equality: the full per-step traces agree."""
    cfgs = [NetConfig(distance_km=d) for d in (1.0, 300.0)]
    pad, hist = batch_padding(cfgs)
    _, batch_traces = simulate_batch(cfgs, WL, "matchrdma", 20_000.0)
    for i, cfg in enumerate(cfgs):
        _, ref_traces = simulate(cfg, WL, get_scheme("matchrdma"), 20_000.0,
                                 delay_pad=pad, history_slots=hist)
        for k in ("thr_inter", "q_dst", "pause_dst"):
            a = np.asarray(ref_traces[k])
            b = np.asarray(batch_traces[k])[i]
            denom = max(np.abs(a).max(), 1e-9)
            assert np.abs(a - b).max() / denom < 1e-3, (cfg.distance_km, k)


def test_fig3_ordering_regression_1000km():
    """Fig. 3 directions at 1000 km (congestion scenario): the segmented,
    rate-matched scheme must beat conventional e2e RDMA on throughput AND
    destination-OTN buffer stress."""
    cfgs = [NetConfig(distance_km=1000.0)]
    wl = congestion_workload()
    m = run_experiment_batch(cfgs, wl, "matchrdma", 100_000.0)[0]
    d = run_experiment_batch(cfgs, wl, "dcqcn", 100_000.0)[0]
    assert m["throughput_gbps"] >= d["throughput_gbps"]
    assert m["peak_buffer_mb"] < d["peak_buffer_mb"]


def test_sweep_order_and_batch_consistency():
    """The batched sweep keeps the historical row order (distance-major)
    and its rows equal the scheme-wise batched runs it is built from."""
    cfg = NetConfig()
    schemes = ("dcqcn", "matchrdma")
    rows = sweep(cfg, WL, schemes, DISTS, horizon_us=30_000.0)
    assert len(rows) == len(DISTS) * len(schemes)
    for i, d in enumerate(DISTS):
        for j, s in enumerate(schemes):
            r = rows[i * len(schemes) + j]
            assert r["distance_km"] == d
            assert r["scheme"] == s


def test_heterogeneous_capacity_and_buffer_grid():
    """Mixed OTN capacities / asymmetric buffer thresholds as first-class
    per-scenario leaves in ONE batch: more capacity must not hurt
    throughput; every metric stays finite and non-negative."""
    base = NetConfig(distance_km=100.0)
    cfgs = [
        dataclasses.replace(base, num_otn_links=4),      # 400 Gbps OTN
        dataclasses.replace(base, num_otn_links=16),     # 1.6 Tbps OTN
        dataclasses.replace(base, pfc_xoff_kb=512.0, pfc_xon_kb=256.0),
        dataclasses.replace(base, otn_buffer_bdp_frac=0.5),
    ]
    rows = sweep_grid(cfgs, WL, ("matchrdma",), horizon_us=40_000.0)
    assert len(rows) == len(cfgs)
    for r in rows:
        assert np.isfinite(r["throughput_gbps"])
        assert r["throughput_gbps"] >= 0.0
        assert r["peak_buffer_mb"] >= 0.0
    # both cells saturate near the 400 Gbps leaf; allow controller noise
    assert rows[1]["throughput_gbps"] >= 0.95 * rows[0]["throughput_gbps"]


def test_stack_net_params_shapes():
    cfgs = [NetConfig(distance_km=d) for d in DISTS]
    stacked = stack_net_params(cfgs)
    for name, leaf in zip(NetParams._fields, stacked):
        if name == "chan_schedule":
            assert leaf.shape == (len(DISTS), 1, 0, 3)  # [B, L, K=0, 3]
        elif name == "fail_windows":
            assert leaf.shape == (len(DISTS), 1, 0, 2)  # [B, L, W=0, 2]
        elif name.startswith("link_"):
            assert leaf.shape == (len(DISTS), 1)  # [B, L] at L=1
        else:
            assert leaf.shape == (len(DISTS),)
    np.testing.assert_allclose(
        np.asarray(stacked.one_way_delay_us),
        [c.one_way_delay_us for c in cfgs])
    single = NetParams.of(cfgs[0])
    assert len(single) == len(stacked)


def test_batch_rejects_mixed_static_structure():
    """Any non-traced field varying across a batch must fail loudly, not
    silently simulate every cell with one cell's value."""
    cfgs = [NetConfig(), dataclasses.replace(NetConfig(), dt_us=10.0)]
    with pytest.raises(ValueError, match="dt_us"):
        simulate_batch(cfgs, WL, "dcqcn", 10_000.0)
    # regression: DCQCN constants are compile-time too — mixing them used
    # to be silently collapsed onto the template's value
    cfgs = [NetConfig(),
            dataclasses.replace(NetConfig(), dcqcn_rai_mbps=30_000.0)]
    with pytest.raises(ValueError, match="dcqcn_rai_mbps"):
        simulate_batch(cfgs, WL, "dcqcn", 10_000.0)


def test_delay_ring_sizing_f32_consistent():
    """Distances whose delays are f32-equal must produce identical rings
    and bit-identical traces — regression for the f64 static sizing
    undercutting the f32 traced wrap index (ring rows were silently
    aliased through JAX index clamping, inflating throughput)."""
    a = simulate(NetConfig(distance_km=3.4999999), WL,
                 get_scheme("dcqcn"), 5_000.0)
    b = simulate(NetConfig(distance_km=3.5), WL,
                 get_scheme("dcqcn"), 5_000.0)
    for k in a[1]:
        np.testing.assert_array_equal(np.asarray(a[1][k]),
                                      np.asarray(b[1][k]), err_msg=k)


@settings(max_examples=3, deadline=None)
@given(st.integers(1, 8), st.sampled_from([64 << 10, 1 << 20]))
def test_workload_axis_equivalence_property(num_flows, msg):
    """Property: ANY workload run inside a padded (config x workload) batch
    matches its sequential twin — the active_mask keeps padding inert even
    when the cell is padded far above its own flow count."""
    wls = [throughput_workload(msg_size=msg, concurrency=1,
                               num_flows=num_flows),
           congestion_workload(num_inter=8, num_intra=8,
                               burst_start_us=3_000.0, burst_len_us=4_000.0,
                               horizon_us=12_000.0)]
    cfgs = [NetConfig(distance_km=100.0), NetConfig(distance_km=400.0)]
    pad, hist = batch_padding(cfgs)
    rows = run_experiment_batch(cfgs, wls, "matchrdma", 12_000.0)
    ref = run_experiment(cfgs[0], wls[0], get_scheme("matchrdma"), 12_000.0,
                         delay_pad=pad, history_slots=hist)
    for m in METRICS + ("goodput_bytes",):
        assert _rel(rows[0][m], ref[m]) < 1e-3, (m, rows[0][m], ref[m])


@settings(max_examples=3, deadline=None)
@given(st.integers(1, 500), st.sampled_from([100.0, 400.0]))
def test_batch_sequential_equivalence_property(distance_km, dst_gbps):
    """Property: ANY (distance, leaf-capacity) cell run inside a batch
    matches its padded sequential twin."""
    cfgs = [NetConfig(distance_km=float(distance_km), dst_dc_gbps=dst_gbps),
            NetConfig(distance_km=500.0)]
    pad, hist = batch_padding(cfgs)
    rows = run_experiment_batch(cfgs, WL, "matchrdma", 15_000.0)
    ref = run_experiment(cfgs[0], WL, get_scheme("matchrdma"), 15_000.0,
                         delay_pad=pad, history_slots=hist)
    for m in METRICS:
        assert _rel(rows[0][m], ref[m]) < 1e-3, (m, rows[0][m], ref[m])
