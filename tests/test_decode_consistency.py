"""Decode-with-cache must reproduce full-sequence forward logits (f32)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import get_model_config
from repro.models import build_model

ARCHS = ["qwen1.5-0.5b", "internlm2-1.8b", "recurrentgemma-2b",
         "mamba2-370m", "musicgen-large", "nemotron-4-340b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(get_model_config(arch, smoke=True),
                              act_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    s0, t = 48, 4
    key = jax.random.PRNGKey(3)
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (2, s0 + t), 0, cfg.vocab_size)
        caches, lg = model.prefill(params, toks[:, :s0], max_len=s0 + t)
        for i in range(t):
            caches, lg = model.decode_step(params, caches, toks[:, s0 + i],
                                           jnp.int32(s0 + i))
        _, lg_full = model.prefill(params, toks, max_len=s0 + t)
    else:
        emb = jax.random.normal(key, (2, s0 + t, cfg.d_model), jnp.float32)
        caches, lg = model.prefill(params, emb[:, :s0], max_len=s0 + t)
        for i in range(t):
            caches, lg = model.decode_step(params, caches,
                                           emb[:, s0 + i:s0 + i + 1],
                                           jnp.int32(s0 + i))
        _, lg_full = model.prefill(params, emb, max_len=s0 + t)
    err = float(jnp.abs(lg - lg_full).max())
    assert err < 5e-4, err


def test_moe_decode_consistency_without_drops():
    """MoE matches when capacity is large enough that nothing drops
    (capacity-drop divergence is documented GShard semantics)."""
    cfg = dataclasses.replace(get_model_config("phi3.5-moe-42b-a6.6b", smoke=True),
                              act_dtype="float32", param_dtype="float32",
                              moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    s0, t = 48, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, s0 + t), 0,
                              cfg.vocab_size)
    caches, lg = model.prefill(params, toks[:, :s0], max_len=s0 + t)
    for i in range(t):
        caches, lg = model.decode_step(params, caches, toks[:, s0 + i],
                                       jnp.int32(s0 + i))
    _, lg_full = model.prefill(params, toks, max_len=s0 + t)
    assert float(jnp.abs(lg - lg_full).max()) < 5e-4
