"""Chunked / local attention vs the naive oracle, across shapes & dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (
    chunked_causal_attention, decode_attention, decode_local_attention,
    local_attention, naive_causal_attention,
)


def _qkv(key, b, s, hq, hk, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,hq,hk,d,bq,bk", [
    (2, 128, 8, 2, 32, 32, 32),
    (1, 256, 4, 4, 64, 64, 128),
    (2, 96, 6, 3, 16, 32, 32),
    (1, 64, 2, 1, 128, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_matches_naive(b, s, hq, hk, d, bq, bk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, s, hq, hk, d, dtype)
    out = chunked_causal_attention(q, k, v, block_q=bq, block_kv=bk)
    ref = naive_causal_attention(q, k, v)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("s,w", [(128, 32), (96, 32), (200, 64), (64, 64)])
def test_local_matches_naive_window(s, w):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, s, 4, 2, 32, jnp.float32)
    out = local_attention(q, k, v, window=w)
    ref = naive_causal_attention(q, k, v, window=w)
    assert float(jnp.abs(out - ref).max()) < 2e-6


def test_softcap_path():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 64, 2, 2, 32, jnp.float32)
    out = chunked_causal_attention(q, k, v, block_q=32, block_kv=32,
                                   softcap=20.0)
    ref = naive_causal_attention(q, k, v, softcap=20.0)
    assert float(jnp.abs(out - ref).max()) < 2e-6


def test_decode_matches_last_row_of_full():
    """decode_attention at pos must equal row `pos` of full attention."""
    b, s, hq, hk, d = 2, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(3), b, s, hq, hk, d, jnp.float32)
    full = naive_causal_attention(q, k, v)
    pos = s - 1
    out = decode_attention(q[:, pos], k, v, jnp.int32(pos))
    assert float(jnp.abs(out - full[:, pos]).max()) < 2e-6


def test_decode_local_ring():
    """Ring-buffer local decode must equal banded attention's last row."""
    b, s, hq, hk, d, w = 1, 96, 2, 1, 16, 32
    q, k, v = _qkv(jax.random.PRNGKey(4), b, s, hq, hk, d, jnp.float32)
    full = naive_causal_attention(q, k, v, window=w)
    pos = s - 1
    # build ring: slot = p % w holds position p for p in (pos-w, pos]
    slots = (jnp.arange(s - w, s)) % w
    k_ring = jnp.zeros((b, w, hk, d)).at[:, slots].set(k[:, s - w:])
    v_ring = jnp.zeros((b, w, hk, d)).at[:, slots].set(v[:, s - w:])
    out = decode_local_attention(q[:, pos], k_ring, v_ring, jnp.int32(pos))
    assert float(jnp.abs(out - full[:, pos]).max()) < 2e-6
