"""Budget controller laws: τ-paced probing, capability matching & ratchet."""
import jax.numpy as jnp
import numpy as np

from repro.config.base import NetConfig
from repro.core.budget import (
    ctrl_window_slots, fair_share, init_budget, update_budget,
)
from repro.core.estimator import RateEstimate

CFG = NetConfig(distance_km=100.0)


def _est(rate, cap=0.0, have=0.0):
    return RateEstimate(rate=jnp.float32(rate), stable_frac=jnp.float32(1.0),
                        recurrent=jnp.float32(0.0),
                        capability=jnp.float32(cap),
                        have_capability=jnp.float32(have))


def test_ctrl_window_scales_with_distance():
    short = ctrl_window_slots(NetConfig(distance_km=1.0))
    mid = ctrl_window_slots(NetConfig(distance_km=100.0))
    far = ctrl_window_slots(NetConfig(distance_km=1000.0))
    assert short <= mid <= far
    assert far >= 100   # 2*5ms / 100µs


def test_initial_budget_conservative():
    b = init_budget(CFG)
    cap = CFG.otn_capacity_gbps * 1e9 / 8.0
    assert float(b.budget) < 0.5 * cap


def test_matched_regime_tracks_capability_not_throttled_egress():
    st = init_budget(CFG)
    # constrained, capability known at 50 GB/s, current egress only 10 GB/s
    st2 = update_budget(st, _est(10e9, cap=50e9, have=1.0),
                        cnp_in_slot=jnp.float32(0.0),
                        cong_recent=jnp.float32(1.0), cfg=CFG, ctrl_slots=4)
    np.testing.assert_allclose(float(st2.budget),
                               CFG.budget_headroom * 50e9, rtol=0.01)


def test_open_up_paced_by_ctrl_window():
    st = init_budget(CFG)
    b0 = float(st.budget)
    ctrl = 6
    budgets = []
    for _ in range(ctrl * 3):
        st = update_budget(st, _est(1e9), jnp.float32(0.0),
                           jnp.float32(0.0), CFG, ctrl_slots=ctrl)
        budgets.append(float(st.budget))
    # at most 3 raises in 18 clear slots with ctrl=6
    raises = sum(1 for a, b in zip([b0] + budgets, budgets) if b > a * 1.01)
    assert raises <= 3
    assert budgets[-1] > b0                      # but it does open up


def test_capability_ratchet_on_clean_absorption():
    """Clear windows at high egress must ratchet cap_ewma upward."""
    st = init_budget(CFG)
    # seed capability low
    st = update_budget(st, _est(10e9, cap=10e9, have=1.0), jnp.float32(0.0),
                       jnp.float32(1.0), CFG, ctrl_slots=2)
    assert abs(float(st.cap_ewma) - 10e9) / 10e9 < 0.01
    # then sustained clear slots with egress 30 GB/s
    for _ in range(10):
        st = update_budget(st, _est(30e9, have=0.0), jnp.float32(0.0),
                           jnp.float32(0.0), CFG, ctrl_slots=2)
    assert float(st.cap_ewma) >= 30e9 * 0.99


def test_budget_bounds():
    st = init_budget(CFG)
    cap = CFG.otn_capacity_gbps * 1e9 / 8.0
    floor = CFG.budget_floor_mbps * 1e6 / 8.0
    st2 = update_budget(st, _est(1e20, cap=1e20, have=1.0), jnp.float32(0.0),
                        jnp.float32(1.0), CFG, ctrl_slots=1)
    assert float(st2.budget) <= cap
    st3 = update_budget(st, _est(0.0, cap=0.0, have=1.0), jnp.float32(10.0),
                        jnp.float32(1.0), CFG, ctrl_slots=1)
    assert float(st3.budget) >= floor


def test_tighten_decays_and_recovers():
    st = init_budget(CFG)
    for _ in range(5):
        st = update_budget(st, _est(10e9, cap=10e9, have=1.0),
                           jnp.float32(10.0), jnp.float32(1.0), CFG, 1)
    tight = float(st.tighten)
    assert tight < 1.0
    for _ in range(50):
        st = update_budget(st, _est(10e9, cap=10e9, have=1.0),
                           jnp.float32(0.0), jnp.float32(1.0), CFG, 1)
    assert float(st.tighten) > tight
    assert float(st.tighten) <= 1.0


def test_fair_share():
    active = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    s = fair_share(jnp.float32(90.0), active)
    np.testing.assert_allclose(np.asarray(s), [30.0, 30.0, 0.0, 30.0])
