"""Multi-link topology engine (PR 6): the traced [L] link axis, per-flow
routing, per-link impairments, the rdmacell flowcell-spraying scheme, and
the L=1 bit-identity guarantee the refactor rests on.

The golden tests (tests/test_scheme_api.py) already pin every registered
scheme's L=1 traces bit-for-bit against the pre-refactor engine — this
file covers what is NEW: explicit single-path tuples must hit the same
single-pipe code path, L>1 must conserve bytes and respect routing, and
rdmacell's token spraying must shift load toward capacity."""
import dataclasses
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config.base import NetConfig, NetParams, stack_net_params
from repro.netsim import (
    get_scheme, run_experiment_batch, simulate, simulate_batch,
    throughput_workload,
)
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import FlowSpec, Workload

WL = throughput_workload(msg_size=1 << 20, concurrency=16, num_flows=4)
HORIZON = 8_000.0

LINK_KEYS = ("q_dst_link", "link_tx", "link_pause")


def _cfg3(**kw):
    """Three unequal paths: longer ones are thinner (the OTN mesh shape
    rdmacell's token spraying is built for)."""
    base = dict(distance_km=100.0, horizon_us=HORIZON, num_paths=3,
                path_delay_scale=(1.0, 1.5, 2.0),
                path_cap_frac=(0.5, 0.3, 0.2))
    base.update(kw)
    return NetConfig(**base)


# ---------------------------------------------------------------------------
# L=1: the refactor must be invisible
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_explicit_unit_path_tuples_bit_identical(scheme):
    """num_paths=1 with EXPLICIT unit path tuples resolves to the same
    traced leaves as the bare config — same single-pipe jaxpr, same bits.
    (The goldens pin the bare config against the pre-refactor engine; this
    closes the loop for the spelled-out form.)"""
    plain = NetConfig(distance_km=100.0)
    spelled = NetConfig(distance_km=100.0, num_paths=1,
                        path_delay_scale=(1.0,), path_cap_frac=(1.0,))
    f_a, tr_a = simulate(plain, WL, get_scheme(scheme), HORIZON)
    f_b, tr_b = simulate(spelled, WL, get_scheme(scheme), HORIZON)
    assert set(tr_a) == set(tr_b)
    for k in tr_a:
        np.testing.assert_array_equal(np.asarray(tr_a[k]),
                                      np.asarray(tr_b[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(f_a.delivered),
                                  np.asarray(f_b.delivered))


def test_l1_traces_carry_no_link_keys():
    _, traces = simulate(NetConfig(distance_km=100.0), WL,
                         get_scheme("dcqcn"), HORIZON)
    assert not set(LINK_KEYS) & set(traces)


def test_path_tuple_validation():
    with pytest.raises(ValueError, match="path_delay_scale"):
        NetConfig(num_paths=3, path_delay_scale=(1.0, 2.0)).path_delays_us()
    cfg = NetConfig(num_paths=2)
    assert cfg.path_caps_gbps() == (cfg.otn_capacity_gbps / 2,) * 2
    assert cfg.path_delays_us() == (cfg.one_way_delay_us,) * 2


# ---------------------------------------------------------------------------
# L>1 physics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ("dcqcn", "matchrdma", "rdmacell"))
def test_multilink_conserves_and_traces(scheme):
    final, traces = simulate(_cfg3(), WL, get_scheme(scheme), HORIZON)
    for k in LINK_KEYS:
        assert k in traces and np.asarray(traces[k]).shape[-1] == 3, k
    assert float(np.max(np.asarray(traces["cons_err"]))) < 1e-3
    assert float(np.sum(np.asarray(final.delivered))) > 0


def test_route_matrix_steers_traffic():
    """A workload routed entirely onto links 0+1 must leave link 2 dark."""
    wl = Workload(tuple(FlowSpec(True, 1 << 20, 16, route=(1.0, 1.0, 0.0))
                        for _ in range(4)))
    _, traces = simulate(_cfg3(), wl, get_scheme("dcqcn"), HORIZON)
    link_tx = np.asarray(traces["link_tx"])
    assert float(link_tx[:, 2].max()) == 0.0
    assert float(link_tx[:, :2].sum()) > 0.0


def test_route_width_mismatch_raises():
    wl = Workload(tuple(FlowSpec(True, 1 << 20, 16, route=(1.0, 1.0))
                        for _ in range(2)))
    with pytest.raises(ValueError, match="route"):
        simulate(_cfg3(), wl, get_scheme("dcqcn"), HORIZON)


def test_multilink_batch_matches_sequential():
    cfgs = [_cfg3(), _cfg3(path_delay_scale=(1.0, 1.2, 1.4))]
    finals, traces = simulate_batch(cfgs, WL, get_scheme("dcqcn"), HORIZON)
    for i, cfg in enumerate(cfgs):
        f, tr = simulate(cfg, WL, get_scheme("dcqcn"), HORIZON)
        np.testing.assert_allclose(
            np.asarray(traces["thr_inter"])[i], np.asarray(tr["thr_inter"]),
            rtol=1e-4, atol=1e4)  # bytes/s on a ~5e10 scale: ring-padding
        # reorders f32 sums, so transient steps wobble by ~1e-6 of scale
        np.testing.assert_allclose(
            np.asarray(finals.delivered)[i], np.asarray(f.delivered),
            rtol=1e-5)


def test_stacked_link_leaves_shape():
    cfgs = [_cfg3(), _cfg3(distance_km=300.0)]
    stacked = stack_net_params(cfgs)
    for name, leaf in zip(NetParams._fields, stacked):
        if name == "chan_schedule":
            expect = (2, 3, 0, 3)   # [B, L, K=0, 3] — no schedule set
        elif name == "fail_windows":
            expect = (2, 3, 0, 2)   # [B, L, W=0, 2] — no outages set
        elif name.startswith("link_"):
            expect = (2, 3)
        else:
            expect = (2,)
        assert leaf.shape == expect, (name, leaf.shape)


def test_per_link_impairments_decorrelate():
    """An OTN-flap channel at L=3 must not flap all links in lockstep:
    per-link fold_in keys give each link its own loss process."""
    cfg = _cfg3(loss_rate=5e-4)
    _, traces = simulate(cfg, WL, get_scheme("dcqcn"), HORIZON,
                         channel="impaired")
    assert "chan_lost" in traces
    assert float(np.sum(np.asarray(traces["chan_lost"]))) > 0.0
    assert float(np.max(np.asarray(traces["cons_err"]))) < 1e-3


# ---------------------------------------------------------------------------
# rdmacell
# ---------------------------------------------------------------------------

def test_rdmacell_sprays_toward_capacity():
    """Token buckets refill at link rate, so steady-state spray weights
    track capacity: the 0.5/0.3/0.2 split must show in link_tx, while the
    workload-routed baseline sprays its (equal) route weights."""
    _, tr_cell = simulate(_cfg3(), WL, get_scheme("rdmacell"), HORIZON)
    _, tr_base = simulate(_cfg3(), WL, get_scheme("dcqcn"), HORIZON)
    tail_cell = np.asarray(tr_cell["link_tx"])[-200:].mean(axis=0)
    tail_base = np.asarray(tr_base["link_tx"])[-200:].mean(axis=0)
    frac_cell = tail_cell / tail_cell.sum()
    frac_base = tail_base / tail_base.sum()
    np.testing.assert_allclose(frac_cell, (0.5, 0.3, 0.2), atol=0.05)
    np.testing.assert_allclose(frac_base, (1 / 3,) * 3, atol=0.05)
    # scheme-owned trace columns exist and are sane
    assert float(np.min(np.asarray(tr_cell["rdmacell_tokens_mb"]))) >= 0.0
    assert float(np.min(np.asarray(tr_cell["rdmacell_rob_mb"]))) >= 0.0


def test_rdmacell_rob_limit_gates_senders():
    """A tiny ROB limit must hold estimated ROB occupancy below what a
    huge limit allows (the back-pressure knob actually gates)."""
    loose = _cfg3(rdmacell_rob_limit_mb=1e4)
    tight = _cfg3(rdmacell_rob_limit_mb=2.0)
    _, tr_loose = simulate(loose, WL, get_scheme("rdmacell"), HORIZON)
    _, tr_tight = simulate(tight, WL, get_scheme("rdmacell"), HORIZON)
    rob_loose = float(np.asarray(tr_loose["rdmacell_rob_mb"])[-200:].mean())
    rob_tight = float(np.asarray(tr_tight["rdmacell_rob_mb"])[-200:].mean())
    assert rob_tight <= rob_loose + 1e-6


def test_rdmacell_streams_reorder_and_entropy_columns():
    rows = run_experiment_batch([_cfg3()], WL, get_scheme("rdmacell"),
                                HORIZON, trace_mode="metrics")
    (row,) = rows
    assert row["mean_reorder_buf_mb"] >= 0.0
    assert 0.0 <= row["spray_entropy"] <= 1.0
    # unequal caps but all links used: entropy strictly inside (0, 1)
    assert 0.5 < row["spray_entropy"] < 1.0


def test_rdmacell_l1_streams_baseline_columns():
    """At L=1 rdmacell carries the default extra state — its streamed
    columns are the baseline's (no reorder/entropy machinery exists)."""
    rows = run_experiment_batch([NetConfig(distance_km=100.0)], WL,
                                get_scheme("rdmacell"), HORIZON,
                                trace_mode="metrics")
    (row,) = rows
    assert "mean_reorder_buf_mb" not in row
    assert "spray_entropy" not in row
    assert "mean_budget_gbps" in row


# ---------------------------------------------------------------------------
# Launch-plan interaction (satellite: chunk_cells edge cases)
# ---------------------------------------------------------------------------

def test_chunk_cells_scales_with_links_and_decimate():
    from repro.netsim import runner
    t = 100_000
    base = runner.chunk_cells(t, "full")
    # L>1 traces are wider per step -> smaller chunks under the same budget
    l8 = runner.chunk_cells(t, "full", num_links=8)
    assert l8 <= base
    assert l8 * t * (runner._TRACE_KEYS_EST + 24) <= runner.MAX_TRACE_FLOATS
    # decimation shrinks the materialized block -> larger chunks
    dec = runner.chunk_cells(t, "decimate", decimate=10)
    assert dec >= base
    # the 256MB bound holds at every (decimate, L) corner
    for k in (1, 7):
        for L in (1, 3, 16):
            c = runner.chunk_cells(t, "decimate", decimate=k, num_links=L)
            keys = runner._TRACE_KEYS_EST + (3 * L if L > 1 else 0)
            assert c * max(t // k, 1) * keys <= max(
                runner.MAX_TRACE_FLOATS, max(t // k, 1) * keys)
    # metrics mode ignores trace width entirely
    assert runner.chunk_cells(t, "metrics", num_links=16) \
        == runner.METRICS_CHUNK_CELLS


_SUBPROC_TOPOLOGY = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.config.base import NetConfig
    from repro.netsim import run_experiment_batch, throughput_workload
    assert len(jax.devices()) == 4
    wl = throughput_workload(1 << 20, 16, num_flows=4)
    cfg = dict(horizon_us=6_000.0, num_paths=2,
               path_delay_scale=(1.0, 1.6), path_cap_frac=(0.6, 0.4))
    # 3 cells (< one chunk) on 4 devices: the single launch must still be
    # padded to a device multiple so sharding engages and rows come back
    # for exactly the real cells
    cfgs = [NetConfig(distance_km=d, **cfg) for d in (50.0, 100.0, 200.0)]
    rows = run_experiment_batch(cfgs, wl, "rdmacell", 6_000.0,
                                trace_mode="metrics")
    assert len(rows) == len(cfgs)
    single = run_experiment_batch(cfgs, wl, "rdmacell", 6_000.0,
                                  trace_mode="metrics",
                                  devices=jax.devices()[:1])
    for a, b in zip(rows, single):
        for k, va in a.items():
            if not isinstance(va, float) or not np.isfinite(va):
                continue
            assert abs(va - b[k]) <= 1e-6 * max(abs(va), abs(b[k]), 1e-9), \\
                (k, va, b[k])
    print("TOPOLOGY_SHARDED_OK")
""")


def test_small_multilink_grid_on_forced_devices():
    """Satellite 3 pin: a grid smaller than one chunk on 4 (forced host)
    devices — the launch plan pads the single launch to a device multiple
    (``_plan_launches`` must round pad_to unconditionally, not only when
    the grid spills into multiple chunks) and the rows match the
    single-device run."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_TOPOLOGY],
                       capture_output=True, text=True, cwd=".", timeout=600)
    assert "TOPOLOGY_SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_plan_launches_pad_invariants():
    """Every launch of every plan shape pads to a device multiple >= its
    real cell count (the invariant ``shard_scenario_axis`` depends on) —
    including single-launch grids smaller than one chunk."""
    from repro.netsim.runner import _plan_launches
    for n_cells in (1, 2, 3, 5, 8, 17):
        for chunk in (4, 8, 64):
            for n_dev in (1, 2, 4):
                plan = _plan_launches(n_cells, ("s",), chunk, n_dev)
                covered = []
                for launch in plan:
                    assert launch.pad_to % n_dev == 0
                    assert launch.pad_to >= launch.hi - launch.lo
                    covered.extend(range(launch.lo, launch.hi))
                assert covered == list(range(n_cells))
