"""The fault-injection subsystem: schedule building/validation + JSON I/O,
all-up bit-identity against the goldens (seq + batch), outage byte
accounting through the loss-repair path, engine-level reroute onto
survivors, the NaN-safe all-dead stall, the live-mask property test for
every registered scheme, and the hardened sweep runner (checkpoints /
resume, NaN quarantine, strict conservation, OOM backoff, and the
subprocess crash-then-resume pin on the failover benchmark)."""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.config.base import NetConfig
from repro.netsim import (
    FailureSchedule, fluid, get_scheme, load_failure_json,
    run_experiment_batch, save_failure_json, simulate, simulate_batch,
    sweep_grid, throughput_workload,
)
from repro.netsim import runner
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import congestion_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")
# keys an armed (but never-firing) failure schedule may ADD on top of a
# golden run — the goldens' own keys must stay bit-identical
FAIL_EXTRA_KEYS = {"chan_backlog", "chan_lost", "chan_repair_wait_us",
                   "chan_retx", "chan_wire", "fail_live"}
# the all-up L=1 schedule: one no-op (0, 0) window on the single link —
# machinery compiled in, every where() on its clean branch
ALL_UP_1 = (((0.0, 0.0),),)

WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
# streaming traffic that keeps the pipe full (so an outage always catches
# bytes in flight): big messages, deep concurrency
SWL = throughput_workload(msg_size=1 << 23, concurrency=4, num_flows=4)

MULTI = NetConfig(distance_km=100.0, num_paths=3,
                  path_cap_frac=(0.5, 0.3, 0.2))


def _outage_cfg(down_us=600.0, up_us=2_000.0, link=0):
    fs = FailureSchedule.empty(3).link_outage(link, down_us, up_us)
    return fs.apply(MULTI)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


# ---------------------------------------------------------------------------
# FailureSchedule building + validation
# ---------------------------------------------------------------------------

def test_schedule_builder_composes_and_pads():
    fs = (FailureSchedule.empty(3)
          .link_outage(0, 1_000.0, 2_000.0)
          .link_outage(0, 5_000.0, 6_000.0)
          .link_outage(2, 3_000.0, 4_000.0))
    assert fs.num_windows == 2
    t = fs.to_config_tuple()
    assert len(t) == 3 and all(len(edge) == 2 for edge in t)
    assert t[1] == ((0.0, 0.0), (0.0, 0.0))       # padded no-ops
    assert t[2][0] == (3_000.0, 4_000.0)
    cfg = fs.apply(MULTI)
    assert cfg.failure_len == 2
    assert cfg.failure_array().shape == (3, 2, 2)


def test_schedule_builder_validation():
    with pytest.raises(ValueError, match="up_at_us must be > down_at_us"):
        FailureSchedule.empty(2).link_outage(0, 5_000.0, 5_000.0)
    with pytest.raises(ValueError, match="down_at_us must be >= 0"):
        FailureSchedule.empty(2).link_outage(0, -1.0, 5.0)
    with pytest.raises(ValueError, match="outside"):
        FailureSchedule.empty(2).link_outage(2, 0.0, 5.0)
    with pytest.raises(ValueError, match="num_paths is 3"):
        FailureSchedule.empty(2).link_outage(0, 1.0, 2.0).apply(MULTI)
    with pytest.raises(ValueError, match="no edge is incident"):
        FailureSchedule.empty(2).site_outage(7, 1.0, 2.0, ((0, 1), (0, 1)))


def test_site_outage_hits_every_incident_edge():
    pairs = ((0, 1), (0, 2), (2, 1))
    fs = FailureSchedule.empty(3).site_outage(2, 1_000.0, 2_000.0, pairs)
    assert fs.windows[0] == ()                    # 0->1 untouched
    assert fs.windows[1] == ((1_000.0, 2_000.0),)
    assert fs.windows[2] == ((1_000.0, 2_000.0),)


def test_empty_schedule_is_structurally_absent():
    assert FailureSchedule.empty(4).to_config_tuple() == ()
    cfg = FailureSchedule.empty(3).apply(MULTI)
    assert cfg.failure_len == 0
    assert cfg.failure_array().shape == (3, 0, 2)


def test_config_validation_names_the_problem():
    with pytest.raises(ValueError, match="expected 3 .* window lists"):
        _ = dataclasses.replace(MULTI, failure_schedule=ALL_UP_1).failure_len
    ragged = (((0.0, 0.0), (1.0, 2.0)), ((0.0, 0.0),), ((0.0, 0.0),))
    with pytest.raises(ValueError, match="differ in length"):
        _ = dataclasses.replace(MULTI, failure_schedule=ragged).failure_len


def test_failure_json_roundtrip(tmp_path):
    fs = (FailureSchedule.empty(2)
          .link_outage(0, 1_000.0, 2_000.0)
          .link_outage(1, 3_000.0, 4_500.0))
    p = tmp_path / "outages.json"
    save_failure_json(p, fs)
    back = load_failure_json(p)
    assert back == fs


def test_failure_json_errors_name_the_edge(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(
        {"edges": [{"windows": [[0.0, 5.0]]}, {"windows": [[1.0]]}]}))
    with pytest.raises(ValueError, match="edge 1"):
        load_failure_json(p)
    p.write_text(json.dumps({"edges": [{"windows": [[5.0, 2.0]]}]}))
    with pytest.raises(ValueError, match="up_at_us must be > down_at_us"):
        load_failure_json(p)


# ---------------------------------------------------------------------------
# All-up bit-identity: an armed schedule whose windows never fire must not
# perturb a single bit of the goldens (seq + batch), and at L > 1 the
# schedule-free and all-up programs must agree on every shared trace key.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_all_up_identity_vs_goldens(golden, scheme):
    cfg = NetConfig(distance_km=100.0, failure_schedule=ALL_UP_1)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    final, traces = simulate(cfg, wl, get_scheme(scheme), 10_000.0)
    golden_keys = {k.rsplit("/", 1)[1] for k in golden.files
                   if k.startswith(f"seq/{scheme}/traces/")}
    assert golden_keys <= set(traces)
    assert set(traces) - golden_keys <= FAIL_EXTRA_KEYS
    for k in golden_keys:
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"{scheme}/{k} diverged bit-for-bit under an all-up "
                    f"failure schedule")
    for k in ("sent", "acked", "delivered", "done_at_us"):
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/final/{k}"],
            np.asarray(getattr(final, k)),
            err_msg=f"{scheme} final.{k} diverged under all-up schedule")
    # the no-op windows are visibly armed: every step reports 1.0 live
    assert np.all(np.asarray(traces["fail_live"]) == 1.0)


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_all_up_identity_batched(golden, scheme):
    cfgs = [NetConfig(distance_km=d, failure_schedule=ALL_UP_1)
            for d in (1.0, 300.0)]
    final, traces = simulate_batch(cfgs, WL, get_scheme(scheme), 8_000.0)
    keys = {k.rsplit("/", 1)[1] for k in golden.files
            if k.startswith(f"batch/{scheme}/traces/")}
    for k in keys:
        np.testing.assert_array_equal(
            golden[f"batch/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"batched {scheme}/{k} diverged under all-up schedule")
    np.testing.assert_array_equal(
        golden[f"batch/{scheme}/final/delivered"],
        np.asarray(final.delivered))


def test_all_up_multilink_matches_no_schedule():
    """At L=3 the all-up program agrees with the schedule-free program on
    every shared trace key and the final state, bit for bit."""
    fs = FailureSchedule(3, (((0.0, 0.0),),) * 3)
    cfg_up = fs.apply(MULTI)
    sch = get_scheme("dcqcn")
    f0, t0 = simulate(MULTI, SWL, sch, 3_000.0)
    f1, t1 = simulate(cfg_up, SWL, sch, 3_000.0)
    assert set(t0) <= set(t1)
    for k in t0:
        np.testing.assert_array_equal(np.asarray(t0[k]), np.asarray(t1[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(f0.delivered),
                                  np.asarray(f1.delivered))


# ---------------------------------------------------------------------------
# Outage physics: dump-at-exit byte accounting, reroute, all-dead stall
# ---------------------------------------------------------------------------

def test_outage_dumps_and_repairs_with_conservation():
    """A dead link's in-flight bytes land in ``lost``, ride the
    notification ring home, and are retransmitted — conservation holds
    through the whole outage (the subsystem's core accounting pin)."""
    cfg = _outage_cfg(600.0, 2_000.0)
    _, tr = simulate(cfg, SWL, get_scheme("dcqcn"), 4_000.0)
    lost = float(np.asarray(tr["chan_lost"]).sum())
    retx = float(np.asarray(tr["chan_retx"]).sum())
    assert lost > 0, "outage caught no bytes in flight"
    assert retx > 0
    assert float(np.asarray(tr["cons_err"]).max()) < 1e-3
    # the live-mask trace shades the window: link 0 down strictly inside
    # (600, 2000) us, siblings up throughout (dt = 5 us -> steps 120..399)
    live = np.asarray(tr["fail_live"])                    # [T, L]
    assert np.all(live[:, 1:] == 1.0)
    assert np.all(live[125:395, 0] == 0.0)
    assert np.all(live[:115, 0] == 1.0) and np.all(live[405:, 0] == 1.0)


@pytest.mark.parametrize("scheme", ("dcqcn", "rdmacell"))
def test_reroute_shifts_spray_onto_survivors(scheme):
    """During the outage the dead link transmits nothing while the
    surviving links keep carrying traffic — the ``link_live`` reroute
    contract, for the default hook and rdmacell's token spray."""
    cfg = _outage_cfg(600.0, 2_000.0)
    _, tr = simulate(cfg, SWL, get_scheme(scheme), 4_000.0)
    tx = np.asarray(tr["link_tx"])                        # [T, L]
    down = slice(125, 395)
    assert float(tx[down, 0].sum()) == 0.0, \
        f"{scheme} sprayed bytes onto a dead link"
    assert float(tx[down, 1].sum()) > 0.0
    assert float(tx[down, 2].sum()) > 0.0
    assert float(tx[:115, 0].sum()) > 0.0                 # alive before


def test_all_links_down_stalls_without_nans():
    """Every link dead: flows stall (zero throughput, bytes wait at the
    source) and NOTHING goes non-finite — the spray renormalization must
    not divide by zero (the NaN-safety pin)."""
    fs = FailureSchedule.empty(3)
    for li in range(3):
        fs = fs.link_outage(li, 600.0, 1_500.0)
    cfg = fs.apply(MULTI)
    final, tr = simulate(cfg, SWL, get_scheme("matchrdma"), 3_000.0)
    for k, v in tr.items():
        assert np.isfinite(np.asarray(v)).all(), f"non-finite {k}"
    thr = np.asarray(tr["thr_inter"])
    assert float(thr[150:280].sum()) == 0.0               # fully stalled
    assert float(thr[:110].sum()) > 0.0
    assert np.isfinite(np.asarray(final.sent)).all()
    assert float(np.asarray(tr["cons_err"]).max()) < 1e-3


def test_batch_path_keeps_failure_trace_keys():
    """Regression: ``batch_template`` resets ``failure_schedule``, so the
    batched program must gate the failure machinery on the traced
    ``fail_windows`` leaf SHAPE — single-cell and batched runs expose the
    same trace-key set, and metric rows carry the channel columns."""
    cfg = _outage_cfg(600.0, 2_000.0)
    _, t1 = simulate(cfg, SWL, get_scheme("dcqcn"), 3_000.0,
                     trace_mode="decimate", decimate=4)
    _, tb = simulate_batch([cfg], [SWL], get_scheme("dcqcn"), 3_000.0,
                           trace_mode="decimate", decimate=4)
    assert sorted(t1) == sorted(tb)
    assert "fail_live" in tb and "chan_lost" in tb
    rows = run_experiment_batch([cfg], SWL, "dcqcn", 3_000.0,
                                trace_mode="metrics")
    assert np.isfinite(rows[0]["goodput_gbps"])
    assert rows[0]["retx_frac"] > 0


# ---------------------------------------------------------------------------
# Live-mask property: for EVERY registered scheme, route weights under an
# arbitrary live-mask (including all-dead) stay finite, non-negative, and
# zero on dead links — and the skeleton's renormalization stays NaN-free.
# ---------------------------------------------------------------------------

_ROUTE_FIXTURES = {}


def _route_fixture(scheme_name):
    if scheme_name not in _ROUTE_FIXTURES:
        scheme = get_scheme(scheme_name)
        wlp = SWL.params()
        step = fluid.make_step_fn(MULTI, wlp, scheme)
        state = fluid.init_state(MULTI, int(wlp.is_inter.shape[0]),
                                 scheme=scheme)
        _ROUTE_FIXTURES[scheme_name] = (scheme, step.ctx, state)
    return _ROUTE_FIXTURES[scheme_name]


@settings(max_examples=25, deadline=None)
@given(scheme_name=st.sampled_from(ALL_SCHEMES),
       live_bits=st.integers(min_value=0, max_value=7),
       route_bits=st.integers(min_value=1, max_value=7),
       scale=st.floats(min_value=0.01, max_value=100.0))
def test_route_weights_live_mask_property(scheme_name, live_bits,
                                          route_bits, scale):
    import jax.numpy as jnp
    scheme, ctx, state = _route_fixture(scheme_name)
    live = np.array([(live_bits >> i) & 1 for i in range(3)], np.float32)
    route_row = np.array([(route_bits >> i) & 1 for i in range(3)],
                         np.float32) * scale
    f = int(ctx.is_inter.shape[0])
    base = jnp.asarray(np.tile(route_row, (f, 1)))
    w = np.asarray(scheme.route_weights(
        ctx._replace(link_live=jnp.asarray(live)), state, base))
    assert np.isfinite(w).all(), (scheme_name, live_bits)
    assert (w >= 0.0).all(), (scheme_name, live_bits)
    assert np.all(w[:, live == 0.0] == 0.0), \
        f"{scheme_name} routed weight onto a dead link"
    # the skeleton's renormalization on these weights is NaN-free even
    # when a row is all-zero (all routable links dead -> the flow stalls)
    s = w.sum(axis=1, keepdims=True)
    share = np.where(s > 0.0, w / np.maximum(s, 1e-30), 0.0)
    assert np.isfinite(share).all()


# ---------------------------------------------------------------------------
# Hardened runner: conservation guard, finite guard, checkpoints, OOM
# ---------------------------------------------------------------------------

def test_strict_conservation_reports_coordinates():
    """An impossibly tight tolerance turns the outage's benign float
    residual into a ``ConservationError`` carrying grid-order (cell, step)
    coordinates — exact step under materialized traces, ``None`` under
    streaming metrics."""
    cfg = _outage_cfg(600.0, 2_000.0)
    with pytest.raises(runner.ConservationError) as ei:
        run_experiment_batch([cfg], SWL, "dcqcn", 3_000.0,
                             trace_mode="decimate", decimate=4,
                             strict_conservation=True,
                             conservation_tol=1e-12)
    err = ei.value
    assert err.scheme_name == "dcqcn"
    assert err.cell == 0
    assert err.step is not None and (err.step + 1) % 4 == 0
    assert err.err > 1e-12
    with pytest.raises(runner.ConservationError, match="step unknown"):
        run_experiment_batch([cfg], SWL, "dcqcn", 3_000.0,
                             trace_mode="metrics",
                             strict_conservation=True,
                             conservation_tol=1e-12)
    # the default tolerance passes the same cell
    rows = run_experiment_batch([cfg], SWL, "dcqcn", 3_000.0,
                                trace_mode="decimate", decimate=4,
                                strict_conservation=True)
    assert len(rows) == 1


def test_conservation_coordinate_math():
    """Unit pin on the coordinate report: grid-order cell = launch ``lo``
    + batch row, step = ``(j + 1) * decimate - 1`` (sample j of a
    decimated trace is the engine value AT that step), padded rows beyond
    ``n_real`` are ignored, metrics mode reports ``step=None``."""
    from types import SimpleNamespace
    cons = np.zeros((3, 5), np.float32)
    cons[1, 2] = 7e-3                             # first real violation
    cons[2, 0] = 9e-3                             # a PADDED row: ignored
    aux = {"cons_err": cons}
    with pytest.raises(runner.ConservationError) as ei:
        runner._check_conservation("dcqcn", aux, lo=10, n_real=2,
                                   trace_mode="decimate", decimate=4,
                                   tol=1e-3)
    assert (ei.value.cell, ei.value.step) == (11, 11)   # 10+1, (2+1)*4-1
    runner._check_conservation("dcqcn", aux, lo=10, n_real=1,
                               trace_mode="decimate", decimate=4, tol=1e-3)
    macc = SimpleNamespace(maxes={"cons_err": np.array([0.0, 5e-3, 9e-3])})
    with pytest.raises(runner.ConservationError) as ei:
        runner._check_conservation("themis", macc, lo=4, n_real=2,
                                   trace_mode="metrics", decimate=1,
                                   tol=1e-3)
    assert (ei.value.cell, ei.value.step) == (5, None)


def test_nonfinite_guard_quarantines_and_raises():
    good = {"scheme": "dcqcn", "distance_km": 10.0, "throughput_gbps": 1.0,
            "avg_fct_us": float("inf")}          # documented sentinel: kept
    bad = {"scheme": "dcqcn", "distance_km": 20.0,
           "throughput_gbps": float("nan"), "peak_buffer_mb": float("inf")}
    assert runner._guard_nonfinite([good, bad], 4, "keep") == [good, bad]
    out = runner._guard_nonfinite([good, bad], 4, "quarantine")
    assert out[0] is good
    assert out[1] == {"scheme": "dcqcn", "distance_km": 20.0,
                      "cell_index": 5, "failed": True,
                      "nonfinite_cols": ["peak_buffer_mb",
                                         "throughput_gbps"]}
    with pytest.raises(RuntimeError, match="cell 5 .*peak_buffer_mb"):
        runner._guard_nonfinite([good, bad], 4, "raise")
    with pytest.raises(ValueError, match="on_nonfinite"):
        run_experiment_batch([MULTI], SWL, "dcqcn", 1_000.0,
                             trace_mode="metrics", on_nonfinite="explode")


def test_checkpoint_resume_is_bit_identical(tmp_path):
    """Kill a sweep mid-plan (the deterministic crash hook), resume it,
    and get row-for-row, bit-for-bit the rows of an uninterrupted run —
    resumed cells replay from the JSON checkpoints exactly."""
    cfgs = [_outage_cfg(600.0, 1_200.0 + 300.0 * i) for i in range(4)]
    kw = dict(trace_mode="metrics", chunk_cells=1)
    ref = sweep_grid(cfgs, SWL, ("dcqcn", "matchrdma"), 2_500.0, **kw)
    ck = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="abort_after_launches"):
        sweep_grid(cfgs, SWL, ("dcqcn", "matchrdma"), 2_500.0,
                   checkpoint_dir=ck, abort_after_launches=3, **kw)
    assert len(os.listdir(ck)) == 3               # the finished launches
    resumed = sweep_grid(cfgs, SWL, ("dcqcn", "matchrdma"), 2_500.0,
                         checkpoint_dir=ck, resume=True, **kw)
    assert len(resumed) == len(ref) == 8
    for a, b in zip(ref, resumed):
        assert set(a) == set(b)
        for k, v in a.items():
            if isinstance(v, float):
                assert (v == b[k]
                        or (np.isnan(v) and np.isnan(b[k]))), (k, v, b[k])
            else:
                assert v == b[k], k


def test_checkpoint_fingerprint_mismatch_refuses(tmp_path):
    ck = str(tmp_path / "ck")
    sweep_grid([MULTI], SWL, ("dcqcn",), 1_500.0, trace_mode="metrics",
               checkpoint_dir=ck)
    with pytest.raises(ValueError, match="DIFFERENT launch plan"):
        sweep_grid([MULTI], SWL, ("dcqcn",), 2_000.0, trace_mode="metrics",
                   checkpoint_dir=ck, resume=True)
    # a torn checkpoint (killed mid-write) is treated as absent, re-run
    path = os.path.join(ck, os.listdir(ck)[0])
    with open(path, "w") as f:
        f.write('{"fingerprint": "abc", "rows": [{"thro')
    rows = sweep_grid([MULTI], SWL, ("dcqcn",), 1_500.0,
                      trace_mode="metrics", checkpoint_dir=ck, resume=True)
    assert len(rows) == 1 and "throughput_gbps" in rows[0]


def test_oom_backoff_splits_launches(monkeypatch):
    """A device-OOM failure retries as half-size launches (down to single
    cells), warns, and still returns every cell's row."""
    real = runner.simulate_batch
    calls = []

    def fake(cfgs, *a, **kw):
        calls.append(len(cfgs))
        if len(cfgs) > 1:
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory "
                               "allocating 1 exabyte")
        return real(cfgs, *a, **kw)

    monkeypatch.setattr(runner, "simulate_batch", fake)
    cfgs = [dataclasses.replace(MULTI, distance_km=d)
            for d in (10.0, 50.0, 100.0, 200.0)]
    with pytest.warns(RuntimeWarning, match="device OOM"):
        rows = run_experiment_batch(cfgs, SWL, "dcqcn", 1_500.0,
                                    trace_mode="metrics")
    assert len(rows) == 4
    assert all(np.isfinite(r["throughput_gbps"]) for r in rows)
    assert max(calls) > 1 and calls.count(1) == 4


def test_oom_backoff_gives_up_at_single_cell(monkeypatch):
    def always_oom(cfgs, *a, **kw):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    monkeypatch.setattr(runner, "simulate_batch", always_oom)
    with pytest.warns(RuntimeWarning, match="device OOM"), \
            pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        run_experiment_batch([MULTI, MULTI], SWL, "dcqcn", 1_500.0,
                             trace_mode="metrics")


# ---------------------------------------------------------------------------
# The failover benchmark end to end: crash a real sweep subprocess
# mid-plan, resume it, and pin the CSV rows against an uninterrupted run.
# ---------------------------------------------------------------------------

def _run_failover(tmp_dir, *extra):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), os.pardir,
                                       "src"))
    cmd = [sys.executable, "-m", "benchmarks.scheme_compare",
           "--failover-grid", "--smoke", *extra]
    return subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), os.pardir))


def _csv_rows(stdout):
    return [ln for ln in stdout.splitlines()
            if "," in ln and not ln.startswith("#")
            and not ln.startswith("scheme,")]


def test_failover_sweep_crash_then_resume_reproduces_rows(tmp_path):
    ck = str(tmp_path / "ck")
    crashed = _run_failover(tmp_path, "--checkpoint-dir", ck,
                            "--crash-after-launches", "2")
    assert crashed.returncode != 0
    assert "abort_after_launches" in crashed.stderr
    assert os.listdir(ck), "crash left no checkpoints behind"
    resumed = _run_failover(tmp_path, "--checkpoint-dir", ck, "--resume")
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    assert "SCHEME_COMPARE_FAILOVER_SMOKE_OK" in resumed.stdout
    clean = _run_failover(tmp_path)
    assert clean.returncode == 0, clean.stderr[-2000:]
    rows_resumed, rows_clean = _csv_rows(resumed.stdout), \
        _csv_rows(clean.stdout)
    assert rows_resumed, "no CSV rows in resumed output"
    assert rows_resumed == rows_clean, \
        "resumed sweep's rows differ from the uninterrupted run"
