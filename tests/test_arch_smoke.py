"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finite values, plus prefill/decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_model_config, list_archs
from repro.models import build_model

B, S = 2, 64


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        return {"tokens": toks, "labels": toks}
    emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return {"embeds": emb, "labels": toks}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    # loss near ln(V) at init
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5
    gnorm2 = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                 for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm2) and gnorm2 > 0


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_decode(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    if cfg.embed_inputs:
        inp = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        dec_in = jnp.zeros((B,), jnp.int32)
    else:
        inp = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        dec_in = jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
    caches, logits = model.prefill(params, inp, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    caches, logits2 = model.decode_step(params, caches, dec_in, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
