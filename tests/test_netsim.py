"""Fluid simulator: conservation properties, the ACK-limit law, and the
paper's scheme ordering (Fig. 3 directions)."""
import dataclasses

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.config.base import NetConfig
from repro.netsim import (
    SCHEMES, FlowSpec, Workload, congestion_workload, get_scheme,
    run_experiment, simulate, throughput_workload,
)

CFG100 = NetConfig(distance_km=100.0)


@pytest.fixture(scope="module")
def thr_results():
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    out = {}
    for scheme in ("dcqcn", "pseudo_ack", "themis", "matchrdma"):
        out[scheme] = run_experiment(CFG100, wl, get_scheme(scheme), 100_000.0)
    return out


def test_conservation(thr_results):
    """delivered <= sent and every queue is non-negative, every scheme."""
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    for scheme in ("dcqcn", "matchrdma"):
        final, traces = simulate(CFG100, wl, get_scheme(scheme), 30_000.0)
        sent = np.asarray(final.sent)
        deliv = np.asarray(final.delivered)
        # fp32 accumulators at ~3e7 bytes carry a few bytes of ulp noise
        assert (deliv <= sent * (1.0 + 1e-5) + 1.0).all()
        for q in ("q_src", "q_dst", "q_leaf"):
            assert np.asarray(traces[q]).min() >= -1e-3


@pytest.mark.parametrize("scheme", SCHEMES)
def test_per_flow_byte_conservation(scheme):
    """At EVERY traced step, per flow: sent == delivered + q_src + q_dst +
    q_leaf + in-flight pipe bytes (fp32 tolerance). The simulator publishes
    the per-step max relative residual as the ``cons_err`` trace."""
    wl = congestion_workload(num_inter=4, num_intra=4,
                            burst_start_us=5_000.0, burst_len_us=8_000.0,
                            horizon_us=20_000.0)
    _, traces = simulate(CFG100, wl, get_scheme(scheme), 20_000.0)
    cons = np.asarray(traces["cons_err"])
    assert cons.shape[0] == traces["q_dst"].shape[0]   # every step traced
    assert float(cons.max()) < 1e-3, (scheme, float(cons.max()))


def test_ack_limit_law():
    """Conventional RDMA throughput at long distance must equal
    concurrency*msg/RTT (the paper's bottleneck #1)."""
    cfg = NetConfig(distance_km=1000.0)
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    r = run_experiment(cfg, wl, get_scheme("dcqcn"), 150_000.0)
    rtt = 2 * cfg.one_way_delay_us * 1e-6
    pred = 4 * (1 << 20) / rtt * 8 / 1e9
    assert abs(r["throughput_gbps"] - pred) / pred < 0.1


def test_pseudo_ack_distance_insensitive(thr_results):
    assert thr_results["pseudo_ack"]["throughput_gbps"] > \
        5 * thr_results["dcqcn"]["throughput_gbps"]
    assert thr_results["matchrdma"]["throughput_gbps"] > \
        5 * thr_results["dcqcn"]["throughput_gbps"]


def test_matchrdma_buffer_and_pause_lower_than_pseudo_ack(thr_results):
    m = thr_results["matchrdma"]
    p = thr_results["pseudo_ack"]
    assert m["peak_buffer_mb"] < 0.5 * p["peak_buffer_mb"]
    assert m["pause_ratio"] < 0.5 * p["pause_ratio"] + 1e-6


def test_congestion_scenario_ordering():
    """Fig. 3(c,d): MatchRDMA lowest buffer stress and pause ratio."""
    wl = congestion_workload()
    res = {s: run_experiment(CFG100, wl, get_scheme(s), 80_000.0)
           for s in ("dcqcn", "pseudo_ack", "matchrdma")}
    assert res["matchrdma"]["p99_buffer_mb"] < res["dcqcn"]["p99_buffer_mb"]
    assert res["matchrdma"]["p99_buffer_mb"] < res["pseudo_ack"]["p99_buffer_mb"]
    assert res["matchrdma"]["pause_ratio"] < 0.5 * res["dcqcn"]["pause_ratio"]
    # intra-DC traffic survives alongside MatchRDMA
    assert res["matchrdma"]["intra_thr_gbps"] > 10.0


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 3), st.sampled_from([64 << 10, 1 << 20]))
def test_finite_flows_complete(seed, msg):
    """Finite flows complete under matchrdma for arbitrary small workloads."""
    rng = np.random.default_rng(seed)
    flows = [FlowSpec(True, msg, 4, total_bytes=4 * msg,
                      start_us=float(rng.uniform(0, 5000)))
             for _ in range(3)]
    wl = Workload(tuple(flows))
    r = run_experiment(CFG100, wl, get_scheme("matchrdma"), 150_000.0)
    assert r["completion_frac"] == 1.0
