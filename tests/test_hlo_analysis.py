"""HLO parser: collectives, group classification, while trip counts, dot
FLOPs / HBM bytes — against a synthetic module and a real compiled one."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (
    aggregate, collective_summary, parse_hlo_module,
)

SYNTHETIC = """
HloModule test

%cond.1 (arg.0: (s32[], f32[64])) -> pred[] {
  %arg.0 = (s32[], f32[64]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%arg.0), index=0
  %c.0 = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte.0, %c.0), direction=LT
}

%body.1 (arg.1: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg.1 = (s32[], f32[64]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %gte.2 = f32[64]{0} get-tuple-element(%arg.1), index=1
  %ar.0 = f32[64]{0} all-reduce(%gte.2), replica_groups=[32,16]<=[512], to_apply=%add.1
  %c.1 = s32[] constant(1)
  %add.0 = s32[] add(%gte.1, %c.1)
  ROOT %t.0 = (s32[], f32[64]) tuple(%add.0, %ar.0)
}

%add.1 (x.0: f32[], y.0: f32[]) -> f32[] {
  %x.0 = f32[] parameter(0)
  %y.0 = f32[] parameter(1)
  ROOT %s.0 = f32[] add(%x.0, %y.0)
}

ENTRY %main.1 (p.0: f32[64], p.1: f32[128,256], p.2: f32[256,32]) -> f32[64] {
  %p.0 = f32[64]{0} parameter(0)
  %p.1 = f32[128,256]{1,0} parameter(1)
  %p.2 = f32[256,32]{1,0} parameter(2)
  %d.0 = f32[128,32]{1,0} dot(%p.1, %p.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag.0 = f32[128]{0} all-gather(%p.0), replica_groups=[256,2]<=[512]T(1,0), dimensions={0}
  %c.2 = s32[] constant(0)
  %t.1 = (s32[], f32[64]) tuple(%c.2, %p.0)
  %w.0 = (s32[], f32[64]) while(%t.1), condition=%cond.1, body=%body.1
  ROOT %gte.3 = f32[64]{0} get-tuple-element(%w.0), index=1
}
"""


def test_synthetic_module():
    comps = parse_hlo_module(SYNTHETIC)
    colls, flops, hbm = aggregate(comps)
    # dot: 2 * 128*32 * 256
    assert flops == 2 * 128 * 32 * 256
    # collectives: all-gather (group 2 => pod) once + all-reduce (group 16)
    # inside the while executed 12 times
    kinds = sorted((c.kind, c.count) for c in colls)
    assert ("all-gather", 1) in kinds
    assert ("all-reduce", 12) in kinds


def test_group_classification():
    s = collective_summary(SYNTHETIC, multi_pod=True)
    # the group-size-2 all-gather crosses pods: 128 floats * (2-1)/2 * 4B
    assert abs(s["inter_pod_bytes_per_device"] - 128 * 4 * 0.5) < 1e-6
    # the group-16 all-reduce is intra-pod: 2*(15/16)*256B * 12 trips
    assert abs(s["intra_pod_bytes_per_device"]
               - 2 * (15 / 16) * 256 * 12) < 1e-3


def test_single_pod_classification():
    s = collective_summary(SYNTHETIC, multi_pod=False)
    assert s["inter_pod_bytes_per_device"] == 0.0


def test_real_compiled_module():
    """Compile a scan-of-matmuls and check trip-count-aware flops."""
    n, d, trips = 64, 32, 9

    @jax.jit
    def f(a, bs):
        def body(c, x):
            return c @ x, None
        out, _ = jax.lax.scan(body, a, bs)
        return out

    txt = f.lower(jax.ShapeDtypeStruct((n, d), jnp.float32),
                  jax.ShapeDtypeStruct((trips, d, d), jnp.float32)
                  ).compile().as_text()
    _, flops, hbm = aggregate(parse_hlo_module(txt))
    assert flops == trips * 2 * n * d * d
    assert hbm > trips * (n * d + d * d) * 4   # at least reads per iter
