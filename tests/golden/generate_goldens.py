"""Regenerate the golden netsim traces pinning scheme behaviour bit-for-bit.

Two families of pins live in the .npz:

  * The paper's four schemes (``SCHEMES``): captured from the PRE-Scheme-API
    monolithic ``fluid.make_step_fn`` (PR 1 state, commit 98b8c0e) and
    compared bit-for-bit by ``tests/test_scheme_api.py::test_golden_*`` —
    the registry-backed hook decomposition must emit the numerically
    identical program.
  * The related-work pack (``RELATED_SCHEMES``: geopipe, sdr_rdma — PR 4 —
    and rdmacell — PR 6): captured from their first registered
    implementation — the pin freezes their physics against accidental
    drift. (All pins are L=1 single-pipe runs: rdmacell's golden is
    bit-identical to dcqcn's by construction, which is itself the pinned
    claim — the spraying machinery must vanish below ``num_paths > 1``.)

Re-running this script simply re-captures current behaviour — only do that
deliberately, when a simulator's or a scheme's physics (not its API)
changes, and say so in the PR. When regenerating, diff the four paper
schemes' arrays against the previous file: they must stay bit-identical
unless the engine physics changed.

    PYTHONPATH=src python tests/golden/generate_goldens.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.config.base import NetConfig
from repro.netsim import get_scheme, simulate, simulate_batch
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import congestion_workload, throughput_workload

OUT = os.path.join(os.path.dirname(__file__), "netsim_scheme_traces.npz")

SEQ_HORIZON_US = 10_000.0
BATCH_HORIZON_US = 8_000.0
BATCH_DISTS = (1.0, 300.0)


def main():
    arrays = {}
    # single-cell: the congestion workload exercises inter + intra flows,
    # ECN/PFC, CNPs and (for matchrdma) the full slot/budget/channel loop.
    cfg = NetConfig(distance_km=100.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=SEQ_HORIZON_US)
    for scheme in ALL_SCHEMES:
        final, traces = simulate(cfg, wl, get_scheme(scheme), SEQ_HORIZON_US)
        for k, v in traces.items():
            arrays[f"seq/{scheme}/traces/{k}"] = np.asarray(v)
        for k in ("sent", "acked", "delivered", "done_at_us"):
            arrays[f"seq/{scheme}/final/{k}"] = np.asarray(getattr(final, k))

    # batched: two distances through the padded-ring batch engine. Every
    # per-scheme trace key is captured (scheme-owned extras included).
    cfgs = [NetConfig(distance_km=d) for d in BATCH_DISTS]
    bwl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    for scheme in ALL_SCHEMES:
        final, traces = simulate_batch(cfgs, bwl, get_scheme(scheme),
                                       BATCH_HORIZON_US)
        for k, v in traces.items():
            arrays[f"batch/{scheme}/traces/{k}"] = np.asarray(v)
        arrays[f"batch/{scheme}/final/delivered"] = np.asarray(final.delivered)

    np.savez_compressed(OUT, **arrays)
    print(f"wrote {OUT} ({os.path.getsize(OUT)} bytes, {len(arrays)} arrays)")


if __name__ == "__main__":
    main()
