"""The soft-step relaxation contract (docs/differentiable.md):

  * ``soft_step=False`` is BIT-IDENTICAL to the hard engine no matter
    what ``soft_temp`` says — the golden arrays pin this for all seven
    schemes, sequential and batched (the relaxation must be gated out of
    the jaxpr, not merely small);
  * with ``soft_step=True`` the streamed metrics converge to the
    hard-mode metrics as the temperature drops (the property test:
    error at the coldest temperature is small, and no warmer temperature
    is dramatically closer than the coldest — a temperature anneal
    batches in ONE launch because ``soft_temp`` is a traced leaf).
"""
import dataclasses
import os

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.config.base import NetConfig
from repro.netsim import (
    get_scheme, run_experiment_batch, simulate, simulate_batch,
)
from repro.netsim.schemes import ALL_SCHEMES
from repro.netsim.workload import congestion_workload, throughput_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "netsim_scheme_traces.npz")
WL = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


# ---------------------------------------------------------------------------
# soft_step=False: bit-identity regardless of the temperature leaf
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_soft_off_bit_identical_sequential(golden, scheme):
    # an absurd temperature: if ANY soft helper leaked into the hard
    # program, this run could not reproduce the golden bits
    cfg = NetConfig(distance_km=100.0, soft_step=False, soft_temp=777.0)
    wl = congestion_workload(num_inter=4, num_intra=4,
                             burst_start_us=3_000.0, burst_len_us=4_000.0,
                             horizon_us=10_000.0)
    final, traces = simulate(cfg, wl, get_scheme(scheme), 10_000.0)
    for k, v in traces.items():
        np.testing.assert_array_equal(
            golden[f"seq/{scheme}/traces/{k}"], np.asarray(v),
            err_msg=f"{scheme}/{k}: soft_step=False is not bit-identical")
    np.testing.assert_array_equal(
        golden[f"seq/{scheme}/final/delivered"],
        np.asarray(final.delivered))


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_soft_off_bit_identical_batched(golden, scheme):
    # per-cell DIFFERENT temperatures (soft_temp is a traced leaf): the
    # hard batch engine must ignore all of them
    cfgs = [NetConfig(distance_km=d, soft_step=False, soft_temp=t)
            for d, t in ((1.0, 0.05), (300.0, 33.0))]
    final, traces = simulate_batch(cfgs, WL, get_scheme(scheme), 8_000.0)
    keys = [k.rsplit("/", 1)[1] for k in golden.files
            if k.startswith(f"batch/{scheme}/traces/")]
    for k in keys:
        np.testing.assert_array_equal(
            golden[f"batch/{scheme}/traces/{k}"], np.asarray(traces[k]),
            err_msg=f"batched {scheme}/{k}: soft_step=False drifted")
    np.testing.assert_array_equal(
        golden[f"batch/{scheme}/final/delivered"],
        np.asarray(final.delivered))


# ---------------------------------------------------------------------------
# soft -> hard convergence as temperature drops
# ---------------------------------------------------------------------------
TEMPS = (0.5, 0.2, 0.05)     # one batched launch: soft_temp is traced
HORIZON = 6_000.0
CONV_WL = throughput_workload(8e6, 4, num_flows=4)


def _convergence_errors(scheme_name):
    hard = run_experiment_batch(
        [NetConfig(distance_km=96.0, horizon_us=HORIZON)],
        CONV_WL, get_scheme(scheme_name), HORIZON,
        trace_mode="metrics")[0]
    cfgs = [NetConfig(distance_km=96.0, horizon_us=HORIZON,
                      soft_step=True, soft_temp=t) for t in TEMPS]
    soft = run_experiment_batch(cfgs, CONV_WL, get_scheme(scheme_name),
                                HORIZON, trace_mode="metrics")
    ref = max(abs(hard["throughput_gbps"]), 1e-6)
    return [abs(r["throughput_gbps"] - hard["throughput_gbps"]) / ref
            for r in soft]


@settings(max_examples=7, deadline=None)
@given(st.sampled_from(ALL_SCHEMES))
def test_soft_converges_to_hard(scheme_name):
    errs = _convergence_errors(scheme_name)
    # cold relaxation lands on the hard metric (5%), and the coldest
    # temperature is never much worse than the warmest (no divergence
    # as the gates sharpen)
    assert errs[-1] < 0.05, (scheme_name, errs)
    assert errs[-1] <= errs[0] + 0.02, (scheme_name, errs)
