"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Default = reduced grids (minutes on
CPU); ``--full`` = the paper's complete grids.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3b,fig3cd,fig3e,sweeps,netsim_sweep,"
                         "roofline,kernels")
    args = ap.parse_args()

    from benchmarks import figures, kernels_bench, netsim_sweep_bench, roofline

    suites = {
        "fig3b": lambda: figures.fig3b_throughput(args.full),
        "fig3cd": lambda: figures.fig3cd_buffer_pause(args.full),
        "fig3e": lambda: figures.fig3e_fct(args.full),
        "sweeps": lambda: figures.sweeps(args.full),
        "netsim_sweep": lambda: netsim_sweep_bench.run(args.full),
        "kernels": lambda: kernels_bench.run(args.full),
        "roofline": lambda: roofline.run(args.full),
    }
    selected = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")
    for name in selected:
        for row in suites[name]():
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
