"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode (Python — correctness only,
not speed), so the MEANINGFUL µs numbers here are the jnp reference paths
(what the dry-run lowers); kernel rows are labeled interpret-mode.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import attention_ref, ssd_ref
from repro.models.attention import chunked_causal_attention
from repro.models.ssm import ssd_chunked
from repro.models.rglru import rglru_scan


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(full: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    # attention: chunked jnp path (the dry-run path)
    b, s, hq, hk, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
    f = jax.jit(lambda q, k, v: chunked_causal_attention(
        q, k, v, block_q=256, block_kv=256))
    rows.append((f"kernelref/chunked_attn/b{b}s{s}h{hq}d{d}",
                 _time(f, q, k, v), "jnp flash-style (dry-run path)"))
    fr = jax.jit(attention_ref)
    rows.append((f"kernelref/naive_attn/b{b}s{s}h{hq}d{d}",
                 _time(fr, q, k, v), "naive oracle"))

    # ssd
    b, s, h, p, n = 2, 1024, 8, 64, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[0], (b, s, 1, n)) * 0.3
    C = jax.random.normal(ks[1], (b, s, 1, n)) * 0.3
    fs = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    rows.append((f"kernelref/ssd_chunked/b{b}s{s}h{h}", _time(fs, x, dt, A, B, C),
                 "jnp chunked SSD (dry-run path)"))
    fq = jax.jit(ssd_ref)
    rows.append((f"kernelref/ssd_sequential/b{b}s{s}h{h}",
                 _time(fq, x, dt, A, B, C), "sequential oracle"))

    # rglru associative scan
    from repro.models.rglru import init_rglru_block
    from repro.config import get_model_config
    cfg = get_model_config("recurrentgemma-2b", smoke=True)
    pr = init_rglru_block(jax.random.PRNGKey(1), cfg)
    xw = jax.random.normal(key, (2, 1024, cfg.rglru_width), jnp.float32)
    fg = jax.jit(lambda x: rglru_scan(pr, x)[0])
    rows.append((f"kernelref/rglru_assoc_scan/s1024w{cfg.rglru_width}",
                 _time(fg, xw), "jnp associative scan (dry-run path)"))

    if full:
        from repro.kernels.flash_attention import flash_attention_fwd
        t0 = time.time()
        flash_attention_fwd(q[:, :256], k[:, :256], v[:, :256],
                            block_q=128, block_kv=128, interpret=True)
        rows.append(("kernel/flash_attention_interpret/s256",
                     (time.time() - t0) * 1e6,
                     "Pallas interpret mode (correctness only)"))
    return rows
