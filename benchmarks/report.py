"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.2f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def load(dirname):
    cells = [json.load(open(f)) for f in sorted(glob.glob(
        os.path.join(dirname, "*.json")))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"]))
    return cells


def dryrun_table(cells):
    print("| arch | shape | mesh | status | compile | params/dev | temp/dev |"
          " HLO flops/dev | HBM bytes/dev | coll bytes/dev (inter-pod) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        st = c.get("status", "?")
        if st != "OK":
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {st} |"
                  " - | - | - | - | - | - |")
            continue
        arg = c.get("argument_size_in_bytes", 0)
        tmp = c.get("temp_size_in_bytes", 0)
        fl = c.get("hlo_dot_flops_per_device", 0)
        hb = c.get("hlo_hbm_bytes_per_device", 0)
        cb = c.get("collective_bytes_per_device", 0)
        ip = c.get("inter_pod_bytes_per_device", 0)
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | OK | "
              f"{c.get('compile_s', 0):.0f}s | {fmt_bytes(arg)} | "
              f"{fmt_bytes(tmp)} | {fl:.2e} | {fmt_bytes(hb)} | "
              f"{fmt_bytes(cb)} ({fmt_bytes(ip)}) |")


def roofline_table(cells):
    print("| arch | shape | mesh | T_comp | T_mem | T_coll(intra+inter) |"
          " dominant | roofline frac | useful |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") != "OK":
            continue
        rf = c["roofline"]
        tc, tm, tl = rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]
        b = max(tc, tm, tl)
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {tc:.3f}s | "
              f"{tm:.3f}s | {tl:.3f}s ({rf['t_coll_intra_s']:.3f}+"
              f"{rf['t_coll_inter_s']:.3f}) | {rf['dominant']} | "
              f"{tc / b if b else 0:.3f} | {rf['useful_flops_ratio']:.2f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--which", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    cells = load(args.dir)
    if args.which in ("dryrun", "both"):
        dryrun_table(cells)
        print()
    if args.which in ("roofline", "both"):
        roofline_table(cells)


if __name__ == "__main__":
    main()
