"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
results/dryrun/*.json — plus the netsim sweep-artifact hook: any
``sweep_grid`` row list exports to CSV/JSON with ``export_sweep_rows``, and
``--netsim-out DIR`` runs a small demo (config × workload) grid and writes
``DIR/netsim_sweep.{csv,json}``.

    PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]
    PYTHONPATH=src python -m benchmarks.report --netsim-out results/netsim
"""
from __future__ import annotations

import argparse
import csv
import glob
import json
import os


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.2f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def load(dirname):
    cells = [json.load(open(f)) for f in sorted(glob.glob(
        os.path.join(dirname, "*.json")))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"]))
    return cells


def dryrun_table(cells):
    print("| arch | shape | mesh | status | compile | params/dev | temp/dev |"
          " HLO flops/dev | HBM bytes/dev | coll bytes/dev (inter-pod) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        st = c.get("status", "?")
        if st != "OK":
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {st} |"
                  " - | - | - | - | - | - |")
            continue
        arg = c.get("argument_size_in_bytes", 0)
        tmp = c.get("temp_size_in_bytes", 0)
        fl = c.get("hlo_dot_flops_per_device", 0)
        hb = c.get("hlo_hbm_bytes_per_device", 0)
        cb = c.get("collective_bytes_per_device", 0)
        ip = c.get("inter_pod_bytes_per_device", 0)
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | OK | "
              f"{c.get('compile_s', 0):.0f}s | {fmt_bytes(arg)} | "
              f"{fmt_bytes(tmp)} | {fl:.2e} | {fmt_bytes(hb)} | "
              f"{fmt_bytes(cb)} ({fmt_bytes(ip)}) |")


def roofline_table(cells):
    print("| arch | shape | mesh | T_comp | T_mem | T_coll(intra+inter) |"
          " dominant | roofline frac | useful |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") != "OK":
            continue
        rf = c["roofline"]
        tc, tm, tl = rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]
        b = max(tc, tm, tl)
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {tc:.3f}s | "
              f"{tm:.3f}s | {tl:.3f}s ({rf['t_coll_intra_s']:.3f}+"
              f"{rf['t_coll_inter_s']:.3f}) | {rf['dominant']} | "
              f"{tc / b if b else 0:.3f} | {rf['useful_flops_ratio']:.2f} |")


# ---------------------------------------------------------------------------
# netsim sweep artifacts
# ---------------------------------------------------------------------------

def export_sweep_rows(rows, csv_path=None, json_path=None):
    """Write a ``sweep_grid``/``run_experiment_batch`` row list (list of
    flat metric dicts) to CSV and/or JSON artifact files. Returns the
    paths written. Columns are the union of row keys, scheme/distance
    first, so heterogeneous scenario grids land in one table."""
    rows = list(rows)
    if not rows:
        raise ValueError("export_sweep_rows: empty row list")
    lead = [k for k in ("scheme", "distance_km") if k in rows[0]]
    rest = sorted({k for r in rows for k in r} - set(lead))
    cols = lead + rest
    written = []
    if csv_path:
        os.makedirs(os.path.dirname(csv_path) or ".", exist_ok=True)
        with open(csv_path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols, restval="")
            w.writeheader()
            w.writerows(rows)
        written.append(csv_path)
    if json_path:
        # strict JSON: NaN/Inf (e.g. avg_fct_us of throughput-only
        # workloads) become null — bare NaN tokens break jq/JSON.parse
        def _finite(v):
            if isinstance(v, float) and not (v == v and abs(v) != float("inf")):
                return None
            return v
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump([{k: _finite(v) for k, v in r.items()} for r in rows],
                      f, indent=2, allow_nan=False)
            f.write("\n")
        written.append(json_path)
    return written


def export_demo_timeline(timeline_out: str, horizon_us: float = 40_000.0):
    """Re-run the demo grid's congestion scenario per scheme under
    ``trace_mode="window"`` with the event ring enabled and export a
    Chrome trace-event / Perfetto JSON (one process per scheme, counter
    tracks for the windowed traces, instant events from the ring —
    docs/observability.md)."""
    import dataclasses

    from repro.config.base import NetConfig
    from repro.netsim import (
        congestion_workload, export_timeline, get_scheme, simulate,
    )
    from repro.netsim.obs import decode_events
    from repro.netsim.obs.timeline import timeline_cell

    slots = 64
    cfg = dataclasses.replace(NetConfig(distance_km=100.0),
                              event_ring_slots=slots)
    wl = congestion_workload()
    steps = cfg.horizon_steps(horizon_us)
    recs = []
    for pid, scheme in enumerate(("dcqcn", "matchrdma")):
        _, aux = simulate(cfg, wl, get_scheme(scheme), horizon_us,
                          trace_mode="window")
        recs.extend(timeline_cell(
            pid, label=f"{scheme} @ 100km congestion", dt_us=cfg.dt_us,
            steps=steps, window_steps=cfg.trace_window_steps,
            window={k: v for k, v in aux.window.items()},
            events=decode_events(aux.events, slots)))
    doc = {"traceEvents": recs, "displayTimeUnit": "ms"}
    export_timeline(timeline_out, doc)
    print(f"wrote {timeline_out} ({len(recs)} trace events)")
    return timeline_out


def netsim_demo_grid(out_dir: str, trace_mode: str = "metrics",
                     timeline_out: str = None):
    """Run a small heterogeneous (config × workload) Scenario grid through
    ``sweep_grid`` and export the rows as CSV + JSON artifacts. The default
    ``trace_mode="metrics"`` streams all reductions in-scan (O(B) device
    memory) and adds the scheme-streamed columns (``mean_budget_gbps``,
    ...) to the artifacts; ``window`` additionally keeps the last-W-steps
    trace ring; pass ``full`` for the trace-materialized path.
    ``timeline_out`` additionally exports a Perfetto/Chrome-trace JSON of
    the congestion scenario (window mode + event ring)."""
    from repro.config.base import NetConfig
    from repro.netsim import (
        Scenario, congestion_workload, sweep_grid, throughput_workload,
    )
    scens = [
        Scenario(NetConfig(distance_km=100.0),
                 throughput_workload(1 << 20, 1, num_flows=4)),
        Scenario(NetConfig(distance_km=1000.0),
                 throughput_workload(1 << 20, 1, num_flows=4)),
        Scenario(NetConfig(distance_km=100.0), congestion_workload()),
    ]
    rows = sweep_grid(scens, ("dcqcn", "matchrdma"), horizon_us=40_000.0,
                      trace_mode=trace_mode)
    paths = export_sweep_rows(
        rows,
        csv_path=os.path.join(out_dir, "netsim_sweep.csv"),
        json_path=os.path.join(out_dir, "netsim_sweep.json"))
    for p in paths:
        print(f"wrote {p} ({len(rows)} rows)")
    if timeline_out:
        export_demo_timeline(timeline_out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--which", default="both",
                    choices=["dryrun", "roofline", "both"])
    ap.add_argument("--netsim-out", default=None, metavar="DIR",
                    help="run the demo netsim Scenario grid and write "
                         "DIR/netsim_sweep.{csv,json} instead of the "
                         "dryrun tables")
    ap.add_argument("--trace-mode", default="metrics",
                    choices=["full", "decimate", "metrics", "window"],
                    help="execution mode of the --netsim-out demo grid "
                         "(default: streaming in-scan metrics; 'window' "
                         "also keeps the last-W-steps trace ring)")
    ap.add_argument("--timeline-out", default=None, metavar="JSON",
                    help="with --netsim-out: also export a Perfetto/"
                         "Chrome-trace JSON of the congestion scenario "
                         "(window mode + event ring; open in "
                         "ui.perfetto.dev or chrome://tracing)")
    args = ap.parse_args()
    if args.netsim_out:
        netsim_demo_grid(args.netsim_out, trace_mode=args.trace_mode,
                         timeline_out=args.timeline_out)
        return
    cells = load(args.dir)
    if args.which in ("dryrun", "both"):
        dryrun_table(cells)
        print()
    if args.which in ("roofline", "both"):
        roofline_table(cells)


if __name__ == "__main__":
    main()
