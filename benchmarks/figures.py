"""One benchmark per paper figure (Fig. 3b/c/d/e) + the extra sweeps.

Each function returns a list of CSV rows: (name, value, derived-note).
The grids are reduced versions of the paper's (distance x message size x
concurrency) so the full suite runs in minutes on CPU; pass full=True for
the complete grid.

Execution rides the unified scenario axis: message-size / concurrency /
jitter grids vary the WORKLOAD per cell, so each figure's whole grid is one
``Scenario`` batch — one vmapped launch per scheme, even where every cell
used to be its own ``run_experiment`` compile.
"""
from __future__ import annotations

import time

from repro.config.base import NetConfig
from repro.netsim import (
    SCHEMES, Scenario, congestion_workload, mixed_fct_workload,
    run_experiment_batch, sweep_grid, throughput_workload,
)
from repro.netsim.workload import aicb_workload


def fig3b_throughput(full: bool = False):
    """Fig. 3(b): inter-DC throughput vs distance under different message
    sizes. Derived: MatchRDMA/DCQCN speedup (paper: up to 20x).

    Batched engine: per (msg, scheme) the full distance grid is ONE vmapped
    launch; the per-row time is the batch wall-clock amortized over cells."""
    rows = []
    dists = (1.0, 100.0, 1000.0) if not full else (1.0, 10.0, 50.0, 100.0,
                                                   300.0, 500.0, 1000.0)
    msgs = (64 << 10, 1 << 20) if not full else (1 << 10, 16 << 10, 64 << 10,
                                                 256 << 10, 1 << 20, 8 << 20)
    cfgs = [NetConfig(distance_km=d) for d in dists]
    h = max(100_000.0, 40 * max(c.one_way_delay_us for c in cfgs) + 20_000.0)
    best_speedup = 0.0
    for msg in msgs:
        wl = throughput_workload(msg_size=msg, concurrency=1, num_flows=4)
        res = {}
        for s in SCHEMES:
            t0 = time.time()
            res[s] = run_experiment_batch(cfgs, wl, s, h)
            us_per_cell = (time.time() - t0) * 1e6 / len(cfgs)
            for r in res[s]:
                rows.append((f"fig3b/thr_gbps/{s}/d{int(r['distance_km'])}km/"
                             f"msg{msg >> 10}KB", us_per_cell,
                             f"{r['throughput_gbps']:.2f}Gbps"))
        for i, _ in enumerate(dists):
            sp = (res["matchrdma"][i]["throughput_gbps"]
                  / max(res["dcqcn"][i]["throughput_gbps"], 1e-9))
            best_speedup = max(best_speedup, sp)
    rows.append(("fig3b/max_speedup_vs_dcqcn", 0.0,
                 f"{best_speedup:.1f}x (paper: up to 20x)"))
    return rows


def fig3cd_buffer_pause(full: bool = False):
    """Fig. 3(c): destination-OTN runtime buffer; Fig. 3(d): pause ratio."""
    rows = []
    dists = (100.0,) if not full else (10.0, 100.0, 500.0, 1000.0)
    cfgs = [NetConfig(distance_km=d) for d in dists]
    wl = congestion_workload()
    base = {}
    for s in SCHEMES:
        t0 = time.time()
        batch = run_experiment_batch(cfgs, wl, s, 100_000.0)
        us = (time.time() - t0) * 1e6 / len(cfgs)
        for d, r in zip(dists, batch):
            rows.append((f"fig3c/peak_buffer_mb/{s}/d{int(d)}km", us,
                         f"{r['peak_buffer_mb']:.1f}MB p99={r['p99_buffer_mb']:.1f}"))
            rows.append((f"fig3d/pause_ratio/{s}/d{int(d)}km", us,
                         f"{r['pause_ratio']:.4f}"))
            base[(s, d)] = r
    for d in dists:
        m, dq = base[("matchrdma", d)], base[("dcqcn", d)]
        rows.append((f"fig3c/buffer_reduction/d{int(d)}km", 0.0,
                     f"peak {-100 * (1 - m['peak_buffer_mb'] / max(dq['peak_buffer_mb'], 1e-9)):+.1f}% "
                     f"p99 {-100 * (1 - m['p99_buffer_mb'] / max(dq['p99_buffer_mb'], 1e-9)):+.1f}% "
                     f"(paper: -62.7% peak)"))
        rows.append((f"fig3d/pause_reduction/d{int(d)}km", 0.0,
                     f"{-100 * (1 - m['pause_ratio'] / max(dq['pause_ratio'], 1e-9)):+.1f}% "
                     f"(paper: -94.1%)"))
    return rows


def fig3e_fct(full: bool = False):
    """Fig. 3(e): mixed-traffic average FCT vs message size.

    The message-size grid varies the WORKLOAD, not the config — so the
    whole figure is one Scenario batch: one vmapped launch per scheme
    instead of one compile per (scheme, message size)."""
    rows = []
    msgs = (64 << 10, 1 << 20, 8 << 20)
    cfg = NetConfig(distance_km=100.0)
    scens = [Scenario(cfg, mixed_fct_workload(msg_size=msg)) for msg in msgs]
    res = {}
    for s in SCHEMES:
        t0 = time.time()
        batch = run_experiment_batch([sc.net for sc in scens],
                                     [sc.workload for sc in scens],
                                     s, 200_000.0)
        us = (time.time() - t0) * 1e6 / len(scens)
        res[s] = [r["avg_fct_us"] for r in batch]
        for msg, r in zip(msgs, batch):
            rows.append((f"fig3e/avg_fct_us/{s}/msg{msg >> 10}KB", us,
                         f"{r['avg_fct_us']:.0f}us"))
    for i, msg in enumerate(msgs):
        imp = 100 * (1 - res["matchrdma"][i] / max(res["dcqcn"][i], 1e-9))
        rows.append((f"fig3e/fct_improvement/msg{msg >> 10}KB", 0.0,
                     f"{imp:+.1f}% vs dcqcn (paper: +31.5..43.9%)"))
    return rows


def sweeps(full: bool = False):
    """Text-mentioned robustness sweeps: concurrency and traffic jitter.

    Pure workload grids over one config — each sweep is a Scenario batch
    through ``sweep_grid`` (one launch per scheme)."""
    rows = []
    cfg = NetConfig(distance_km=100.0)
    schemes = ("dcqcn", "matchrdma")

    concs = (1, 16, 64)
    scens = [Scenario(cfg, throughput_workload(msg_size=256 << 10,
                                               concurrency=c, num_flows=4))
             for c in concs]
    t0 = time.time()
    grid = sweep_grid(scens, schemes, horizon_us=100_000.0)
    us = (time.time() - t0) * 1e6 / len(grid)
    for i, conc in enumerate(concs):
        for j, s in enumerate(schemes):
            r = grid[i * len(schemes) + j]
            rows.append((f"sweep/concurrency{conc}/{s}", us,
                         f"{r['throughput_gbps']:.1f}Gbps buf={r['peak_buffer_mb']:.1f}MB"))

    jitters = (0.0, 0.5)
    scens = [Scenario(cfg, aicb_workload(comm_bytes_per_iter=2e9,
                                         iter_us=20_000.0, comm_frac=0.3,
                                         num_flows=8, msg_size=4 << 20,
                                         jitter=j))
             for j in jitters]
    t0 = time.time()
    grid = sweep_grid(scens, schemes, horizon_us=120_000.0)
    us = (time.time() - t0) * 1e6 / len(grid)
    for i, jitter in enumerate(jitters):
        for j, s in enumerate(schemes):
            r = grid[i * len(schemes) + j]
            rows.append((f"sweep/jitter{jitter}/{s}", us,
                         f"{r['throughput_gbps']:.1f}Gbps pause={r['pause_ratio']:.3f}"))
    return rows
