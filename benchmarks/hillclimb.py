"""§Perf hillclimb harness: compile ONE cell under a named variant, print the
three roofline terms + the op-level byte breakdown. This is the per-iteration
measurement tool of the hypothesis → change → re-lower → re-analyse loop.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen-decode \
        --variant baseline|cache_carry
    PYTHONPATH=src python -m benchmarks.hillclimb --cell granite-train-multi \
        --variant baseline|grouped_moe
    PYTHONPATH=src python -m benchmarks.hillclimb --cell nemotron-train-multi \
        --variant baseline|hier|hier_int8

Netsim controller tuning rides the same harness: each hillclimb iteration
evaluates a whole POPULATION of candidate NetConfigs in one batched
``simulate_batch`` launch (the batched scenario engine as the inner loop):

    PYTHONPATH=src python -m benchmarks.hillclimb --cell netsim-tune \
        --variant headroom|slot|grad|grad-slot

``grad*`` variants route to the gradient tuner (``repro.netsim.grad_tune``
— Adam through the differentiable soft-step engine, scored on the hard
engine); the bracket variants stay the zeroth-order regression baseline.
"""
import argparse
import dataclasses
import json
import os
import time


def _setup_model_cell_env():
    # model cells lower against the 512-chip production mesh on CPU; must be
    # set before the jax backend initializes (importing repro.launch.dryrun
    # also sets it — which is why the heavy imports below are deferred until
    # a model cell is chosen). The netsim cell wants the REAL device count:
    # forcing 512 host devices makes the fluid scan crawl.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    global jax, jnp, P, SHAPES, get_model_config, get_parallel_config
    global TrainConfig, HBM_BW, ICI_BW, OTN_BW, PEAK_FLOPS
    global collective_summary, op_breakdown, make_production_mesh
    global decode_input_specs, params_and_opt_specs, train_input_specs
    global build_model, compressed_psum, named
    global adam_update, clip_by_global_norm

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.config import SHAPES, get_model_config, get_parallel_config
    from repro.config.base import TrainConfig
    from repro.launch.dryrun import HBM_BW, ICI_BW, OTN_BW, PEAK_FLOPS
    from repro.launch.hlo_analysis import collective_summary, op_breakdown
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import (
        decode_input_specs, params_and_opt_specs, train_input_specs,
    )
    from repro.models import build_model
    from repro.parallel.compression import compressed_psum
    from repro.parallel.sharding import named
    from repro.train.optimizer import adam_update, clip_by_global_norm


def analyse(lowered, multi_pod, model_flops, chips, label):
    t0 = time.time()
    compiled = lowered.compile()
    txt = compiled.as_text()
    s = collective_summary(txt, multi_pod)
    fl = s["hlo_dot_flops_per_device"]
    hb = s["hlo_hbm_bytes_per_device"]
    t_c = fl / PEAK_FLOPS
    t_m = hb / HBM_BW
    t_i = s["intra_pod_bytes_per_device"] / ICI_BW
    t_x = s["inter_pod_bytes_per_device"] * 256 / OTN_BW if multi_pod else 0.0
    print(f"\n===== {label} (compile {time.time() - t0:.0f}s) =====")
    print(f"T_compute={t_c:.4f}s T_memory={t_m:.4f}s "
          f"T_coll={t_i + t_x:.4f}s (intra={t_i:.4f} inter={t_x:.4f})")
    print(f"useful_flops_ratio={model_flops / max(chips * fl, 1):.3f} "
          f"inter_pod_bytes/pod={s['inter_pod_bytes_per_device'] * 256 / 1e9:.2f}GB")
    print("top ops by HBM bytes:")
    for op, b in op_breakdown(txt, top=8):
        print(f"  {op:26s} {b / 1e9:10.2f} GB")
    from benchmarks.record import memory_figures
    figs = memory_figures(compiled)
    if "temp_size_in_bytes" in figs:
        print(f"temp/device={figs['temp_size_in_bytes'] / 1e9:.2f}GB "
              f"args={figs.get('argument_size_in_bytes', 0) / 1e9:.2f}GB")
    return {"t_compute": t_c, "t_memory": t_m, "t_intra": t_i, "t_inter": t_x}


def qwen_decode(variant):
    arch, shape = "qwen1.5-0.5b", SHAPES["decode_32k"]
    mc = get_model_config(arch)
    par = get_parallel_config(arch, multi_pod=False)
    mesh = make_production_mesh(multi_pod=False)
    if variant == "cache_carry_tm":
        mc = dataclasses.replace(mc, decode_k_time_minor=True)
    model = build_model(mc, remat="none",
                        decode_cache_in_carry=(variant in
                                               ("cache_carry",
                                                "cache_carry_tm")))
    params_s, params_p, _, _ = params_and_opt_specs(model, par, with_opt=False)
    cache_s, cache_p, inp_s, inp_p, pos_s = decode_input_specs(mc, par, shape)

    def serve_step(params, caches, inp, pos):
        caches, logits = model.decode_step(params, caches, inp, pos)
        return caches, jnp.argmax(logits, -1).astype(jnp.int32)

    fn = jax.jit(serve_step,
                 in_shardings=(named(mesh, params_p), named(mesh, cache_p),
                               named(mesh, inp_p), None),
                 donate_argnums=(1,))
    with jax.set_mesh(mesh):
        lowered = fn.lower(params_s, cache_s, inp_s, pos_s)
    mf = 2.0 * mc.active_param_count() * shape.global_batch
    return analyse(lowered, False, mf, 256, f"qwen decode_32k [{variant}]")


def _train_cell(arch, variant, grouped_moe=False, hier=None):
    shape = SHAPES["train_4k"]
    mc = get_model_config(arch)
    if grouped_moe:
        mc = dataclasses.replace(mc, moe_group_by_batch=True)
    par = get_parallel_config(arch, multi_pod=True)
    mesh = make_production_mesh(multi_pod=True)
    model = build_model(mc, remat=par.remat)
    params_s, params_p, opt_s, opt_p = params_and_opt_specs(model, par)
    tc = TrainConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)
    batch_s, batch_p = train_input_specs(mc, par, shape)

    if hier is None:
        def train_step(params, opt_state, batch):
            (loss, m), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            grads, gn = clip_by_global_norm(grads, tc.grad_clip)
            params, opt_state, om = adam_update(params, grads, opt_state, tc)
            return params, opt_state, {"loss": loss}
    elif hier in ("vmap", "vmap_int8"):
        # pure-pjit hierarchical exchange: params stacked [2, ...] and
        # sharded P("pod", ...) — physically identical to pod replication,
        # but vmap(grad) over the pod dim keeps per-pod gradients UNREDUCED.
        # The explicit mean over dim 0 then moves only the (data, model)-
        # sharded 2D shards across the OTN (reduce-scatter-first ordering).
        # int8 variant: quantize, collective-permute (flip) the int8
        # payload, dequant-sum locally — int8 on the wire.
        compress = hier == "vmap_int8"

        def train_step(params2, opt_state2, batch):
            b2 = {k: v.reshape(2, v.shape[0] // 2, *v.shape[1:])
                  for k, v in batch.items()}
            (loss, m), grads2 = jax.vmap(jax.value_and_grad(
                model.loss_fn, has_aux=True))(params2, b2)
            if compress:
                from repro.parallel.compression import (
                    dequantize_int8, quantize_int8)

                def exchange(g):
                    q, scale = jax.vmap(quantize_int8)(g)      # [2,...] int8
                    qo = jnp.flip(q, 0)                        # pod permute
                    so = jnp.flip(scale, 0)
                    mine = jax.vmap(lambda qq, ss: dequantize_int8(
                        qq, ss, g.shape[1:], jnp.float32))(q, scale)
                    theirs = jax.vmap(lambda qq, ss: dequantize_int8(
                        qq, ss, g.shape[1:], jnp.float32))(qo, so)
                    return ((mine + theirs) / 2.0).astype(g.dtype)

                grads2 = jax.tree.map(exchange, grads2)
            else:
                grads2 = jax.tree.map(
                    lambda g: jnp.broadcast_to(
                        jnp.mean(g.astype(jnp.float32), axis=0,
                                 keepdims=True), g.shape).astype(g.dtype),
                    grads2)
            grads2, gn = clip_by_global_norm(grads2, tc.grad_clip * 1.41421)
            params2, opt_state2, om = adam_update(params2, grads2,
                                                  opt_state2, tc)
            return params2, opt_state2, {"loss": jnp.mean(loss)}

        # stack every param/opt leaf with a leading pod dim
        def stack_specs(t):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((2, *s.shape), s.dtype), t)
        from repro.train.optimizer import AdamState
        params_s = stack_specs(params_s)
        opt_s = AdamState(step=opt_s.step, m=stack_specs(opt_s.m),
                          v=stack_specs(opt_s.v))

        def stack_pspec(t):
            return jax.tree.map(lambda s: P("pod", *s), t,
                                is_leaf=lambda x: isinstance(x, P))
        params_p = stack_pspec(params_p)
        opt_p = AdamState(step=P(), m=stack_pspec(opt_p.m),
                          v=stack_pspec(opt_p.v))
    else:
        # geo train step: shard_map over the POD axis only (auto over
        # data/model). Per-pod grads from the pod-local batch half; the pod
        # exchange is explicit — psum (hier) or int8 error-feedback
        # compressed (hier_int8) — so ONLY the (data,model)-sharded gradient
        # shard crosses the OTN.
        compress = hier == "int8"

        def pod_step(params, opt_state, batch):
            def loss_fn(p, b):
                loss, m = model.loss_fn(p, b)
                return loss, m
            (loss, m), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if compress:
                flat, tree = jax.tree.flatten(grads)
                outs = []
                for g in flat:
                    err = jnp.zeros_like(g, dtype=jnp.float32)
                    o, _ = compressed_psum(g, "pod", err)
                    outs.append(o / 2.0)
                grads = tree.unflatten(outs)
            else:
                grads = jax.tree.map(
                    lambda g: (jax.lax.psum(g.astype(jnp.float32), "pod")
                               / 2.0).astype(g.dtype), grads)
            grads, gn = clip_by_global_norm(grads, tc.grad_clip)
            params, opt_state, om = adam_update(params, grads, opt_state, tc)
            return params, opt_state, {"loss": jax.lax.pmean(loss, "pod")}

        # shard_map specs mention ONLY the manual axis ("pod"); the
        # data/model sharding stays with the outer jit in_shardings (auto).
        def _rep(tree):
            return jax.tree.map(lambda s: P(), tree,
                                is_leaf=lambda x: isinstance(x, P))
        bspec_pod = jax.tree.map(
            lambda s: P("pod", *([None] * (len(s) - 1))), batch_p,
            is_leaf=lambda x: isinstance(x, P))
        from repro.train.optimizer import AdamState
        opt_rep = AdamState(step=P(), m=_rep(params_p), v=_rep(params_p))
        train_step = jax.shard_map(
            pod_step, mesh=mesh,
            in_specs=(_rep(params_p), opt_rep, bspec_pod),
            out_specs=(_rep(params_p), opt_rep, P()),
            check_vma=False, axis_names={"pod"})

    in_sh = (named(mesh, params_p), named(mesh, opt_p), named(mesh, batch_p))
    out_sh = (named(mesh, params_p), named(mesh, opt_p), None)
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    with jax.set_mesh(mesh):
        lowered = fn.lower(params_s, opt_s, batch_s)
    mf = 6.0 * mc.active_param_count() * shape.global_batch * shape.seq_len
    return analyse(lowered, True, mf, 512, f"{arch} train_4k multi [{variant}]")


def netsim_tune(variant: str, iters: int = 4, scheme: str = "matchrdma",
                dists=(100.0, 1000.0), horizon_us: float = 80_000.0,
                grad_steps: int = 8):
    """Tune a netsim controller knob: zeroth-order bracket search (the
    historical hillclimb — kept as the regression baseline) or the
    gradient tuner (``--variant grad*`` — ``repro.netsim.grad_tune``).

    Zeroth-order: each iteration evaluates the full candidate population x
    distance grid with ONE `simulate_batch` launch. Both knobs are traced
    ``NetParams`` leaves (``slot_us`` became traced with the soft-step
    engine), so the whole population — slot sweeps included — shares one
    compiled scan across every iteration. Objective: steady inter-DC
    throughput minus a destination-buffer penalty (the paper's
    throughput-vs-buffer tradeoff). ``scheme`` is resolved through the
    scheme registry, so a custom ``@register_scheme`` scheme tunes with
    the same harness.

    Returns ``(best_knob_value, best_score, sim_evals_per_cell)`` —
    ``sim_evals_per_cell`` is the honest per-cell simulator-evaluation
    count the grad-vs-hillclimb bench compares on.
    """
    from repro.config.base import NetConfig
    from repro.netsim import get_scheme, run_experiment_batch
    from repro.netsim.workload import congestion_workload

    if variant.startswith("grad"):
        # gradient path: Adam through the soft-step engine, scored hard —
        # 2 evals per step + 1 final vs the bracket's 5 per iteration
        from repro.netsim.grad_tune import tune
        knob = {"grad": "budget_headroom", "grad-headroom": "budget_headroom",
                "grad-slot": "slot_us"}[variant]
        res = tune(knobs=(knob,), scheme=scheme, dists=dists,
                   horizon_us=horizon_us, steps=grad_steps, verbose=True)
        print(f"best {knob}={res.knobs[knob]:.4g} score={res.objective:.2f} "
              f"({res.sim_evals} evals/cell)")
        return res.knobs[knob], res.objective, res.sim_evals

    scheme = get_scheme(scheme)
    knob = {"headroom": "budget_headroom", "slot": "slot_us",
            "baseline": "budget_headroom"}[variant]
    lo, hi = {"budget_headroom": (0.85, 1.0),
              "slot_us": (50.0, 400.0)}[knob]
    wl = congestion_workload()
    best = None
    evals = 0
    center = (lo + hi) / 2.0
    span = (hi - lo) / 2.0
    for it in range(iters):
        # fixed population size: clipping near a knob bound may duplicate
        # values, but deduping would change the batch shape and force a
        # fresh compile — duplicates are cheaper than re-tracing the scan
        candidates = sorted(max(lo, min(hi, center + f * span))
                            for f in (-1.0, -0.5, 0.0, 0.5, 1.0))
        t0 = time.time()
        scores = {}
        # both knobs are traced NetParams leaves: the ENTIRE population x
        # distance grid is one vmapped launch, and every iteration of the
        # hillclimb reuses the same compiled program.
        cfgs = [NetConfig(distance_km=d, **{knob: val})
                for val in candidates for d in dists]
        # streaming metrics: the tuner only consumes scalar columns
        # (p99 via the in-scan histogram), so no [B, T] trace block is
        # ever materialized across hillclimb iterations
        rows = run_experiment_batch(cfgs, wl, scheme, horizon_us,
                                    trace_mode="metrics")
        for j, val in enumerate(candidates):
            cell = rows[j * len(dists):(j + 1) * len(dists)]
            thr = sum(r["throughput_gbps"] for r in cell) / len(cell)
            buf = sum(r["p99_buffer_mb"] for r in cell) / len(cell)
            scores[val] = thr - 0.5 * buf
        evals += len(candidates)
        val, score = max(scores.items(), key=lambda kv: kv[1])
        dt = time.time() - t0
        print(f"iter {it}: {knob}={val:.4g} score={score:.2f} "
              f"({len(candidates)}x{len(dists)} cells in {dt:.1f}s)")
        if best is None or score > best[1]:
            best = (val, score)
        center, span = val, span / 2.0
    print(f"best {knob}={best[0]:.4g} score={best[1]:.2f}")
    return best[0], best[1], evals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=["qwen-decode", "granite-train-multi",
                             "nemotron-train-multi", "netsim-tune"])
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    if args.cell == "netsim-tune":
        netsim_tune(args.variant)
        return
    _setup_model_cell_env()
    if args.cell == "qwen-decode":
        qwen_decode(args.variant)
    elif args.cell == "granite-train-multi":
        _train_cell("granite-moe-1b-a400m", args.variant,
                    grouped_moe=(args.variant == "grouped_moe"))
    else:
        hier = {"baseline": None, "hier": "vmap", "hier_int8": "vmap_int8",
                "smap": "psum", "smap_int8": "int8"}[args.variant]
        _train_cell("nemotron-4-340b", args.variant, hier=hier)


if __name__ == "__main__":
    main()
