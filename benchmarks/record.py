"""Shared bench record-keeping: git-rev stamping, deduplicating appends to
``BENCH_netsim_sweep.json``, and XLA memory-figure capture.

Every bench used to carry its own copy of this logic
(``netsim_sweep_bench._git_rev``/``_append_record``, an ad-hoc
``memory_analysis()`` print in ``hillclimb.analyse``); this module is the
one home. ``git_rev`` and ``memory_figures`` are re-exports of the
canonical implementations in ``repro.netsim.obs.profile`` (src never
imports benchmarks, so the dependency points this way only).
"""
from __future__ import annotations

import json
import os
import time

from repro.netsim.obs.profile import git_rev, memory_figures  # noqa: F401

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_netsim_sweep.json")


def append_record(record: dict, path: str = None) -> None:
    """Timestamp ``record`` and append it to the bench history JSON,
    replacing any prior entry with the same ``(grid, backend, git_rev)``
    key — re-running a bench at the same rev refreshes its row instead of
    stacking near-identical ones. The record should already carry a
    ``git_rev`` field (stamp it with ``git_rev()``)."""
    path = BENCH_PATH if path is None else path
    record = dict(record, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    key = (record["grid"], record.get("backend"), record.get("git_rev"))
    history = [h for h in history
               if (h.get("grid"), h.get("backend"), h.get("git_rev")) != key]
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
