"""Micro-bench: batched (vmapped) vs sequential netsim scenario sweeps.

The sequential baseline is what ``runner.sweep`` used to do — a Python loop
of per-cell ``simulate`` calls, re-tracing/compiling for every distinct
distance (each distance is a different delay-line shape, hence a different
jit cache key). The batched path stacks the grid into one ``NetParams``
pytree and runs it as a single ``jax.vmap``-ed ``lax.scan``: one compile
per scheme, one device launch for the whole grid.

Results are printed as CSV rows and appended to ``BENCH_netsim_sweep.json``
at the repo root so speedups are tracked across PRs. ``--smoke`` runs a
tiny grid in seconds and appends nothing — it exists so ``make ci``
exercises the benchmark path on every run.

    PYTHONPATH=src python -m benchmarks.netsim_sweep_bench [--full|--smoke]
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.config.base import NetConfig
from repro.netsim.fluid import batch_padding, simulate, simulate_batch
from repro.netsim.schemes import get_scheme
from repro.netsim.workload import throughput_workload

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_netsim_sweep.json")


def _block(tree):
    jax.tree.map(lambda x: x.block_until_ready(), tree)


def _sequential_sweep(cfgs, wl, schemes, horizon_us):
    for c in cfgs:
        for s in schemes:
            final, traces = simulate(c, wl, s, horizon_us)
    _block(traces)
    return final


def _batched_sweep(cfgs, wl, schemes, horizon_us):
    for s in schemes:
        final, traces = simulate_batch(cfgs, wl, s, horizon_us)
    _block(traces)
    return final


def run(full: bool = False, smoke: bool = False):
    # a realistic figure-grid: every distance is a fresh delay-line shape,
    # i.e. a fresh compile for the sequential loop (one per cell); the
    # batched engine compiles once per scheme for the whole grid.
    dists = (1.0, 10.0, 50.0, 100.0, 300.0, 500.0, 1000.0)
    if full:
        dists = dists + (30.0, 700.0, 2000.0)
    schemes = ("dcqcn", "pseudo_ack", "themis", "matchrdma")
    horizon_us = 20_000.0
    if smoke:
        # CI smoke: two distances x two schemes, a short horizon, and no
        # BENCH json append — just prove the benchmark path executes.
        dists = (1.0, 100.0)
        schemes = ("dcqcn", "matchrdma")
        horizon_us = 4_000.0
    scheme_objs = tuple(get_scheme(s) for s in schemes)
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    cfgs = [NetConfig(distance_km=d) for d in dists]
    cells = len(cfgs) * len(schemes)

    # cold: includes compilation — the sequential loop compiles once per
    # (scheme, distance) cell, the batched engine once per scheme.
    t0 = time.time()
    _sequential_sweep(cfgs, wl, scheme_objs, horizon_us)
    seq_cold = time.time() - t0
    t0 = time.time()
    _batched_sweep(cfgs, wl, scheme_objs, horizon_us)
    batch_cold = time.time() - t0

    # warm: steady-state relaunch of the already-compiled sweeps.
    t0 = time.time()
    _sequential_sweep(cfgs, wl, scheme_objs, horizon_us)
    seq_warm = time.time() - t0
    t0 = time.time()
    _batched_sweep(cfgs, wl, scheme_objs, horizon_us)
    batch_warm = time.time() - t0

    record = {
        "grid": {"distances_km": list(dists), "schemes": list(schemes),
                 "horizon_us": horizon_us, "cells": cells},
        "delay_pad_steps": batch_padding(cfgs)[0],
        "sequential_cold_s": round(seq_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "sequential_warm_s": round(seq_warm, 3),
        "batched_warm_s": round(batch_warm, 3),
        "speedup_cold": round(seq_cold / max(batch_cold, 1e-9), 2),
        "speedup_warm": round(seq_warm / max(batch_warm, 1e-9), 2),
        "backend": jax.default_backend(),
    }
    if not smoke:
        _append_record(record)

    return [
        (f"netsim_sweep/sequential_cold/{cells}cells", seq_cold * 1e6,
         f"{seq_cold:.2f}s ({len(cfgs)}x{len(schemes)} compiles)"),
        (f"netsim_sweep/batched_cold/{cells}cells", batch_cold * 1e6,
         f"{batch_cold:.2f}s ({len(schemes)} compiles)"),
        (f"netsim_sweep/sequential_warm/{cells}cells", seq_warm * 1e6,
         f"{seq_warm:.2f}s"),
        (f"netsim_sweep/batched_warm/{cells}cells", batch_warm * 1e6,
         f"{batch_warm:.2f}s"),
        ("netsim_sweep/speedup", 0.0,
         f"cold {record['speedup_cold']}x warm {record['speedup_warm']}x"),
    ]


def _append_record(record: dict) -> None:
    record = dict(record, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    history = []
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(record)
    with open(BENCH_PATH, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid, seconds, no BENCH json append")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for n, us, derived in run(args.full, smoke=args.smoke):
        print(f"{n},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
