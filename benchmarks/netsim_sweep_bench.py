"""Micro-bench: batched vs sequential netsim sweeps, and streaming
(``trace_mode="metrics"``) vs trace-materialized metric extraction.

The sequential baseline is what ``runner.sweep`` used to do — a Python loop
of per-cell ``simulate`` calls, re-tracing/compiling for every distinct
distance (each distance is a different delay-line shape, hence a different
jit cache key). The batched path stacks the grid into one ``NetParams``
pytree and runs it as a single ``jax.vmap``-ed ``lax.scan``: one compile
per scheme, one device launch for the whole grid.

On top of that, the streaming comparison times ``run_experiment_batch``
end-to-end in ``full`` mode (materialize [B, T] traces, transfer to host,
reduce in numpy) against ``metrics`` mode (all reductions accumulate in
the scan carry; only O(B) accumulators transfer), and records the aux
buffer footprint of both — the O(B·T) → O(B) memory drop.

Results are printed as CSV rows and appended to ``BENCH_netsim_sweep.json``
at the repo root so speedups are tracked across PRs; every record is
stamped with the git rev, and an exact duplicate of an existing
(grid, backend, git_rev) entry replaces it instead of accumulating.
``--smoke`` appends nothing and shrinks the batched-vs-sequential leg to a
tiny grid; the streaming comparison keeps its mid-size grid (16 cells x
4000 steps, ~tens of seconds total) because it ASSERTS streaming <=
materialized wall-clock, and that inequality is only meaningful in the
regime streaming targets — it exists so ``make ci`` exercises both paths
on every run.

    PYTHONPATH=src python -m benchmarks.netsim_sweep_bench [--full|--smoke]
"""
from __future__ import annotations

import os
import time

import jax

from repro.config.base import NetConfig
from repro.netsim.fluid import batch_padding, simulate, simulate_batch
from repro.netsim.runner import run_experiment_batch
from repro.netsim.schemes import get_scheme
from repro.netsim.workload import throughput_workload

from benchmarks import record as _record

BENCH_PATH = _record.BENCH_PATH


def _git_rev() -> str:
    """Short HEAD rev, with a ``-dirty`` suffix when the worktree has
    uncommitted changes — a bench row must never attribute dirty-tree
    results to the clean commit (canonical impl: benchmarks.record)."""
    return _record.git_rev(cwd=os.path.dirname(BENCH_PATH) or ".")


def _block(tree):
    jax.tree.map(lambda x: x.block_until_ready(), tree)


def _aux_bytes(tree) -> int:
    """Total bytes of the launch's aux output — the [B, T] trace block in
    full mode, the O(B) ``MetricAcc`` in streaming mode."""
    import numpy as np
    return int(sum(np.asarray(x).nbytes
                   for x in jax.tree_util.tree_leaves(tree)))


def _sequential_sweep(cfgs, wl, schemes, horizon_us):
    for c in cfgs:
        for s in schemes:
            final, traces = simulate(c, wl, s, horizon_us)
    _block(traces)
    return final


def _batched_sweep(cfgs, wl, schemes, horizon_us):
    for s in schemes:
        final, traces = simulate_batch(cfgs, wl, s, horizon_us)
    _block(traces)
    return final


def _stream_vs_full(cfgs, wl, scheme, horizon_us, repeats: int = 2):
    """Best-of-N end-to-end (launch + transfer + metric extraction) timing
    of full vs streaming mode, plus each mode's aux-buffer footprint. The
    compile launch doubles as the memory measurement — no extra runs."""
    timings, mem = {}, {}
    for mode in ("full", "metrics"):
        _, aux = simulate_batch(cfgs, wl, scheme, horizon_us,
                                trace_mode=mode)        # compile + measure
        mem[mode] = _aux_bytes(aux)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_experiment_batch(cfgs, wl, scheme, horizon_us,
                                 trace_mode=mode)
            best = min(best, time.perf_counter() - t0)
        timings[mode] = best
    return timings, mem


def run(full: bool = False, smoke: bool = False):
    # a realistic figure-grid: every distance is a fresh delay-line shape,
    # i.e. a fresh compile for the sequential loop (one per cell); the
    # batched engine compiles once per scheme for the whole grid.
    dists = (1.0, 10.0, 50.0, 100.0, 300.0, 500.0, 1000.0)
    if full:
        dists = dists + (30.0, 700.0, 2000.0)
    schemes = ("dcqcn", "pseudo_ack", "themis", "matchrdma")
    horizon_us = 20_000.0
    # the streaming comparison uses a wider grid of one scheme: the win is
    # O(B·T) transfer + numpy reduction vs O(B) accumulators, so give it a
    # batch where that block is non-trivial
    stream_dists = tuple(float(d) for d in range(50, 850, 50))
    stream_horizon_us = 20_000.0
    if smoke:
        # CI smoke: two distances x two schemes for the batched-vs-
        # sequential leg, a short horizon, and no BENCH json append — prove
        # the benchmark path executes and the streaming mode is no slower.
        dists = (1.0, 100.0)
        schemes = ("dcqcn", "matchrdma")
        horizon_us = 4_000.0
    scheme_objs = tuple(get_scheme(s) for s in schemes)
    wl = throughput_workload(msg_size=1 << 20, concurrency=1, num_flows=4)
    cfgs = [NetConfig(distance_km=d) for d in dists]
    cells = len(cfgs) * len(schemes)

    # cold: includes compilation — the sequential loop compiles once per
    # (scheme, distance) cell, the batched engine once per scheme.
    t0 = time.time()
    _sequential_sweep(cfgs, wl, scheme_objs, horizon_us)
    seq_cold = time.time() - t0
    t0 = time.time()
    _batched_sweep(cfgs, wl, scheme_objs, horizon_us)
    batch_cold = time.time() - t0

    # warm: steady-state relaunch of the already-compiled sweeps.
    t0 = time.time()
    _sequential_sweep(cfgs, wl, scheme_objs, horizon_us)
    seq_warm = time.time() - t0
    t0 = time.time()
    _batched_sweep(cfgs, wl, scheme_objs, horizon_us)
    batch_warm = time.time() - t0

    # streaming vs materialized metric extraction (end-to-end rows).
    # best-of-3 under --smoke: the CI assertion below compares these two
    # numbers, and min-of-N timing is robust to scheduler noise
    stream_cfgs = [NetConfig(distance_km=d) for d in stream_dists]
    timings, mem = _stream_vs_full(stream_cfgs, wl, get_scheme("matchrdma"),
                                   stream_horizon_us,
                                   repeats=3 if smoke else 2)
    stream_cells = len(stream_cfgs)

    record = {
        "grid": {"distances_km": list(dists), "schemes": list(schemes),
                 "horizon_us": horizon_us, "cells": cells},
        "git_rev": _git_rev(),
        "delay_pad_steps": batch_padding(cfgs)[0],
        "sequential_cold_s": round(seq_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "sequential_warm_s": round(seq_warm, 3),
        "batched_warm_s": round(batch_warm, 3),
        "speedup_cold": round(seq_cold / max(batch_cold, 1e-9), 2),
        "speedup_warm": round(seq_warm / max(batch_warm, 1e-9), 2),
        "stream_grid": {"distances_km": list(stream_dists),
                        "horizon_us": stream_horizon_us,
                        "cells": stream_cells},
        "full_mode_warm_s": round(timings["full"], 3),
        "stream_mode_warm_s": round(timings["metrics"], 3),
        "stream_speedup_warm": round(
            timings["full"] / max(timings["metrics"], 1e-9), 2),
        "cells_per_s_full": round(stream_cells / max(timings["full"], 1e-9), 1),
        "cells_per_s_stream": round(
            stream_cells / max(timings["metrics"], 1e-9), 1),
        "trace_bytes_full": mem["full"],
        "acc_bytes_stream": mem["metrics"],
        "trace_mem_ratio": round(mem["full"] / max(mem["metrics"], 1), 1),
        "backend": jax.default_backend(),
    }
    if smoke:
        # 10% measurement slack on top of best-of-3: the observed margin is
        # ~1.2-1.45x, so a genuine regression still trips this while
        # scheduler jitter (which only inflates, and min-of-N filters) does
        # not turn CI into a coin flip
        assert timings["metrics"] <= timings["full"] * 1.10, (
            f"streaming metric extraction regressed: "
            f"{timings['metrics']:.3f}s vs materialized "
            f"{timings['full']:.3f}s")
    else:
        _append_record(record)

    return [
        (f"netsim_sweep/sequential_cold/{cells}cells", seq_cold * 1e6,
         f"{seq_cold:.2f}s ({len(cfgs)}x{len(schemes)} compiles)"),
        (f"netsim_sweep/batched_cold/{cells}cells", batch_cold * 1e6,
         f"{batch_cold:.2f}s ({len(schemes)} compiles)"),
        (f"netsim_sweep/sequential_warm/{cells}cells", seq_warm * 1e6,
         f"{seq_warm:.2f}s"),
        (f"netsim_sweep/batched_warm/{cells}cells", batch_warm * 1e6,
         f"{batch_warm:.2f}s"),
        ("netsim_sweep/speedup", 0.0,
         f"cold {record['speedup_cold']}x warm {record['speedup_warm']}x"),
        (f"netsim_sweep/full_mode_warm/{stream_cells}cells",
         timings["full"] * 1e6,
         f"{timings['full']:.2f}s {record['cells_per_s_full']} cells/s"),
        (f"netsim_sweep/stream_mode_warm/{stream_cells}cells",
         timings["metrics"] * 1e6,
         f"{timings['metrics']:.2f}s {record['cells_per_s_stream']} cells/s"),
        ("netsim_sweep/stream_vs_full", 0.0,
         f"{record['stream_speedup_warm']}x wall-clock, "
         f"{record['trace_mem_ratio']}x less aux memory "
         f"({mem['full']} -> {mem['metrics']} bytes)"),
    ]


def _append_record(record: dict) -> None:
    # module-global BENCH_PATH read at CALL time: tests monkeypatch it to
    # redirect the append (benchmarks.record holds the shared logic)
    _record.append_record(record, BENCH_PATH)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid, seconds, no BENCH json append; "
                         "asserts streaming <= materialized wall-clock")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for n, us, derived in run(args.full, smoke=args.smoke):
        print(f"{n},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
